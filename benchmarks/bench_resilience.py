"""Resilience benchmark: serving throughput/p99 under injected faults.

Drives the deterministic fault harness (serve/morph/resilience.py) against
``ShardedMorphService`` and measures what degraded operation actually costs:

* **healthy** — all shards up, faults off: the baseline the 3%-overhead
  acceptance bar compares against (alongside re-running bench_serve).
* **shard_loss** — the busiest shard hard-fails (``FaultPlan(fail_shard,
  fail_after)``): every request must still complete (rerouted) or fail
  typed; reports steady-state N-1 throughput, p99, and reroute counts.
* **injected_latency** — the same shard answers slowly (``latency_ms``):
  throughput/p99 under partial degradation, no failures.

Traffic cycles over five single-op plans (erode … gradient) so the crc32
(plan, bucket, dtype) tokens spread across shards; the faulted shard is the
*computed* primary of the most groups, so the fault is guaranteed to sit in
the traffic path. Each scenario runs the full stream once unmeasured (warm
compiles; for shard_loss this is where the breaker trips) and times a
second pass — shard_loss therefore measures rerouted steady state, which is
the N-1 number that matters.

Plus a single-service **overhead** row: the full resilience path (bounded
queue, deadline bookkeeping, retry policy) vs a pre-resilience config
(``max_queue=None, retry=None``) on an identical stream — the measured cost
of the machinery when nothing goes wrong.

Every scenario asserts zero hung futures and zero lost requests, and every
completed result is checked bit-exact against the direct kernel output.

Emits ``benchmarks/results/BENCH_resilience.json`` (rendered by report.py).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.bench_resilience [--smoke]
"""
from __future__ import annotations

import argparse
import collections
import json
import os
import time
import zlib

import jax
import numpy as np

from benchmarks.common import p99_ms
from repro import core
from repro.serve.morph import (
    BrownoutPolicy,
    FailoverPolicy,
    FaultPlan,
    HedgePolicy,
    MorphService,
    PRIORITY_HIGH,
    PRIORITY_LOW,
    RetryPolicy,
    ServeError,
    ServiceConfig,
    TenantQuota,
)
from repro.shard import ShardedMorphService

RESULTS = os.path.join(
    os.path.dirname(__file__), "results", "BENCH_resilience.json"
)

# Distinct plan names -> distinct (plan, bucket, dtype) routing tokens ->
# traffic spreads across shards, so faulting one shard actually moves load.
OPS = ("erode", "dilate", "opening", "closing", "gradient")
SE = (5, 5)
REF = {op: getattr(core, op) for op in OPS}


def synth_requests(n: int, h: int, w: int, jitter: int, seed: int):
    """Images with mild shape jitter (multiples of 8, so the reference
    kernels compile a handful of shapes, not one per image)."""
    rng = np.random.default_rng(seed)
    return [
        rng.integers(
            0, 256,
            (h - 8 * int(rng.integers(0, jitter // 8)),
             w - 8 * int(rng.integers(0, jitter // 8))),
            dtype=np.uint8,
        )
        for _ in range(n)
    ]


def primary_index(plan_name: str, bucket, dtype_str: str, n: int) -> int:
    return zlib.crc32(f"{plan_name}|{bucket}|{dtype_str}".encode()) % n


def busiest_primary(bucket, n: int) -> int:
    """The shard that is the crc32 primary of the most op groups — faulting
    it guarantees the fault sits in the traffic path."""
    dtype_str = np.dtype(np.uint8).str
    counts = collections.Counter(
        primary_index(op, bucket, dtype_str, n) for op in OPS
    )
    return counts.most_common(1)[0][0]


def run_scenario(
    name: str,
    imgs,
    expected,
    *,
    shards: int,
    bucket,
    faults: FaultPlan | None,
    window_ms: float = 2.0,
    failover: FailoverPolicy | None = None,
    hedge: HedgePolicy | None = None,
    warm_chunk: int | None = None,
) -> dict:
    devs = jax.devices()
    devices = [devs[i % len(devs)] for i in range(shards)]
    cfg = ServiceConfig(
        buckets=(bucket,),
        max_batch=16,
        window_ms=window_ms,
        retry=RetryPolicy(max_retries=1, backoff_ms=1.0),
        # slow detection off by default so the breaker scenarios stay pure
        # (logical shards share one CPU — contention would mis-mark); the
        # gray_failure scenario turns it on explicitly
        failover=failover or FailoverPolicy(failure_threshold=2,
                                            probe_interval_s=600.0,
                                            slow_detection=False),
        hedge=hedge or HedgePolicy(),
        faults=faults,
    )
    ops = [OPS[i % len(OPS)] for i in range(len(imgs))]
    with ShardedMorphService(cfg, devices=devices) as svc:
        # unmeasured pass: compiles warm; for shard_loss the breaker trips
        # here, so the timed pass below measures rerouted steady state.
        # warm_chunk first bounds in-flight requests so latency EWMAs
        # reflect the shards, not host contention (what slow-marking
        # needs); the full-burst pass that follows still runs, compiling
        # the large batch-bucket executables the timed burst will hit
        if warm_chunk:
            for i in range(0, len(imgs), warm_chunk):
                chunk = [
                    svc.submit(im, op, SE)
                    for im, op in zip(imgs[i:i + warm_chunk],
                                      ops[i:i + warm_chunk])
                ]
                for f in chunk:
                    try:
                        f.result(timeout=300)
                    except ServeError:
                        pass
        for f in [svc.submit(im, op, SE) for im, op in zip(imgs, ops)]:
            try:
                f.result(timeout=300)
            except ServeError:
                pass
        t0 = time.perf_counter()
        futs = [svc.submit(im, op, SE) for im, op in zip(imgs, ops)]
        completed = failed = 0
        latencies = []
        for i, f in enumerate(futs):
            t = time.perf_counter()
            try:
                out = f.result(timeout=300)
                completed += 1
                # rerouted results stay bit-exact
                np.testing.assert_array_equal(out, expected[i])
            except ServeError:
                failed += 1  # typed, never hung
            latencies.append(time.perf_counter() - t)
        wall = time.perf_counter() - t0
        assert all(f.done() for f in futs), "hung futures"
        assert completed + failed == len(imgs), "lost requests"
        stats = svc.stats()
    row = {
        "scenario": name,
        "shards": shards,
        "requests": len(imgs),
        "completed": completed,
        "failed_typed": failed,
        "img_s": round(len(imgs) / wall, 2),
        "p99_ms": round(p99_ms(latencies), 2),
        "healthy_shards": stats["healthy_shards"],
        "slow_shards": stats["slow_shards"],
        "trips": sum(h["trips"] for h in stats["health"]),
        "reroutes": stats["resilience"]["reroutes"],
        "rewarms": stats["resilience"]["rewarms"],
        "retries": stats["resilience"]["retries"],
        "hedges": stats["resilience"]["hedges"],
        "hedge_wins": stats["resilience"]["hedge_wins"],
    }
    print(
        f"{name:18s} img/s={row['img_s']:8.1f}  p99={row['p99_ms']:7.1f} ms  "
        f"completed={completed}/{len(imgs)}  healthy={row['healthy_shards']}"
        f"/{shards}  reroutes={row['reroutes']}  slow={row['slow_shards']}  "
        f"hedges={row['hedges']}"
    )
    return row


def bench_overhead(imgs, bucket) -> dict:
    """Single-service throughput: resilience machinery on (default config)
    vs off (pre-resilience semantics) over an identical stream. Both
    services run the stream once unmeasured first, so compiles don't skew
    whichever config happens to run first."""

    def one(cfg):
        with MorphService(cfg) as svc:
            for f in [svc.submit(im, "erode", SE) for im in imgs]:
                f.result(timeout=300)
            best = 0.0
            for _ in range(3):  # best-of-3: the stream is short, jitter isn't
                t0 = time.perf_counter()
                futs = [svc.submit(im, "erode", SE) for im in imgs]
                for f in futs:
                    f.result(timeout=300)
                best = max(best, len(imgs) / (time.perf_counter() - t0))
            return best

    on = one(ServiceConfig(buckets=(bucket,), max_batch=16, window_ms=2.0))
    off = one(ServiceConfig(buckets=(bucket,), max_batch=16, window_ms=2.0,
                            max_queue=None, retry=None))
    row = {
        "resilience_on_img_s": round(on, 2),
        "resilience_off_img_s": round(off, 2),
        "on_vs_off": round(on / off, 3) if off else None,
    }
    print(f"overhead           on={on:8.1f} img/s  off={off:8.1f} img/s  "
          f"ratio={row['on_vs_off']}")
    return row


def bench_multi_tenant_overload(
    imgs, expected, *, shards: int, bucket, smoke: bool,
    healthy_p99: float, healthy_img_s: float
) -> dict:
    """ISSUE 9 acceptance scenario: two tenants at 2x overload against one
    gray-failure shard, with quotas, brownout, hedging, and slow-state
    routing all live.

    * tenant "gold" submits at PRIORITY_HIGH with 4x weight, "free" at
      PRIORITY_LOW — the brownout ladder must shed free (typed) while gold
      keeps its p99 within 1.5x the healthy baseline;
    * one shard pays persistent injected latency: hedges + slow-state
      draining route around it without ever tripping its breaker;
    * every completed result is checked bit-exact, every future resolves,
      and the router's request count ticks once per completed request
      however many shards raced on it.
    """
    devs = jax.devices()
    devices = [devs[i % len(devs)] for i in range(shards)]
    target = busiest_primary(bucket, shards)
    n = len(imgs)
    gray_ms = 100.0 if smoke else 150.0
    cfg = ServiceConfig(
        buckets=(bucket,),
        max_batch=16,
        window_ms=2.0,
        max_queue=2 * n,  # the cliff; brownout acts well before it
        retry=RetryPolicy(max_retries=1, backoff_ms=1.0),
        failover=FailoverPolicy(
            failure_threshold=2, probe_interval_s=600.0,
            slow_min_count=8, slow_min_ms=1.0, slow_probe_interval_s=600.0,
        ),
        hedge=HedgePolicy(enabled=True, min_delay_ms=25.0),
        tenants={"gold": TenantQuota(weight=4.0),
                 "free": TenantQuota(weight=1.0)},
        brownout=BrownoutPolicy(enter_widen=0.15, enter_shed=0.30,
                                enter_global=0.95),
        faults=FaultPlan(latency_shard=target, latency_ms=gray_ms),
    )
    # SLO per class: gold's bar is 1.5x the healthy baseline for the SAME
    # offered load — the larger of the healthy p99 and the time a healthy
    # service needs to drain this scenario's 2n burst (at sub-millisecond
    # smoke latencies a pure p99 ratio stops meaning anything), floored at
    # 25 ms; free gets double the bar (it sheds under pressure instead of
    # missing quietly)
    healthy_drain_ms = 2.0 * n / healthy_img_s * 1e3
    gold_slo = max(1.5 * healthy_p99, 1.5 * healthy_drain_ms, 25.0)
    slo = {"gold": gold_slo, "free": 2.0 * gold_slo}
    classes = {"gold": PRIORITY_HIGH, "free": PRIORITY_LOW}
    ops = [OPS[i % len(OPS)] for i in range(n)]
    with ShardedMorphService(cfg, devices=devices) as svc:
        # unmeasured pass (normal priority, chunked): warms compiles and
        # feeds the latency EWMAs so the gray shard is slow-marked before
        # the overload burst
        for i in range(0, n, 8):
            chunk = [
                svc.submit(im, op, SE)
                for im, op in zip(imgs[i:i + 8], ops[i:i + 8])
            ]
            for f in chunk:
                try:
                    f.result(timeout=300)
                except ServeError:
                    pass
        # full-burst warm (still anonymous): compiles the large
        # batch-bucket executables the overload burst will hit
        for f in [svc.submit(im, op, SE) for im, op in zip(imgs, ops)]:
            try:
                f.result(timeout=300)
            except ServeError:
                pass
        pre = svc.stats()
        # 2x overload burst: the full stream once per tenant, interleaved
        t0 = time.perf_counter()
        futs, shed_at_submit = [], {"gold": 0, "free": 0}
        for i, (im, op) in enumerate(zip(imgs, ops)):
            for tenant in ("gold", "free") if i % 2 == 0 else ("free", "gold"):
                try:
                    futs.append((tenant, i, svc.submit(
                        im, op, SE, tenant=tenant,
                        priority=classes[tenant])))
                except ServeError:
                    shed_at_submit[tenant] += 1
        per = {t: {"latencies": [], "completed": 0, "failed_typed": 0}
               for t in classes}
        for tenant, i, f in futs:
            t = time.perf_counter()
            try:
                out = f.result(timeout=300)
                np.testing.assert_array_equal(out, expected[i])
                per[tenant]["completed"] += 1
                per[tenant]["latencies"].append(time.perf_counter() - t)
            except ServeError:
                per[tenant]["failed_typed"] += 1
        wall = time.perf_counter() - t0
        assert all(f.done() for _, _, f in futs), "hung futures"
        stats = svc.stats()
    completed = sum(c["completed"] for c in per.values())
    # exactly-once: the router-own counter ticked once per completed
    # request, no matter how many shards raced on it under hedging
    assert stats["requests"] - pre["requests"] == completed, "double count"
    rows = {}
    for tenant, acc in per.items():
        lat = acc["latencies"]
        attained = sum(1 for s in lat if s * 1e3 <= slo[tenant])
        submitted = acc["completed"] + acc["failed_typed"] \
            + shed_at_submit[tenant]
        rows[tenant] = {
            "priority": classes[tenant],
            "submitted": submitted,
            "completed": acc["completed"],
            "shed_typed": acc["failed_typed"] + shed_at_submit[tenant],
            "p99_ms": round(p99_ms(lat), 2) if lat else None,
            "slo_ms": round(slo[tenant], 2),
            "slo_attained": round(attained / submitted, 3) if submitted
            else None,
        }
        print(
            f"tenant {tenant:5s}      p99={rows[tenant]['p99_ms']} ms  "
            f"slo<={rows[tenant]['slo_ms']} ms  "
            f"attained={rows[tenant]['slo_attained']}  "
            f"shed={rows[tenant]['shed_typed']}/{submitted}"
        )
    h = stats["health"][target]
    out = {
        "gray_shard": target,
        "gray_latency_ms": gray_ms,
        "overload_factor": 2.0,
        "wall_s": round(wall, 3),
        "healthy_p99_ms": round(healthy_p99, 2),
        "classes": rows,
        "gray_shard_state": h["state"],
        "gray_shard_trips": h["trips"],
        "slow_shards": stats["slow_shards"],
        "hedges": stats["resilience"]["hedges"],
        "hedge_wins": stats["resilience"]["hedge_wins"],
        "brownout_level_peak": stats["resilience"]["brownout_level"],
        "tenant_counters": stats["resilience"]["tenants"],
    }
    print(
        f"multi_tenant       gray shard {target}: state={h['state']} "
        f"trips={h['trips']}  hedges={out['hedges']} "
        f"(wins {out['hedge_wins']})"
    )
    return out


def run(smoke: bool = False) -> dict:
    shards = 4 if smoke else 8
    n = 48 if smoke else 256
    h, w = (64, 96) if smoke else (160, 224)
    bucket = (64, 128) if smoke else (192, 256)
    imgs = synth_requests(n, h, w, jitter=16, seed=7)
    ops = [OPS[i % len(OPS)] for i in range(n)]
    # references precomputed so verification costs no compiles in the loop
    expected = [np.asarray(REF[op](im, SE)) for im, op in zip(imgs, ops)]
    target = busiest_primary(bucket, shards)

    rows = [
        run_scenario("healthy", imgs, expected,
                     shards=shards, bucket=bucket, faults=None),
        # the busiest shard hard-fails early; timed pass = N-1 steady state
        run_scenario(
            "shard_loss", imgs, expected, shards=shards, bucket=bucket,
            faults=FaultPlan(fail_shard=target, fail_after=2, fail_for=None),
        ),
        # the same shard answers, slowly: degraded-but-alive
        run_scenario(
            "injected_latency", imgs, expected, shards=shards, bucket=bucket,
            faults=FaultPlan(latency_shard=target,
                             latency_ms=5.0 if smoke else 20.0),
        ),
        # gray failure with the full defense on: hedging races the slow
        # shard until the EWMA marks it, then traffic drains around it —
        # breaker closed throughout (slow != dead)
        run_scenario(
            "gray_failure", imgs, expected, shards=shards, bucket=bucket,
            faults=FaultPlan(latency_shard=target,
                             latency_ms=100.0 if smoke else 150.0),
            # probes effectively off: the chunked warm pass marks the shard
            # slow and the timed pass measures the fully drained steady
            # state; the hedge delay rides the measured p99 so only genuine
            # stragglers hedge (no hedge storm)
            failover=FailoverPolicy(
                failure_threshold=2, probe_interval_s=600.0,
                slow_min_count=8, slow_min_ms=1.0,
                slow_probe_interval_s=600.0,
            ),
            hedge=HedgePolicy(enabled=True, min_delay_ms=25.0),
            warm_chunk=8,
        ),
    ]
    multi_tenant = bench_multi_tenant_overload(
        imgs, expected, shards=shards, bucket=bucket, smoke=smoke,
        healthy_p99=rows[0]["p99_ms"], healthy_img_s=rows[0]["img_s"],
    )
    out = {
        "shards": shards,
        "requests": n,
        "shape": [h, w],
        "bucket": list(bucket),
        "faulted_shard": target,
        "smoke": smoke,
        "overhead": bench_overhead(imgs, bucket),
        "scenarios": rows,
        "multi_tenant_overload": multi_tenant,
    }
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {RESULTS}")
    return out


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small shapes, fewer requests, 4 shards (CI)")
    out = run(smoke=p.parse_args().smoke)
    healthy = next(r for r in out["scenarios"] if r["scenario"] == "healthy")
    loss = next(r for r in out["scenarios"] if r["scenario"] == "shard_loss")
    ok = True
    if loss["completed"] != loss["requests"]:
        ok = False
        print(f"FAIL: {loss['requests'] - loss['completed']} requests failed "
              f"during shard loss — expected all rerouted")
    if loss["healthy_shards"] != loss["shards"] - 1 or not loss["reroutes"]:
        ok = False
        print("FAIL: shard_loss scenario did not actually trip the breaker")
    if healthy["failed_typed"]:
        ok = False
        print("FAIL: failures in the healthy scenario")
    gray = next(r for r in out["scenarios"] if r["scenario"] == "gray_failure")
    if gray["completed"] != gray["requests"]:
        ok = False
        print("FAIL: requests lost under gray failure — hedging/slow routing "
              "must keep everything completing")
    if gray["slow_shards"] < 1 or gray["trips"] != 0:
        ok = False
        print(f"FAIL: gray shard not handled as slow-but-alive "
              f"(slow_shards={gray['slow_shards']}, trips={gray['trips']})")
    mt = out["multi_tenant_overload"]
    gold, free = mt["classes"]["gold"], mt["classes"]["free"]
    if gold["p99_ms"] is None or gold["p99_ms"] > gold["slo_ms"]:
        ok = False
        print(f"FAIL: gold p99 {gold['p99_ms']} ms exceeds the 1.5x-healthy "
              f"acceptance bound {gold['slo_ms']} ms")
    if free["shed_typed"] == 0:
        ok = False
        print("FAIL: 2x overload shed nothing from the low-priority class")
    if gold["shed_typed"] > 0:
        ok = False
        print(f"FAIL: {gold['shed_typed']} high-priority requests shed under "
              f"brownout — the ladder must protect gold")
    if mt["gray_shard_state"] != "slow" or mt["gray_shard_trips"] != 0:
        ok = False
        print(f"FAIL: gray shard ended {mt['gray_shard_state']} with "
              f"{mt['gray_shard_trips']} trips — expected drained-but-alive")
    if mt["hedges"] < 1:
        ok = False
        print("FAIL: no hedges fired against the gray shard")
    ratio = out["overhead"]["on_vs_off"]
    if ratio is not None and ratio < 0.97:
        print(f"WARNING: resilience machinery overhead {1 - ratio:.1%} "
              f"exceeds the 3% bar")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
