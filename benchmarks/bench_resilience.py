"""Resilience benchmark: serving throughput/p99 under injected faults.

Drives the deterministic fault harness (serve/morph/resilience.py) against
``ShardedMorphService`` and measures what degraded operation actually costs:

* **healthy** — all shards up, faults off: the baseline the 3%-overhead
  acceptance bar compares against (alongside re-running bench_serve).
* **shard_loss** — the busiest shard hard-fails (``FaultPlan(fail_shard,
  fail_after)``): every request must still complete (rerouted) or fail
  typed; reports steady-state N-1 throughput, p99, and reroute counts.
* **injected_latency** — the same shard answers slowly (``latency_ms``):
  throughput/p99 under partial degradation, no failures.

Traffic cycles over five single-op plans (erode … gradient) so the crc32
(plan, bucket, dtype) tokens spread across shards; the faulted shard is the
*computed* primary of the most groups, so the fault is guaranteed to sit in
the traffic path. Each scenario runs the full stream once unmeasured (warm
compiles; for shard_loss this is where the breaker trips) and times a
second pass — shard_loss therefore measures rerouted steady state, which is
the N-1 number that matters.

Plus a single-service **overhead** row: the full resilience path (bounded
queue, deadline bookkeeping, retry policy) vs a pre-resilience config
(``max_queue=None, retry=None``) on an identical stream — the measured cost
of the machinery when nothing goes wrong.

Every scenario asserts zero hung futures and zero lost requests, and every
completed result is checked bit-exact against the direct kernel output.

Emits ``benchmarks/results/BENCH_resilience.json`` (rendered by report.py).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python -m benchmarks.bench_resilience [--smoke]
"""
from __future__ import annotations

import argparse
import collections
import json
import os
import time
import zlib

import jax
import numpy as np

from benchmarks.common import p99_ms
from repro import core
from repro.serve.morph import (
    FailoverPolicy,
    FaultPlan,
    MorphService,
    RetryPolicy,
    ServeError,
    ServiceConfig,
)
from repro.shard import ShardedMorphService

RESULTS = os.path.join(
    os.path.dirname(__file__), "results", "BENCH_resilience.json"
)

# Distinct plan names -> distinct (plan, bucket, dtype) routing tokens ->
# traffic spreads across shards, so faulting one shard actually moves load.
OPS = ("erode", "dilate", "opening", "closing", "gradient")
SE = (5, 5)
REF = {op: getattr(core, op) for op in OPS}


def synth_requests(n: int, h: int, w: int, jitter: int, seed: int):
    """Images with mild shape jitter (multiples of 8, so the reference
    kernels compile a handful of shapes, not one per image)."""
    rng = np.random.default_rng(seed)
    return [
        rng.integers(
            0, 256,
            (h - 8 * int(rng.integers(0, jitter // 8)),
             w - 8 * int(rng.integers(0, jitter // 8))),
            dtype=np.uint8,
        )
        for _ in range(n)
    ]


def primary_index(plan_name: str, bucket, dtype_str: str, n: int) -> int:
    return zlib.crc32(f"{plan_name}|{bucket}|{dtype_str}".encode()) % n


def busiest_primary(bucket, n: int) -> int:
    """The shard that is the crc32 primary of the most op groups — faulting
    it guarantees the fault sits in the traffic path."""
    dtype_str = np.dtype(np.uint8).str
    counts = collections.Counter(
        primary_index(op, bucket, dtype_str, n) for op in OPS
    )
    return counts.most_common(1)[0][0]


def run_scenario(
    name: str,
    imgs,
    expected,
    *,
    shards: int,
    bucket,
    faults: FaultPlan | None,
    window_ms: float = 2.0,
) -> dict:
    devs = jax.devices()
    devices = [devs[i % len(devs)] for i in range(shards)]
    cfg = ServiceConfig(
        buckets=(bucket,),
        max_batch=16,
        window_ms=window_ms,
        retry=RetryPolicy(max_retries=1, backoff_ms=1.0),
        failover=FailoverPolicy(failure_threshold=2, probe_interval_s=600.0),
        faults=faults,
    )
    ops = [OPS[i % len(OPS)] for i in range(len(imgs))]
    with ShardedMorphService(cfg, devices=devices) as svc:
        # unmeasured pass: compiles warm; for shard_loss the breaker trips
        # here, so the timed pass below measures rerouted steady state
        for f in [svc.submit(im, op, SE) for im, op in zip(imgs, ops)]:
            try:
                f.result(timeout=300)
            except ServeError:
                pass
        t0 = time.perf_counter()
        futs = [svc.submit(im, op, SE) for im, op in zip(imgs, ops)]
        completed = failed = 0
        latencies = []
        for i, f in enumerate(futs):
            t = time.perf_counter()
            try:
                out = f.result(timeout=300)
                completed += 1
                # rerouted results stay bit-exact
                np.testing.assert_array_equal(out, expected[i])
            except ServeError:
                failed += 1  # typed, never hung
            latencies.append(time.perf_counter() - t)
        wall = time.perf_counter() - t0
        assert all(f.done() for f in futs), "hung futures"
        assert completed + failed == len(imgs), "lost requests"
        stats = svc.stats()
    row = {
        "scenario": name,
        "shards": shards,
        "requests": len(imgs),
        "completed": completed,
        "failed_typed": failed,
        "img_s": round(len(imgs) / wall, 2),
        "p99_ms": round(p99_ms(latencies), 2),
        "healthy_shards": stats["healthy_shards"],
        "reroutes": stats["resilience"]["reroutes"],
        "rewarms": stats["resilience"]["rewarms"],
        "retries": stats["resilience"]["retries"],
    }
    print(
        f"{name:18s} img/s={row['img_s']:8.1f}  p99={row['p99_ms']:7.1f} ms  "
        f"completed={completed}/{len(imgs)}  healthy={row['healthy_shards']}"
        f"/{shards}  reroutes={row['reroutes']}"
    )
    return row


def bench_overhead(imgs, bucket) -> dict:
    """Single-service throughput: resilience machinery on (default config)
    vs off (pre-resilience semantics) over an identical stream. Both
    services run the stream once unmeasured first, so compiles don't skew
    whichever config happens to run first."""

    def one(cfg):
        with MorphService(cfg) as svc:
            for f in [svc.submit(im, "erode", SE) for im in imgs]:
                f.result(timeout=300)
            best = 0.0
            for _ in range(3):  # best-of-3: the stream is short, jitter isn't
                t0 = time.perf_counter()
                futs = [svc.submit(im, "erode", SE) for im in imgs]
                for f in futs:
                    f.result(timeout=300)
                best = max(best, len(imgs) / (time.perf_counter() - t0))
            return best

    on = one(ServiceConfig(buckets=(bucket,), max_batch=16, window_ms=2.0))
    off = one(ServiceConfig(buckets=(bucket,), max_batch=16, window_ms=2.0,
                            max_queue=None, retry=None))
    row = {
        "resilience_on_img_s": round(on, 2),
        "resilience_off_img_s": round(off, 2),
        "on_vs_off": round(on / off, 3) if off else None,
    }
    print(f"overhead           on={on:8.1f} img/s  off={off:8.1f} img/s  "
          f"ratio={row['on_vs_off']}")
    return row


def run(smoke: bool = False) -> dict:
    shards = 4 if smoke else 8
    n = 48 if smoke else 256
    h, w = (64, 96) if smoke else (160, 224)
    bucket = (64, 128) if smoke else (192, 256)
    imgs = synth_requests(n, h, w, jitter=16, seed=7)
    ops = [OPS[i % len(OPS)] for i in range(n)]
    # references precomputed so verification costs no compiles in the loop
    expected = [np.asarray(REF[op](im, SE)) for im, op in zip(imgs, ops)]
    target = busiest_primary(bucket, shards)

    rows = [
        run_scenario("healthy", imgs, expected,
                     shards=shards, bucket=bucket, faults=None),
        # the busiest shard hard-fails early; timed pass = N-1 steady state
        run_scenario(
            "shard_loss", imgs, expected, shards=shards, bucket=bucket,
            faults=FaultPlan(fail_shard=target, fail_after=2, fail_for=None),
        ),
        # the same shard answers, slowly: degraded-but-alive
        run_scenario(
            "injected_latency", imgs, expected, shards=shards, bucket=bucket,
            faults=FaultPlan(latency_shard=target,
                             latency_ms=5.0 if smoke else 20.0),
        ),
    ]
    out = {
        "shards": shards,
        "requests": n,
        "shape": [h, w],
        "bucket": list(bucket),
        "faulted_shard": target,
        "smoke": smoke,
        "overhead": bench_overhead(imgs, bucket),
        "scenarios": rows,
    }
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {RESULTS}")
    return out


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small shapes, fewer requests, 4 shards (CI)")
    out = run(smoke=p.parse_args().smoke)
    healthy = next(r for r in out["scenarios"] if r["scenario"] == "healthy")
    loss = next(r for r in out["scenarios"] if r["scenario"] == "shard_loss")
    ok = True
    if loss["completed"] != loss["requests"]:
        ok = False
        print(f"FAIL: {loss['requests'] - loss['completed']} requests failed "
              f"during shard loss — expected all rerouted")
    if loss["healthy_shards"] != loss["shards"] - 1 or not loss["reroutes"]:
        ok = False
        print("FAIL: shard_loss scenario did not actually trip the breaker")
    if healthy["failed_typed"]:
        ok = False
        print("FAIL: failures in the healthy scenario")
    ratio = out["overhead"]["on_vs_off"]
    if ratio is not None and ratio < 0.97:
        print(f"WARNING: resilience machinery overhead {1 - ratio:.1%} "
              f"exceeds the 3% bar")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
