"""Front-tier ingress benchmark: QPS/SLO load against a live worker fleet.

Spawns 2 (``--smoke``) or 4 real worker *processes* (``spawn_worker``: the
same ``python -m repro.serve.ingress.worker`` entry point production would
run), routes through an in-process :class:`Frontier`, and measures the
ingress tier end to end:

* **bit_exact** — for every plan in the mix, the remote result is compared
  bit-for-bit against a direct in-process ``MorphService`` (the acceptance
  gate: the wire adds a process boundary, not a numerics boundary);
* **qps_slo** — an open-loop, paced multi-tenant load generator: tenant
  "gold" (PRIORITY_HIGH) and "free" (PRIORITY_LOW) interleave at a fixed
  offered QPS (calibrated to ~60% of measured healthy throughput, so the
  numbers mean sustained service, not queue growth). Reports sustained QPS
  and per-class p99 against SLOs set at 1.5x the healthy calibration p99
  (floored at 25 ms), the same bar the resilience bench uses;
* **typed_errors** — deadline misses, per-tenant quota floods, and a
  drain-then-reject worker shutdown each come back as the *same* typed
  exception a local caller gets (``DeadlineExceeded``, ``QuotaExceeded``
  with its ``.tenant``, ``ServiceClosed``), reconstructed client-side from
  the wire;
* **worker_kill** — SIGKILL the hash-owner worker with a burst in flight:
  every future must resolve with the bit-exact result via survivors (zero
  lost futures), the fleet ``stats()`` must still merge, and the exported
  cross-process Chrome trace must validate with zero open spans.

Emits ``benchmarks/results/BENCH_router.json`` (rendered by report.py) and
the merged multi-process trace next to it.

    REPRO_PALLAS_INTERPRET=1 PYTHONPATH=src \\
        python -m benchmarks.bench_router [--smoke]
"""
from __future__ import annotations

import argparse
import collections
import json
import os
import time
import zlib

import numpy as np

from benchmarks.common import p99_ms
from repro import core
from repro.obs import ObsConfig, validate_chrome_trace
from repro.serve.ingress import Connection, Frontier, spawn_worker
from repro.serve.morph import (
    DeadlineExceeded,
    FailoverPolicy,
    MorphService,
    PRIORITY_HIGH,
    PRIORITY_LOW,
    QuotaExceeded,
    ServeError,
    ServiceClosed,
    ServiceConfig,
    single_op_plan,
)

RESULTS = os.path.join(
    os.path.dirname(__file__), "results", "BENCH_router.json"
)
TRACE_OUT = os.path.join(
    os.path.dirname(__file__), "results", "BENCH_router_trace.json"
)

OPS = ("erode", "dilate", "opening", "closing", "gradient")
SE = (3, 3)
BUCKET = (64, 64)
PLANS = {op: single_op_plan(op, SE) for op in OPS}
REF = {op: getattr(core, op) for op in OPS}


def synth_requests(n: int, seed: int):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(0, 256,
                     (40 + 8 * int(rng.integers(0, 4)),
                      48 + 8 * int(rng.integers(0, 3))),
                     dtype=np.uint8)
        for _ in range(n)
    ]


def owner(plan_name: str, n: int) -> int:
    token = f"{plan_name}|{BUCKET}|{np.dtype(np.uint8).str}".encode()
    return zlib.crc32(token) % n


def busiest_owner(n: int) -> int:
    """The worker owning the most plan groups — killing it guarantees the
    chaos sits in the traffic path."""
    counts = collections.Counter(owner(PLANS[op].name, n) for op in OPS)
    return counts.most_common(1)[0][0]


def worker_config(smoke: bool) -> dict:
    return {
        "buckets": [list(BUCKET)],
        "window_ms": 2.0,
        "max_batch": 16,
        "obs": True,
        "interpret": bool(smoke),
        # gold/free are weighted classes for the QPS phase; quota_probe is
        # a deliberately tiny budget the typed-errors phase floods
        "tenants": {
            "gold": {"weight": 4.0},
            "free": {"weight": 1.0},
            "quota_probe": {"max_outstanding": 2},
        },
    }


def submit_timed(front, im, plan, sink, ref, **kw):
    t0 = time.perf_counter()
    fut = front.submit_plan(im, plan, **kw)

    def done(f, t0=t0, ref=ref):
        sink.append((time.perf_counter() - t0, f, ref))

    fut.add_done_callback(done)
    return fut


# ------------------------------------------------------------------ phases
def phase_bit_exact(front, imgs) -> dict:
    """Every plan in the mix: remote-through-the-fleet vs direct."""
    with MorphService(ServiceConfig(buckets=(BUCKET,))) as direct:
        checked = 0
        for op in OPS:
            for im in imgs:
                remote = np.asarray(front.run_plan(im, PLANS[op]))
                local = np.asarray(direct.run_plan(im, PLANS[op]))
                np.testing.assert_array_equal(remote, local)
                ref = np.asarray(REF[op](im, SE))
                np.testing.assert_array_equal(remote, ref)
                checked += 1
    print(f"bit_exact          {checked} remote results == direct == kernel "
          f"reference, {len(OPS)} plans")
    return {"plans": list(OPS), "checked": checked, "mismatches": 0}


def phase_qps_slo(front, imgs, *, n_requests: int) -> dict:
    """Open-loop paced load, two tenant classes interleaved 1:1."""
    # calibration: unpaced burst measures healthy capacity and p99
    calib: list = []
    t0 = time.perf_counter()
    futs = [
        submit_timed(front, im, PLANS[OPS[i % len(OPS)]], calib,
                     np.asarray(REF[OPS[i % len(OPS)]](im, SE)))
        for i, im in enumerate(imgs)
    ]
    for f in futs:
        f.result(timeout=300)
    healthy_img_s = len(imgs) / (time.perf_counter() - t0)
    healthy_p99 = p99_ms([lat for lat, _, _ in calib])
    slo_gold = max(1.5 * healthy_p99, 25.0)
    slo = {"gold": slo_gold, "free": 2.0 * slo_gold}
    classes = {"gold": PRIORITY_HIGH, "free": PRIORITY_LOW}

    qps = max(20.0, min(0.6 * healthy_img_s, 1000.0))
    per = {t: [] for t in classes}
    stream = []
    for i in range(n_requests):
        im = imgs[i % len(imgs)]
        op = OPS[i % len(OPS)]
        tenant = "gold" if i % 2 == 0 else "free"
        stream.append((im, op, tenant))
    t_start = time.perf_counter()
    futs = []
    for i, (im, op, tenant) in enumerate(stream):
        target = t_start + i / qps
        now = time.perf_counter()
        if target > now:
            time.sleep(target - now)
        futs.append(submit_timed(
            front, im, PLANS[op], per[tenant], np.asarray(REF[op](im, SE)),
            tenant=tenant, priority=classes[tenant],
        ))
    completed = shed = 0
    for f in futs:
        try:
            f.result(timeout=300)
            completed += 1
        except ServeError:
            shed += 1  # typed, never hung
    wall = time.perf_counter() - t_start
    assert all(f.done() for f in futs), "hung futures in the load phase"
    assert completed + shed == n_requests, "lost futures in the load phase"
    rows = {}
    for tenant, sink in per.items():
        lats, ok = [], 0
        for lat, f, ref in sink:
            if f.exception() is None:
                np.testing.assert_array_equal(np.asarray(f.result()), ref)
                lats.append(lat)
                if lat * 1e3 <= slo[tenant]:
                    ok += 1
        rows[tenant] = {
            "priority": classes[tenant],
            "submitted": len(sink),
            "completed": len(lats),
            "p99_ms": round(p99_ms(lats), 2) if lats else None,
            "slo_ms": round(slo[tenant], 2),
            "slo_attained": round(ok / len(sink), 3) if sink else None,
        }
        print(f"tenant {tenant:5s}       p99={rows[tenant]['p99_ms']} ms  "
              f"slo<={rows[tenant]['slo_ms']} ms  "
              f"attained={rows[tenant]['slo_attained']}")
    out = {
        "healthy_img_s": round(healthy_img_s, 2),
        "healthy_p99_ms": round(healthy_p99, 2),
        "offered_qps": round(qps, 2),
        "sustained_qps": round(completed / wall, 2),
        "requests": n_requests,
        "completed": completed,
        "shed_typed": shed,
        "classes": rows,
    }
    print(f"qps_slo            offered={out['offered_qps']}/s  "
          f"sustained={out['sustained_qps']}/s  "
          f"completed={completed}/{n_requests}")
    return out


def phase_typed_errors(front, addrs, imgs, smoke: bool) -> dict:
    """Every rejection crosses the wire as the same typed exception."""
    out: dict = {}
    # deadline: straight to a worker with an already-expired deadline —
    # the worker raises at submit, the client reconstructs from the frame
    with Connection(addrs[0]) as conn:
        try:
            conn.submit_plan(imgs[0], PLANS["erode"], deadline_ms=0).result(60)
            raise AssertionError("expired deadline did not fail")
        except DeadlineExceeded:
            out["deadline"] = {"typed": True}
    # quota: flood the 2-slot quota_probe tenant through the frontier; the
    # worker sheds typed and the frontier propagates, .tenant intact
    futs = [
        front.submit_plan(imgs[i % len(imgs)], PLANS["erode"],
                          tenant="quota_probe")
        for i in range(24)
    ]
    quota_hits = completed = 0
    for f in futs:
        try:
            f.result(timeout=300)
            completed += 1
        except QuotaExceeded as exc:
            assert exc.tenant == "quota_probe", exc.tenant
            quota_hits += 1
    assert quota_hits >= 1, "quota flood never tripped QuotaExceeded"
    out["quota"] = {"typed": quota_hits, "completed": completed,
                    "tenant": "quota_probe"}
    # drain-then-reject: a dedicated slow worker is told to shut down with
    # requests in flight — accepted work drains to results, late work gets
    # ServiceClosed over the wire, and nothing sees a dropped connection
    cfgd = dict(worker_config(smoke))
    cfgd["faults"] = {"latency_ms": 150.0}
    proc, addr = spawn_worker(cfgd, worker_id=9)
    closed_hits = late_results = 0
    try:
        with Connection(addr) as conn:
            held = [conn.submit_plan(im, PLANS["erode"]) for im in imgs[:4]]
            conn.rpc("shutdown")
            deadline = time.monotonic() + 30
            while closed_hits == 0 and time.monotonic() < deadline:
                try:
                    conn.submit_plan(imgs[0], PLANS["erode"]).result(60)
                    late_results += 1  # raced the closing flag; accepted
                except ServiceClosed:
                    closed_hits += 1
            for f in held:  # accepted-before-drain work always completes
                assert isinstance(np.asarray(f.result(60)), np.ndarray)
    finally:
        proc.wait(timeout=60)
    assert closed_hits >= 1, "shutdown never surfaced typed ServiceClosed"
    out["service_closed"] = {"typed": closed_hits,
                             "raced_accepted": late_results,
                             "drained": len(imgs[:4])}
    print(f"typed_errors       DeadlineExceeded=1  "
          f"QuotaExceeded={quota_hits}  ServiceClosed={closed_hits} "
          f"(all reconstructed client-side)")
    return out


def phase_worker_kill(front, procs, n_workers: int, imgs) -> dict:
    """SIGKILL the busiest owner with a burst in flight; zero lost
    futures, bit-exact reroutes, merged stats, schema-valid trace."""
    victim = busiest_owner(n_workers)
    sink: list = []
    futs = []
    for i, im in enumerate(imgs):
        op = OPS[i % len(OPS)]
        futs.append(submit_timed(front, im, PLANS[op], sink,
                                 np.asarray(REF[op](im, SE))))
    procs[victim].kill()
    completed = 0
    for f in futs:
        f.result(timeout=300)  # any raise here is a lost/failed future
        completed += 1
    for _, f, ref in sink:
        np.testing.assert_array_equal(np.asarray(f.result()), ref)
    assert completed == len(imgs), "futures lost during worker kill"
    stats = front.stats()
    assert stats["healthy_workers"] == n_workers - 1, stats["health"]
    assert stats["per_worker"][victim] is None
    assert sum(1 for p in stats["per_worker"] if p) == n_workers - 1
    doc = front.export_trace()
    errors = validate_chrome_trace(doc)
    pids = sorted({e.get("pid") for e in doc["traceEvents"]})
    open_spans = front.open_spans()
    os.makedirs(os.path.dirname(TRACE_OUT), exist_ok=True)
    with open(TRACE_OUT, "w") as f:
        json.dump(doc, f)
    out = {
        "victim": victim,
        "requests": len(imgs),
        "completed": completed,
        "healthy_workers": stats["healthy_workers"],
        "fleet_requests": stats["requests"],
        "fleet_p99_ms": round(stats["p99_ms"], 2),
        "reroutes": stats["reroutes"],
        "trace_events": len(doc["traceEvents"]),
        "trace_pids": pids,
        "trace_validation_errors": len(errors),
        "open_spans": open_spans,
        "trace_file": os.path.relpath(TRACE_OUT),
    }
    print(f"worker_kill        victim={victim}  completed={completed}/"
          f"{len(imgs)}  healthy={out['healthy_workers']}/{n_workers}  "
          f"trace: {out['trace_events']} events over pids {pids}, "
          f"{len(errors)} schema errors, {open_spans} open spans")
    return out


# -------------------------------------------------------------------- driver
def run(smoke: bool = False) -> dict:
    n_workers = 2 if smoke else 4
    n_bitexact = 4 if smoke else 12
    n_calib = 40 if smoke else 120
    n_load = 160 if smoke else 600
    n_kill = 48 if smoke else 96

    procs, addrs = [], []
    try:
        for i in range(n_workers):
            proc, addr = spawn_worker(worker_config(smoke), worker_id=i)
            procs.append(proc)
            addrs.append(addr)
        with Frontier(addrs, buckets=(BUCKET,), obs=ObsConfig(),
                      failover=FailoverPolicy(probe_interval_s=600.0)
                      ) as front:
            out = {
                "workers": n_workers,
                "smoke": smoke,
                "bucket": list(BUCKET),
                "bit_exact": phase_bit_exact(
                    front, synth_requests(n_bitexact, seed=3)),
                "qps_slo": phase_qps_slo(
                    front, synth_requests(n_calib, seed=5),
                    n_requests=n_load),
                "typed_errors": phase_typed_errors(
                    front, addrs, synth_requests(8, seed=7), smoke),
                "worker_kill": phase_worker_kill(
                    front, procs, n_workers, synth_requests(n_kill, seed=9)),
            }
    finally:
        for p in procs:
            p.kill()
            p.wait(timeout=60)
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {RESULTS}")
    return out


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="2 workers, short streams, interpret kernels (CI)")
    out = run(smoke=p.parse_args().smoke)
    ok = True
    if out["bit_exact"]["mismatches"]:
        ok = False
        print("FAIL: remote results diverged from direct service")
    q = out["qps_slo"]
    gold = q["classes"]["gold"]
    if gold["p99_ms"] is None or gold["p99_ms"] > gold["slo_ms"]:
        ok = False
        print(f"FAIL: gold p99 {gold['p99_ms']} ms exceeds its SLO "
              f"{gold['slo_ms']} ms")
    free = q["classes"]["free"]
    if free["p99_ms"] is not None and free["p99_ms"] > free["slo_ms"]:
        print(f"WARNING: free p99 {free['p99_ms']} ms exceeds its SLO "
              f"{free['slo_ms']} ms")
    if q["sustained_qps"] < 0.8 * q["offered_qps"]:
        ok = False
        print(f"FAIL: sustained {q['sustained_qps']}/s fell below 80% of "
              f"offered {q['offered_qps']}/s")
    te = out["typed_errors"]
    if not (te["deadline"]["typed"] and te["quota"]["typed"]
            and te["service_closed"]["typed"]):
        ok = False
        print("FAIL: a rejection class did not reconstruct typed")
    k = out["worker_kill"]
    if k["completed"] != k["requests"]:
        ok = False
        print(f"FAIL: {k['requests'] - k['completed']} futures lost in the "
              f"worker-kill reroute")
    if k["trace_validation_errors"] or k["open_spans"]:
        ok = False
        print(f"FAIL: fleet trace invalid ({k['trace_validation_errors']} "
              f"schema errors, {k['open_spans']} open spans)")
    if len(k["trace_pids"]) < 2:
        ok = False
        print(f"FAIL: trace does not span processes (pids {k['trace_pids']})")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
