import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", ""
) + " --xla_force_host_platform_device_count=512"

"""Roofline analysis per (arch x shape) on the single-pod mesh (§Roofline).

Methodology
-----------
``compiled.cost_analysis()`` counts a ``lax.scan`` body ONCE regardless of
trip count, so a scanned-layer model reports ~1 layer of FLOPs. This
driver therefore lowers two *unrolled shallow probes* per cell —
depth u1 and u2 = 2*u1 (layers; groups for the VLM; enc+dec pairs for
Whisper) — and extrapolates linearly:

    total(L_units) = f(u1) + (L_units - 1) * (f(u2) - f(u1))

which is exact because the transformer trunk is linear in depth. The same
correction applies to bytes-accessed and per-kind collective bytes (parsed
from the partitioned HLO, i.e. already per-chip quantities).

The RWKV/Mamba *time* recurrences run under an inner ``lax.scan`` over T
that the probes cannot unroll (T up to 524288); their FLOPs are added
analytically (≈8·hd² per head-step for WKV, ≈8·di·n per step for the SSM
head — derivation in EXPERIMENTS.md §Roofline notes).

Hardware model (TPU v5e): 197 TFLOP/s bf16 / chip, 819 GB/s HBM,
~50 GB/s/link ICI. Terms are reported in seconds per step per chip:

    compute    = flops_chip / 197e12
    memory     = bytes_chip / 819e9
    collective = coll_bytes_chip / 50e9

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) with D = tokens/step
(x1/3 for forward-only cells); the ratio MODEL_FLOPS/HLO_FLOPs is the
usefulness metric that catches remat/dispatch waste.
"""
import argparse
import dataclasses
import json

import jax

from repro.launch.dryrun import SHAPES, analyze, cell_applicable, lower_any
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.models.config import ARCH_IDS, get_config

CHIPS = 256
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def probe_cfg(cfg, units: int):
    """A cfg with `units` depth-units (layer / group / enc-dec pair)."""
    if cfg.family == "vlm":
        return dataclasses.replace(cfg, num_layers=units * cfg.cross_attn_every)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, num_layers=units, num_encoder_layers=units)
    return dataclasses.replace(cfg, num_layers=units)


def depth_units(cfg) -> int:
    if cfg.family == "vlm":
        return cfg.num_layers // cfg.cross_attn_every
    return cfg.num_layers


def recurrence_flops(cfg, shape: str) -> float:
    """Analytic FLOPs of inner time-scans (global, all chips)."""
    info = SHAPES[shape]
    b = info["batch"]
    t = info["seq"] if info["kind"] in ("train", "prefill") else 1
    train_mult = 3.0 if info["kind"] == "train" else 1.0
    total = 0.0
    if cfg.family == "ssm":  # RWKV-6 WKV
        h, hd = cfg.d_model // 64, 64
        total += 8.0 * b * t * h * hd * hd * cfg.num_layers
    if cfg.family == "hybrid":  # Mamba branch
        di = cfg.ssm_expand * cfg.d_model
        total += 8.0 * b * t * di * cfg.ssm_state * cfg.num_layers
    return total * train_mult


def model_flops(cfg, shape: str) -> float:
    """6·N_active·D convention (global)."""
    info = SHAPES[shape]
    tokens = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1)
    n = cfg.active_param_count()
    mult = 6.0 if info["kind"] == "train" else 2.0
    return mult * n * tokens + recurrence_flops(cfg, shape)


def probe(cfg, shape: str, mesh, units: int) -> dict:
    tfm.set_unroll(True)
    try:
        lowered = lower_any(probe_cfg(cfg, units), shape, mesh)
        compiled = lowered.compile()
        return analyze(lowered, compiled)
    finally:
        tfm.set_unroll(False)
        tfm.set_activation_spec(None)


def extrapolate(a1: dict, a2: dict, total_units: int, u1: int, u2: int) -> dict:
    """Linear-in-depth extrapolation from two probes.

    The per-unit slope is clamped to >= 0: GSPMD occasionally lays out the
    1-unit probe with *more* fixed collectives than the 2-unit probe, and a
    negative slope would extrapolate to nonsense at full depth."""
    def ex(v1, v2):
        per = max((v2 - v1) / (u2 - u1), 0.0)
        base = max(v1 - u1 * per, 0.0)
        return base + per * total_units

    out = {
        "flops": ex(a1["flops"], a2["flops"]),
        "bytes_accessed": ex(a1["bytes_accessed"], a2["bytes_accessed"]),
        "collective_bytes": ex(
            a1["collectives"]["total_bytes"], a2["collectives"]["total_bytes"]
        ),
        "collective_kinds": {
            k: ex(a1["collectives"]["bytes"][k], a2["collectives"]["bytes"][k])
            for k in a1["collectives"]["bytes"]
        },
    }
    return out


def roofline_cell(arch: str, shape: str, *, probes=(1, 2)) -> dict:
    ok, why = cell_applicable(arch, shape)
    if not ok:
        return {"arch": arch, "shape": shape, "status": "skipped", "reason": why}
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=False)
    u1, u2 = probes
    if cfg.family == "vlm":
        u1, u2 = 1, 2  # groups of 5 layers
    a1 = probe(cfg, shape, mesh, u1)
    a2 = probe(cfg, shape, mesh, u2)
    total = extrapolate(a1, a2, depth_units(cfg), u1, u2)

    rec = recurrence_flops(cfg, shape) / CHIPS  # per chip
    flops_chip = total["flops"] + rec  # probe flops are per-chip (SPMD module)
    bytes_chip = total["bytes_accessed"]
    coll_chip = total["collective_bytes"]

    compute_s = flops_chip / PEAK_FLOPS
    memory_s = bytes_chip / HBM_BW
    coll_s = coll_chip / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    step_s = max(terms.values())  # no-overlap bound
    mfu = (mf / CHIPS / step_s) / PEAK_FLOPS if step_s > 0 else 0.0
    return {
        "arch": arch,
        "shape": shape,
        "status": "ok",
        "per_chip": {
            "flops": flops_chip,
            "bytes": bytes_chip,
            "collective_bytes": coll_chip,
            "collective_kinds": total["collective_kinds"],
        },
        "terms_s": terms,
        "dominant": dominant,
        "model_flops_global": mf,
        "usefulness": mf / CHIPS / flops_chip if flops_chip else None,
        "roofline_mfu": mfu,
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/roofline.json")
    args = ap.parse_args(argv)

    cells = (
        [(a, s) for a in ARCH_IDS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    results = []
    for arch, shape in cells:
        try:
            r = roofline_cell(arch, shape)
        except Exception as e:  # noqa: BLE001
            r = {"arch": arch, "shape": shape, "status": "error",
                 "error": f"{type(e).__name__}: {e}"}
        if r["status"] == "ok":
            t = r["terms_s"]
            print(f"[roofline] {arch:>22} {shape:<12} "
                  f"C={t['compute_s']:.3e}s M={t['memory_s']:.3e}s "
                  f"X={t['collective_s']:.3e}s dom={r['dominant'][:-2]:<10} "
                  f"useful={r['usefulness']:.2f} MFU={r['roofline_mfu']*100:.1f}%",
                  flush=True)
        else:
            print(f"[roofline] {arch:>22} {shape:<12} {r['status']}: "
                  f"{r.get('reason', r.get('error',''))[:80]}", flush=True)
        results.append(r)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
