"""Paper §5.3: the hybrid dispatch — and calibration of w0 / cost tables.

Measures the full 2-D erosion (both passes) three ways:
  paper_linear   linear for both passes at every w (paper small-w choice)
  paper_vhgw     vHGW for both passes at every w (paper baseline)
  hybrid         the dispatch policy (linear_tree under w0, vHGW above)

Writes the measured crossovers into src/repro/core/calibration.json so
core.dispatch.DispatchPolicy.calibrated() uses machine-local thresholds —
the exact procedure the paper followed on Exynos 5422.

``--fit-cost-table`` replaces the hand-edited-scalar workflow: it fits the
per-(axis kind, method, dtype) affine cost curves of
``repro.morph.opt.cost`` from the same sweeps (plus a fused-kernel sweep
for the ``fused`` axis kind and whole-op fused-vs-two-pass fits) and
persists them in ``src/repro/core/cost_table.json`` keyed by device kind.
``DispatchPolicy.calibrated()`` then adopts the crossovers those curves
imply, and the IR optimizer / dispatch layers query the curves directly.
"""
from __future__ import annotations

import functools
import json
import os
import sys

import jax

from benchmarks.bench_passes import crossover, sweep
from benchmarks.common import emit, paper_image, time_fn
from repro.configs.morphology import CONFIG as MORPH
from repro.core import DispatchPolicy, erode
from repro.core.dispatch import _CALIBRATION_FILE


def run() -> None:
    x = paper_image()
    # calibrate from 1-D sweeps (same data as Fig 3/4)
    fig3 = sweep(axis=-2, fig="calib_rowwindow")
    fig4 = sweep(axis=-1, fig="calib_colwindow")
    w0_major = crossover(fig3, small="linear_tree")
    w0_minor = crossover(fig4, small="linear_tree")
    with open(_CALIBRATION_FILE, "w") as f:
        json.dump({"w0_major": int(w0_major), "w0_minor": int(w0_minor),
                   "small_method": "linear_tree"}, f)
    emit("calibrated_w0_major", w0_major, f"paper={MORPH.paper_w0_major}")
    emit("calibrated_w0_minor", w0_minor, f"paper={MORPH.paper_w0_minor}")

    policy = DispatchPolicy.calibrated()
    for w in (3, 15, 31, 61, 101):
        t_lin = time_fn(jax.jit(functools.partial(
            erode, se=(w, w), method="linear")), x)
        t_vhgw = time_fn(jax.jit(functools.partial(
            erode, se=(w, w), method="vhgw")), x)
        t_hyb = time_fn(jax.jit(functools.partial(
            erode, se=(w, w), method="auto", policy=policy)), x)
        best = min(t_lin, t_vhgw)
        emit(f"erode2d_linear_w{w}", t_lin * 1e6)
        emit(f"erode2d_vhgw_w{w}", t_vhgw * 1e6)
        emit(f"erode2d_hybrid_w{w}", t_hyb * 1e6,
             f"envelope_ratio={t_hyb / best:.2f} (<=1.1 reproduces paper §5.3)")


def _fit_1d_entries(results: dict, kind: str, dtype: str = "uint8") -> dict:
    """Fit (c0_us, c1_us) per method from a ``sweep()`` result dict
    ({method: {w: seconds}}); the minor axis's transpose-trick variant
    (``vhgw_T``) folds into ``vhgw`` as the per-w envelope."""
    from repro.morph.opt.cost import feature, fit_affine

    entries = {}
    merged: dict[str, dict[int, float]] = {}
    for mname, pts in results.items():
        base = "vhgw" if mname.startswith("vhgw") else mname
        for w, t in pts.items():
            cur = merged.setdefault(base, {})
            cur[w] = min(cur[w], t) if w in cur else t
    for mname, pts in merged.items():
        samples = [(feature(mname, w), t * 1e6) for w, t in sorted(pts.items())]
        entries[(kind, mname, dtype)] = fit_affine(samples)
    return entries


def _fused_sweep(ws, *, dtype: str = "uint8") -> dict:
    """Time the fused megakernel with each method forced, per square SE;
    attribute half the whole-op time to each axis pass (both fused passes
    are sublane passes over the same strip)."""
    from repro.kernels.morph_fused import morph2d_fused

    x = paper_image()
    out: dict[str, dict[int, float]] = {"linear": {}, "vhgw": {}}
    for w in ws:
        for m in out:
            fn = jax.jit(functools.partial(
                morph2d_fused, se=(w, w), op="min", method=m))
            t = time_fn(fn, x, warmup=1, iters=5)
            out[m][w] = t / 2.0
            emit(f"cost_fused_{m}_w{w}", t * 1e6)
    return out


def _op2d_fits(ws, *, dtype: str = "uint8") -> dict:
    """Whole-op fused-vs-two-pass affine fits (feature: w_h + w_w) for the
    optimizer's per-node dispatch decision.

    The fused samples call the fused kernels *directly* — routing through
    ``raw_morph2d`` would consult the pre-existing cost table's own
    fused-vs-two-pass decision and could silently time the two-pass path
    under the "fused" label on a refit."""
    from repro.kernels.morph_fused import gradient2d_fused, morph2d_fused
    from repro.kernels.ops import raw_morph2d, raw_gradient2d
    from repro.morph.opt.cost import fit_affine

    import dataclasses

    x = paper_image()
    # calibrated thresholds, not class defaults: the two-pass baseline must
    # dispatch its per-axis methods the way a tuned deployment would, or the
    # fused-vs-two-pass comparison is fit against a mistimed baseline
    two_pol = dataclasses.replace(DispatchPolicy.calibrated(), fused_2d=False)
    samples: dict[str, list] = {k: [] for k in (
        "fused", "two_pass", "gradient_fused", "gradient_two_pass")}
    for w in ws:
        se = (w, w)
        t_f = time_fn(jax.jit(functools.partial(
            morph2d_fused, se=se, op="min")), x, warmup=1, iters=5)
        t_t = time_fn(jax.jit(functools.partial(
            raw_morph2d, se=se, op="min", policy=two_pol)), x,
            warmup=1, iters=5)
        g_f = time_fn(jax.jit(functools.partial(
            gradient2d_fused, se=se)), x, warmup=1, iters=5)
        g_t = time_fn(jax.jit(functools.partial(
            raw_gradient2d, se=se, policy=two_pol)), x, warmup=1, iters=5)
        for k, t in (("fused", t_f), ("two_pass", t_t),
                     ("gradient_fused", g_f), ("gradient_two_pass", g_t)):
            samples[k].append((float(2 * w), t * 1e6))
            emit(f"cost_op2d_{k}_w{w}", t * 1e6)
    return {(k, dtype): fit_affine(v) for k, v in samples.items()}


def fit_cost_table(quick: bool = False) -> str:
    """Fit and persist this device's cost table (the ``--fit-cost-table``
    entry point). Returns the table path."""
    from repro.morph.opt.cost import CostModel, device_kind, save_measured

    fig3 = sweep(axis=-2, fig="cost_major")
    fig4 = sweep(axis=-1, fig="cost_minor")
    entries = {}
    entries.update(_fit_1d_entries(fig3, "major"))
    entries.update(_fit_1d_entries(fig4, "minor"))
    fused_ws = (3, 7, 15) if quick else (3, 7, 15, 31, 63, 101)
    entries.update(_fit_1d_entries(_fused_sweep(fused_ws), "fused"))
    op2d = {} if quick else _op2d_fits((3, 9, 15, 31))
    model = CostModel(entries=entries, crossovers={}, source="measured")
    crossovers = {
        "w0_major": model.crossover("major", small="linear_tree",
                                    sweep=MORPH.window_sweep),
        "w0_minor": model.crossover("minor", small="linear_tree",
                                    sweep=MORPH.window_sweep),
        "w0_fused": model.crossover("fused", small="linear", dtype="uint8"),
        "small_method": "linear_tree",
    }
    path = save_measured(entries, crossovers, op2d=op2d)
    emit("cost_table_written", 0.0, f"device={device_kind()} path={path}")
    for k, v in crossovers.items():
        if k != "small_method":
            emit(f"cost_table_{k}", float(v))
    return path


if __name__ == "__main__":
    if "--fit-cost-table" in sys.argv:
        fit_cost_table(quick="--quick" in sys.argv)
    else:
        run()
