"""Paper §5.3: the hybrid dispatch — and calibration of w0.

Measures the full 2-D erosion (both passes) three ways:
  paper_linear   linear for both passes at every w (paper small-w choice)
  paper_vhgw     vHGW for both passes at every w (paper baseline)
  hybrid         the dispatch policy (linear_tree under w0, vHGW above)

Writes the measured crossovers into src/repro/core/calibration.json so
core.dispatch.DispatchPolicy.calibrated() uses machine-local thresholds —
the exact procedure the paper followed on Exynos 5422.
"""
from __future__ import annotations

import functools
import json
import os

import jax

from benchmarks.bench_passes import crossover, sweep
from benchmarks.common import emit, paper_image, time_fn
from repro.configs.morphology import CONFIG as MORPH
from repro.core import DispatchPolicy, erode
from repro.core.dispatch import _CALIBRATION_FILE


def run() -> None:
    x = paper_image()
    # calibrate from 1-D sweeps (same data as Fig 3/4)
    fig3 = sweep(axis=-2, fig="calib_rowwindow")
    fig4 = sweep(axis=-1, fig="calib_colwindow")
    w0_major = crossover(fig3, small="linear_tree")
    w0_minor = crossover(fig4, small="linear_tree")
    with open(_CALIBRATION_FILE, "w") as f:
        json.dump({"w0_major": int(w0_major), "w0_minor": int(w0_minor),
                   "small_method": "linear_tree"}, f)
    emit("calibrated_w0_major", w0_major, f"paper={MORPH.paper_w0_major}")
    emit("calibrated_w0_minor", w0_minor, f"paper={MORPH.paper_w0_minor}")

    policy = DispatchPolicy.calibrated()
    for w in (3, 15, 31, 61, 101):
        t_lin = time_fn(jax.jit(functools.partial(
            erode, se=(w, w), method="linear")), x)
        t_vhgw = time_fn(jax.jit(functools.partial(
            erode, se=(w, w), method="vhgw")), x)
        t_hyb = time_fn(jax.jit(functools.partial(
            erode, se=(w, w), method="auto", policy=policy)), x)
        best = min(t_lin, t_vhgw)
        emit(f"erode2d_linear_w{w}", t_lin * 1e6)
        emit(f"erode2d_vhgw_w{w}", t_vhgw * 1e6)
        emit(f"erode2d_hybrid_w{w}", t_hyb * 1e6,
             f"envelope_ratio={t_hyb / best:.2f} (<=1.1 reproduces paper §5.3)")


if __name__ == "__main__":
    run()
