"""Expression-IR lowering overhead microbenchmark (§Perf guardrail).

The unified morphology API routes every operator through graph construction
+ a lowering pass. That must cost nothing where it matters: post-jit
steady-state must match a hand-written jnp chain (the graphs trace to the
same XLA program), and the trace-time tax (build expr -> evaluate -> trace)
must stay microscopic next to one compile. This harness measures:

* ``build_us``     — expr construction + ``to_plan`` (graph + halo traversal);
* ``lower_us``     — un-jitted lowering walk (trace-time overhead proxy);
* ``ir_call_us``   / ``hand_call_us`` — jitted steady-state, IR-lowered vs
  hand-written composition (ratio ~1.0 is the acceptance bar);

and writes ``benchmarks/results/BENCH_expr.json`` (rendered by
``benchmarks.report``).

    PYTHONPATH=src python -m benchmarks.bench_expr [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import closing, erode as core_erode, gradient, opening
from repro.morph import X, halo, lower_xla, node_count, to_plan

RESULTS = os.path.join(os.path.dirname(__file__), "results", "BENCH_expr.json")

def _hand_cleanup(x):
    return gradient(closing(opening(x, (3, 3)), (5, 5)), (3, 3))


def _median_us(fn, iters: int) -> float:
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def run(quick: bool = False) -> list[dict]:
    shape = (128, 128) if quick else (600, 800)
    warmup, iters = (1, 3) if quick else (2, 10)
    build_iters = 20 if quick else 200
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 256, shape, dtype=np.uint8))
    cases = [
        ("erode_3x3", X.erode((3, 3)), lambda v: core_erode(v, (3, 3))),
        (
            "cleanup_chain",
            X.opening((3, 3)).closing((5, 5)).gradient((3, 3)),
            _hand_cleanup,
        ),
    ]
    rows = []
    for name, expr, hand in cases:
        build_us = _median_us(lambda: to_plan(expr, name=name).halo(), build_iters)
        lower_us = _median_us(lambda: lower_xla(expr), build_iters)
        ir_fn = jax.jit(lower_xla(expr))
        hand_fn = jax.jit(hand)
        t_ir = time_fn(ir_fn, x, warmup=warmup, iters=iters)
        t_hand = time_fn(hand_fn, x, warmup=warmup, iters=iters)
        row = {
            "case": name,
            "shape": list(shape),
            "nodes": node_count(expr),
            "halo": list(halo(expr)),
            "build_us": build_us,
            "lower_us": lower_us,
            "ir_call_us": t_ir * 1e6,
            "hand_call_us": t_hand * 1e6,
            "ir_vs_hand": t_ir / t_hand if t_hand else float("nan"),
        }
        rows.append(row)
        emit(
            f"expr_{name}", t_ir * 1e6,
            f"ir/hand={row['ir_vs_hand']:.3f}x build={build_us:.1f}us "
            f"nodes={row['nodes']}",
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / few iters (CI smoke)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        # quick runs get their own file so they never clobber the full record
        args.out = RESULTS.replace(".json", "_quick.json") if args.quick else RESULTS
    rows = run(quick=args.quick)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
