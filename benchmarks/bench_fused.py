"""Fused megakernel vs two-pass kernels vs pure-JAX hybrid (§Perf A/B).

Sweeps SE sizes {3, 15, 63} over shapes {512^2, 2048^2, (8, 1024^2)} and
writes ``benchmarks/results/BENCH_fused.json`` (rendered into markdown by
``benchmarks.report``). The fused column is the single-``pallas_call``
megakernel (1 HBM read + 1 write); two-pass is the legacy
morph + transpose + morph + transpose pipeline (4 traversals); jnp-hybrid is
the pure-XLA separable path from core/morphology.py.

    PYTHONPATH=src python -m benchmarks.bench_fused [--quick]
"""
from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core import erode
from repro.kernels import erode2d_tpu

RESULTS = os.path.join(os.path.dirname(__file__), "results", "BENCH_fused.json")

FULL_SHAPES = [(512, 512), (2048, 2048), (8, 1024, 1024)]
FULL_WINDOWS = [3, 15, 63]
QUICK_SHAPES = [(128, 128), (2, 64, 128)]
QUICK_WINDOWS = [3, 15]


def _image(shape) -> jnp.ndarray:
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(0, 256, shape, dtype=np.uint8))


def _two_pass(x, se):
    # for (B, H, W) the legacy path runs as vmap-of-kernels (the old story)
    return erode2d_tpu(x, se, fused=False)


def run(quick: bool = False) -> list[dict]:
    shapes = QUICK_SHAPES if quick else FULL_SHAPES
    windows = QUICK_WINDOWS if quick else FULL_WINDOWS
    warmup, iters = (1, 2) if quick else (1, 3)
    rows = []
    for shape in shapes:
        x = _image(shape)
        for w in windows:
            se = (w, w)
            t_fused = time_fn(
                functools.partial(erode2d_tpu, se=se, fused=True), x,
                warmup=warmup, iters=iters,
            )
            t_two = time_fn(
                functools.partial(_two_pass, se=se), x, warmup=warmup, iters=iters
            )
            t_jnp = time_fn(
                jax.jit(functools.partial(erode, se=se)), x,
                warmup=warmup, iters=iters,
            )
            row = {
                "shape": list(shape),
                "se": w,
                "fused_s": t_fused,
                "two_pass_s": t_two,
                "jnp_hybrid_s": t_jnp,
                "fused_vs_two_pass": t_two / t_fused,
            }
            rows.append(row)
            emit(
                f"erode2d_{'x'.join(map(str, shape))}_w{w}_fused", t_fused * 1e6,
                f"two-pass/fused={row['fused_vs_two_pass']:.2f}x "
                f"jnp/fused={t_jnp / t_fused:.2f}x",
            )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / few iters (CI smoke)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        # quick runs get their own file so they never clobber the full record
        args.out = RESULTS.replace(".json", "_quick.json") if args.quick else RESULTS
    rows = run(quick=args.quick)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
