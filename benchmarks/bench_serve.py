"""Serving benchmark: micro-batched MorphService vs sequential dispatch.

Traffic model: every request is a novel (h, w) — scanned documents never
share shapes. Each concurrency level runs the ``document_cleanup`` chain
three ways over the same request stream:

* **direct** — the pre-serving status quo: one ``cleanup_batch(img[None])``
  call per request, sequentially. Every novel shape pays an XLA compile —
  exactly the failure mode the bucket ladder exists to remove.
* **direct_warm** — the same stream replayed after all its shapes have
  compiled: an artificial steady state (real diverse traffic never reaches
  it) isolating pure compute, so the bucket-padding tax is visible.
* **serve** — all requests submitted concurrently to MorphService, which
  pads them into one bucket and coalesces them into stacks behind a single
  warm executable (cache misses stay at 1 regardless of shape diversity).

Emits ``benchmarks/results/BENCH_serve.json``. The acceptance bar
(ISSUE 2): serve img/s >= 3x direct at 64 concurrent requests with a warm
executable cache; ``speedup`` is that ratio, ``speedup_warm`` the
compute-parity secondary.

    PYTHONPATH=src python -m benchmarks.bench_serve [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import p99_ms
from repro.data.images import cleanup_batch
from repro.serve.morph import MorphService, ServiceConfig

RESULTS = os.path.join(os.path.dirname(__file__), "results", "BENCH_serve.json")


def synth_requests(
    n: int, h: int, w: int, jitter: int, seed: int
) -> list[np.ndarray]:
    """n u8 images with distinct-ish (h, w) — diverse serving traffic."""
    rng = np.random.default_rng(seed)
    return [
        rng.integers(
            0,
            256,
            (h - int(rng.integers(0, jitter)), w - int(rng.integers(0, jitter))),
            dtype=np.uint8,
        )
        for _ in range(n)
    ]


def _direct_pass(imgs: list[np.ndarray]) -> list[float]:
    per_call = []
    for img in imgs:
        t = time.perf_counter()
        clean, edges = cleanup_batch(img[None])
        np.asarray(clean), np.asarray(edges)
        per_call.append(time.perf_counter() - t)
    return per_call


def bench_direct(streams: list[list[np.ndarray]]) -> tuple[float, float, float, float]:
    """Sequential single-image dispatch over fresh-shape streams.

    Returns (img/s, p99 ms) for the diverse stream and for a warm replay of
    the same shapes."""
    per_call = []
    t0 = time.perf_counter()
    for imgs in streams:
        per_call.extend(_direct_pass(imgs))
    wall = time.perf_counter() - t0
    n = sum(len(s) for s in streams)
    # replay: every shape above is now jit-warm
    per_warm = []
    t0 = time.perf_counter()
    for imgs in streams:
        per_warm.extend(_direct_pass(imgs))
    wall_warm = time.perf_counter() - t0
    return (
        n / wall,
        p99_ms(per_call),
        n / wall_warm,
        p99_ms(per_warm),
    )


def bench_serve(
    streams: list[list[np.ndarray]], bucket: tuple[int, int], max_batch: int
) -> tuple[float, float, dict]:
    cfg = ServiceConfig(buckets=(bucket,), max_batch=max_batch, window_ms=2.0)
    n = sum(len(s) for s in streams)
    with MorphService(cfg) as svc:
        # warm the executable cache (one compile per batch-size bucket)
        svc.run_batch(streams[0], "document_cleanup")
        latencies: list[float] = []
        stamps: dict[int, float] = {}

        def done(f):
            latencies.append(time.perf_counter() - stamps[id(f)])

        t0 = time.perf_counter()
        for imgs in streams:
            futs = []
            for img in imgs:
                t_sub = time.perf_counter()
                f = svc.submit_plan(img, "document_cleanup")
                stamps[id(f)] = t_sub
                f.add_done_callback(done)  # fires inline if already resolved
                futs.append(f)
            for f in futs:
                f.result()
        wall = time.perf_counter() - t0
        stats = svc.stats()
    p99 = p99_ms(latencies)
    return n / wall, p99, stats


def run(quick: bool = False) -> list[dict]:
    h, w = (64, 96) if quick else (160, 224)
    bucket = (64, 128) if quick else (192, 256)
    levels = (1, 8, 16) if quick else (1, 8, 64)
    rounds = 2 if quick else 3
    rows = []
    for n in levels:
        streams = [
            synth_requests(n, h, w, jitter=16, seed=1000 * n + r)
            for r in range(rounds)
        ]
        d_ips, d_p99, dw_ips, dw_p99 = bench_direct(streams)
        s_ips, s_p99, stats = bench_serve(streams, bucket, max_batch=min(64, n))
        row = {
            "concurrency": n,
            "shape": [h, w],
            "bucket": list(bucket),
            "rounds": rounds,
            "direct_img_s": round(d_ips, 2),
            "direct_warm_img_s": round(dw_ips, 2),
            "serve_img_s": round(s_ips, 2),
            "speedup": round(s_ips / d_ips, 2) if d_ips else None,
            "speedup_warm": round(s_ips / dw_ips, 2) if dw_ips else None,
            "direct_p99_ms": round(d_p99, 2),
            "direct_warm_p99_ms": round(dw_p99, 2),
            "serve_p99_ms": round(s_p99, 2),
            "occupancy": round(stats["occupancy"], 3),
            "mean_batch": round(stats["mean_batch"], 2),
            "cache_hit_rate": round(stats["cache"]["hit_rate"], 3),
            "cache_misses": stats["cache"]["misses"],
        }
        rows.append(row)
        print(
            f"concurrency={n:3d}  direct={d_ips:7.1f} img/s  "
            f"serve={s_ips:7.1f} img/s  speedup={row['speedup']}x "
            f"(warm {row['speedup_warm']}x)  serve_p99={s_p99:.1f} ms  "
            f"occupancy={row['occupancy']}"
        )
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(rows, f, indent=2)
    print(f"wrote {RESULTS}")
    return rows


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true",
                   help="small buckets + few rounds (CI smoke)")
    rows = run(quick=p.parse_args().quick)
    top = rows[-1]
    if top["speedup"] is not None and top["speedup"] < 3.0:
        print(f"WARNING: serve speedup {top['speedup']}x below the 3x bar "
              f"at concurrency {top['concurrency']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
