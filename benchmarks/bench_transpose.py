"""Paper Table 1: matrix transpose micro-benchmark.

Paper: 8x8.16 in 20 ns vs 114 ns scalar (5.7x), 16x16.8 in 47 ns vs 565 ns
(12x) on Exynos 5422+NEON. This environment is CPU+XLA, so the reproduced
*claim* is relative: the vector-rearrange transpose path (XLA's permute
network — the analog of the paper's VTRN ladder) vs an elementwise
gather transpose (the "without SIMD" analog: one element moved per op).
The Pallas tile kernel itself is validated for correctness in interpret
mode (tests/test_kernels.py); its wall-time here would measure the Python
interpreter, not the lowering target, so it is excluded from timing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn


@jax.jit
def vector_transpose(x):
    return jnp.swapaxes(x, -1, -2)


@jax.jit
def gather_transpose(x):
    """Scalar-analog: per-element gather through a flat permutation."""
    *b, h, w = x.shape
    idx = (jnp.arange(h * w) % h) * w + (jnp.arange(h * w) // h)
    flat = x.reshape(*b, h * w)
    return jnp.take(flat, idx, axis=-1).reshape(*b, w, h)


def run() -> None:
    cases = [
        ("8x8.u16", (4096, 8, 8), np.uint16),
        ("16x16.u8", (4096, 16, 16), np.uint8),
        ("128x128.u8", (64, 128, 128), np.uint8),
        ("600x800.u8", (1, 600, 800), np.uint8),
    ]
    rng = np.random.default_rng(0)
    for name, shape, dt in cases:
        x = jnp.asarray(rng.integers(0, 255, shape).astype(dt))
        n = shape[0]
        tv = time_fn(vector_transpose, x) / n
        tg = time_fn(gather_transpose, x) / n
        np.testing.assert_array_equal(
            np.asarray(vector_transpose(x)), np.asarray(gather_transpose(x))
        )
        emit(f"transpose_vector_{name}", tv * 1e6, f"speedup_vs_gather={tg / tv:.2f}x")
        emit(f"transpose_gather_{name}", tg * 1e6, "scalar-analog baseline")


if __name__ == "__main__":
    run()
