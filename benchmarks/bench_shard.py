"""Sharded morphology benchmark: scaling curve + halo-exchange vs reshard.

Three measurements, written to ``benchmarks/results/BENCH_shard.json``:

* **scaling** — large-image operators through ``repro.shard.to_sharded``
  at shard counts 1/2/4/8 (capped by available devices) vs the
  single-device ``lower_xla`` path. The interesting number is img/s at the
  max shard count over the single-device baseline (the ISSUE 5 bar: >= 2x
  at 8 shards).
* **ab** — halo-exchange vs reshard schedules at several SE wings on the
  max-shard mesh: the measured form of the decision
  ``CostModel.exchange_wins`` makes from the ``collective`` axis kind.
* **--fit-collective** — times raw ``ppermute`` / ``all_to_all`` sweeps
  inside ``shard_map``, fits the affine ``cost_us(elems)`` curves, and
  merges them into ``src/repro/core/cost_table.json`` under this device —
  after which ``strategy="auto"`` decides from measurements instead of the
  wing-vs-interior byte heuristic.

Run with forced host devices to exercise on CPU:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.bench_shard [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import time_fn_amortized as _time

RESULTS = os.path.join(os.path.dirname(__file__), "results", "BENCH_shard.json")



def _cases(smoke: bool):
    from repro.morph import X, occo_expr

    h, w = (512, 512) if smoke else (4096, 4096)
    return h, w, [
        ("erode15", X.erode((15, 15))),
        ("gradient7", X.gradient((7, 7))),
        ("occo5", occo_expr(X, (5, 5))),
    ]


def bench_scaling(img, exprs, shard_counts, reps) -> list[dict]:
    import jax

    from repro.morph import lower_xla
    from repro.shard import image_mesh, to_sharded

    rows = []
    for name, expr in exprs:
        base_s = _time(jax.jit(lower_xla(expr)), img, reps=reps)
        entry = {
            "case": name,
            "shape": list(img.shape),
            "single_device_s": round(base_s, 5),
            "per_shards": [],
        }
        for n in shard_counts:
            fn = jax.jit(to_sharded(expr, image_mesh(n)))
            s = _time(fn, img, reps=reps)
            entry["per_shards"].append(
                {"shards": n, "time_s": round(s, 5),
                 "speedup": round(base_s / s, 2)}
            )
        best = entry["per_shards"][-1]
        print(f"{name:10s} single={base_s*1e3:8.1f} ms   "
              + "  ".join(f"{p['shards']}sh={p['time_s']*1e3:.1f}ms"
                          f"({p['speedup']}x)" for p in entry["per_shards"]))
        entry["max_shards_speedup"] = best["speedup"]
        rows.append(entry)
    return rows


def bench_ab(img, shards, reps) -> list[dict]:
    """Exchange vs reshard for one erode at growing wings."""
    import jax

    from repro.morph import X
    from repro.shard import image_mesh, to_sharded

    mesh = image_mesh(shards)
    rows = []
    interior = img.shape[-2] // shards
    for se_h in (3, 15, 63):
        expr = X.erode((se_h, 3))
        ex_s = _time(jax.jit(to_sharded(expr, mesh, strategy="exchange")),
                     img, reps=reps)
        rs_s = _time(jax.jit(to_sharded(expr, mesh, strategy="reshard")),
                     img, reps=reps)
        rows.append({
            "se_h": se_h,
            "wing": (se_h - 1) // 2,
            "shard_interior": interior,
            "exchange_s": round(ex_s, 5),
            "reshard_s": round(rs_s, 5),
            "exchange_vs_reshard": round(rs_s / ex_s, 2),
        })
        print(f"A/B se_h={se_h:3d}: exchange={ex_s*1e3:.1f} ms  "
              f"reshard={rs_s*1e3:.1f} ms  ratio={rows[-1]['exchange_vs_reshard']}x")
    return rows


def fit_collective(shards, width, reps) -> dict:
    """Fit affine cost_us(elems) curves for ppermute/all_to_all and merge
    them into cost_table.json (the ``collective`` axis kind)."""
    import jax
    import jax.numpy as jnp
    from jax import lax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.dispatch import DispatchPolicy
    from repro.morph.opt.cost import (
        fit_affine,
        load_measured,
        save_measured,
    )
    from repro.shard import image_mesh

    mesh = image_mesh(shards)
    points: dict[str, list] = {"ppermute": [], "all_to_all": []}
    for rows in (8, 32, 128, 512):
        x = jnp.asarray(
            np.random.default_rng(0).integers(
                0, 256, (rows * shards, width), dtype=np.uint8
            )
        )
        elems = rows * width  # per-device elements in flight

        def pp(v):
            return lax.ppermute(
                v, "rows", [(i, i + 1) for i in range(shards - 1)]
            )

        def a2a(v):
            return lax.all_to_all(v, "rows", split_axis=v.ndim - 1,
                                  concat_axis=v.ndim - 2, tiled=True)

        for name, f in (("ppermute", pp), ("all_to_all", a2a)):
            fn = jax.jit(shard_map(
                f, mesh=mesh, in_specs=P("rows", None),
                out_specs=P("rows", None), check_rep=False,
            ))
            t = _time(fn, x, reps=reps)
            points[name].append((float(elems), t * 1e6))
    fits = {m: fit_affine(pts) for m, pts in points.items()}
    measured = load_measured()
    if measured is not None:
        entries = dict(measured.entries)
        crossovers = dict(measured.crossovers)
        op2d = dict(measured.op2d)
    else:
        # seed crossovers from the active policy so calibrated() (which
        # adopts a table's crossovers) keeps matching this table
        p = DispatchPolicy.calibrated()
        entries, op2d = {}, {}
        crossovers = {"w0_major": p.w0_major, "w0_minor": p.w0_minor,
                      "w0_fused": p.w0_fused, "small_method": p.small_method}
    for m, (c0, c1) in fits.items():
        # a collective cannot have negative launch cost; a fit can (noise
        # at the small end of the sweep), and a negative intercept would
        # make small transfers read as free
        entries[("collective", m, "uint8")] = (round(max(0.0, c0), 3),
                                               round(max(0.0, c1), 8))
    path = save_measured(entries, crossovers, op2d=op2d)
    print(f"fit collectives -> {path}: "
          + ", ".join(f"{m}: {c0:.1f}us + {c1*1e3:.4f}ns/elem"
                      for m, (c0, c1) in fits.items()))
    return {m: list(f) for m, f in fits.items()}


def run(smoke: bool = False, fit: bool = False) -> dict:
    import jax

    n_dev = len(jax.devices())
    shard_counts = [n for n in (1, 2, 4, 8) if n <= n_dev]
    if n_dev == 1:
        print("WARNING: one device only — scaling sweep is degenerate; "
              "run with XLA_FLAGS=--xla_force_host_platform_device_count=8")
    reps = 2 if smoke else 5
    h, w, exprs = _cases(smoke)
    img = np.random.default_rng(0).integers(0, 256, (h, w), dtype=np.uint8)
    out = {
        "devices": n_dev,
        "device_kind": str(jax.devices()[0].device_kind),
        "shape": [h, w],
        "smoke": smoke,
        "scaling": bench_scaling(img, exprs, shard_counts, reps),
        "ab": (bench_ab(img, shard_counts[-1], reps)
               if shard_counts[-1] > 1 else []),
    }
    if fit and shard_counts[-1] > 1:
        out["collective_fit"] = fit_collective(shard_counts[-1], w, reps)
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {RESULTS}")
    return out


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small image + few reps (CI)")
    p.add_argument("--fit-collective", action="store_true",
                   help="fit ppermute/all_to_all cost curves into "
                        "cost_table.json")
    args = p.parse_args()
    out = run(smoke=args.smoke, fit=args.fit_collective)
    worst = min((r["max_shards_speedup"] for r in out["scaling"]), default=0.0)
    if out["devices"] > 1 and worst < 2.0:
        print(f"WARNING: weakest case scaled {worst}x at "
              f"{out['scaling'][0]['per_shards'][-1]['shards']} shards — "
              f"below the 2x ISSUE bar")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
