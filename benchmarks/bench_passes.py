"""Paper Fig. 3 / Fig. 4: 1-D pass execution time vs window size.

Fig. 3 (paper "horizontal pass"): window along the image's *row* index —
our sublane/major axis (-2). Fig. 4 ("vertical pass"): window along the
column index — our lane/minor axis (-1). For each axis we sweep w over the
paper's range and time the three algorithms:

  linear       O(w) accumulator walk   (paper §5.1.2 / §5.2.2)
  linear_tree  O(log w) doubling ladder (beyond-paper)
  vhgw         O(1) amortized segment scans (paper §5.1.1 baseline)

Expected reproduction of the paper's claims: linear grows ~linearly in w,
vHGW is ~flat in w, and they cross at some w0 (paper: 69 / 59) — the
absolute times and exact w0 differ on CPU+XLA vs NEON, the *shape* and
the existence of the crossover are the claims under test.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, paper_image, time_fn
from repro.configs.morphology import CONFIG as MORPH
from repro.core import linear_1d, linear_1d_tree, vhgw_1d

def _vhgw_transpose(x, w, *, axis, op):
    """Paper §5.2.1 baseline: transpose -> major-axis vHGW -> transpose.

    Only meaningful for the minor-axis pass, where direct vHGW pays a
    strided segment reshape; this is exactly why the paper pairs the
    vertical pass with its fast transpose."""
    xt = jnp.swapaxes(x, -1, -2)
    out = vhgw_1d(xt, w, axis=axis, op=op)
    return jnp.swapaxes(out, -1, -2)


METHODS = {
    "linear": linear_1d,
    "linear_tree": linear_1d_tree,
    "vhgw": vhgw_1d,
}


def sweep(axis: int, fig: str) -> dict:
    x = paper_image()
    methods = dict(METHODS)
    if axis % 2 == 1:  # minor axis: add the paper's transpose-trick variant
        methods["vhgw_T"] = functools.partial(_vhgw_transpose)
    results = {m: {} for m in methods}
    for w in MORPH.window_sweep:
        for mname, fn in methods.items():
            a = -2 if mname == "vhgw_T" else axis
            jf = jax.jit(functools.partial(fn, w=w, axis=a, op="min"))
            t = time_fn(jf, x)
            results[mname][w] = t
            emit(f"{fig}_{mname}_w{w}", t * 1e6, f"axis={axis}")
    return results


def crossover(results: dict, small: str = "linear") -> int:
    """First w where vHGW (best variant) beats the small-window method."""
    for w in MORPH.window_sweep:
        big = min(results[m][w] for m in results if m.startswith("vhgw"))
        if big < results[small][w]:
            return w
    return MORPH.window_sweep[-1]


def run() -> dict:
    fig3 = sweep(axis=-2, fig="fig3_rowwindow")
    fig4 = sweep(axis=-1, fig="fig4_colwindow")
    w0_major = crossover(fig3)
    w0_minor = crossover(fig4)
    emit("fig3_crossover_w0", w0_major, f"paper_w0={MORPH.paper_w0_major}")
    emit("fig4_crossover_w0", w0_minor, f"paper_w0={MORPH.paper_w0_minor}")
    return {"fig3": fig3, "fig4": fig4, "w0_major": w0_major, "w0_minor": w0_minor}


if __name__ == "__main__":
    run()
