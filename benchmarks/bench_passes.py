"""Paper Fig. 3 / Fig. 4: 1-D pass execution time vs window size.

Fig. 3 (paper "horizontal pass"): window along the image's *row* index —
our sublane/major axis (-2). Fig. 4 ("vertical pass"): window along the
column index — our lane/minor axis (-1). For each axis we sweep w over the
paper's range and time the three algorithms:

  linear       O(w) accumulator walk   (paper §5.1.2 / §5.2.2)
  linear_tree  O(log w) doubling ladder (beyond-paper)
  vhgw         O(1) amortized segment scans (paper §5.1.1 baseline)

Expected reproduction of the paper's claims: linear grows ~linearly in w,
vHGW is ~flat in w, and they cross at some w0 (paper: 69 / 59) — the
absolute times and exact w0 differ on CPU+XLA vs NEON, the *shape* and
the existence of the crossover are the claims under test.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, paper_image, time_fn
from repro.configs.morphology import CONFIG as MORPH
from repro.core import linear_1d, linear_1d_tree, vhgw_1d

def _vhgw_transpose(x, w, *, axis, op):
    """Paper §5.2.1 baseline: transpose -> major-axis vHGW -> transpose.

    Only meaningful for the minor-axis pass, where direct vHGW pays a
    strided segment reshape; this is exactly why the paper pairs the
    vertical pass with its fast transpose."""
    xt = jnp.swapaxes(x, -1, -2)
    out = vhgw_1d(xt, w, axis=axis, op=op)
    return jnp.swapaxes(out, -1, -2)


METHODS = {
    "linear": linear_1d,
    "linear_tree": linear_1d_tree,
    "vhgw": vhgw_1d,
}


def sweep(axis: int, fig: str) -> dict:
    x = paper_image()
    methods = dict(METHODS)
    if axis % 2 == 1:  # minor axis: add the paper's transpose-trick variant
        methods["vhgw_T"] = functools.partial(_vhgw_transpose)
    results = {m: {} for m in methods}
    for w in MORPH.window_sweep:
        for mname, fn in methods.items():
            a = -2 if mname == "vhgw_T" else axis
            jf = jax.jit(functools.partial(fn, w=w, axis=a, op="min"))
            t = time_fn(jf, x)
            results[mname][w] = t
            emit(f"{fig}_{mname}_w{w}", t * 1e6, f"axis={axis}")
    return results


def crossover(results: dict, small: str = "linear") -> int:
    """First w where vHGW (best variant) beats the small-window method."""
    for w in MORPH.window_sweep:
        big = min(results[m][w] for m in results if m.startswith("vhgw"))
        if big < results[small][w]:
            return w
    return MORPH.window_sweep[-1]


def run() -> dict:
    fig3 = sweep(axis=-2, fig="fig3_rowwindow")
    fig4 = sweep(axis=-1, fig="fig4_colwindow")
    w0_major = crossover(fig3)
    w0_minor = crossover(fig4)
    emit("fig3_crossover_w0", w0_major, f"paper_w0={MORPH.paper_w0_major}")
    emit("fig4_crossover_w0", w0_minor, f"paper_w0={MORPH.paper_w0_minor}")
    return {"fig3": fig3, "fig4": fig4, "w0_major": w0_major, "w0_minor": w0_minor}


# ------------------------------------------------------------ IR optimizer
# Optimized-vs-raw graph benchmark (BENCH_opt.json): the same expression
# graphs lowered with the optimizer off (DispatchPolicy(opt_level=0)) and on,
# timed jitted on the jnp backend. The multi-output cases are where CSE pays
# (outputs that structurally share an erosion compute it once); the
# decomposition case reports whatever the cost model actually decided — with
# no measured table the analytic model correctly declines (one vHGW pass
# already beats k small ladders on this backend), so its honest speedup is
# ~1.0 until a device where the fit says otherwise.

_OPT_RESULTS = "benchmarks/results/BENCH_opt.json"


def _opt_cases(se=(5, 5)):
    from repro.morph import X

    return [
        # opening + top-hat + gradient over one input: the classic document
        # feature set; tophat rebuilds its own opening, gradient its own
        # erosion — 6 primitive launches raw, 3 after CSE.
        ("features_open_tophat_grad",
         {"open": X.opening(se), "tophat": X.tophat(se), "grad": X.gradient(se)}),
        # opening+closing saved plus edges off the cleaned image (the served
        # document_cleanup shape, as a raw multi-output expression)
        ("cleanup_clean_edges",
         {"clean": X.opening((3, 3)).closing((5, 5)),
          "edges": X.opening((3, 3)).closing((5, 5)).gradient((3, 3))}),
        # user-chained same-op passes: folding turns four passes into two
        ("folded_erode_chain", X.erode((3, 3)).erode((5, 5)).erode((3, 3))),
        # large-SE opening: the SE-decomposition candidate
        ("decompose_opening_31", X.opening((31, 31))),
    ]


def _paired_times(fa, fb, x, *, warmup: int, iters: int):
    """Alternating per-call timings of two jitted functions; medians of
    each. Interleaving makes the a/b ratio robust to the slow clock drift
    that sequential ``time_fn`` sweeps pick up on shared machines."""
    import time as _time

    import numpy as _np

    for _ in range(warmup):
        jax.block_until_ready(fa(x))
        jax.block_until_ready(fb(x))
    ta, tb = [], []
    for _ in range(iters):
        t0 = _time.perf_counter()
        jax.block_until_ready(fa(x))
        ta.append(_time.perf_counter() - t0)
        t0 = _time.perf_counter()
        jax.block_until_ready(fb(x))
        tb.append(_time.perf_counter() - t0)
    return float(_np.median(ta)), float(_np.median(tb))


def bench_opt(quick: bool = False) -> list:
    import json as _json
    import os as _os

    import dataclasses as _dc

    import numpy as _np
    import jax.numpy as _jnp

    from benchmarks.common import paper_image as _paper_image
    from repro.core.dispatch import DispatchPolicy
    from repro.morph import lower_xla, optimize, prim_count
    from repro.morph.opt import cost_model_for

    x = _paper_image() if not quick else _jnp.asarray(
        _np.random.default_rng(0).integers(0, 256, (128, 160), dtype=_np.uint8))
    warmup, iters = (1, 3) if quick else (2, 10)
    # identical policies except the optimizer level, so the A/B isolates
    # the graph rewrites from threshold calibration differences
    opt_policy = DispatchPolicy.calibrated()
    raw_policy = _dc.replace(opt_policy, opt_level=0)
    model = cost_model_for(opt_policy)
    rows = []
    for case, outs in _opt_cases():
        optimized = optimize(outs, policy=opt_policy)
        # structural inequality catches rewrites; the prim-count delta
        # catches pure CSE (identity sharing leaves structure equal)
        changed = optimized != outs or prim_count(optimized) != prim_count(outs)
        raw_fn = jax.jit(lower_xla(outs, policy=raw_policy))
        if changed:
            opt_fn = jax.jit(lower_xla(outs, policy=opt_policy))
            chk_r, chk_o = raw_fn(x), opt_fn(x)
            if isinstance(chk_r, dict):
                assert all(
                    bool(_jnp.array_equal(chk_r[k], chk_o[k])) for k in chk_r)
            else:
                assert bool(_jnp.array_equal(chk_r, chk_o))
            # interleave the two timings so clock drift between whole sweeps
            # cancels out of the ratio instead of masquerading as a speedup
            t_raw, t_opt = _paired_times(raw_fn, opt_fn, x,
                                         warmup=warmup, iters=iters)
        else:
            # the optimizer (correctly) left the graph alone — same program,
            # so don't report timing jitter as a "speedup"
            t_raw = time_fn(raw_fn, x, warmup=warmup, iters=iters)
            t_opt = t_raw
        row = {
            "case": case,
            "raw_s": t_raw,
            "opt_s": t_opt,
            "speedup": round(t_raw / t_opt, 3),
            "changed": changed,
            "prims_raw": prim_count(outs),
            "prims_opt": prim_count(optimized),
            "cost_model": model.source,
        }
        rows.append(row)
        emit(f"opt_{case}_raw", t_raw * 1e6)
        emit(f"opt_{case}_opt", t_opt * 1e6,
             f"speedup={row['speedup']}x prims {row['prims_raw']}->"
             f"{row['prims_opt']}" + ("" if changed else " (graph unchanged)"))
    _os.makedirs(_os.path.dirname(_OPT_RESULTS), exist_ok=True)
    with open(_OPT_RESULTS, "w") as f:
        _json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    import sys

    if "--opt" in sys.argv:
        bench_opt(quick="--quick" in sys.argv)
    else:
        run()
