"""Shared benchmark utilities: timing, latency summaries, workload generation.

Latency percentiles go through the serving tier's histogram
(``repro.obs.Histogram``) rather than ``np.percentile``, so a benchmark's
reported p50/p99 quantizes exactly as the live ``stats()`` surface does —
one estimator, comparable numbers.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.morphology import CONFIG as MORPH
from repro.obs import DEFAULT_LATENCY_BUCKETS_MS, Histogram, quantile_from_snapshot


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def time_fn_amortized(fn, *args, reps: int = 5) -> float:
    """Mean wall-time (seconds) over one blocking sweep of ``reps`` calls —
    the cheap estimator for already-warm compiled fns (one sync at the end
    instead of per call)."""
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def latency_summary(latencies_s) -> dict:
    """p50/p99/mean (milliseconds) of per-request latencies given in
    seconds, estimated from the obs latency histogram."""
    h = Histogram(DEFAULT_LATENCY_BUCKETS_MS)
    h.observe_many([t * 1e3 for t in latencies_s])
    snap = h.snapshot()
    return {
        "n": h.count,
        "mean_ms": h.mean(),
        "p50_ms": quantile_from_snapshot(snap, 0.50),
        "p99_ms": quantile_from_snapshot(snap, 0.99),
    }


def p99_ms(latencies_s) -> float:
    return latency_summary(latencies_s)["p99_ms"]


def paper_image(seed: int = 0) -> jnp.ndarray:
    """The paper's experimental input: 800x600 u8 gray image."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, 256, (MORPH.height, MORPH.width), dtype=np.uint8)
    )


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
