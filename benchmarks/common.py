"""Shared benchmark utilities: timing, CSV emission, workload generation."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.morphology import CONFIG as MORPH


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time (seconds) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def paper_image(seed: int = 0) -> jnp.ndarray:
    """The paper's experimental input: 800x600 u8 gray image."""
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.integers(0, 256, (MORPH.height, MORPH.width), dtype=np.uint8)
    )


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")
