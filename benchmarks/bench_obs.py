"""Observability overhead benchmark + chaos trace validation (ISSUE 7).

Two claims to hold the obs subsystem to:

* **Disabled is free.** ``ServiceConfig(obs=None)`` (the default) must run
  the BENCH_serve traffic mix at the same img/s as before the subsystem
  existed — every hook site is one ``is None`` check. Measured as an A/A
  ratio between two disabled passes (the noise floor) reported next to it.
* **Enabled is cheap.** ``obs=ObsConfig()`` (tracing + executor profiling)
  must cost <= ~5% on the same mix — spans are two ``perf_counter`` calls
  and a deque append per pipeline stage.

Plus the acceptance scenario: a chaos replay (one shard's dispatches
failing, one poison request, on logical shards) with obs enabled must
export Chrome trace-event JSON that passes ``validate_chrome_trace``,
contains the full resilience span vocabulary (queue / dispatch / executor /
retry / hop / failover), and closes every span exactly once.

Emits ``benchmarks/results/BENCH_obs.json`` and the chaos trace itself as
``benchmarks/results/trace_obs_chaos.json`` (drop it into ui.perfetto.dev).

    PYTHONPATH=src python -m benchmarks.bench_obs [--quick|--smoke]

``--smoke`` is the CI gate: quick sizes, and a nonzero exit if the disabled
path regresses past the noise gate or the chaos trace fails validation.
"""
from __future__ import annotations

import argparse
import json
import os
import time
import zlib

import jax
import numpy as np

from benchmarks.bench_serve import synth_requests
from benchmarks.common import latency_summary
from repro.obs import ObsConfig, validate_chrome_trace
from repro.serve.morph import MorphService, ServiceConfig
from repro.serve.morph.plans import single_op_plan
from repro.serve.morph.resilience import FaultPlan, RetryPolicy, ServeError
from repro.shard import ShardedMorphService

RESULTS = os.path.join(os.path.dirname(__file__), "results", "BENCH_obs.json")
TRACE_OUT = os.path.join(
    os.path.dirname(__file__), "results", "trace_obs_chaos.json"
)

# The chaos span vocabulary the exported trace must contain (the router
# adds "hop"/"failover"; the batcher adds "retry"; "bisect" appears only
# when a poison hides inside a multi-request group).
REQUIRED_CHAOS_SPANS = {
    "queue", "dispatch", "executor", "retry", "hop", "failover",
}


# --------------------------------------------------------------- overhead
def _serve_pass(
    streams, bucket, max_batch: int, obs: ObsConfig | None
) -> tuple[float, dict]:
    """One BENCH_serve-style serving pass; returns (img/s, latency summary)."""
    cfg = ServiceConfig(
        buckets=(bucket,), max_batch=max_batch, window_ms=2.0, obs=obs
    )
    n = sum(len(s) for s in streams)
    with MorphService(cfg) as svc:
        svc.run_batch(streams[0], "document_cleanup")  # warm the cache
        latencies: list[float] = []
        t0 = time.perf_counter()
        for imgs in streams:
            pairs = [
                (time.perf_counter(), svc.submit_plan(img, "document_cleanup"))
                for img in imgs
            ]
            for t_sub, f in pairs:
                f.result()
                latencies.append(time.perf_counter() - t_sub)
        wall = time.perf_counter() - t0
    return n / wall, latency_summary(latencies)


def bench_overhead(quick: bool = False, repeats: int = 3) -> list[dict]:
    h, w = (64, 96) if quick else (160, 224)
    bucket = (64, 128) if quick else (192, 256)
    levels = (8,) if quick else (8, 64)
    rounds = 2 if quick else 3
    rows = []
    for n in levels:
        streams = [
            synth_requests(n, h, w, jitter=16, seed=1000 * n + r)
            for r in range(rounds)
        ]
        modes = {
            "off_a": None,
            "off_b": None,  # A/A: the noise floor the "free" claim is read against
            "on": ObsConfig(),
        }
        best: dict[str, tuple[float, dict]] = {}
        for _ in range(repeats):
            for name, obs in modes.items():
                ips, lat = _serve_pass(streams, bucket, min(64, n), obs)
                if name not in best or ips > best[name][0]:
                    best[name] = (ips, lat)
        off_ips = max(best["off_a"][0], best["off_b"][0])
        on_ips = best["on"][0]
        row = {
            "concurrency": n,
            "rounds": rounds,
            "repeats": repeats,
            "off_img_s": round(off_ips, 2),
            "on_img_s": round(on_ips, 2),
            # disabled-path A/A ratio: ~1.0 up to measurement noise
            "disabled_aa_ratio": round(
                best["off_a"][0] / best["off_b"][0], 4
            ) if best["off_b"][0] else None,
            # enabled overhead: how much slower tracing+profiling makes it
            "enabled_overhead": round(off_ips / on_ips, 4) if on_ips else None,
            "off_p99_ms": round(best["off_a"][1]["p99_ms"], 2),
            "on_p99_ms": round(best["on"][1]["p99_ms"], 2),
        }
        rows.append(row)
        print(
            f"concurrency={n:3d}  off={off_ips:8.1f} img/s  "
            f"on={on_ips:8.1f} img/s  A/A={row['disabled_aa_ratio']}  "
            f"enabled={row['enabled_overhead']}x"
        )
    return rows


# ------------------------------------------------------------ chaos trace
def bench_chaos_trace(n_shards: int = 4) -> dict:
    """The acceptance scenario: one shard's dispatches fail (breaker trips,
    traffic fails over), one request is poisoned (fails alone, typed), obs
    on — then the exported trace must validate and balance."""
    plan = single_op_plan("erode", (3, 3))
    bucket = (64, 64)
    primary = zlib.crc32(
        f"{plan.name}|{bucket}|{np.dtype(np.uint8).str}".encode()
    ) % n_shards
    cfg = ServiceConfig(
        buckets=(bucket,),
        window_ms=0.0,
        max_batch=8,
        retry=RetryPolicy(max_retries=1, backoff_ms=0.5, backoff_cap_ms=2.0),
        faults=FaultPlan(
            fail_shard=primary, fail_after=0, fail_for=None,
            poison_tags=frozenset({"poison"}),
        ),
        obs=ObsConfig(),
    )
    rng = np.random.default_rng(7)
    imgs = [
        rng.integers(0, 256, (64, 64), dtype=np.uint8) for _ in range(24)
    ]
    devices = [jax.devices()[0]] * n_shards  # logical shards; CPU-safe
    completed = failed = 0
    with ShardedMorphService(cfg, devices=devices) as svc:
        futs = [
            svc.submit_plan(img, plan, tag="poison" if i == 5 else None)
            for i, img in enumerate(imgs)
        ]
        for f in futs:
            try:
                f.result(timeout=120)
                completed += 1
            except ServeError:
                failed += 1
        svc.flush(30)
        stats = svc.stats()
        doc = svc.export_trace()
        open_spans = svc._obs.tracer.open_count() + sum(
            s._obs.tracer.open_count() for s in svc.shards
        )
    errors = validate_chrome_trace(doc)
    names = {e["name"] for e in doc["traceEvents"]}
    missing = sorted(REQUIRED_CHAOS_SPANS - names)
    os.makedirs(os.path.dirname(TRACE_OUT), exist_ok=True)
    with open(TRACE_OUT, "w") as f:
        json.dump(doc, f)
    summary = {
        "shards": n_shards,
        "requests": len(imgs),
        "completed": completed,
        "failed_typed": failed,
        "events": len(doc["traceEvents"]),
        "span_names": sorted(names - {"process_name"}),
        "missing_spans": missing,
        "open_spans": open_spans,
        "validation_errors": len(errors),
        "failovers": stats["resilience"]["failovers"],
        "retries": stats["resilience"]["retries"],
        "trace_file": os.path.relpath(TRACE_OUT, os.path.dirname(__file__)),
    }
    print(
        f"chaos trace: {summary['events']} events, spans={summary['span_names']}, "
        f"open={open_spans}, validation_errors={len(errors)}"
    )
    if errors:
        for e in errors[:5]:
            print("  validation:", e)
    return summary


def run(quick: bool = False) -> dict:
    overhead = bench_overhead(quick=quick, repeats=2 if quick else 3)
    chaos = bench_chaos_trace()
    out = {"overhead": overhead, "chaos_trace": chaos}
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {RESULTS}")
    return out


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true", help="small sizes")
    p.add_argument("--smoke", action="store_true",
                   help="CI gate: quick sizes + hard asserts on the chaos "
                        "trace and the disabled path")
    args = p.parse_args()
    out = run(quick=args.quick or args.smoke)
    chaos = out["chaos_trace"]
    worst_enabled = max(
        (r["enabled_overhead"] or 0.0) for r in out["overhead"]
    )
    if worst_enabled > 1.05:
        print(f"WARNING: enabled-obs overhead {worst_enabled}x above the 1.05x bar")
    if args.smoke:
        # hard gates (loose enough for noisy CI hosts; the trace checks are
        # exact): the chaos trace must validate, balance, and cover the
        # resilience vocabulary; the disabled path must stay near the A/A
        # noise floor.
        ok = (
            chaos["validation_errors"] == 0
            and chaos["open_spans"] == 0
            and not chaos["missing_spans"]
            and all(
                r["disabled_aa_ratio"] is not None
                and 0.5 <= r["disabled_aa_ratio"] <= 2.0
                for r in out["overhead"]
            )
        )
        if not ok:
            print("SMOKE FAILED:", json.dumps(chaos, indent=2))
            return 1
        print("smoke ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
