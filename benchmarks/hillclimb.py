import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", ""
) + " --xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: lowers a cell in a named *variant* configuration
and reports the roofline terms, so each hypothesis -> change -> measure
iteration in EXPERIMENTS.md §Perf is one invocation.

    PYTHONPATH=src python -m benchmarks.hillclimb --arch qwen2.5-3b \
        --shape decode_32k --variant serve_shardings

Variants:
  baseline          paper-faithful lowering (same as dryrun)
  serve_shardings   iteration A: replicate TP params over DP at decode
  donate_cache      iteration B1: in-place KV cache update
  serve+donate      A + B1 combined
  banded_local      iteration C: block-banded local attention (gemma2/hymba)
"""
import argparse
import json

from repro.launch.dryrun import SHAPES, analyze, lower_any
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as tfm
from repro.models.config import ARCH_IDS, get_config

CHIPS = 256
PEAK_FLOPS, HBM_BW, ICI_BW = 197e12, 819e9, 50e9

VARIANTS = {
    "baseline": {},
    "serve_shardings": {"serve_shardings": True},
    "donate_cache": {"donate_cache": True},
    "serve+donate": {"serve_shardings": True, "donate_cache": True},
    "banded_local": {"banded_local": True},
    "int8_kv": {"kv_cache_dtype": "int8"},
    "int8_kv+serve": {"kv_cache_dtype": "int8", "serve_shardings": True},
    "moe_ep": {"moe_ep": True},
    "moe_ep+int8": {"moe_ep": True, "kv_cache_dtype": "int8",
                    "serve_shardings": True},
}


def measure(arch: str, shape: str, variant: str) -> dict:
    cfg = get_config(arch)
    opts = dict(VARIANTS[variant])
    banded = opts.pop("banded_local", False)
    mesh = make_production_mesh()
    tfm.set_banded_local(banded)
    if opts.get("moe_ep"):
        from repro.models import ffn
        ffn.set_moe_ep(mesh)
    try:
        lowered = lower_any(cfg, shape, mesh, **opts)
        compiled = lowered.compile()
        a = analyze(lowered, compiled)
    finally:
        tfm.set_banded_local(False)
        tfm.set_activation_spec(None)
        from repro.models import ffn
        ffn.set_moe_ep(None)
    terms = {
        "compute_s": a["flops"] / PEAK_FLOPS,
        "memory_s": a["bytes_accessed"] / HBM_BW,
        "collective_s": a["collectives"]["total_bytes"] / ICI_BW,
    }
    return {
        "arch": arch, "shape": shape, "variant": variant,
        "flops": a["flops"], "bytes": a["bytes_accessed"],
        "collective_bytes": a["collectives"]["total_bytes"],
        "collective_kinds": a["collectives"]["bytes"],
        "temp_bytes": a.get("temp_size_in_bytes"),
        "terms_s": terms,
        "dominant": max(terms, key=terms.get),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", required=True, choices=list(SHAPES))
    ap.add_argument("--variant", required=True, choices=list(VARIANTS))
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    r = measure(args.arch, args.shape, args.variant)
    t = r["terms_s"]
    print(f"[hillclimb] {args.arch} {args.shape} {args.variant}: "
          f"C={t['compute_s']:.3e} M={t['memory_s']:.3e} "
          f"X={t['collective_s']:.3e} dom={r['dominant']} "
          f"coll={r['collective_bytes']:.3e}B temp={r['temp_bytes']}")
    if args.out:
        with open(args.out, "a") as f:
            f.write(json.dumps(r) + "\n")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
