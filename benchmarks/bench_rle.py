"""RLE backend benchmark: representation A/B across run densities.

Three measurements, written to ``benchmarks/results/BENCH_rle.json``:

* **sweep** — one boolean opening served three ways at run densities
  0.1%–50% on 1–8 Mpx masks: the RLE host path (``lower_rle``, what the
  serving gate dispatches to), the dense separable path (jitted
  ``lower_xla``), and the fused Pallas megakernel (``lower_kernel``,
  compiled backends only — interpreting Pallas on CPU measures the
  interpreter, not the kernel). The acceptance number is the RLE-over-dense
  ratio at <= 1% density on the >= 4 Mpx masks.
* **serve_mix** — a mixed sparse/dense boolean traffic stream through
  ``MorphService`` with the density gate on: per-representation request
  counts straight from ``stats()``, showing the gate splitting one traffic
  mix between executions.
* **--fit-cost-table** — fits the cost model's *representation axis*: RLE
  cost affine in the measured run count, dense cost affine in the pixel
  count, merged into ``src/repro/core/cost_table.json`` under this device
  (preserving every previously fit axis) — after which the serving gate
  decides from measurements instead of the 5% density heuristic.

Run: ``PYTHONPATH=src python -m benchmarks.bench_rle [--smoke] [--fit-cost-table]``
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from benchmarks.common import time_fn_amortized as _amortized

RESULTS = os.path.join(os.path.dirname(__file__), "results", "BENCH_rle.json")

SE = (9, 9)
MEAN_RUN = 40  # px; strokes longer than the SE wing, the document regime


def _time_host(fn, *args, reps: int = 5) -> float:
    import time

    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        fn(*args)
    return (time.perf_counter() - t0) / reps


def _cases(smoke: bool):
    if smoke:
        return [(512, 512)], (0.005, 0.2)
    return (
        [(1024, 1024), (2048, 2048), (2048, 4096)],
        (0.001, 0.005, 0.01, 0.05, 0.2, 0.5),
    )


def bench_sweep(shapes, densities, reps) -> list[dict]:
    import jax
    import jax.numpy as jnp

    from repro.core.dispatch import DispatchPolicy, resolve_interpret
    from repro.data.images import synth_sparse_masks
    from repro.morph import X, lower_kernel, lower_xla
    from repro.rle import encode, lower_rle

    expr = X.opening(SE)
    interpret = resolve_interpret(None, DispatchPolicy.calibrated())
    dense_fn = jax.jit(lower_xla(expr))
    fused_fn = None if interpret else jax.jit(lower_kernel(expr))
    rle_fn = lower_rle(expr)

    rows = []
    for h, w in shapes:
        for density in densities:
            m = synth_sparse_masks(1, h, w, run_density=density,
                                   mean_run=MEAN_RUN, seed=0)[0]
            im = encode(m)
            mj = jnp.asarray(m)
            t_dense = _amortized(dense_fn, mj, reps=reps)
            t_rle = _time_host(rle_fn, m, reps=reps)
            t_fused = (
                _amortized(fused_fn, mj, reps=reps)
                if fused_fn is not None else None
            )
            row = {
                "shape": [h, w],
                "mpx": round(h * w / 1e6, 2),
                "run_density": density,
                "runs": int(im.n),
                "density_measured": round(im.n / (h * w), 5),
                "dense_s": t_dense,
                "rle_s": t_rle,
                "fused_s": t_fused,
                "rle_over_dense": round(t_dense / t_rle, 2),
            }
            rows.append(row)
            print(f"  {h}x{w} density={density}: dense {t_dense*1e3:.1f}ms "
                  f"rle {t_rle*1e3:.1f}ms -> {row['rle_over_dense']}x")
    return rows


def bench_serve_mix(reps_per_class: int, shape=(512, 512)) -> dict:
    from repro.data.images import synth_sparse_masks
    from repro.serve.morph import MorphService, Plan, ServiceConfig, Step

    plan = Plan("mask_open", (Step("opening", (3, 3)),))
    sparse = synth_sparse_masks(reps_per_class, *shape, run_density=0.003,
                                mean_run=MEAN_RUN, seed=1)
    dense = np.random.default_rng(2).random((reps_per_class, *shape)) < 0.5
    with MorphService(ServiceConfig(window_ms=0.5)) as svc:
        futs = []
        for i in range(reps_per_class):  # interleave: one mix, not two phases
            futs.append(svc.submit_plan(sparse[i], plan))
            futs.append(svc.submit_plan(dense[i], plan))
        for f in futs:
            f.result()
        st = svc.stats()
    out = {
        "requests": st["requests"],
        "rle_requests": st["rle_requests"],
        "repr": st["repr"],
        "p50_ms": st["p50_ms"],
        "p99_ms": st["p99_ms"],
    }
    print(f"  serve mix: {out['repr']['rle']} -> rle, "
          f"{out['repr']['dense']} -> dense "
          f"(density_p50 {out['repr']['density_p50']})")
    return out


def fit_repr_axis(sweep_rows) -> dict:
    """Fit the representation-axis curves from the sweep samples and merge
    them into this device's cost table (never clobbering other axes)."""
    from repro.core.dispatch import DispatchPolicy
    from repro.morph.opt.cost import fit_affine, load_measured, save_measured

    rle_pts = [(r["runs"], r["rle_s"] * 1e6) for r in sweep_rows]
    dense_pts = [(r["shape"][0] * r["shape"][1], r["dense_s"] * 1e6)
                 for r in sweep_rows]
    fits = {"rle": fit_affine(rle_pts), "dense": fit_affine(dense_pts)}
    measured = load_measured()
    if measured is not None:
        entries = dict(measured.entries)
        crossovers = dict(measured.crossovers)
        op2d = dict(measured.op2d)
    else:
        # seed crossovers from the active policy so calibrated() (which
        # adopts a table's crossovers) keeps matching this table
        p = DispatchPolicy.calibrated()
        entries, op2d = {}, {}
        crossovers = {"w0_major": p.w0_major, "w0_minor": p.w0_minor,
                      "w0_fused": p.w0_fused, "small_method": p.small_method}
    for method, (c0, c1) in fits.items():
        # negative intercepts are sweep noise; clamping keeps tiny inputs
        # from reading as free
        entries[("repr", method, "bool")] = (round(max(0.0, c0), 3),
                                             round(max(0.0, c1), 8))
    path = save_measured(entries, crossovers, op2d=op2d)
    print("fit repr axis -> " + path + ": "
          + ", ".join(f"{m}: {c0:.1f}us + {c1:.4f}us/driver"
                      for m, (c0, c1) in fits.items()))
    return {m: list(f) for m, f in fits.items()}


def run(smoke: bool = False, fit: bool = False) -> dict:
    import jax

    shapes, densities = _cases(smoke)
    reps = 2 if smoke else 5
    sweep = bench_sweep(shapes, densities, reps)
    out = {
        "device_kind": str(jax.devices()[0].device_kind),
        "se": list(SE),
        "mean_run": MEAN_RUN,
        "smoke": smoke,
        "sweep": sweep,
        "serve_mix": bench_serve_mix(2 if smoke else 8),
    }
    if fit:
        out["repr_fit"] = fit_repr_axis(sweep)
    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {RESULTS}")
    return out


def main() -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--smoke", action="store_true",
                   help="small mask + few reps (CI)")
    p.add_argument("--fit-cost-table", action="store_true",
                   help="fit the repr axis and merge into cost_table.json")
    a = p.parse_args()
    run(smoke=a.smoke, fit=a.fit_cost_table)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
