"""Render §Dry-run and §Roofline markdown tables from the results JSONs.

    PYTHONPATH=src python -m benchmarks.report > benchmarks/results/report.md
"""
from __future__ import annotations

import json
import sys


def gb(x):
    return f"{x / 1e9:.2f}" if x is not None else "-"


def dryrun_table(path: str, title: str) -> str:
    with open(path) as f:
        rows = json.load(f)
    out = [f"### {title}", "",
           "| arch | shape | status | HLO GFLOPs/chip | GB accessed/chip | "
           "coll GB/chip | temp GB/chip | args GB/chip | compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            reason = r.get("reason", r.get("error", ""))[:60]
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} "
                       f"| - | - | - | - | - | {reason} |")
            continue
        a = r["analysis"]
        out.append(
            f"| {r['arch']} | {r['shape']} | ok "
            f"| {a['flops']/1e9:.1f} | {gb(a['bytes_accessed'])} "
            f"| {gb(a['collectives']['total_bytes'])} "
            f"| {gb(a.get('temp_size_in_bytes'))} "
            f"| {gb(a.get('argument_size_in_bytes'))} "
            f"| {r['compile_s']} |")
    ok = sum(r["status"] == "ok" for r in rows)
    sk = sum(r["status"] == "skipped" for r in rows)
    er = sum(r["status"] == "error" for r in rows)
    out.append("")
    out.append(f"**{ok} ok / {sk} documented skips / {er} errors** "
               f"({len(rows)} cells)")
    return "\n".join(out)


def fused_table(path: str) -> str:
    with open(path) as f:
        rows = json.load(f)
    out = ["### Fused megakernel vs two-pass vs jnp-hybrid (erode2d)", "",
           "| shape | SE | fused ms | two-pass ms | jnp-hybrid ms | "
           "fused speedup vs two-pass |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        shape = "x".join(str(s) for s in r["shape"])
        out.append(
            f"| {shape} | {r['se']}x{r['se']} "
            f"| {r['fused_s']*1e3:.2f} | {r['two_pass_s']*1e3:.2f} "
            f"| {r['jnp_hybrid_s']*1e3:.2f} "
            f"| **{r['fused_vs_two_pass']:.2f}x** |")
    return "\n".join(out)


def serve_table(path: str) -> str:
    with open(path) as f:
        rows = json.load(f)
    out = ["### Morphology serving (MorphService vs sequential dispatch, "
           "document_cleanup)", "",
           "| concurrency | shape | direct img/s | serve img/s | speedup | "
           "speedup (warm shapes) | serve p99 ms | occupancy | cache hit-rate |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        shape = "x".join(str(s) for s in r["shape"])
        out.append(
            f"| {r['concurrency']} | {shape} "
            f"| {r['direct_img_s']} | {r['serve_img_s']} "
            f"| **{r['speedup']}x** | {r['speedup_warm']}x "
            f"| {r['serve_p99_ms']} | {r['occupancy']} "
            f"| {r['cache_hit_rate']} |")
    out.append("")
    out.append("direct pays one XLA compile per novel request shape; the "
               "service's bucket ladder keeps one warm executable "
               "(speedup-warm isolates pure compute on a replayed stream).")
    return "\n".join(out)


def expr_table(path: str) -> str:
    with open(path) as f:
        rows = json.load(f)
    out = ["### Expression-IR lowering overhead (graph API vs hand-written)", "",
           "| case | nodes | halo | build+halo us | lower us | "
           "IR call us | hand call us | IR/hand |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['case']} | {r['nodes']} | {tuple(r['halo'])} "
            f"| {r['build_us']:.1f} | {r['lower_us']:.1f} "
            f"| {r['ir_call_us']:.1f} | {r['hand_call_us']:.1f} "
            f"| **{r['ir_vs_hand']:.3f}x** |")
    out.append("")
    out.append("post-jit the IR lowers to the same XLA program as the "
               "hand-written chain; build/lower are one-time trace costs.")
    return "\n".join(out)


def opt_table(path: str) -> str:
    with open(path) as f:
        rows = json.load(f)
    out = ["### IR optimizer (optimized vs raw graphs, jnp backend)", "",
           "| case | prim launches raw -> opt | raw ms | opt ms | speedup | "
           "cost model |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        out.append(
            f"| {r['case']} | {r['prims_raw']} -> {r['prims_opt']} "
            f"| {r['raw_s']*1e3:.2f} | {r['opt_s']*1e3:.2f} "
            f"| **{r['speedup']:.2f}x** | {r['cost_model']} |")
    out.append("")
    out.append("CSE shares erosions across multi-output graphs; folding "
               "merges same-op chains; SE decomposition applies only where "
               "the measured cost table says it wins (the analytic fallback "
               "declines, so those rows read ~1.0x until a table is fit).")
    return "\n".join(out)


def shard_table(path: str) -> str:
    with open(path) as f:
        d = json.load(f)
    shape = "x".join(str(s) for s in d["shape"])
    out = [f"### Sharded morphology ({shape}, {d['devices']} devices, "
           f"{d['device_kind']})", "",
           "| case | single-device ms | " +
           " | ".join(f"{p['shards']} shards"
                      for p in d["scaling"][0]["per_shards"]) +
           " | max speedup |",
           "|---|---|" + "---|" * (len(d["scaling"][0]["per_shards"]) + 1)]
    for r in d["scaling"]:
        cells = " | ".join(
            f"{p['time_s']*1e3:.1f} ({p['speedup']}x)" for p in r["per_shards"]
        )
        out.append(f"| {r['case']} | {r['single_device_s']*1e3:.1f} "
                   f"| {cells} | **{r['max_shards_speedup']}x** |")
    if d.get("ab"):
        out += ["", "halo-exchange vs reshard (erode, max shards; ratio > 1 "
                "means exchange wins):", "",
                "| SE rows | wing | shard interior | exchange ms | "
                "reshard ms | reshard/exchange |", "|---|---|---|---|---|---|"]
        for r in d["ab"]:
            out.append(
                f"| {r['se_h']} | {r['wing']} | {r['shard_interior']} "
                f"| {r['exchange_s']*1e3:.1f} | {r['reshard_s']*1e3:.1f} "
                f"| **{r['exchange_vs_reshard']}x** |")
    return "\n".join(out)


def resilience_table(path: str) -> str:
    with open(path) as f:
        d = json.load(f)
    shape = "x".join(str(s) for s in d["shape"])
    out = [f"### Resilience ({d['shards']} shards, {d['requests']} requests, "
           f"~{shape}, faulted shard {d['faulted_shard']})", "",
           "| scenario | img/s | p99 ms | completed | healthy shards | "
           "reroutes | slow | hedges | retries |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in d["scenarios"]:
        out.append(
            f"| {r['scenario']} | {r['img_s']} | {r['p99_ms']} "
            f"| {r['completed']}/{r['requests']} "
            f"| {r['healthy_shards']}/{r['shards']} "
            f"| {r['reroutes']} | {r.get('slow_shards', 0)} "
            f"| {r.get('hedges', 0)} | {r['retries']} |")
    ov = d["overhead"]
    out.append("")
    out.append(f"machinery overhead (single service, faults off): "
               f"{ov['resilience_on_img_s']} img/s with admission control + "
               f"retry policy vs {ov['resilience_off_img_s']} img/s without "
               f"(**{ov['on_vs_off']}x**; acceptance bar >= 0.97x). "
               f"shard_loss is rerouted steady state: the breaker trips "
               f"during the warm pass and every request still completes "
               f"bit-exact on survivors. gray_failure is drained steady "
               f"state: the slow shard is marked from its peer-relative "
               f"latency EWMA and routed around, breaker closed throughout.")
    mt = d.get("multi_tenant_overload")
    if mt:
        out.append("")
        out.append(
            f"### Multi-tenant overload ({mt['overload_factor']}x load, "
            f"gray shard {mt['gray_shard']} at +{mt['gray_latency_ms']} ms)")
        out.append("")
        out.append("| tenant | priority | submitted | completed | "
                   "shed (typed) | p99 ms | SLO ms | SLO attained |")
        out.append("|---|---|---|---|---|---|---|---|")
        for name, c in mt["classes"].items():
            out.append(
                f"| {name} | {c['priority']} | {c['submitted']} "
                f"| {c['completed']} | {c['shed_typed']} | {c['p99_ms']} "
                f"| {c['slo_ms']} | {c['slo_attained']} |")
        out.append("")
        out.append(
            f"gray shard ended `{mt['gray_shard_state']}` with "
            f"{mt['gray_shard_trips']} breaker trips (slow, never dead); "
            f"{mt['hedges']} hedges ({mt['hedge_wins']} wins), peak "
            f"brownout level {mt['brownout_level_peak']}. High-priority "
            f"SLO is 1.5x the healthy baseline for the same offered load; "
            f"low-priority sheds typed errors instead of missing quietly.")
    return "\n".join(out)


def obs_table(path: str) -> str:
    with open(path) as f:
        d = json.load(f)
    out = ["### Observability overhead (tracing + executor profiling, "
           "BENCH_serve traffic mix)", "",
           "| concurrency | obs off img/s | obs on img/s | A/A noise ratio | "
           "enabled overhead | off p99 ms | on p99 ms |",
           "|---|---|---|---|---|---|---|"]
    for r in d["overhead"]:
        out.append(
            f"| {r['concurrency']} | {r['off_img_s']} | {r['on_img_s']} "
            f"| {r['disabled_aa_ratio']} | **{r['enabled_overhead']}x** "
            f"| {r['off_p99_ms']} | {r['on_p99_ms']} |")
    c = d["chaos_trace"]
    out.append("")
    out.append(
        f"chaos replay (faulted shard + poison request, {c['shards']} shards): "
        f"{c['completed']}/{c['requests']} completed, "
        f"{c['events']} trace events over spans {', '.join(c['span_names'])}; "
        f"{c['validation_errors']} schema errors, {c['open_spans']} unclosed "
        f"spans. Load `{c['trace_file']}` at ui.perfetto.dev.")
    return "\n".join(out)


def rle_table(path: str) -> str:
    with open(path) as f:
        d = json.load(f)
    se = "x".join(str(s) for s in d["se"])
    out = [f"### RLE vs dense binary morphology (opening {se}, "
           f"mean run {d['mean_run']} px, {d['device_kind']})", "",
           "| shape | run density | runs | dense ms | RLE ms | fused ms | "
           "RLE vs dense |",
           "|---|---|---|---|---|---|---|"]
    for r in d["sweep"]:
        shape = "x".join(str(s) for s in r["shape"])
        fused = f"{r['fused_s']*1e3:.1f}" if r.get("fused_s") else "-"
        out.append(
            f"| {shape} | {r['run_density']} | {r['runs']} "
            f"| {r['dense_s']*1e3:.1f} | {r['rle_s']*1e3:.1f} | {fused} "
            f"| **{r['rle_over_dense']}x** |")
    m = d["serve_mix"]
    out.append("")
    out.append(
        f"run-domain cost scales with content, not pixels: the win grows "
        f"with image size and collapses past a few % density — which is why "
        f"dispatch is per-request. Serve mix ({m['requests']} boolean "
        f"requests): density gate sent {m['repr']['rle']} to RLE and "
        f"{m['repr']['dense']} to dense (density p50 "
        f"{m['repr']['density_p50']}).")
    return "\n".join(out)


def router_table(path: str) -> str:
    with open(path) as f:
        d = json.load(f)
    q, k, te = d["qps_slo"], d["worker_kill"], d["typed_errors"]
    out = [f"### Front-tier ingress ({d['workers']} worker processes, "
           f"crc32 affinity routing)", "",
           "| tenant | priority | submitted | completed | p99 ms | SLO ms | "
           "SLO attained |",
           "|---|---|---|---|---|---|---|"]
    for name, c in q["classes"].items():
        out.append(
            f"| {name} | {c['priority']} | {c['submitted']} "
            f"| {c['completed']} | {c['p99_ms']} | {c['slo_ms']} "
            f"| {c['slo_attained']} |")
    out.append("")
    out.append(
        f"offered {q['offered_qps']} req/s open-loop, sustained "
        f"{q['sustained_qps']} req/s ({q['completed']}/{q['requests']} "
        f"completed; healthy calibration {q['healthy_img_s']} img/s at "
        f"p99 {q['healthy_p99_ms']} ms). Every remote result in the "
        f"{len(d['bit_exact']['plans'])}-plan mix is bit-exact vs a direct "
        f"in-process service ({d['bit_exact']['checked']} checked).")
    out.append("")
    out.append(
        f"typed errors over the wire: DeadlineExceeded, QuotaExceeded "
        f"(tenant `{te['quota']['tenant']}`, {te['quota']['typed']} sheds), "
        f"ServiceClosed from a draining worker ({te['service_closed']['drained']} "
        f"in-flight requests drained to results first) — all reconstructed "
        f"client-side as the same exception types. Worker kill (SIGKILL on "
        f"owner {k['victim']}): {k['completed']}/{k['requests']} futures "
        f"completed bit-exact via survivors, fleet stats merged across "
        f"{k['healthy_workers']} live workers, cross-process trace "
        f"{k['trace_events']} events over pids {k['trace_pids']} with "
        f"{k['trace_validation_errors']} schema errors and "
        f"{k['open_spans']} open spans (`{k['trace_file']}`).")
    return "\n".join(out)


def roofline_table(path: str) -> str:
    with open(path) as f:
        rows = json.load(f)
    out = ["### Roofline (single-pod 16x16, probe-corrected)", "",
           "| arch | shape | compute s | memory s | collective s | dominant "
           "| useful (6ND/HLO) | roofline-MFU |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"{r['status']} | - | - |")
            continue
        t = r["terms_s"]
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | **{r['dominant'][:-2]}** "
            f"| {r['usefulness']:.2f} | {r['roofline_mfu']*100:.1f}% |")
    return "\n".join(out)


def main():
    base = "benchmarks/results"
    parts = []
    try:
        parts.append(dryrun_table(f"{base}/dryrun_single_pod.json",
                                  "Dry-run — single pod (16x16 = 256 chips)"))
    except FileNotFoundError:
        parts.append("single-pod dry-run results missing")
    try:
        parts.append(dryrun_table(f"{base}/dryrun_multi_pod.json",
                                  "Dry-run — multi-pod (2x16x16 = 512 chips)"))
    except FileNotFoundError:
        parts.append("multi-pod dry-run results missing")
    try:
        parts.append(fused_table(f"{base}/BENCH_fused.json"))
    except FileNotFoundError:
        parts.append("fused-kernel results missing (run benchmarks.bench_fused)")
    try:
        parts.append(serve_table(f"{base}/BENCH_serve.json"))
    except FileNotFoundError:
        parts.append("serving results missing (run benchmarks.bench_serve)")
    try:
        parts.append(expr_table(f"{base}/BENCH_expr.json"))
    except FileNotFoundError:
        parts.append("expr-IR results missing (run benchmarks.bench_expr)")
    try:
        parts.append(opt_table(f"{base}/BENCH_opt.json"))
    except FileNotFoundError:
        parts.append("optimizer results missing (run benchmarks.bench_passes --opt)")
    try:
        parts.append(shard_table(f"{base}/BENCH_shard.json"))
    except FileNotFoundError:
        parts.append("sharding results missing (run benchmarks.bench_shard "
                     "with XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    try:
        parts.append(resilience_table(f"{base}/BENCH_resilience.json"))
    except FileNotFoundError:
        parts.append("resilience results missing (run benchmarks.bench_resilience)")
    try:
        parts.append(obs_table(f"{base}/BENCH_obs.json"))
    except FileNotFoundError:
        parts.append("observability results missing (run benchmarks.bench_obs)")
    try:
        parts.append(rle_table(f"{base}/BENCH_rle.json"))
    except FileNotFoundError:
        parts.append("RLE results missing (run benchmarks.bench_rle)")
    try:
        parts.append(router_table(f"{base}/BENCH_router.json"))
    except FileNotFoundError:
        parts.append("ingress results missing (run benchmarks.bench_router)")
    try:
        parts.append(roofline_table(f"{base}/roofline.json"))
    except FileNotFoundError:
        parts.append("roofline results missing")
    print("\n\n".join(parts))


if __name__ == "__main__":
    sys.exit(main())
