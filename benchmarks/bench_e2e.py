"""End-to-end morphology benchmarks beyond the paper's figures:

* separable vs naive 2-D (the complexity win separability buys),
* erosion == dilation cost symmetry (paper: "identical, we show erosion"),
* fused-gradient vs two-pass gradient (beyond-paper kernel, jnp-level),
* the document-cleanup pipeline (data/images.py) throughput,
* the serving engine (serve/morph) vs sequential dispatch on diverse-shape
  traffic (the full sweep lives in benchmarks.bench_serve).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, paper_image, time_fn
from repro.core import dilate, erode, gradient, morph2d_naive
from repro.data import ImagePipelineConfig, cleanup_batch, synth_documents
from repro.kernels import erode2d_tpu


def run() -> None:
    x = paper_image()
    for w in (3, 9, 21):
        t_sep = time_fn(jax.jit(functools.partial(erode, se=(w, w))), x)
        t_naive = time_fn(
            jax.jit(functools.partial(morph2d_naive, se=(w, w), op="min")), x
        )
        emit(f"erode2d_separable_w{w}", t_sep * 1e6,
             f"naive/sep={t_naive / t_sep:.2f}x (grows with w)")
        emit(f"erode2d_naive_w{w}", t_naive * 1e6)

    t_e = time_fn(jax.jit(functools.partial(erode, se=(9, 9))), x)
    t_d = time_fn(jax.jit(functools.partial(dilate, se=(9, 9))), x)
    emit("erosion_vs_dilation_sym", abs(t_e - t_d) / t_e * 100,
         "percent diff (paper: identical)")

    t_g = time_fn(jax.jit(functools.partial(gradient, se=(5, 5))), x)
    emit("gradient_5x5", t_g * 1e6)

    # kernel-level: fused megakernel (1 pallas_call) vs two-pass (4 calls)
    for w in (3, 15):
        t_f = time_fn(functools.partial(erode2d_tpu, se=(w, w), fused=True), x)
        t_2 = time_fn(functools.partial(erode2d_tpu, se=(w, w), fused=False), x)
        emit(f"erode2d_kernel_fused_w{w}", t_f * 1e6,
             f"two-pass/fused={t_2 / t_f:.2f}x")

    imgs = synth_documents(ImagePipelineConfig(), 4)
    t_clean = time_fn(lambda: cleanup_batch(imgs))
    emit("document_cleanup_batch4_800x600", t_clean * 1e6,
         f"{4 / t_clean:.1f} img/s")

    # serving engine: micro-batched service vs sequential single-image
    # dispatch over diverse request shapes (one quick point; the sweep is
    # benchmarks.bench_serve -> BENCH_serve.json)
    import time as _time

    from repro.serve.morph import MorphService, ServiceConfig

    rng = np.random.default_rng(3)
    reqs = [rng.integers(0, 256, (120 - int(rng.integers(0, 16)),
                                  160 - int(rng.integers(0, 16))),
                         dtype=np.uint8) for _ in range(16)]
    t0 = _time.perf_counter()
    for r in reqs:
        c, e = cleanup_batch(r[None])
        np.asarray(c)
    t_seq = _time.perf_counter() - t0
    with MorphService(ServiceConfig(buckets=((128, 256),), max_batch=16,
                                    window_ms=2.0)) as svc:
        svc.run_batch(reqs, "document_cleanup")  # warm
        t0 = _time.perf_counter()
        svc.run_batch(reqs, "document_cleanup")
        t_srv = _time.perf_counter() - t0
    emit("serve_cleanup_16_diverse_shapes", t_srv * 1e6,
         f"sequential/serve={t_seq / t_srv:.1f}x (compile-per-shape removed)")


if __name__ == "__main__":
    run()
