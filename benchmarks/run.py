"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Run as:
    PYTHONPATH=src python -m benchmarks.run [--only transpose|passes|hybrid|e2e]
"""
from __future__ import annotations

import argparse


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    choices=["transpose", "passes", "hybrid", "e2e"])
    args = ap.parse_args(argv)

    from benchmarks import bench_e2e, bench_hybrid, bench_passes, bench_transpose

    suites = {
        "transpose": bench_transpose.run,  # paper Table 1
        "passes": bench_passes.run,        # paper Fig. 3 / Fig. 4
        "hybrid": bench_hybrid.run,        # paper §5.3 + w0 calibration
        "e2e": bench_e2e.run,              # separability / symmetry / pipeline
    }
    print("name,us_per_call,derived")
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---")
        fn()


if __name__ == "__main__":
    main()
