"""Feed-forward blocks: gated-linear-unit MLPs and token-choice MoE.

The MoE is a capacity-based, token-dropping top-k router (GShard/Switch
family — the form Grok-1 and DBRX use) implemented with *gather/scatter*
dispatch rather than one-hot einsum dispatch, so HLO FLOPs stay close to
6·N_active·D (the usefulness ratio in §Roofline would otherwise be
polluted by disguised-gather matmuls). Per-expert selection uses an
argsort over slot priorities — an O(S log S) integer sort per expert,
negligible next to the expert GEMMs.

Expert weights are stacked (E, d, ff); the expert GEMM is a batched
einsum, which under the TP sharding rules (launch/sharding.py) shards ff
over "model" (TP-in-expert). ``moe_apply_ep`` is the expert-parallel
shard_map path (§Perf iteration D in EXPERIMENTS.md): experts over
"model", expert ff over "data", tokens moved instead of weights.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

Array = jnp.ndarray


def _act(name: str):
    return {"swiglu": jax.nn.silu, "geglu": jax.nn.gelu, "gelu": jax.nn.gelu}[name]


def mlp_init(key, cfg, dtype, *, stacked=None) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.ffn_act in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d, (ff,), dtype, stacked=stacked),
            "w_up": dense_init(ks[1], d, (ff,), dtype, stacked=stacked),
            "w_down": dense_init(ks[2], ff, (d,), dtype, stacked=stacked),
        }
    return {
        "w_up": dense_init(ks[1], d, (ff,), dtype, stacked=stacked),
        "w_down": dense_init(ks[2], ff, (d,), dtype, stacked=stacked),
    }


def mlp_apply(cfg, p, x: Array) -> Array:
    act = _act(cfg.ffn_act)
    if cfg.ffn_act in ("swiglu", "geglu"):
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["w_up"])
    else:
        h = act(jnp.einsum("bsd,df->bsf", x, p["w_up"]))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def moe_init(key, cfg, dtype, *, stacked=None) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)

    def ew(k, i, o):
        shape = (e, i, o) if stacked is None else (stacked, e, i, o)
        return (0.02 * jax.random.truncated_normal(k, -2.0, 2.0, shape)).astype(dtype)

    return {
        "router": dense_init(ks[0], d, (e,), jnp.float32, stacked=stacked),
        "w_gate": ew(ks[1], d, ff),
        "w_up": ew(ks[2], d, ff),
        "w_down": ew(ks[3], ff, d),
    }


# Expert-parallel hook (§Perf iteration D): when a mesh is installed here
# and E % tp == 0, MoE blocks run the shard_map EP path instead of the
# GSPMD-FSDP path. Installed by launch/dryrun (variant) or a launcher.
_EP_MESH = None


def set_moe_ep(mesh) -> None:
    global _EP_MESH
    _EP_MESH = mesh


def ep_enabled(cfg) -> bool:
    return (
        _EP_MESH is not None
        and cfg.num_experts > 0
        and cfg.num_experts % _EP_MESH.shape["model"] == 0
    )


def _routing(cfg, probs, x_dtype):
    """Shared top-k routing math -> (gate_w, gate_idx, aux)."""
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    gate_w, gate_idx = jax.lax.top_k(probs, k)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)
    frac_tokens = jnp.mean(jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=(1, 2))
    frac_probs = jnp.mean(probs, axis=1)
    aux = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))
    return gate_w, gate_idx, aux.astype(jnp.float32)


def moe_apply_ep(cfg, p, x: Array) -> tuple[Array, Array]:
    """Expert-parallel MoE (§Perf iteration D, decode-oriented).

    Layout: experts over "model" (E_loc = E/tp per rank), expert ff over
    the data axes (ff_loc = ff/dp); tokens are all-gathered over data
    inside the region (cheap at decode: B·d bytes) and each (data, model)
    chip computes its (ff-shard, expert-shard) partial, reduced with two
    psums. Weight movement per step: ZERO — the FSDP per-layer expert
    weight all-gathers (the dominant collective of the MoE decode cells)
    disappear; activations move instead (B·d ≪ E·d·ff).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.launch.mesh import data_axes

    mesh = _EP_MESH
    dp = data_axes(mesh)
    tp = mesh.shape["model"]
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    e_loc = e // tp
    act = _act(cfg.ffn_act)

    pspecs = {
        "router": P(None, None),
        "w_gate": P("model", None, dp),
        "w_up": P("model", None, dp),
        "w_down": P("model", dp, None),
    }
    xspec = P(dp, None, None)

    def local_fn(pm, xx):
        # xx: (B_loc, S, d) -> gather the full token set over the data axes
        xf = xx
        for ax in reversed(dp):  # innermost first => axis0 ends dp[0]-major
            xf = jax.lax.all_gather(xf, ax, axis=0, tiled=True)
        b, s, d = xf.shape
        cap = max(1, int(b * s * k / e * cfg.moe_capacity_factor))
        logits = jnp.einsum("bsd,de->bse", xf.astype(jnp.float32), pm["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        gate_w, gate_idx, aux = _routing(cfg, probs, xf.dtype)
        # aux is identical on every rank post-gather; pmean proves it to
        # the replication checker.
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)

        # flatten tokens across (B, S) — decode has S=1, so route over B
        t = b * s
        expert_of = gate_idx.reshape(t, k)
        weight_of = gate_w.reshape(t, k)
        flat_x = xf.reshape(t, d)
        big = jnp.int32(t * k + 1)
        slot_pos = jnp.arange(t * k, dtype=jnp.int32)
        tok_of = slot_pos // k
        my_e0 = jax.lax.axis_index("model") * e_loc

        out = jnp.zeros((t, d), jnp.float32)
        for j in range(e_loc):
            ei = my_e0 + j
            prio = jnp.where(expert_of.reshape(-1) == ei, slot_pos, big)
            order = jnp.argsort(prio)[:cap]
            valid = jnp.take(prio, order) < big
            tok = jnp.take(tok_of, order)
            wgt = jnp.take(weight_of.reshape(-1), order) * valid
            xe = flat_x[tok]  # (cap, d)
            h = act(jnp.einsum("cd,df->cf", xe, pm["w_gate"][j]))
            h = h * jnp.einsum("cd,df->cf", xe, pm["w_up"][j])
            ye = jnp.einsum("cf,fd->cd", h, pm["w_down"][j])  # partial over ff
            out = out.at[tok].add(ye.astype(jnp.float32) * wgt[:, None])
        # reduce ff-partials over data, then expert-partials over model
        for ax in dp:
            out = jax.lax.psum(out, ax)
        out = jax.lax.psum(out, "model")
        out = out.reshape(b, s, d).astype(xf.dtype)
        # return this rank's data slice
        b_loc = xx.shape[0]
        i0 = 0
        mul = 1
        for ax in reversed(dp):
            i0 = i0 + jax.lax.axis_index(ax) * mul
            mul = mul * mesh.shape[ax]
        out = jax.lax.dynamic_slice_in_dim(out, i0 * b_loc, b_loc, axis=0)
        return out, aux

    out, aux = shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspecs, xspec),
        out_specs=(xspec, P()),
    )(p, x)
    return out, aux


def moe_apply(cfg, p, x: Array) -> tuple[Array, Array]:
    """Token-choice top-k MoE with capacity dropping.

    x: (B, S, d) -> (out, aux_loss). Routing groups are batch rows, so all
    dispatch gathers/scatters are local to the "data" mesh axis.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    cap = max(1, int(s * k / e * cfg.moe_capacity_factor))
    act = _act(cfg.ffn_act)

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)  # (B,S,k)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    # Load-balancing aux loss (Switch eq. 4-6).
    frac_tokens = jnp.mean(
        jax.nn.one_hot(gate_idx, e, dtype=jnp.float32), axis=(1, 2)
    )  # (B, E)
    frac_probs = jnp.mean(probs, axis=1)  # (B, E)
    aux = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    # Flatten the (S, k) assignment slots in token order.
    slots = s * k
    expert_of = gate_idx.reshape(b, slots)
    tok_of = jnp.repeat(jnp.arange(s), k)[None, :].astype(jnp.int32)  # (1, slots)
    weight_of = gate_w.reshape(b, slots)

    big = jnp.int32(slots + 1)
    slot_pos = jnp.arange(slots, dtype=jnp.int32)[None, :]
    out = jnp.zeros((b, s, d), x.dtype)
    batch_ix = jnp.arange(b)[:, None]
    for ei in range(e):  # unrolled: E is a small static constant (8 / 16)
        prio = jnp.where(expert_of == ei, slot_pos, big)
        order = jnp.argsort(prio, axis=-1)[:, :cap]  # first `cap` slots, token order
        sel_prio = jnp.take_along_axis(prio, order, axis=-1)
        valid = sel_prio < big  # (B, cap)
        tok = jnp.take_along_axis(jnp.broadcast_to(tok_of, (b, slots)), order, axis=-1)
        wgt = jnp.take_along_axis(weight_of, order, axis=-1) * valid  # drops overflow
        xe = x[batch_ix, tok]  # (B, cap, d) gather
        h = act(jnp.einsum("bcd,df->bcf", xe, p["w_gate"][ei]))
        h = h * jnp.einsum("bcd,df->bcf", xe, p["w_up"][ei])
        ye = jnp.einsum("bcf,fd->bcd", h, p["w_down"][ei])
        out = out.at[batch_ix, tok].add((ye * wgt[..., None]).astype(x.dtype))
    return out, aux.astype(jnp.float32)
