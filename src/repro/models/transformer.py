"""Layer blocks and scan-over-layers stacks for every assigned family.

Every stack runs its (stacked-leaf) layer parameters through one
``jax.lax.scan`` with ``jax.checkpoint`` on the body, so HLO size and
compile time are O(1) in depth and activation memory is O(sqrt-ish) via
rematerialization — required for 100-layer archs on the 1-core compile
budget and for the 512-device dry-run (DESIGN.md §5).

Mixed layer patterns (Gemma-2 local/global alternation, Hymba's mostly
local pattern) pass a per-layer flag through scan ``xs`` and select between
two precomputed masks with ``lax.select`` — no double compute. The local
band mask is built by the paper's dilation primitive (core.masks).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import ffn, ssm
from repro.models.layers import norm_apply, norm_init

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Per-layer blocks (params are single-layer slices inside scan)
# ---------------------------------------------------------------------------


def dense_block(cfg, p, x, *, mask, positions):
    x = x + attn.self_attention(
        cfg, p["attn"], norm_apply(cfg, x, p["ln_attn"]), mask=mask, positions=positions
    )
    x = x + ffn.mlp_apply(cfg, p["mlp"], norm_apply(cfg, x, p["ln_mlp"]))
    return x


def moe_block(cfg, p, x, *, mask, positions):
    x = x + attn.self_attention(
        cfg, p["attn"], norm_apply(cfg, x, p["ln_attn"]), mask=mask, positions=positions
    )
    out, aux = ffn.moe_apply(cfg, p["moe"], norm_apply(cfg, x, p["ln_mlp"]))
    return x + out, aux


def rwkv_block(cfg, p, x, state: ssm.RWKVState):
    out, state = ssm.rwkv_time_mix(cfg, p["tm"], norm_apply(cfg, x, p["ln_tm"]), state)
    x = x + out
    out, state = ssm.rwkv_channel_mix(cfg, p["cm"], norm_apply(cfg, x, p["ln_cm"]), state)
    return x + out, state


def hymba_block(cfg, p, x, *, mask, positions, mamba_state):
    n = norm_apply(cfg, x, p["ln_attn"])
    a = attn.self_attention(cfg, p["attn"], n, mask=mask, positions=positions)
    m, mamba_state = ssm.mamba_apply(cfg, p["mamba"], n, mamba_state)
    fused = 0.5 * (
        norm_apply(cfg, a, p["ln_a_out"]) + norm_apply(cfg, m, p["ln_m_out"])
    )
    x = x + fused
    x = x + ffn.mlp_apply(cfg, p["mlp"], norm_apply(cfg, x, p["ln_mlp"]))
    return x, mamba_state


def encdec_block(cfg, p, x, *, self_mask, ctx, positions):
    x = x + attn.self_attention(
        cfg, p["attn"], norm_apply(cfg, x, p["ln_attn"]), mask=self_mask, positions=positions
    )
    x = x + attn.cross_attention(cfg, p["xattn"], norm_apply(cfg, x, p["ln_xattn"]), ctx)
    x = x + ffn.mlp_apply(cfg, p["mlp"], norm_apply(cfg, x, p["ln_mlp"]))
    return x


# ---------------------------------------------------------------------------
# Layer-parameter initializers (stacked leading dim = num_layers)
# ---------------------------------------------------------------------------


def _block_init(cfg, key, dtype, n_layers: int, *, kind: str) -> dict:
    ks = jax.random.split(key, 8)
    p = {"ln_attn": norm_init(cfg, dtype, stacked=n_layers),
         "ln_mlp": norm_init(cfg, dtype, stacked=n_layers)}
    p["attn"] = attn.attn_init(ks[0], cfg, dtype, stacked=n_layers)
    if kind == "dense":
        p["mlp"] = ffn.mlp_init(ks[1], cfg, dtype, stacked=n_layers)
    elif kind == "moe":
        p["moe"] = ffn.moe_init(ks[1], cfg, dtype, stacked=n_layers)
    elif kind == "hymba":
        p["mlp"] = ffn.mlp_init(ks[1], cfg, dtype, stacked=n_layers)
        p["mamba"] = ssm.mamba_init(ks[2], cfg, dtype, stacked=n_layers)
        p["ln_a_out"] = norm_init(cfg, dtype, stacked=n_layers)
        p["ln_m_out"] = norm_init(cfg, dtype, stacked=n_layers)
    elif kind == "encdec":
        p["mlp"] = ffn.mlp_init(ks[1], cfg, dtype, stacked=n_layers)
        p["xattn"] = attn.attn_init(ks[3], cfg, dtype, stacked=n_layers)
        p["ln_xattn"] = norm_init(cfg, dtype, stacked=n_layers)
    return p


def stack_init(cfg, key, dtype, n_layers: int, *, kind: str) -> dict:
    if kind == "rwkv":
        ks = jax.random.split(key, 2)
        tm = ssm.rwkv_init(ks[0], cfg, dtype, stacked=n_layers)
        cm = {k: tm.pop(k) for k in list(tm) if k.startswith("cm_")}
        return {
            "ln_tm": norm_init(cfg, dtype, stacked=n_layers),
            "ln_cm": norm_init(cfg, dtype, stacked=n_layers),
            "tm": tm,
            "cm": cm,
        }
    return _block_init(cfg, key, dtype, n_layers, kind=kind)


# ---------------------------------------------------------------------------
# Masks and layer patterns
# ---------------------------------------------------------------------------


def layer_is_local(cfg) -> Optional[Array]:
    """Per-layer bool flags for mixed local/global patterns (None = uniform)."""
    L = cfg.num_layers
    if cfg.layer_pattern == "local_global":
        return jnp.arange(L) % 2 == 0  # even layers local (Gemma-2 style)
    if cfg.layer_pattern == "local":
        # Hymba: global attention only at first / middle / last layer
        glob = jnp.zeros(L, bool).at[jnp.array([0, L // 2, L - 1])].set(True)
        return ~glob
    return None


def train_masks(cfg, s: int):
    """(global_mask, local_mask_or_None) for a training step of seq s."""
    g = attn.causal_mask(s, s)
    if cfg.local_window is None:
        return g, None
    l = attn.causal_mask(s, s, window=cfg.local_window)
    return g, l


# ---------------------------------------------------------------------------
# Scan-over-layers stacks (training / full-sequence forward)
# ---------------------------------------------------------------------------


# Activation-sharding hook: launch/dryrun.py (and real launchers) install a
# PartitionSpec here so the remat-saved layer-scan carry is sequence-sharded
# over the TP axis (Megatron-SP analog); None = no constraint (single host).
_ACT_SPEC = None

# Unroll hook: benchmarks/roofline.py probes lower tiny-depth configs with
# the layer scan *unrolled* so XLA cost_analysis counts every layer (a scan
# body is otherwise counted once regardless of trip count). Never set for
# real runs.
_UNROLL = False


def set_activation_spec(spec) -> None:
    global _ACT_SPEC
    _ACT_SPEC = spec


def set_unroll(v: bool) -> None:
    global _UNROLL
    _UNROLL = bool(v)


def unrolled() -> bool:
    return _UNROLL


# Banded-local-attention hook (§Perf iteration C): when set, local layers of
# local_global-pattern models compute block-banded attention (O(S*2W))
# instead of masked full attention (O(S^2)).
_BANDED = False


def set_banded_local(v: bool) -> None:
    global _BANDED
    _BANDED = bool(v)


# Remat-policy hook (§Perf iteration E): "full" rematerializes everything in
# the backward pass (min memory, ~1.5x forward flops extra); "dots" saves
# matmul outputs (jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
# trading saved-activation bytes for recompute flops.
_REMAT_POLICY = "full"


def set_remat_policy(name: str) -> None:
    global _REMAT_POLICY
    assert name in ("full", "dots")
    _REMAT_POLICY = name


def _checkpoint(fn):
    if _REMAT_POLICY == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _constrain(x):
    if _ACT_SPEC is not None and getattr(x, "ndim", 0) == 3 and x.shape[1] > 1:
        return jax.lax.with_sharding_constraint(x, _ACT_SPEC)
    return x


def _scan(body, carry, xs, n_layers):
    def wrapped(c, x):
        if isinstance(c, tuple):
            c = (_constrain(c[0]),) + c[1:]
        else:
            c = _constrain(c)
        return body(c, x)

    return jax.lax.scan(
        _checkpoint(wrapped), carry, xs, length=n_layers, unroll=_UNROLL
    )


def decoder_stack(cfg, stacked, x, *, positions, kind: str):
    """Full-seq forward for dense / moe / hymba / rwkv stacks.

    Returns (x, aux_loss, final_states) — states only for stateful kinds.
    """
    s = x.shape[1]
    gmask, lmask = train_masks(cfg, s)
    is_local = layer_is_local(cfg)

    if kind == "rwkv":
        state0 = ssm.rwkv_init_state(cfg, x.shape[0], x.dtype)

        def body(x, layer_p):
            x, _ = rwkv_block(cfg, layer_p, x, state0)
            return x, None

        x, _ = _scan(body, x, stacked, cfg.num_layers)
        return x, jnp.float32(0.0)

    if kind == "hymba":
        mstate0 = ssm.mamba_init_state(cfg, x.shape[0], x.dtype)

        def body(x, inp):
            layer_p, loc = inp
            mask = jax.lax.select(loc, lmask, gmask) if lmask is not None else gmask
            x, _ = hymba_block(
                cfg, layer_p, x, mask=mask, positions=positions, mamba_state=mstate0
            )
            return x, None

        x, _ = _scan(body, x, (stacked, is_local), cfg.num_layers)
        return x, jnp.float32(0.0)

    if kind == "moe":
        def body(carry, layer_p):
            x, aux = carry
            x, a = moe_block(cfg, layer_p, x, mask=gmask, positions=positions)
            return (x, aux + a), None

        (x, aux), _ = _scan(body, (x, jnp.float32(0.0)), stacked, cfg.num_layers)
        return x, aux / cfg.num_layers

    # dense (with optional local/global alternation)
    if (
        kind == "dense"
        and cfg.layer_pattern == "local_global"
        and _BANDED
        and cfg.num_layers % 2 == 0
        and s % (cfg.local_window or s + 1) == 0
    ):
        # §Perf iteration C: scan over (local, global) layer PAIRS so the
        # local layer runs block-banded attention with no select and no
        # double compute. Gemma-2 alternates strictly, so pairing is exact.
        paired = jax.tree.map(
            lambda a: a.reshape((cfg.num_layers // 2, 2) + a.shape[1:]), stacked
        )

        def body(x, pair_p):
            p_loc = jax.tree.map(lambda a: a[0], pair_p)
            p_glob = jax.tree.map(lambda a: a[1], pair_p)
            x = x + attn.local_attention_banded(
                cfg, p_loc["attn"], norm_apply(cfg, x, p_loc["ln_attn"]),
                positions=positions, window=cfg.local_window,
            )
            x = x + ffn.mlp_apply(cfg, p_loc["mlp"], norm_apply(cfg, x, p_loc["ln_mlp"]))
            x = dense_block(cfg, p_glob, x, mask=gmask, positions=positions)
            return x, None

        x, _ = _scan(body, x, paired, cfg.num_layers // 2)
        return x, jnp.float32(0.0)

    if is_local is None:
        def body(x, layer_p):
            return dense_block(cfg, layer_p, x, mask=gmask, positions=positions), None

        x, _ = _scan(body, x, stacked, cfg.num_layers)
    else:
        def body(x, inp):
            layer_p, loc = inp
            mask = jax.lax.select(loc, lmask, gmask)
            return dense_block(cfg, layer_p, x, mask=mask, positions=positions), None

        x, _ = _scan(body, x, (stacked, is_local), cfg.num_layers)
    return x, jnp.float32(0.0)


def encoder_stack(cfg, stacked, x):
    """Bidirectional encoder (Whisper): full mask, no RoPE (sinusoid added
    by caller)."""
    mask = jnp.ones((1, 1, 1, x.shape[1], x.shape[1]), bool)
    positions = jnp.arange(x.shape[1])[None]

    def body(x, layer_p):
        return dense_block(cfg, layer_p, x, mask=mask, positions=positions), None

    x, _ = _scan(body, x, stacked, cfg.num_encoder_layers)
    return x


def encdec_decoder_stack(cfg, stacked, x, ctx, *, positions):
    s = x.shape[1]
    mask = attn.causal_mask(s, s)

    def body(x, layer_p):
        return encdec_block(cfg, layer_p, x, self_mask=mask, ctx=ctx, positions=positions), None

    x, _ = _scan(body, x, stacked, cfg.num_layers)
    return x, jnp.float32(0.0)


def vlm_stack(cfg, stacked, x, image_ctx, *, positions):
    """Llama-3.2-Vision: scan over groups of (cross_attn_every - 1) self
    layers + 1 self-layer followed by image cross-attention."""
    s = x.shape[1]
    mask = attn.causal_mask(s, s)
    per = cfg.cross_attn_every
    groups = cfg.num_layers // per

    def body(x, group_p):
        for i in range(per - 1):
            layer_p = jax.tree.map(lambda a: a[i], group_p["self"])
            x = dense_block(cfg, layer_p, x, mask=mask, positions=positions)
        x = dense_block(cfg, group_p["last_self"], x, mask=mask, positions=positions)
        x = x + attn.cross_attention(
            cfg, group_p["xattn"], norm_apply(cfg, x, group_p["ln_xattn"]), image_ctx
        )
        return x, None

    x, _ = _scan(body, x, stacked, groups)
    return x, jnp.float32(0.0)


def vlm_stack_init(cfg, key, dtype) -> dict:
    per = cfg.cross_attn_every
    groups = cfg.num_layers // per
    ks = jax.random.split(key, 4)
    inner = _block_init(cfg, ks[0], dtype, groups, kind="dense")
    # add an inner (per-1) dim by re-initializing with groups*(per-1) and reshaping
    flat = _block_init(cfg, ks[1], dtype, groups * (per - 1), kind="dense")
    self_p = jax.tree.map(
        lambda a: a.reshape((groups, per - 1) + a.shape[1:]), flat
    )
    return {
        "self": self_p,
        "last_self": inner,
        "xattn": attn.attn_init(ks[2], cfg, dtype, stacked=groups),
        "ln_xattn": norm_init(cfg, dtype, stacked=groups),
    }
