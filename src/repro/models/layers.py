"""Primitive NN layers as pure functions over parameter pytrees.

No flax/haiku in this environment, so parameters are plain nested dicts of
jnp arrays. Initializers build *stacked* per-layer leaves (leading dim =
num_layers) so the transformer stack runs under one ``lax.scan`` — this
keeps HLO size O(1) in depth, which both the 1-core compile budget and the
512-device dry-run depend on (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


def truncnorm(key, shape, dtype, scale=0.02):
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(dtype)


def dense_init(key, in_dim, out_dims, dtype, *, stacked: int | None = None):
    """Weight of shape (in_dim, *out_dims), optionally layer-stacked."""
    shape = (in_dim,) + tuple(np.atleast_1d(out_dims))
    if stacked is not None:
        shape = (stacked,) + shape
    return truncnorm(key, shape, dtype, scale=0.02 / np.sqrt(max(in_dim / 1024, 1)))


def rmsnorm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_apply(cfg, x: Array, p: dict) -> Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def norm_init(cfg, dtype, *, stacked: int | None = None) -> dict:
    shape = (cfg.d_model,) if stacked is None else (stacked, cfg.d_model)
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones(shape, dtype), "bias": jnp.zeros(shape, dtype)}
    return {"scale": jnp.zeros(shape, dtype)}  # rmsnorm stores (scale - 1)


def softcap(x: Array, cap: float | None) -> Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)  # (D/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoid_embed(seq: int, d: int) -> Array:
    """Fixed sinusoidal position table (Whisper encoder)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    angle = pos / (10_000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_init(key, cfg, dtype) -> dict:
    p = {"embedding": truncnorm(key, (cfg.vocab_size, cfg.d_model), dtype, 0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = truncnorm(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size), dtype, 0.02
        )
    return p


def embed(p: dict, tokens: Array, cfg) -> Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    # Gemma-style sqrt(d) scaling is harmless for the others at init scale.
    return (x.astype(jnp.float32) * jnp.sqrt(float(cfg.d_model))).astype(x.dtype)


def unembed(p: dict, x: Array, cfg) -> Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("...d,vd->...v", x, p["embedding"])
    else:
        logits = jnp.einsum("...d,dv->...v", x, p["unembed"])
    return softcap(logits, cfg.logit_softcap)
