"""Model zoo: the 10 assigned architectures as composable JAX modules."""
from repro.models.config import ARCH_IDS, ModelConfig, get_config, register
