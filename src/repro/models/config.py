"""Model configuration schema + registry for the assigned architectures.

One frozen dataclass describes every family (dense / MoE / SSM / hybrid /
enc-dec / VLM); per-arch instances live in src/repro/configs/<id>.py and
register themselves here. ``reduced()`` derives the CPU smoke-test config
from the full one (same family and wiring, tiny dims), so smoke tests
exercise the exact code path the dry-run compiles at full scale.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

_REGISTRY: dict[str, "ModelConfig"] = {}

ARCH_IDS = [
    "gemma-7b",
    "gemma2-2b",
    "qwen2.5-3b",
    "qwen1.5-0.5b",
    "rwkv6-7b",
    "grok-1-314b",
    "dbrx-132b",
    "whisper-medium",
    "hymba-1.5b",
    "llama-3.2-vision-90b",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention variants
    ffn_act: str = "swiglu"  # swiglu | geglu | gelu
    qkv_bias: bool = False
    logit_softcap: Optional[float] = None
    attn_softcap: Optional[float] = None
    local_window: Optional[int] = None  # sliding-window width for local layers
    layer_pattern: str = "global"  # global | local_global (alternating) | local
    rope_theta: Optional[float] = 10_000.0
    pos_embed: str = "rope"  # rope | absolute (learned dec + sinusoidal enc)
    max_position: int = 0  # only for pos_embed == "absolute"

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    lora_rank: int = 32  # RWKV-6 data-dependent decay LoRA rank

    # enc-dec (Whisper)
    num_encoder_layers: int = 0
    encoder_seq: int = 1500

    # VLM (Llama-3.2-Vision): one cross-attn layer every N self-attn layers
    cross_attn_every: int = 0
    num_image_tokens: int = 0

    # misc
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    notes: str = ""

    # ---- derived ----
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encoder_decoder(self) -> bool:
        return self.family == "encdec"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (DESIGN.md §Arch-applicability)."""
        return self.family in ("ssm", "hybrid")

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads))
        return dataclasses.replace(
            self,
            num_layers=2 if self.cross_attn_every == 0 else max(2, self.cross_attn_every),
            d_model=64,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            lora_rank=8,
            num_encoder_layers=2 if self.num_encoder_layers else 0,
            encoder_seq=16 if self.num_encoder_layers else self.encoder_seq,
            cross_attn_every=2 if self.cross_attn_every else 0,
            num_image_tokens=8 if self.num_image_tokens else 0,
            local_window=8 if self.local_window else None,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Approximate parameter count (used for MODEL_FLOPS = 6 N D)."""
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        attn = d * self.num_heads * self.head_dim + 2 * d * self.num_kv_heads * self.head_dim
        attn += self.num_heads * self.head_dim * d
        if self.ffn_act in ("swiglu", "geglu"):
            mlp = 3 * d * ff
        else:
            mlp = 2 * d * ff
        if self.num_experts:
            mlp *= self.num_experts
            mlp += d * self.num_experts  # router
        per_layer = attn + mlp if self.family != "ssm" else (
            # rwkv6: time-mix (r,k,v,g,o ~ 5 d^2) + channel-mix (3 d ff is stored as 2)
            5 * d * d + 2 * d * ff
        )
        if self.family == "hybrid":
            per_layer = attn + mlp + 2 * d * d * self.ssm_expand
        total = L * per_layer + self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        if self.num_encoder_layers:
            total += self.num_encoder_layers * (attn + mlp) + L * attn  # enc + cross
        if self.cross_attn_every:
            total += (L // self.cross_attn_every) * attn
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE counts top-k experts only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        mats = 3 if self.ffn_act in ("swiglu", "geglu") else 2
        mlp_all = mats * d * ff * self.num_experts * L
        mlp_active = mats * d * ff * self.num_experts_per_tok * L
        return int(full - mlp_all + mlp_active)


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        mod = name.replace("-", "_").replace(".", "_")
        importlib.import_module(f"repro.configs.{mod}")
    return _REGISTRY[name]
