"""Grouped-query attention with local/global masking and KV-cache decode.

Covers every attention variant in the assigned pool: MHA (kv == heads),
GQA (Gemma/Qwen/Grok/DBRX/Hymba/Llama-V), MQA, QKV bias (Qwen), attention
logit soft-capping (Gemma-2), sliding-window local layers (Gemma-2/Hymba,
masks built by core.masks.band_mask — i.e. by the paper's dilation
primitive), RoPE or absolute positions, and cross-attention (Whisper
decoder, Llama-3.2-Vision image layers).

Decode path: cache allocated at full kv_len per layer, updated with
``dynamic_update_slice`` at the current position; sliding-window layers
reuse the same cache with a band mask (ring-buffer compaction is a §Perf
memory optimization, deliberately not the baseline).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init, softcap, truncnorm

Array = jnp.ndarray


class KVCache(NamedTuple):
    k: Array  # (B, T, Kv, D)
    v: Array  # (B, T, Kv, D)


def attn_init(key, cfg, dtype, *, stacked=None, kv_dim=None) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    kd = kv_dim or d
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (h, hd), dtype, stacked=stacked),
        "wk": dense_init(ks[1], kd, (kv, hd), dtype, stacked=stacked),
        "wv": dense_init(ks[2], kd, (kv, hd), dtype, stacked=stacked),
        "wo": dense_init(ks[3], h * hd, (d,), dtype, stacked=stacked),
    }
    if cfg.qkv_bias:
        shape = lambda *s: ((stacked,) + s) if stacked is not None else s
        p["bq"] = jnp.zeros(shape(h, hd), dtype)
        p["bk"] = jnp.zeros(shape(kv, hd), dtype)
        p["bv"] = jnp.zeros(shape(kv, hd), dtype)
    return p


def _project_qkv(cfg, p, x, kv_src):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", kv_src, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", kv_src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


def _sdpa(cfg, q, k, v, mask):
    """q: (B,S,H,D) k/v: (B,T,Kv,D) mask: broadcast to (B,Kv,G,S,T)."""
    b, s, h, d = q.shape
    kv = k.shape[2]
    g = h // kv
    q = q.reshape(b, s, kv, g, d) * (d ** -0.5)
    scores = jnp.einsum("bskgd,btkd->bkgst", q, k).astype(jnp.float32)
    scores = softcap(scores, cfg.attn_softcap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v)
    return out.reshape(b, s, h * d)


def causal_mask(s: int, t: int, *, window: Optional[int] = None) -> Array:
    """(1,1,1,S,T) causal (optionally banded/sliding-window) mask.

    query i attends key j iff j <= i + (t - s) and (window is None or
    j > i + (t - s) - window).
    """
    qi = jnp.arange(s)[:, None] + (t - s)
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window is not None:
        m &= kj > qi - window
    return m[None, None, None]


def self_attention(cfg, p, x, *, mask, positions) -> Array:
    q, k, v = _project_qkv(cfg, p, x, x)
    if cfg.rope_theta is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bse,ed->bsd", out, p["wo"])


def local_attention_banded(cfg, p, x, *, positions, window: int) -> Array:
    """Block-banded sliding-window attention (§Perf iteration C).

    The baseline computes full (S, S) scores and masks outside the band —
    the same waste the paper's linear pass avoids by touching only the
    window. Queries are chunked into window-sized blocks; each block
    attends only to itself + the previous block (2W keys), which covers
    every in-window key exactly. FLOPs and score memory drop from
    O(S^2) to O(S * 2W) per layer.
    """
    b, s, d = x.shape
    w = window
    if s % w or s <= w:
        mask = causal_mask(s, s, window=w)
        return self_attention(cfg, p, x, mask=mask, positions=positions)
    q, k, v = _project_qkv(cfg, p, x, x)
    if cfg.rope_theta is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    h, dd = q.shape[-2], q.shape[-1]
    kv = k.shape[2]
    g = h // kv
    c = s // w
    qc = q.reshape(b, c, w, kv, g, dd) * (dd ** -0.5)
    kc = k.reshape(b, c, w, kv, dd)
    vc = v.reshape(b, c, w, kv, dd)
    pad = [(0, 0)] * 5
    pad[1] = (1, 0)
    kprev = jnp.pad(kc, pad)[:, :-1]
    vprev = jnp.pad(vc, pad)[:, :-1]
    kk = jnp.concatenate([kprev, kc], axis=2)  # (b, c, 2w, kv, dd)
    vv = jnp.concatenate([vprev, vc], axis=2)

    scores = jnp.einsum("bcikgd,bctkd->bckgit", qc, kk).astype(jnp.float32)
    scores = softcap(scores, cfg.attn_softcap)
    # rel position of key t vs query i within the chunk: jg - ig = t - w - i
    i = jnp.arange(w)[:, None]
    t = jnp.arange(2 * w)[None, :]
    rel = t - w - i
    band = (rel <= 0) & (rel > -w)  # causal, within window
    # first chunk has no previous block: its first w key slots are padding
    chunk_ok = (jnp.arange(c)[:, None, None] > 0) | (t[None] >= w)
    mask = band[None] & chunk_ok  # (c, w, 2w)
    scores = jnp.where(mask[None, :, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bckgit,bctkd->bcikgd", probs, vv)
    out = out.reshape(b, s, h * dd)
    return jnp.einsum("bse,ed->bsd", out, p["wo"])


def cross_attention(cfg, p, x, ctx, *, mask=None) -> Array:
    q, k, v = _project_qkv(cfg, p, x, ctx)
    if mask is None:
        mask = jnp.ones((1, 1, 1, 1, 1), bool)
    out = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bse,ed->bsd", out, p["wo"])


def cross_kv(cfg, p, ctx):
    """Precompute cross-attention K/V for a fixed context (decode path)."""
    k = jnp.einsum("btd,dhk->bthk", ctx, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", ctx, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def cross_attention_kv(cfg, p, x, k, v) -> Array:
    """Cross-attention against precomputed K/V (decode path)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    mask = jnp.ones((1, 1, 1, 1, 1), bool)
    out = _sdpa(cfg, q, k, v, mask)
    return jnp.einsum("bse,ed->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# Decode (single-token) path
# ---------------------------------------------------------------------------


def init_cache(cfg, batch: int, kv_len: int, dtype) -> KVCache:
    shape = (batch, kv_len, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def decode_attention_quant(cfg, p, x, k8, v8, k_scale, v_scale, pos: Array,
                           *, window=None):
    """Decode against an int8-quantized KV cache (§Perf iteration B2).

    Per-token-per-head symmetric quantization: scale = max|k|/127 over
    head_dim (KIVI-style per-token). Halves cache HBM traffic — the
    dominant roofline term of MHA decode. Dequantization fuses into the
    attention contractions.

    k8/v8: (B, T, Kv, D) int8; *_scale: (B, T, Kv, 1) f32.
    """
    q, k_new, v_new = _project_qkv(cfg, p, x, x)
    if cfg.rope_theta is not None:
        positions = pos[None].astype(jnp.int32) * jnp.ones((x.shape[0], 1), jnp.int32)
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)

    def quant(t):
        s = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
        s = jnp.maximum(s, 1e-8)
        return jnp.clip(jnp.round(t / s), -127, 127).astype(jnp.int8), s

    kq, ks = quant(k_new)
    vq, vs = quant(v_new)
    k8 = jax.lax.dynamic_update_slice(k8, kq, (0, pos, 0, 0))
    v8 = jax.lax.dynamic_update_slice(v8, vq, (0, pos, 0, 0))
    k_scale = jax.lax.dynamic_update_slice(
        k_scale, ks.astype(k_scale.dtype), (0, pos, 0, 0))
    v_scale = jax.lax.dynamic_update_slice(
        v_scale, vs.astype(v_scale.dtype), (0, pos, 0, 0))

    t = k8.shape[1]
    kj = jnp.arange(t)
    valid = kj <= pos
    if window is not None:
        valid &= kj > pos - window
    mask = valid[None, None, None, None, :]

    b, s_, h, d = q.shape
    kv = k8.shape[2]
    g = h // kv
    qr = q.reshape(b, s_, kv, g, d) * (d ** -0.5)
    # dequant fused into the contractions (int8 read, f32 math)
    scores = jnp.einsum(
        "bskgd,btkd->bkgst", qr.astype(jnp.float32),
        k8.astype(jnp.float32) * k_scale.astype(jnp.float32))
    scores = softcap(scores, cfg.attn_softcap)
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bkgst,btkd->bskgd", probs,
        v8.astype(jnp.float32) * v_scale.astype(jnp.float32))
    out = out.reshape(b, s_, h * d).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return out, (k8, v8, k_scale, v_scale)


def decode_attention(cfg, p, x, cache: KVCache, pos: Array, *, window=None):
    """x: (B, 1, d); pos: scalar int32 — absolute position of this token."""
    q, k_new, v_new = _project_qkv(cfg, p, x, x)
    if cfg.rope_theta is not None:
        positions = pos[None].astype(jnp.int32) * jnp.ones((x.shape[0], 1), jnp.int32)
        q = apply_rope(q, positions, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rope_theta)
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, pos, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, pos, 0, 0))
    t = k.shape[1]
    kj = jnp.arange(t)
    valid = kj <= pos
    if window is not None:
        valid &= kj > pos - window
    mask = valid[None, None, None, None, :]
    out = _sdpa(cfg, q, k, v, mask)
    out = jnp.einsum("bse,ed->bsd", out, p["wo"])
    return out, KVCache(k, v)
