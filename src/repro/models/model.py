"""Model assembly: init / train forward / decode step for all 10 archs.

Public surface:
  init_params(cfg, key)                    -> param pytree (stacked layers)
  forward_train(cfg, params, batch)        -> (logits, aux_loss)
  loss_fn(cfg, params, batch)              -> (loss, metrics)
  init_decode_cache(cfg, batch, kv_len)    -> cache pytree
  serve_step(cfg, params, cache, token, pos) -> (logits, new_cache)

``batch`` for training: {"tokens": (B,S) i32, "labels": (B,S) i32} plus
family extras — "encoder_frames" (B,Tenc,d) for Whisper (conv frontend is a
stub: precomputed frame embeddings per the assignment), and
"image_embeddings" (B,Nimg,d) for Llama-3.2-Vision (patch frontend stub).

Decode caches are layer-stacked pytrees scanned together with the layer
params, so the decode step is also O(1) HLO in depth.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import ffn, ssm, transformer as tfm
from repro.models.config import ModelConfig
from repro.models.layers import (
    embed,
    embed_init,
    norm_apply,
    norm_init,
    sinusoid_embed,
    truncnorm,
    unembed,
)

Array = jnp.ndarray


def _dtype(cfg) -> Any:
    return jnp.dtype(cfg.dtype)


def _kind(cfg) -> str:
    return {
        "dense": "dense",
        "moe": "moe",
        "ssm": "rwkv",
        "hybrid": "hymba",
        "encdec": "encdec",
        "vlm": "vlm",
    }[cfg.family]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> dict:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    p = {
        "embed": embed_init(ks[0], cfg, dt),
        "ln_final": norm_init(cfg, dt),
    }
    kind = _kind(cfg)
    if kind == "vlm":
        p["layers"] = tfm.vlm_stack_init(cfg, ks[1], dt)
    elif kind == "encdec":
        p["layers"] = tfm.stack_init(cfg, ks[1], dt, cfg.num_layers, kind="encdec")
        p["enc_layers"] = tfm.stack_init(
            cfg, ks[2], dt, cfg.num_encoder_layers, kind="dense"
        )
        p["ln_enc_final"] = norm_init(cfg, dt)
        p["pos_embed"] = truncnorm(ks[3], (cfg.max_position, cfg.d_model), dt, 0.01)
    else:
        p["layers"] = tfm.stack_init(cfg, ks[1], dt, cfg.num_layers, kind=kind)
    return p


# ---------------------------------------------------------------------------
# Training forward
# ---------------------------------------------------------------------------


def _encode(cfg, params, frames: Array) -> Array:
    """Whisper encoder over precomputed (stub) frame embeddings."""
    x = frames + sinusoid_embed(frames.shape[1], cfg.d_model).astype(frames.dtype)
    x = tfm.encoder_stack(cfg, params["enc_layers"], x)
    return norm_apply(cfg, x, params["ln_enc_final"])


def forward_train(cfg: ModelConfig, params, batch) -> tuple[Array, Array]:
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    kind = _kind(cfg)

    if kind == "encdec":
        ctx = _encode(cfg, params, batch["encoder_frames"])
        x = x + params["pos_embed"][None, :s].astype(x.dtype)
        x, aux = tfm.encdec_decoder_stack(cfg, params["layers"], x, ctx, positions=positions)
    elif kind == "vlm":
        x, aux = tfm.vlm_stack(
            cfg, params["layers"], x, batch["image_embeddings"], positions=positions
        )
    else:
        x, aux = tfm.decoder_stack(cfg, params["layers"], x, positions=positions, kind=kind)

    x = norm_apply(cfg, x, params["ln_final"])
    return unembed(params["embed"], x, cfg), aux


def _hidden_for_loss(cfg: ModelConfig, params, batch):
    """Shared trunk of forward_train without the unembedding."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    kind = _kind(cfg)
    if kind == "encdec":
        ctx = _encode(cfg, params, batch["encoder_frames"])
        x = x + params["pos_embed"][None, :s].astype(x.dtype)
        x, aux = tfm.encdec_decoder_stack(cfg, params["layers"], x, ctx, positions=positions)
    elif kind == "vlm":
        x, aux = tfm.vlm_stack(
            cfg, params["layers"], x, batch["image_embeddings"], positions=positions
        )
    else:
        x, aux = tfm.decoder_stack(cfg, params["layers"], x, positions=positions, kind=kind)
    return norm_apply(cfg, x, params["ln_final"]), aux


def loss_fn(cfg: ModelConfig, params, batch, *, aux_weight: float = 0.01,
            loss_chunk: int = 512):
    """Cross-entropy with *chunked* unembedding.

    The full (B, S, V) logits tensor is never materialized: the sequence is
    scanned in chunks of ``loss_chunk`` and each chunk's logits are
    rematerialized in the backward pass (fused-softmax-CE convention —
    without this, gemma-7b at B=256 / S=4k / V=256k would need ~1 TB of
    transient logits).
    """
    x, aux = _hidden_for_loss(cfg, params, batch)
    labels = batch["labels"]
    b, s = labels.shape
    c = min(loss_chunk, s)
    if s % c:
        c = s  # fall back to unchunked for odd small seqs
    nchunk = s // c
    xs = x.reshape(b, nchunk, c, -1).swapaxes(0, 1)  # (nchunk, B, c, d)
    ls = labels.reshape(b, nchunk, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_ll(xc, lc):
        logits = unembed(params["embed"], xc, cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return jnp.take_along_axis(logp, lc[..., None], axis=-1)[..., 0].sum()

    def body(acc, inp):
        xc, lc = inp
        return acc + chunk_ll(xc, lc), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xs, ls),
                            unroll=tfm.unrolled())
    ce = -total / (b * s)
    loss = ce + aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


class DecodeCache(NamedTuple):
    """Union cache — unused fields are () placeholders per family.

    ``k_scale``/``v_scale`` are populated only for the int8-quantized KV
    cache (§Perf iteration B2)."""

    k: Any = ()
    v: Any = ()
    rwkv: Any = ()
    mamba: Any = ()
    cross_k: Any = ()
    cross_v: Any = ()
    k_scale: Any = ()
    v_scale: Any = ()


def init_decode_cache(cfg: ModelConfig, batch: int, kv_len: int,
                      *, kv_cache_dtype=None) -> DecodeCache:
    dt = _dtype(cfg)
    L = cfg.num_layers
    kind = _kind(cfg)

    if kv_cache_dtype == "int8" and kind in ("dense", "moe"):
        shape = (L, batch, kv_len, cfg.num_kv_heads, cfg.head_dim)
        sshape = shape[:-1] + (1,)
        return DecodeCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.zeros(sshape, jnp.float32),
            v_scale=jnp.zeros(sshape, jnp.float32),
        )

    def kv(n_layers, t):
        shape = (n_layers, batch, t, cfg.num_kv_heads, cfg.head_dim)
        return jnp.zeros(shape, dt), jnp.zeros(shape, dt)

    if kind == "rwkv":
        st = ssm.rwkv_init_state(cfg, batch, dt)
        return DecodeCache(
            rwkv=jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), st)
        )
    if kind == "hymba":
        k, v = kv(L, kv_len)
        ms = ssm.mamba_init_state(cfg, batch, dt)
        return DecodeCache(
            k=k, v=v,
            mamba=jax.tree.map(lambda a: jnp.broadcast_to(a[None], (L,) + a.shape), ms),
        )
    if kind == "encdec":
        k, v = kv(L, kv_len)
        ck = jnp.zeros((L, batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim), dt)
        return DecodeCache(k=k, v=v, cross_k=ck, cross_v=ck)
    if kind == "vlm":
        per, g = cfg.cross_attn_every, cfg.num_layers // cfg.cross_attn_every
        k_in = jnp.zeros((g, per - 1, batch, kv_len, cfg.num_kv_heads, cfg.head_dim), dt)
        k_last = jnp.zeros((g, batch, kv_len, cfg.num_kv_heads, cfg.head_dim), dt)
        ck = jnp.zeros((g, batch, cfg.num_image_tokens, cfg.num_kv_heads, cfg.head_dim), dt)
        return DecodeCache(
            k={"self": k_in, "last": k_last},
            v={"self": k_in, "last": k_last},
            cross_k=ck, cross_v=ck,
        )
    k, v = kv(L, kv_len)
    return DecodeCache(k=k, v=v)


def prefill_cross_kv(cfg, params, cache: DecodeCache, ctx: Array) -> DecodeCache:
    """Populate encoder/image cross-attention K/V (once per request)."""
    kind = _kind(cfg)
    if kind == "encdec":
        enc = _encode(cfg, params, ctx)
        ck, cv = jax.vmap(
            lambda p: attn_mod.cross_kv(cfg, p, enc)
        )(params["layers"]["xattn"])
        return cache._replace(cross_k=ck, cross_v=cv)
    if kind == "vlm":
        ck, cv = jax.vmap(
            lambda p: attn_mod.cross_kv(cfg, p, ctx)
        )(params["layers"]["xattn"])
        return cache._replace(cross_k=ck, cross_v=cv)
    return cache


def serve_step(cfg: ModelConfig, params, cache: DecodeCache, token: Array, pos: Array):
    """One decode step. token: (B, 1) i32; pos: scalar i32. -> (logits, cache)."""
    x = embed(params["embed"], token, cfg)
    kind = _kind(cfg)
    is_local = tfm.layer_is_local(cfg)
    win = cfg.local_window

    if kind == "rwkv":
        def body(x, inp):
            layer_p, st = inp
            x, st = tfm.rwkv_block(cfg, layer_p, x, st)
            return x, st

        def scan_body(x, inp):
            # token-level decode: seq dim of 1
            return body(x, inp)

        x2 = x
        x2, new_state = jax.lax.scan(scan_body, x2, (params["layers"], cache.rwkv), unroll=tfm.unrolled())
        x, new_cache = x2, cache._replace(rwkv=new_state)

    elif kind == "hymba":
        def body(x, inp):
            layer_p, k, v, mst, loc = inp
            kvc = attn_mod.KVCache(k, v)
            n = norm_apply(cfg, x, layer_p["ln_attn"])
            a, kvc = attn_mod.decode_attention(
                cfg, layer_p["attn"], n, kvc, pos,
                window=jnp.where(loc, win, 10**9) if win else None,
            )
            m, mst = ssm.mamba_apply(cfg, layer_p["mamba"], n, mst)
            fused = 0.5 * (
                norm_apply(cfg, a, layer_p["ln_a_out"])
                + norm_apply(cfg, m, layer_p["ln_m_out"])
            )
            x = x + fused
            x = x + ffn.mlp_apply(cfg, layer_p["mlp"], norm_apply(cfg, x, layer_p["ln_mlp"]))
            return x, (kvc.k, kvc.v, mst)

        x, (nk, nv, nms) = jax.lax.scan(
            body, x, (params["layers"], cache.k, cache.v, cache.mamba, is_local),
            unroll=tfm.unrolled(),
        )
        new_cache = cache._replace(k=nk, v=nv, mamba=nms)

    elif kind == "encdec":
        x = x + jax.lax.dynamic_slice_in_dim(
            params["pos_embed"], pos, 1, axis=0
        )[None].astype(x.dtype)

        def body(x, inp):
            layer_p, k, v, ck, cv = inp
            kvc = attn_mod.KVCache(k, v)
            a, kvc = attn_mod.decode_attention(
                cfg, layer_p["attn"], norm_apply(cfg, x, layer_p["ln_attn"]), kvc, pos
            )
            x = x + a
            x = x + attn_mod.cross_attention_kv(
                cfg, layer_p["xattn"], norm_apply(cfg, x, layer_p["ln_xattn"]), ck, cv
            )
            x = x + ffn.mlp_apply(cfg, layer_p["mlp"], norm_apply(cfg, x, layer_p["ln_mlp"]))
            return x, (kvc.k, kvc.v)

        x, (nk, nv) = jax.lax.scan(
            body, x, (params["layers"], cache.k, cache.v, cache.cross_k, cache.cross_v),
            unroll=tfm.unrolled(),
        )
        new_cache = cache._replace(k=nk, v=nv)

    elif kind == "vlm":
        per = cfg.cross_attn_every

        def self_decode(x, layer_p, k, v):
            kvc = attn_mod.KVCache(k, v)
            a, kvc = attn_mod.decode_attention(
                cfg, layer_p["attn"], norm_apply(cfg, x, layer_p["ln_attn"]), kvc, pos
            )
            x = x + a
            x = x + ffn.mlp_apply(cfg, layer_p["mlp"], norm_apply(cfg, x, layer_p["ln_mlp"]))
            return x, kvc

        def body(x, inp):
            group_p, ks, vs, kl, vl, ck, cv = inp
            new_ks, new_vs = [], []
            for i in range(per - 1):
                layer_p = jax.tree.map(lambda a: a[i], group_p["self"])
                x, kvc = self_decode(x, layer_p, ks[i], vs[i])
                new_ks.append(kvc.k)
                new_vs.append(kvc.v)
            x, kvc = self_decode(x, group_p["last_self"], kl, vl)
            x = x + attn_mod.cross_attention_kv(
                cfg, group_p["xattn"], norm_apply(cfg, x, group_p["ln_xattn"]), ck, cv
            )
            return x, (jnp.stack(new_ks), jnp.stack(new_vs), kvc.k, kvc.v)

        x, (nks, nvs, nkl, nvl) = jax.lax.scan(
            body,
            x,
            (
                params["layers"], cache.k["self"], cache.v["self"],
                cache.k["last"], cache.v["last"], cache.cross_k, cache.cross_v,
            ),
            unroll=tfm.unrolled(),
        )
        new_cache = cache._replace(
            k={"self": nks, "last": nkl}, v={"self": nvs, "last": nvl}
        )

    else:  # dense / moe decode
        quantized = getattr(cache.k, "dtype", None) == jnp.int8

        def mlp_part(x, layer_p):
            if kind == "moe":
                apply = ffn.moe_apply_ep if ffn.ep_enabled(cfg) else ffn.moe_apply
                out, _ = apply(
                    cfg, layer_p["moe"], norm_apply(cfg, x, layer_p["ln_mlp"]))
                return x + out
            return x + ffn.mlp_apply(
                cfg, layer_p["mlp"], norm_apply(cfg, x, layer_p["ln_mlp"]))

        def body(x, inp):
            if quantized:
                if is_local is not None:
                    layer_p, k, v, ks_, vs_, loc = inp
                    window = jnp.where(loc, win, 10**9)
                else:
                    layer_p, k, v, ks_, vs_ = inp
                    window = None
                a, kv_out = attn_mod.decode_attention_quant(
                    cfg, layer_p["attn"], norm_apply(cfg, x, layer_p["ln_attn"]),
                    k, v, ks_, vs_, pos, window=window,
                )
                x = mlp_part(x + a, layer_p)
                return x, kv_out
            if is_local is not None:
                layer_p, k, v, loc = inp
                window = jnp.where(loc, win, 10**9)
            else:
                layer_p, k, v = inp
                window = None
            kvc = attn_mod.KVCache(k, v)
            a, kvc = attn_mod.decode_attention(
                cfg, layer_p["attn"], norm_apply(cfg, x, layer_p["ln_attn"]), kvc, pos,
                window=window,
            )
            x = mlp_part(x + a, layer_p)
            return x, (kvc.k, kvc.v)

        xs = (params["layers"], cache.k, cache.v)
        if quantized:
            xs = xs + (cache.k_scale, cache.v_scale)
        if is_local is not None:
            xs = xs + (is_local,)
        if quantized:
            x, (nk, nv, nks, nvs) = jax.lax.scan(body, x, xs, unroll=tfm.unrolled())
            new_cache = cache._replace(k=nk, v=nv, k_scale=nks, v_scale=nvs)
        else:
            x, (nk, nv) = jax.lax.scan(body, x, xs, unroll=tfm.unrolled())
            new_cache = cache._replace(k=nk, v=nv)

    x = norm_apply(cfg, x, params["ln_final"])
    return unembed(params["embed"], x, cfg), new_cache
