"""Attention-free sequence mixers: RWKV-6 ("Finch") and a Mamba-style
selective SSM head (used by Hymba's parallel attn+mamba layers).

RWKV-6 (arXiv:2404.05892) per layer:
  time-mix: data-dependent token-shift lerp (ddlerp, 5 low-rank adapters),
  data-dependent per-channel decay w_t = exp(-exp(w0 + lora_w(x))),
  WKV state recurrence  S_t = diag(w_t) S_{t-1} + k_t^T v_t,
  out_t = r_t (S_{t-1} + diag(u) k_t^T v_t), per-head group-norm, silu(g) gate;
  channel-mix: squared-relu MLP with token-shift lerp.

Both recurrences run under ``lax.scan`` over time (one HLO step body);
the chunked-parallel formulation is a §Perf iteration. The scan carry is
exactly the decode state, so train and serve share the cell code.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, truncnorm

Array = jnp.ndarray


class RWKVState(NamedTuple):
    wkv: Array  # (B, H, Dk, Dv)
    x_tm: Array  # (B, d) previous token (time-mix shift)
    x_cm: Array  # (B, d) previous token (channel-mix shift)


def rwkv_heads(cfg):
    hd = 64
    assert cfg.d_model % hd == 0
    return cfg.d_model // hd, hd


def rwkv_init(key, cfg, dtype, *, stacked=None) -> dict:
    d, r = cfg.d_model, cfg.lora_rank
    h, hd = rwkv_heads(cfg)
    ks = jax.random.split(key, 12)
    st = lambda *s: ((stacked,) + s) if stacked is not None else s
    return {
        # ddlerp: base mus for (r, w, k, v, g) plus a shared low-rank adapter
        "mu_x": jnp.zeros(st(d), dtype),
        "mu": jnp.zeros(st(5, d), dtype),
        "ddlerp_a": truncnorm(ks[0], st(d, 5 * r), dtype, 0.02),
        "ddlerp_b": truncnorm(ks[1], st(5, r, d), dtype, 0.02),
        # decay: w0 + low-rank data-dependent part
        "w0": jnp.full(st(d), -6.0, dtype),
        "w_a": truncnorm(ks[2], st(d, 2 * r), dtype, 0.02),
        "w_b": truncnorm(ks[3], st(2 * r, d), dtype, 0.02),
        "u": truncnorm(ks[4], st(h, hd), dtype, 0.5),
        "wr": dense_init(ks[5], d, (d,), dtype, stacked=stacked),
        "wk": dense_init(ks[6], d, (d,), dtype, stacked=stacked),
        "wv": dense_init(ks[7], d, (d,), dtype, stacked=stacked),
        "wg": dense_init(ks[8], d, (d,), dtype, stacked=stacked),
        "wo": dense_init(ks[9], d, (d,), dtype, stacked=stacked),
        "ln_x_scale": jnp.ones(st(d), dtype),
        "ln_x_bias": jnp.zeros(st(d), dtype),
        # channel-mix
        "cm_mu_k": jnp.zeros(st(d), dtype),
        "cm_mu_r": jnp.zeros(st(d), dtype),
        "cm_wk": dense_init(ks[10], d, (cfg.d_ff,), dtype, stacked=stacked),
        "cm_wv": dense_init(ks[11], cfg.d_ff, (d,), dtype, stacked=stacked),
        "cm_wr": dense_init(jax.random.fold_in(ks[10], 7), d, (d,), dtype, stacked=stacked),
    }


def _group_norm(x, scale, bias, h, eps=64e-5):
    b, t, d = x.shape
    xg = x.reshape(b, t, h, d // h).astype(jnp.float32)
    mu = xg.mean(-1, keepdims=True)
    var = ((xg - mu) ** 2).mean(-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(b, t, d) * scale + bias).astype(x.dtype)


def rwkv_time_mix(cfg, p, x: Array, state: RWKVState):
    """x: (B, T, d). Returns (out, new_state)."""
    b, t, d = x.shape
    h, hd = rwkv_heads(cfg)
    r = cfg.lora_rank

    x_prev = jnp.concatenate([state.x_tm[:, None], x[:, :-1]], axis=1)
    xx = x_prev - x
    xxx = x + xx * p["mu_x"]
    dyn = jnp.tanh(jnp.einsum("btd,dr->btr", xxx, p["ddlerp_a"]))
    dyn = dyn.reshape(b, t, 5, r)
    dyn = jnp.einsum("btfr,frd->btfd", dyn, p["ddlerp_b"])  # (B,T,5,d)
    mix = p["mu"][None, None] + dyn
    xr, xw, xk, xv, xg = [x + xx * mix[:, :, i] for i in range(5)]

    rr = jnp.einsum("btd,de->bte", xr, p["wr"]).reshape(b, t, h, hd)
    kk = jnp.einsum("btd,de->bte", xk, p["wk"]).reshape(b, t, h, hd)
    vv = jnp.einsum("btd,de->bte", xv, p["wv"]).reshape(b, t, h, hd)
    gg = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"]))

    wdyn = jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["w_a"]))
    wdyn = jnp.einsum("btr,rd->btd", wdyn, p["w_b"])
    w = jnp.exp(-jnp.exp((p["w0"] + wdyn).astype(jnp.float32)))  # (B,T,d) in (0,1)
    w = w.reshape(b, t, h, hd)

    u = p["u"].astype(jnp.float32)

    def step(s, inp):
        rt, kt, vt, wt = inp  # (B,H,hd) each
        kv = jnp.einsum("bhk,bhv->bhkv", kt.astype(jnp.float32), vt.astype(jnp.float32))
        out = jnp.einsum("bhk,bhkv->bhv", rt.astype(jnp.float32), s + u[None, :, :, None] * kv)
        s = wt.astype(jnp.float32)[..., None] * s + kv
        return s, out

    xs = (
        rr.transpose(1, 0, 2, 3),
        kk.transpose(1, 0, 2, 3),
        vv.transpose(1, 0, 2, 3),
        w.transpose(1, 0, 2, 3),
    )
    s_final, outs = jax.lax.scan(step, state.wkv.astype(jnp.float32), xs)
    out = outs.transpose(1, 0, 2, 3).reshape(b, t, d).astype(x.dtype)
    out = _group_norm(out, p["ln_x_scale"], p["ln_x_bias"], h)
    out = jnp.einsum("btd,de->bte", out * gg, p["wo"])
    new_state = RWKVState(s_final.astype(state.wkv.dtype), x[:, -1], state.x_cm)
    return out, new_state


def rwkv_channel_mix(cfg, p, x: Array, state: RWKVState):
    x_prev = jnp.concatenate([state.x_cm[:, None], x[:, :-1]], axis=1)
    xx = x_prev - x
    xk = x + xx * p["cm_mu_k"]
    xr = x + xx * p["cm_mu_r"]
    k = jnp.einsum("btd,df->btf", xk, p["cm_wk"])
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("btf,fd->btd", k, p["cm_wv"])
    out = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["cm_wr"])) * kv
    return out, state._replace(x_cm=x[:, -1])


def rwkv_init_state(cfg, batch: int, dtype) -> RWKVState:
    h, hd = rwkv_heads(cfg)
    return RWKVState(
        wkv=jnp.zeros((batch, h, hd, hd), jnp.float32),
        x_tm=jnp.zeros((batch, cfg.d_model), dtype),
        x_cm=jnp.zeros((batch, cfg.d_model), dtype),
    )


# ---------------------------------------------------------------------------
# Mamba-style selective SSM head (Hymba parallel branch)
# ---------------------------------------------------------------------------


class MambaState(NamedTuple):
    h: Array  # (B, d_inner, N)
    conv: Array  # (B, K-1, d_inner) causal-conv tail

_CONV_K = 4


def mamba_init(key, cfg, dtype, *, stacked=None) -> dict:
    d, n = cfg.d_model, cfg.ssm_state
    di = cfg.ssm_expand * d
    ks = jax.random.split(key, 6)
    st = lambda *s: ((stacked,) + s) if stacked is not None else s
    return {
        "in_proj": dense_init(ks[0], d, (2 * di,), dtype, stacked=stacked),
        "conv_w": truncnorm(ks[1], st(_CONV_K, di), dtype, 0.2),
        "x_proj": dense_init(ks[2], di, (2 * n + 1,), dtype, stacked=stacked),
        "dt_bias": jnp.zeros(st(di), dtype),
        "a_log": jnp.broadcast_to(
            jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32)), st(di, n)
        ).astype(dtype),
        "d_skip": jnp.ones(st(di), dtype),
        "out_proj": dense_init(ks[4], di, (d,), dtype, stacked=stacked),
    }


def mamba_apply(cfg, p, x: Array, state: MambaState):
    """x: (B, T, d) -> (out, new_state). Selective scan over T."""
    b, t, d = x.shape
    n = cfg.ssm_state
    di = cfg.ssm_expand * d
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)  # (B,T,di)

    # depthwise causal conv, kernel K, with carried tail for decode
    pad = jnp.concatenate([state.conv, xs], axis=1)  # (B, K-1+T, di)
    conv = sum(
        pad[:, k : k + t] * p["conv_w"][k][None, None] for k in range(_CONV_K)
    )
    xs = jax.nn.silu(conv)
    new_tail = pad[:, t:][:, -( _CONV_K - 1):]

    proj = jnp.einsum("bte,ec->btc", xs, p["x_proj"])
    dt = jax.nn.softplus(proj[..., :1] + p["dt_bias"][None, None])  # (B,T,di)
    bb = proj[..., 1 : 1 + n]  # (B,T,N)
    cc = proj[..., 1 + n :]  # (B,T,N)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (di, N)

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp  # (B,di),(B,N),(B,N),(B,di)
        da = jnp.exp(dt_t[..., None].astype(jnp.float32) * a[None])  # (B,di,N)
        h = da * h + (dt_t[..., None] * x_t[..., None]).astype(jnp.float32) * b_t[:, None, :].astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, c_t.astype(jnp.float32))
        return h, y

    xs_t = (
        dt.transpose(1, 0, 2),
        bb.transpose(1, 0, 2),
        cc.transpose(1, 0, 2),
        xs.transpose(1, 0, 2),
    )
    h_final, ys = jax.lax.scan(step, state.h.astype(jnp.float32), xs_t)
    y = ys.transpose(1, 0, 2).astype(x.dtype) + xs * p["d_skip"][None, None]
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    return out, MambaState(h_final.astype(state.h.dtype), new_tail)


def mamba_init_state(cfg, batch: int, dtype) -> MambaState:
    di = cfg.ssm_expand * cfg.d_model
    return MambaState(
        h=jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
        conv=jnp.zeros((batch, _CONV_K - 1, di), dtype),
    )
