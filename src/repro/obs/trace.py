"""Per-request tracing: spans over the serving pipeline, Chrome-trace export.

A *trace* is one request's journey: a trace ID is minted at ``submit()``
(process-unique, so a request keeps its identity across shard failover
hops) and every span recorded on its behalf carries it. Spans mark the
pipeline stages — queue wait, group dispatch, executor, retry/backoff,
bisection, router hops — with (plan, bucket, dtype, batch, shard) context
in their args.

Spans cross threads (a queue span opens on the submitting thread and closes
on the batcher worker), so the API is explicit ``begin()``/``end()`` handles
plus a ``span()`` context manager for same-thread scopes. ``end()`` is
exactly-once by construction: a handle leaves the open set when it closes,
and closing it again raises — the invariant the trace-completeness chaos
test asserts.

Finished spans land in a bounded ring buffer (oldest dropped, drop count
kept) and export as Chrome trace-event JSON — ``chrome_trace()`` emits
``{"traceEvents": [...]}`` with complete (``"ph": "X"``) events, loadable
directly in Perfetto (ui.perfetto.dev) or chrome://tracing. Timestamps are
``time.perf_counter()`` microseconds, one timebase across every tracer in
the process, so router and shard spans interleave correctly on one
timeline. :func:`validate_chrome_trace` is the schema check CI runs against
exported documents.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from contextlib import contextmanager

_ids = itertools.count(1)
_ids_lock = threading.Lock()


def new_trace_id() -> int:
    """Process-unique trace ID: one per request, minted at submit and
    threaded through every hop (shards must not re-mint)."""
    with _ids_lock:
        return next(_ids)


class Span:
    """An open span handle. Closed by ``Tracer.end`` (or the ``span()``
    context manager) exactly once."""

    __slots__ = ("name", "trace", "t0", "t1", "tid", "attrs")

    def __init__(self, name: str, trace, tid: int, attrs: dict):
        self.name = name
        self.trace = trace
        self.t0 = time.perf_counter()
        self.t1 = None
        self.tid = tid
        self.attrs = attrs


class Tracer:
    """One tracer per service (the router gets its own). ``pid`` labels the
    process lane in the exported trace — shard index for shard services,
    ``"router"`` for the router."""

    def __init__(self, ring: int = 8192, pid="0", name: str = "service"):
        self.pid = str(pid)
        self.name = name
        self._lock = threading.Lock()
        self._done: collections.deque = collections.deque(maxlen=ring)
        self._open: set[Span] = set()
        self.dropped = 0
        self.spans_begun = 0
        self.spans_ended = 0

    # ------------------------------------------------------------- recording
    def begin(self, name: str, trace=None, **attrs) -> Span:
        span = Span(name, trace, threading.get_ident(), attrs)
        with self._lock:
            self._open.add(span)
            self.spans_begun += 1
        return span

    def end(self, span: Span, **attrs) -> None:
        """Close a span exactly once; closing twice (or closing a handle
        this tracer never began) raises."""
        with self._lock:
            try:
                self._open.remove(span)
            except KeyError:
                raise RuntimeError(
                    f"span {span.name!r} already ended (or foreign to this tracer)"
                ) from None
            span.t1 = time.perf_counter()
            if attrs:
                span.attrs.update(attrs)
            if len(self._done) == self._done.maxlen:
                self.dropped += 1
            self._done.append(span)
            self.spans_ended += 1

    @contextmanager
    def span(self, name: str, trace=None, **attrs):
        s = self.begin(name, trace, **attrs)
        try:
            yield s
        finally:
            self.end(s)

    def instant(self, name: str, trace=None, **attrs) -> None:
        """Zero-duration marker (exported as ``"ph": "i"``)."""
        s = Span(name, trace, threading.get_ident(), attrs)
        s.t1 = s.t0
        with self._lock:
            if len(self._done) == self._done.maxlen:
                self.dropped += 1
            self._done.append(s)

    # ------------------------------------------------------------- reading
    def open_count(self) -> int:
        with self._lock:
            return len(self._open)

    def finished(self) -> list[Span]:
        with self._lock:
            return list(self._done)

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "spans_begun": self.spans_begun,
                "spans_ended": self.spans_ended,
                "open": len(self._open),
                "buffered": len(self._done),
                "dropped": self.dropped,
            }

    def chrome_events(self) -> list[dict]:
        events = [{
            "name": "process_name",
            "ph": "M",
            "pid": self.pid,
            "tid": 0,
            "ts": 0,
            "args": {"name": self.name},
        }]
        for s in self.finished():
            args = {k: _jsonable(v) for k, v in s.attrs.items()}
            if s.trace is not None:
                args["trace_id"] = s.trace
            ev = {
                "name": s.name,
                "cat": "serve",
                "ph": "X" if s.t1 > s.t0 else "i",
                "ts": round(s.t0 * 1e6, 3),
                "pid": self.pid,
                "tid": s.tid,
                "args": args,
            }
            if ev["ph"] == "X":
                ev["dur"] = round((s.t1 - s.t0) * 1e6, 3)
            else:
                ev["s"] = "t"  # instant scope: thread
            events.append(ev)
        return events


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    return str(v)


def chrome_trace(tracers) -> dict:
    """Merge any number of tracers into one Chrome trace-event document
    (Perfetto- and chrome://tracing-loadable)."""
    events: list[dict] = []
    for t in tracers:
        if t is not None:
            events.extend(t.chrome_events())
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": events, "displayTimeUnit": "ms"}


_PHASES = {"X", "i", "M"}


def validate_chrome_trace(doc) -> list[str]:
    """Structural check against the Chrome trace-event format (the subset
    this exporter emits). Returns a list of problems — empty means valid.
    CI runs this over the chaos-replay export."""
    errors: list[str] = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document must be a dict with a 'traceEvents' list"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' must be a list"]
    for i, ev in enumerate(events):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        name, ph = ev.get("name"), ev.get("ph")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: missing/empty 'name'")
        if ph not in _PHASES:
            errors.append(f"{where} ({name}): bad phase {ph!r}")
        for field in ("pid", "tid"):
            if not isinstance(ev.get(field), (int, str)):
                errors.append(f"{where} ({name}): missing '{field}'")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where} ({name}): bad 'ts' {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where} ({name}): 'X' event needs 'dur' >= 0")
        if "args" in ev and not isinstance(ev["args"], dict):
            errors.append(f"{where} ({name}): 'args' must be an object")
    return errors


__all__ = [
    "new_trace_id",
    "Span",
    "Tracer",
    "chrome_trace",
    "validate_chrome_trace",
]
