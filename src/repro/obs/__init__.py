"""End-to-end serving observability (ISSUE 7): one metrics vocabulary,
per-request tracing, and executor profiling for the morphology serving
tier.

    from repro.obs import ObsConfig
    from repro.serve.morph import MorphService, ServiceConfig

    with MorphService(ServiceConfig(obs=ObsConfig())) as svc:
        svc.run(img, "erode", (5, 5))
        json.dump(svc.export_trace(), open("trace.json", "w"))
        # -> load trace.json at ui.perfetto.dev

Three layers (DESIGN.md §12):

* ``metrics`` — counters / gauges / fixed-bucket histograms with explicit
  by-type merge semantics; the serving stats surfaces are views over one
  :class:`MetricsRegistry` per service, and the sharded router's stats are
  a :func:`merge_snapshots` over its shards.
* ``trace`` — trace IDs minted at submit, spans across queue wait /
  dispatch / executor / retry / bisection / failover hops, exported as
  Chrome trace-event JSON.
* ``runtime`` — :class:`ObsConfig` (off by default; ``None`` costs one
  ``is None`` check per hook site) and the :class:`Observability` object
  holding the tracer + executor compile-vs-run profiling.
"""
from repro.obs.metrics import (
    DEFAULT_LATENCY_BUCKETS_MS,
    POW2_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    cache_stats,
    hit_rate,
    merge_snapshots,
    quantile_from_snapshot,
)
from repro.obs.runtime import (
    EXECUTOR_BUCKETS_MS,
    Observability,
    ObsConfig,
    now_s,
)
from repro.obs.trace import (
    Span,
    Tracer,
    chrome_trace,
    new_trace_id,
    validate_chrome_trace,
)

__all__ = [
    "DEFAULT_LATENCY_BUCKETS_MS",
    "POW2_BUCKETS",
    "EXECUTOR_BUCKETS_MS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "cache_stats",
    "hit_rate",
    "merge_snapshots",
    "quantile_from_snapshot",
    "Observability",
    "ObsConfig",
    "now_s",
    "Span",
    "Tracer",
    "chrome_trace",
    "new_trace_id",
    "validate_chrome_trace",
]
