"""Metrics registry: counters, gauges, fixed-bucket histograms (ISSUE 7).

The serving tier grew five hand-rolled stats surfaces (``ExecutableCache``
counters, ``MicroBatcher.counters()``, ``ServiceStats`` deques, the sharded
router's key-by-key merge, and each benchmark's private percentile math).
This module is the one vocabulary they all become views over:

* :class:`Counter` — a monotone int. Merge = sum.
* :class:`Gauge` — a point-in-time value with an explicit merge ``mode``:
  ``"sum"`` for capacities (cache sizes add across shards), ``"max"`` for
  worst-shard readings (effective batching window), ``"min"`` symmetric.
* :class:`Histogram` — fixed bucket boundaries chosen at registration, so
  two histograms of the same metric merge by adding bucket counts — the
  property the cross-shard quantile merge needs (quantiles themselves never
  merge; see :meth:`Histogram.quantile`).

Mutation is deliberately lock-free: every producer in the serving tier
already serializes its hot path under an existing lock (the cache lock, the
batcher cv, the stats lock), and telemetry that *loses* a rare increment
under a data race is acceptable where telemetry that *takes another lock*
per request is not. Snapshots are plain dicts (JSON-ready) and
:func:`merge_snapshots` merges any number of them by metric type — the
replacement for the router's hand-coded per-key aggregation.
"""
from __future__ import annotations

import bisect
import math
import threading

# Latency bucket ladder (milliseconds), log-spaced ~x2: fine enough that a
# p99 read off the histogram tracks np.percentile within a bucket width,
# coarse enough that a snapshot is ~30 ints. Shared by the serving stats and
# benchmarks/common.py so live stats and bench reports quantize identically.
DEFAULT_LATENCY_BUCKETS_MS: tuple[float, ...] = (
    0.05, 0.1, 0.2, 0.5, 1.0, 2.0, 3.0, 5.0, 7.5, 10.0, 15.0, 20.0, 30.0,
    50.0, 75.0, 100.0, 150.0, 200.0, 300.0, 500.0, 750.0, 1000.0, 1500.0,
    2000.0, 3000.0, 5000.0, 10000.0, 30000.0,
)

# Power-of-two ladder for batch sizes and iteration counts.
POW2_BUCKETS: tuple[float, ...] = tuple(float(1 << i) for i in range(11))


def hit_rate(hits: int, misses: int) -> float:
    """The one definition of a hit rate (was copy-pasted between
    ``ExecutableCache.snapshot`` and the router's summed-counter re-derivation)."""
    total = hits + misses
    return hits / total if total else 0.0


def cache_stats(size: int, hits: int, misses: int, evictions: int) -> dict:
    """The executable-cache stats block, derived the same way whether the
    inputs are one service's counters or a cross-shard merged sum."""
    return {
        "size": int(size),
        "hits": int(hits),
        "misses": int(misses),
        "evictions": int(evictions),
        "hit_rate": hit_rate(hits, misses),
    }


class Counter:
    """Monotone event count. ``inc`` is a bare int add — callers serialize
    on their own hot-path lock; merge = sum."""

    kind = "counter"
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> dict:
        return {"type": "counter", "value": self.value}


class Gauge:
    """Point-in-time reading with explicit cross-shard merge semantics."""

    kind = "gauge"
    __slots__ = ("value", "mode")
    MODES = ("sum", "max", "min", "last")

    def __init__(self, mode: str = "last"):
        if mode not in self.MODES:
            raise ValueError(f"gauge mode must be one of {self.MODES}, got {mode!r}")
        self.mode = mode
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def snapshot(self) -> dict:
        return {"type": "gauge", "mode": self.mode, "value": self.value}


class Histogram:
    """Fixed-boundary histogram: ``bounds`` are upper edges of the first
    ``len(bounds)`` buckets plus one overflow bucket. Tracks sum/count and
    exact min/max so quantile reads are tight at the tails.
    """

    kind = "histogram"
    __slots__ = ("bounds", "counts", "total", "count", "min", "max")

    def __init__(self, bounds=DEFAULT_LATENCY_BUCKETS_MS):
        bounds = tuple(float(b) for b in bounds)
        if not bounds or list(bounds) != sorted(set(bounds)):
            raise ValueError("histogram bounds must be non-empty and strictly increasing")
        self.bounds = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.total = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.total += v
        self.count += 1
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def observe_many(self, values) -> None:
        for v in values:
            self.observe(v)

    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        return quantile_from_snapshot(self.snapshot(), q)

    def snapshot(self) -> dict:
        return {
            "type": "histogram",
            "bounds": list(self.bounds),
            "counts": list(self.counts),
            "sum": self.total,
            "count": self.count,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
        }


def quantile_from_snapshot(snap: dict, q: float) -> float:
    """Quantile estimate from a histogram *snapshot* (local or merged):
    find the bucket holding rank ``q`` and interpolate linearly inside it,
    clamped to the recorded min/max so the tails never extrapolate past
    observed data. Empty histograms read 0.0."""
    if snap.get("type") != "histogram":
        raise TypeError(f"need a histogram snapshot, got {snap.get('type')!r}")
    count = snap["count"]
    if not count:
        return 0.0
    bounds, counts = snap["bounds"], snap["counts"]
    lo_all, hi_all = snap["min"], snap["max"]
    rank = q * (count - 1)
    seen = 0
    for i, c in enumerate(counts):
        if not c:
            continue
        if seen + c > rank:
            lo = bounds[i - 1] if i > 0 else lo_all
            hi = bounds[i] if i < len(bounds) else hi_all
            lo, hi = max(lo, lo_all), min(hi, hi_all)
            if hi <= lo:
                return lo
            frac = (rank - seen + 0.5) / c  # mid-rank within the bucket
            return lo + min(1.0, max(0.0, frac)) * (hi - lo)
        seen += c
    return hi_all


def _merge_one(kind: str, snaps: list[dict]) -> dict:
    if kind == "counter":
        return {"type": "counter", "value": sum(s["value"] for s in snaps)}
    if kind == "gauge":
        mode = snaps[0]["mode"]
        vals = [s["value"] for s in snaps]
        if any(s["mode"] != mode for s in snaps):
            raise ValueError("cannot merge gauges with different modes")
        v = {"sum": sum, "max": max, "min": min, "last": lambda x: x[-1]}[mode](vals)
        return {"type": "gauge", "mode": mode, "value": v}
    if kind == "histogram":
        bounds = snaps[0]["bounds"]
        if any(s["bounds"] != bounds for s in snaps):
            raise ValueError("cannot merge histograms with different bounds")
        counted = [s for s in snaps if s["count"]]
        return {
            "type": "histogram",
            "bounds": list(bounds),
            "counts": [sum(c) for c in zip(*(s["counts"] for s in snaps))],
            "sum": sum(s["sum"] for s in snaps),
            "count": sum(s["count"] for s in snaps),
            "min": min(s["min"] for s in counted) if counted else 0.0,
            "max": max(s["max"] for s in counted) if counted else 0.0,
        }
    raise ValueError(f"unknown metric type {kind!r}")


def merge_snapshots(snapshots: list[dict]) -> dict:
    """Merge registry snapshots by metric type: counters sum, gauges apply
    their mode, histograms add bucket counts. A metric missing from some
    shards merges over the shards that have it."""
    merged: dict[str, dict] = {}
    names: list[str] = []
    for snap in snapshots:
        for name in snap:
            if name not in merged:
                merged[name] = {}
                names.append(name)
    for name in names:
        present = [s[name] for s in snapshots if name in s]
        kinds = {p["type"] for p in present}
        if len(kinds) != 1:
            raise ValueError(f"metric {name!r} has conflicting types {kinds}")
        merged[name] = _merge_one(kinds.pop(), present)
    return merged


class MetricsRegistry:
    """Named metrics, registered on first use. Registration takes a lock
    (rare); mutation of the returned metric objects does not (hot)."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, factory, kind: str):
        m = self._metrics.get(name)
        if m is None:
            with self._lock:
                m = self._metrics.get(name)
                if m is None:
                    m = factory()
                    self._metrics[name] = m
        if m.kind != kind:
            raise TypeError(f"metric {name!r} is a {m.kind}, not a {kind}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, "counter")

    def gauge(self, name: str, mode: str = "last") -> Gauge:
        return self._get(name, lambda: Gauge(mode), "gauge")

    def histogram(self, name: str, bounds=DEFAULT_LATENCY_BUCKETS_MS) -> Histogram:
        return self._get(name, lambda: Histogram(bounds), "histogram")

    def snapshot(self) -> dict:
        with self._lock:
            items = list(self._metrics.items())
        return {name: m.snapshot() for name, m in items}

    # alias so call sites read as the class-level operation it is
    merge = staticmethod(merge_snapshots)


__all__ = [
    "DEFAULT_LATENCY_BUCKETS_MS",
    "POW2_BUCKETS",
    "hit_rate",
    "cache_stats",
    "Counter",
    "Gauge",
    "Histogram",
    "quantile_from_snapshot",
    "merge_snapshots",
    "MetricsRegistry",
]
