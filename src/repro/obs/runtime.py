"""ObsConfig and the Observability runtime the serving tier hangs hooks on.

``ObsConfig`` is the single gate (ISSUE 7): ``ServiceConfig.obs = None``
(the default) means no ``Observability`` object is ever constructed and
every hook site in the service/batcher/router is one ``is None`` check —
the same zero-overhead-off contract as ``FaultInjector``. With a config
present, the runtime owns:

* a :class:`~repro.obs.trace.Tracer` (per-request spans, Chrome export);
* executor profiling: compile-vs-run split per cache key (the first call
  of a freshly built executor pays the XLA compile; later calls are pure
  dispatch+run), recorded both as histograms in the service's metrics
  registry and as a bounded per-key table;
* ``BoundedIter`` iters-used/budget as first-class histograms (the
  counters in ``ServiceStats`` only give the mean; reconstruction-depth
  *distribution* is what the wavefront ROADMAP item needs);
* an opt-in ``jax.profiler`` annotation bracket around dispatches, so a
  device profile collected with ``jax.profiler.trace`` carries the serving
  plan names.

The metrics registry itself is NOT gated: it is the always-on substrate
``stats()`` is built from (plain int adds under existing locks — the
pre-obs counters under another name). Only the per-request/per-dispatch
extras above sit behind the gate.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time

from repro.obs.metrics import MetricsRegistry, POW2_BUCKETS
from repro.obs.trace import Tracer, chrome_trace, new_trace_id

# Executor timings spread over ~5 orders of magnitude (sub-ms dispatch to
# multi-second cold compiles); reuse the latency ladder's shape but extend
# the top for compile outliers.
EXECUTOR_BUCKETS_MS = (
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
    1000.0, 2500.0, 5000.0, 10000.0, 30000.0, 60000.0, 120000.0,
)


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability knobs. Constructing one and passing it as
    ``ServiceConfig.obs`` turns the instrumented paths on; ``None`` keeps
    the serving tier exactly as fast as before this module existed."""

    trace: bool = True            # per-request spans + Chrome export
    trace_ring: int = 8192        # finished spans kept per tracer
    profile_executors: bool = True  # compile/run split + per-key table
    profile_keys: int = 256       # bound on the per-key profile table
    jax_profiler: bool = False    # jax.profiler.TraceAnnotation per dispatch

    @property
    def enabled(self) -> bool:
        return self.trace or self.profile_executors or self.jax_profiler


class Observability:
    """Per-service observability runtime. Every public hook is safe to call
    from any thread; hooks are no-ops for the features the config leaves
    off, so call sites only ever test the service's single ``_obs is not
    None`` gate."""

    def __init__(self, config: ObsConfig, registry: MetricsRegistry, *,
                 pid="0", name: str = "service"):
        self.config = config
        self.registry = registry
        self.tracer = (
            Tracer(ring=config.trace_ring, pid=pid, name=name)
            if config.trace else None
        )
        self._plock = threading.Lock()
        self._cold: set = set()
        self._profile: dict[str, dict] = {}
        if config.profile_executors:
            self._h_first = registry.histogram(
                "executor.first_call_ms", EXECUTOR_BUCKETS_MS)
            self._h_run = registry.histogram(
                "executor.run_ms", EXECUTOR_BUCKETS_MS)
            self._h_iters_used = registry.histogram(
                "bounded_iter.used", POW2_BUCKETS)
            self._h_iters_budget = registry.histogram(
                "bounded_iter.budget", POW2_BUCKETS)

    # -------------------------------------------------------- request spans
    def request_submitted(self, req, plan_name: str, bucket, dtype: str) -> None:
        """Mint the request's trace ID (unless a router hop already did) and
        open its queue-wait span."""
        if req.trace is None:
            req.trace = new_trace_id()
        if self.tracer is not None:
            req.qspan = self.tracer.begin(
                "queue", trace=req.trace,
                plan=plan_name, bucket=bucket, dtype=dtype,
            )

    def request_dequeued(self, req, **attrs) -> None:
        """Close the queue span (idempotent: retries re-enter the executor
        but the queue wait ended at first dispatch)."""
        span = getattr(req, "qspan", None)
        if span is not None:
            req.qspan = None
            self.tracer.end(span, **attrs)

    def request_failed(self, req, exc: BaseException) -> None:
        """A request failing before/without dispatch still closes its queue
        span, so chaos traces account for every span exactly once."""
        self.request_dequeued(req, error=type(exc).__name__)

    # ---------------------------------------------------------- group spans
    def group_span(self, name: str, reqs, **attrs):
        """Span covering one dispatched group; args carry every member's
        trace ID so per-request journeys reconstruct from group events."""
        if self.tracer is None:
            return contextlib.nullcontext()
        attrs["trace_ids"] = [r.trace for r in reqs]
        attrs["n"] = len(reqs)
        return self.tracer.span(name, **attrs)

    def instant(self, name: str, reqs=None, **attrs) -> None:
        if self.tracer is None:
            return
        if reqs is not None:
            attrs["trace_ids"] = [r.trace for r in reqs]
        self.tracer.instant(name, **attrs)

    # ----------------------------------------------------- executor profile
    def executor_built(self, key) -> None:
        """Called when the cache misses and a new executor is built: its
        next call pays the XLA compile."""
        if not self.config.profile_executors:
            return
        with self._plock:
            self._cold.add(key)

    def record_execution(self, key, plan_name: str, dur_s: float) -> bool:
        """Record one executor call (dispatch + block-until-ready). Returns
        whether this was the key's compiling first call."""
        if not self.config.profile_executors:
            return False
        dur_ms = dur_s * 1e3
        with self._plock:
            cold = key in self._cold
            self._cold.discard(key)
            ks = _key_str(key)
            row = self._profile.get(ks)
            if row is None:
                if len(self._profile) >= self.config.profile_keys:
                    row = None  # table full: histograms still record
                else:
                    row = self._profile[ks] = {
                        "plan": plan_name, "first_call_ms": None,
                        "calls": 0, "run_ms_total": 0.0, "run_ms_max": 0.0,
                    }
            if row is not None:
                if cold:
                    row["first_call_ms"] = round(dur_ms, 3)
                else:
                    row["calls"] += 1
                    row["run_ms_total"] += dur_ms
                    row["run_ms_max"] = max(row["run_ms_max"], dur_ms)
        (self._h_first if cold else self._h_run).observe(dur_ms)
        return cold

    def record_bounded(self, used: int, budget: int) -> None:
        if not self.config.profile_executors:
            return
        self._h_iters_used.observe(used)
        self._h_iters_budget.observe(budget)

    def dispatch_annotation(self, label: str):
        """Opt-in jax.profiler bracket: names this dispatch in a device
        profile collected around the serving process."""
        if not self.config.jax_profiler:
            return contextlib.nullcontext()
        import jax.profiler

        return jax.profiler.TraceAnnotation(f"morph_serve:{label}")

    # -------------------------------------------------------------- reading
    def executor_profile(self) -> dict:
        with self._plock:
            return {
                k: dict(
                    v,
                    run_ms_mean=(
                        round(v["run_ms_total"] / v["calls"], 3)
                        if v["calls"] else 0.0
                    ),
                )
                for k, v in self._profile.items()
            }

    def export_trace(self) -> dict:
        return chrome_trace([self.tracer])

    def snapshot(self) -> dict:
        out = {
            "trace": self.tracer.snapshot() if self.tracer is not None else None,
            "jax_profiler": self.config.jax_profiler,
        }
        if self.config.profile_executors:
            with self._plock:
                out["profiled_keys"] = len(self._profile)
        return out


def _key_str(key) -> str:
    # executor cache keys embed a Plan; render compactly and hashable-free
    return "|".join(str(getattr(p, "name", p)) for p in key)


def now_s() -> float:
    """The serving tier's duration clock (monotonic, high resolution).
    Durations everywhere use this; wall-clock time is reserved for
    checkpoint metadata (see checkpoint/manager.py)."""
    return time.perf_counter()


__all__ = ["ObsConfig", "Observability", "EXECUTOR_BUCKETS_MS", "now_s"]
