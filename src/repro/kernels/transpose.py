"""Tiled matrix-transpose Pallas kernel (paper §4, adapted to TPU).

The paper builds 8x8.16 / 16x16.8 transposes from VTRN 2x2-block ladders so
that the vertical morphology pass can run on contiguous data. On TPU the
vector unit is an (8, 128) tile and Mosaic owns the in-register shuffle
network, so the adaptation (DESIGN.md §2) is:

* grid over (TILE x TILE) blocks held in VMEM,
* out block (j, i) <- in block (i, j) transposed in-register,
* the in-tile ``.T`` lowers to the TPU transpose/permute unit — the exact
  analog of the paper's VTRN ladder, with the 2x2 recursion replaced by the
  sublane/lane exchange Mosaic emits.

The kernel exists so the W-axis (lane-axis) morphology pass can be executed
as transpose -> sublane pass -> transpose, which is the paper's §5.2
baseline strategy, and so its cost can be compared against the direct
lane-shift pass in the §Perf log.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.types import Array


def _transpose_kernel(x_ref, o_ref):
    # In-tile transpose: one VMEM tile in, one out. Mosaic lowers this to
    # the lane/sublane exchange network (the VTRN-ladder analog).
    o_ref[...] = x_ref[...].T


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def transpose_tiled(x: Array, *, tile: int = 128, interpret: bool = True) -> Array:
    """Transpose the last two dims of ``x`` with an explicitly tiled kernel.

    ``tile`` is the square VMEM block edge; 128 matches the TPU lane width
    (the paper's "8" / "16" matched the NEON register width in elements).
    Non-multiple shapes are padded and cropped.
    """
    *lead, h, w = x.shape
    if lead:
        flat = x.reshape((-1, h, w))
        out = jax.vmap(lambda m: transpose_tiled(m, tile=tile, interpret=interpret))(flat)
        return out.reshape(tuple(lead) + (w, h))

    ph, pw = -h % tile, -w % tile
    xp = jnp.pad(x, ((0, ph), (0, pw)))
    gh, gw = (h + ph) // tile, (w + pw) // tile

    out = pl.pallas_call(
        _transpose_kernel,
        grid=(gh, gw),
        in_specs=[pl.BlockSpec((tile, tile), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((tile, tile), lambda i, j: (j, i)),
        out_shape=jax.ShapeDtypeStruct((w + pw, h + ph), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:w, :h]
