"""Pallas kernel: linear (O(w)) 1-D morphology pass along the sublane axis.

This is the paper's §5.1.2 linear implementation mapped to TPU. The paper
vectorizes 16 u8 pixels per `vminq_u8`; here one `jnp.minimum` inside the
kernel covers an (8, 128) vreg and the window walk happens along sublanes
(the H axis of the block), where shifted operands are free re-slices of the
VMEM block rather than lane rotations — the TPU-side reason this pass is
the "good axis" pass (DESIGN.md §2).

Tiling: grid over W in BW-wide strips; each kernel instance holds the whole
padded column strip (H + 2*wing, BW) in VMEM and writes (H, BW). VMEM
budget: (H + w) * BW * itemsize, e.g. 4096x128xf32 = 2 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.types import Array, as_op, check_window


def _linear_kernel(x_ref, o_ref, *, w: int, opname: str):
    op = as_op(opname)
    h = o_ref.shape[0]
    # Paper's inner loop: a single accumulator reduced against w shifted
    # loads; slices along sublanes are offset reads of the same VMEM block.
    val = x_ref[0:h, :]
    for k in range(1, w):
        val = op.reduce(val, x_ref[k : k + h, :])
    o_ref[...] = val


@functools.partial(
    jax.jit, static_argnames=("w", "op", "block_w", "interpret")
)
def morph_linear_sublane(
    x: Array,
    *,
    w: int,
    op: str = "min",
    block_w: int = 128,
    interpret: bool = True,
) -> Array:
    """Running min/max of window ``w`` along axis -2 of a 2-D array."""
    w = check_window(w)
    mop = as_op(op)
    if x.ndim != 2:
        raise ValueError("kernel operates on (H, W); vmap for batches")
    h, wid = x.shape
    if w == 1:
        return x
    wing = (w - 1) // 2
    pw = -wid % block_w
    xp = jnp.pad(
        x,
        ((wing, wing), (0, pw)),
        constant_values=mop.neutral(x.dtype),
    )
    grid = ((wid + pw) // block_w,)
    out = pl.pallas_call(
        functools.partial(_linear_kernel, w=w, opname=mop.name),
        grid=grid,
        in_specs=[pl.BlockSpec((h + 2 * wing, block_w), lambda j: (0, j))],
        out_specs=pl.BlockSpec((h, block_w), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((h, wid + pw), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:, :wid]
