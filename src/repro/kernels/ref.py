"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the mathematical specification its kernel is tested
against (tests/test_kernels.py sweeps shapes and dtypes with
np.testing.assert_allclose / array_equal).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.linear_pass import linear_1d
from repro.core.morphology import morph2d_naive
from repro.core.types import Array


def transpose_ref(x: Array) -> Array:
    """Oracle for kernels/transpose.py: plain 2-D transpose (..., H, W) -> (..., W, H)."""
    return jnp.swapaxes(x, -1, -2)


def morph_1d_ref(x: Array, w: int, *, axis: int, op: str) -> Array:
    """Oracle for both morph kernels: naive windowed reduction."""
    return linear_1d(x, w, axis=axis, op=op)


def gradient_1d_ref(x: Array, w: int, *, axis: int) -> Array:
    """Oracle for kernels/fused_gradient.py (1-D): dilate - erode, widened."""
    d = linear_1d(x, w, axis=axis, op="max")
    e = linear_1d(x, w, axis=axis, op="min")
    if jnp.issubdtype(x.dtype, jnp.integer):
        return d.astype(jnp.int32) - e.astype(jnp.int32)
    return d - e


def morph2d_ref(x: Array, se, *, op: str) -> Array:
    """Oracle for kernels/morph_fused.py: the naive non-separable 2-D
    reduction (batch dims broadcast)."""
    return morph2d_naive(x, se, op=op)


def gradient2d_ref(x: Array, se) -> Array:
    """Oracle for the fused 2-D gradient: dilate2d - erode2d, widened."""
    d = morph2d_naive(x, se, op="max")
    e = morph2d_naive(x, se, op="min")
    if jnp.issubdtype(x.dtype, jnp.integer):
        return d.astype(jnp.int32) - e.astype(jnp.int32)
    return d - e
