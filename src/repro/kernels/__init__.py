"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel file pairs with a pure-jnp oracle in ref.py; ops.py exposes the
jit'd hybrid dispatch API. The 2-D operators default to the fused
single-``pallas_call`` megakernel (morph_fused.py). Validated with
interpret=True on CPU.
"""
from repro.kernels.fused_gradient import gradient_linear_sublane
from repro.kernels.morph_fused import gradient2d_fused, morph2d_fused
from repro.kernels.morph_linear import morph_linear_sublane
from repro.kernels.morph_vhgw import morph_vhgw_sublane
from repro.kernels.ops import (
    closing2d_tpu,
    dilate2d_tpu,
    erode2d_tpu,
    gradient_1d_tpu,
    gradient2d_tpu,
    morph_1d_tpu,
    opening2d_tpu,
)
from repro.kernels.transpose import transpose_tiled
