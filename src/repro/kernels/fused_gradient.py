"""Pallas kernel: fused 1-D morphological gradient (beyond-paper).

The paper computes gradient as dilate(x) - erode(x): two full passes, two
reads of the image from memory. On TPU the pass is bandwidth-bound for
small windows, so fusing both reductions over a single VMEM block read
halves HBM traffic — this kernel maintains min- and max-accumulators in the
same sublane walk and writes the widened difference directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.types import MAX, MIN, Array, check_window


def _gradient_kernel(xmin_ref, xmax_ref, o_ref, *, w: int):
    h = o_ref.shape[0]
    lo = xmin_ref[0:h, :]
    hi = xmax_ref[0:h, :]
    for k in range(1, w):
        lo = jnp.minimum(lo, xmin_ref[k : k + h, :])
        hi = jnp.maximum(hi, xmax_ref[k : k + h, :])
    o_ref[...] = hi.astype(o_ref.dtype) - lo.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("w", "block_w", "interpret"))
def gradient_linear_sublane(
    x: Array, *, w: int, block_w: int = 128, interpret: bool = True
) -> Array:
    """Fused (dilate - erode) of window ``w`` along axis -2 of a 2-D array.

    Integer inputs produce int32 output (u8 differences fit in u8, but i8
    differences overflow i8; unconditional widening keeps the semantics
    uniform), floats keep their dtype.
    """
    w = check_window(w)
    if x.ndim != 2:
        raise ValueError("kernel operates on (H, W); vmap for batches")
    h, wid = x.shape
    out_dtype = (
        jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer) else x.dtype
    )
    if w == 1:
        return jnp.zeros_like(x, dtype=out_dtype)
    wing = (w - 1) // 2
    pw = -wid % block_w
    # Two padded views of the same data: one with the min-neutral, one with
    # the max-neutral, so both accumulators see correct edge semantics.
    xp_min = jnp.pad(x, ((wing, wing), (0, pw)), constant_values=MIN.neutral(x.dtype))
    xp_max = jnp.pad(x, ((wing, wing), (0, pw)), constant_values=MAX.neutral(x.dtype))
    grid = ((wid + pw) // block_w,)
    spec = pl.BlockSpec((h + 2 * wing, block_w), lambda j: (0, j))
    out = pl.pallas_call(
        functools.partial(_gradient_kernel, w=w),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((h, block_w), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((h, wid + pw), out_dtype),
        interpret=interpret,
    )(xp_min, xp_max)
    return out[:, :wid]
