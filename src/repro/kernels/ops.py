"""Jit'd public wrappers over the Pallas kernels (the paper's §5.3 hybrid).

``morph_1d_tpu`` selects:

* algorithm — ``linear`` kernel for small windows, ``vhgw`` kernel for
  large ones (paper's w0 dispatch; thresholds from core.dispatch policy);
* axis strategy — the sublane (-2) axis runs natively; the lane (-1) axis
  runs as transpose-kernel -> sublane pass -> transpose-kernel, the paper's
  §5.2 transpose trick (or an XLA transpose, selectable, for §Perf A/B).

``erode2d_tpu`` / ``dilate2d_tpu`` compose the two separable passes.
All entry points accept ``interpret=`` so CPU CI validates the same code
that targets TPU.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax.numpy as jnp

from repro.core.dispatch import DispatchPolicy
from repro.core.types import Array, as_op, check_window
from repro.kernels.fused_gradient import gradient_linear_sublane
from repro.kernels.morph_linear import morph_linear_sublane
from repro.kernels.morph_vhgw import morph_vhgw_sublane
from repro.kernels.transpose import transpose_tiled

LaneStrategy = Literal["transpose_kernel", "xla"]


def _sublane_pass(x, w, op, method, policy: DispatchPolicy, interpret):
    if method == "auto":
        method = "linear" if w <= policy.w0_major else "vhgw"
    fn = morph_linear_sublane if method == "linear" else morph_vhgw_sublane
    return fn(x, w=w, op=op, interpret=interpret)


def morph_1d_tpu(
    x: Array,
    w: int,
    *,
    axis: int = -2,
    op: str = "min",
    method: str = "auto",
    lane_strategy: LaneStrategy = "transpose_kernel",
    policy: DispatchPolicy | None = None,
    interpret: bool = True,
) -> Array:
    """Kernel-backed running min/max along ``axis`` of a 2-D array."""
    w = check_window(w)
    op = as_op(op).name
    policy = policy or DispatchPolicy.calibrated()
    if x.ndim != 2:
        raise ValueError("morph_1d_tpu operates on (H, W); vmap for batches")
    axis = axis % 2
    if w == 1:
        return x
    if axis == 0:  # sublane axis: native
        return _sublane_pass(x, w, op, method, policy, interpret)
    # lane axis: paper's transpose trick
    if lane_strategy == "transpose_kernel":
        t = transpose_tiled(x, interpret=interpret)
        t = _sublane_pass(t, w, op, method, policy, interpret)
        return transpose_tiled(t, interpret=interpret)
    xt = jnp.swapaxes(x, 0, 1)
    out = _sublane_pass(xt, w, op, method, policy, interpret)
    return jnp.swapaxes(out, 0, 1)


def erode2d_tpu(x: Array, se=(3, 3), **kw) -> Array:
    w_h, w_w = se
    y = morph_1d_tpu(x, w_h, axis=0, op="min", **kw)
    return morph_1d_tpu(y, w_w, axis=1, op="min", **kw)


def dilate2d_tpu(x: Array, se=(3, 3), **kw) -> Array:
    w_h, w_w = se
    y = morph_1d_tpu(x, w_h, axis=0, op="max", **kw)
    return morph_1d_tpu(y, w_w, axis=1, op="max", **kw)


def opening2d_tpu(x: Array, se=(3, 3), **kw) -> Array:
    return dilate2d_tpu(erode2d_tpu(x, se, **kw), se, **kw)


def closing2d_tpu(x: Array, se=(3, 3), **kw) -> Array:
    return erode2d_tpu(dilate2d_tpu(x, se, **kw), se, **kw)


def gradient_1d_tpu(x: Array, w: int, *, axis: int = -2, interpret: bool = True) -> Array:
    """Fused 1-D morphological gradient (beyond-paper kernel)."""
    w = check_window(w)
    if x.ndim != 2:
        raise ValueError("gradient_1d_tpu operates on (H, W); vmap for batches")
    if axis % 2 == 0:
        return gradient_linear_sublane(x, w=w, interpret=interpret)
    t = transpose_tiled(x, interpret=interpret)
    g = gradient_linear_sublane(t, w=w, interpret=interpret)
    return transpose_tiled(g, interpret=interpret)
