"""Jit'd public wrappers over the Pallas kernels (the paper's §5.3 hybrid).

``morph_1d_tpu`` selects:

* algorithm — ``linear`` kernel for small windows, ``vhgw`` kernel for
  large ones (paper's w0 dispatch; thresholds from core.dispatch policy);
* axis strategy — the sublane (-2) axis runs natively; the lane (-1) axis
  runs as transpose-kernel -> sublane pass -> transpose-kernel, the paper's
  §5.2 transpose trick (or an XLA transpose, selectable, for §Perf A/B).

The 2-D operators (``erode2d_tpu`` / ``dilate2d_tpu`` / ``opening2d_tpu`` /
``closing2d_tpu`` / ``gradient2d_tpu``) are thin wrappers over the
morphology expression IR: each builds its graph (``repro.morph.expr``) and
lowers it through ``repro.morph.lower_kernel``, whose primitives are
``raw_morph2d`` / ``raw_gradient2d`` below — the fused megakernel
(kernels/morph_fused.py, one ``pallas_call`` doing H pass -> in-VMEM
transpose -> W pass) when the policy and SE allow, the legacy two-pass +
double-transpose pipeline otherwise. The lowering recognizes the
``Sub(Dilate, Erode)`` gradient pattern and emits the single-launch fused
gradient kernel.

.. deprecated:: the per-call ``fused=`` / ``method=`` / ``lane_strategy=``
    kwargs. Every dispatch decision now lives on :class:`DispatchPolicy`
    (``fused_2d`` / ``method`` / ``lane_strategy`` / ``interpret``); the
    kwargs keep working as shims that fold into the policy
    (``DispatchPolicy.with_overrides``) so A/B harnesses and old callers
    don't break.

All entry points accept ``interpret=``; the default ``None`` defers to the
single resolver (``core.dispatch.resolve_interpret``): explicit argument >
``DispatchPolicy.interpret`` > ``REPRO_PALLAS_INTERPRET`` env var > backend
default (compiled on TPU, interpret elsewhere) — so CPU CI validates the
same code that targets TPU without production ever silently running
interpreted Pallas.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.dispatch import DispatchPolicy, resolve_interpret
from repro.core.types import Array, as_op, check_window, widen_dtype, widened_sub
from repro.kernels.fused_gradient import gradient_linear_sublane
from repro.kernels.morph_fused import fused_supports, gradient2d_fused, morph2d_fused
from repro.kernels.morph_linear import morph_linear_sublane
from repro.kernels.morph_vhgw import morph_vhgw_sublane
from repro.kernels.transpose import transpose_tiled
from repro.morph.expr import X
from repro.morph.lower_kernel import lower_kernel

LaneStrategy = Literal["transpose_kernel", "xla"]


def _sublane_pass(x, w, op, method, policy: DispatchPolicy, interpret):
    if method == "auto":
        method = "linear" if w <= policy.w0_major else "vhgw"
    elif method != "vhgw":
        # linear_tree / linear_paired are jnp-only variants; the linear
        # ladder kernel is their analog here (same family, same crossover
        # side), so a forced linear-family method stays linear-family
        # instead of silently flipping to vHGW.
        method = "linear"
    fn = morph_linear_sublane if method == "linear" else morph_vhgw_sublane
    return fn(x, w=w, op=op, interpret=interpret)


def morph_1d_tpu(
    x: Array,
    w: int,
    *,
    axis: int = -2,
    op: str = "min",
    method: str = "auto",
    lane_strategy: LaneStrategy | None = None,
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
) -> Array:
    """Kernel-backed running min/max along ``axis`` of a 2-D array."""
    w = check_window(w)
    op = as_op(op).name
    policy = policy or DispatchPolicy.calibrated()
    interpret = resolve_interpret(interpret, policy)
    if lane_strategy is None:
        lane_strategy = policy.lane_strategy
    if x.ndim != 2:
        raise ValueError("morph_1d_tpu operates on (H, W); vmap for batches")
    axis = axis % 2
    if w == 1:
        return x
    if axis == 0:  # sublane axis: native
        return _sublane_pass(x, w, op, method, policy, interpret)
    # lane axis: paper's transpose trick
    if lane_strategy == "transpose_kernel":
        t = transpose_tiled(x, interpret=interpret)
        t = _sublane_pass(t, w, op, method, policy, interpret)
        return transpose_tiled(t, interpret=interpret)
    xt = jnp.swapaxes(x, 0, 1)
    out = _sublane_pass(xt, w, op, method, policy, interpret)
    return jnp.swapaxes(out, 0, 1)


def _morph2d_two_pass(x, se, op, policy, interpret):
    if x.ndim == 3:  # the fused path's batch grid has no two-pass analog
        return jax.vmap(
            lambda m: _morph2d_two_pass(m, se, op, policy, interpret)
        )(x)
    w_h, w_w = se
    y = morph_1d_tpu(
        x, w_h, axis=0, op=op, method=policy.method,
        lane_strategy=policy.lane_strategy, policy=policy, interpret=interpret,
    )
    return morph_1d_tpu(
        y, w_w, axis=1, op=op, method=policy.method,
        lane_strategy=policy.lane_strategy, policy=policy, interpret=interpret,
    )


def _fused_method(policy: DispatchPolicy) -> str:
    # the fused kernel knows only the linear/vhgw pair; forced linear-family
    # variants (linear_tree/linear_paired) map to its linear ladder — the
    # same coercion _sublane_pass applies, so both kernel paths honor the
    # policy's family even when the exact jnp variant has no kernel analog
    if policy.method in ("auto", "linear", "vhgw"):
        return policy.method
    return "linear"


def _fused_wins(se, dtype, policy: DispatchPolicy, *, gradient: bool = False) -> bool:
    """Per-node fused-vs-two-pass decision from the per-device cost model.

    With no measured ``cost_table.json`` (or a hand-tuned policy) the
    analytic model always answers True, preserving the historical
    ``policy.fused_2d``-only dispatch; a measured table lets a device where
    the two-pass pipeline wins for some SE/dtype route just those nodes."""
    from repro.morph.opt.cost import cost_model_for

    return cost_model_for(policy).fused_wins(
        se, jnp.dtype(dtype).name, gradient=gradient
    )


def raw_morph2d(
    x: Array, se, op: str, *, policy: DispatchPolicy, interpret: bool | None = None
) -> Array:
    """Backend primitive for the kernel lowering: fused megakernel when the
    policy, the SE, and the per-node cost model allow; two-pass + transpose
    pipeline otherwise."""
    interpret = resolve_interpret(interpret, policy)
    if (
        policy.fused_2d
        and fused_supports(se)
        and x.ndim in (2, 3)
        and _fused_wins(se, x.dtype, policy)
    ):
        return morph2d_fused(
            x, tuple(se), op=op, method=_fused_method(policy),
            policy=policy, interpret=interpret,
        )
    return _morph2d_two_pass(x, se, op, policy, interpret)


def raw_gradient2d(
    x: Array, se, *, policy: DispatchPolicy, interpret: bool | None = None
) -> Array:
    """Backend primitive for the gradient pattern: the shared-strip fused
    gradient kernel, or two-pass dilate/erode plus a widened subtraction."""
    interpret = resolve_interpret(interpret, policy)
    if x.dtype == jnp.bool_:
        # a boolean gradient is defined in the widened dtype anyway
        # (core.types.widen_dtype), and the fused kernel's in-kernel sub has
        # no boolean form — lattice ops on the widened 0/1 image are
        # bit-identical, so widen once up front
        x = x.astype(widen_dtype(x.dtype))
    if (
        policy.fused_2d
        and fused_supports(se)
        and x.ndim in (2, 3)
        and _fused_wins(se, x.dtype, policy, gradient=True)
    ):
        return gradient2d_fused(
            x, tuple(se), method=_fused_method(policy),
            policy=policy, interpret=interpret,
        )
    two = dataclasses.replace(policy, fused_2d=False)
    d = raw_morph2d(x, se, "max", policy=two, interpret=interpret)
    e = raw_morph2d(x, se, "min", policy=two, interpret=interpret)
    return widened_sub(d, e)


def _folded_policy(policy, fused, method, lane_strategy, interpret) -> DispatchPolicy:
    policy = policy or DispatchPolicy.calibrated()
    return policy.with_overrides(
        fused=fused, method=method, lane_strategy=lane_strategy, interpret=interpret
    )


def _run2d(expr, x, policy, fused, method, lane_strategy, interpret) -> Array:
    policy = _folded_policy(policy, fused, method, lane_strategy, interpret)
    return lower_kernel(expr, policy=policy)(x)


def erode2d_tpu(
    x: Array,
    se=(3, 3),
    *,
    fused: bool | None = None,
    method: str = "auto",
    lane_strategy: LaneStrategy | None = None,
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
) -> Array:
    """2-D erosion: ``lower_kernel(X.erode(se))`` — one fused
    ``pallas_call`` by default (``fused=False`` selects two-pass for A/B)."""
    return _run2d(X.erode(se), x, policy, fused, method, lane_strategy, interpret)


def dilate2d_tpu(
    x: Array,
    se=(3, 3),
    *,
    fused: bool | None = None,
    method: str = "auto",
    lane_strategy: LaneStrategy | None = None,
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
) -> Array:
    """2-D dilation: ``lower_kernel(X.dilate(se))``."""
    return _run2d(X.dilate(se), x, policy, fused, method, lane_strategy, interpret)


def opening2d_tpu(
    x: Array,
    se=(3, 3),
    *,
    fused: bool | None = None,
    method: str = "auto",
    lane_strategy: LaneStrategy | None = None,
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
) -> Array:
    """Erode then dilate: two fused launches by default (was eight)."""
    return _run2d(X.opening(se), x, policy, fused, method, lane_strategy, interpret)


def closing2d_tpu(
    x: Array,
    se=(3, 3),
    *,
    fused: bool | None = None,
    method: str = "auto",
    lane_strategy: LaneStrategy | None = None,
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
) -> Array:
    """Dilate then erode: two fused launches by default (was eight)."""
    return _run2d(X.closing(se), x, policy, fused, method, lane_strategy, interpret)


def gradient2d_tpu(
    x: Array,
    se=(3, 3),
    *,
    fused: bool | None = None,
    method: str = "auto",
    lane_strategy: LaneStrategy | None = None,
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
) -> Array:
    """2-D morphological gradient (dilate - erode, widened for integers).

    ``X.gradient(se)`` is a ``Sub(Dilate, Erode)`` over a shared child; the
    kernel lowering pattern-matches it into the fused gradient kernel (one
    launch sharing the haloed strip between both pipelines) when the policy
    allows, and otherwise into the two-pass pair plus a widened subtraction
    — the same centralized rule (``core.types.widened_sub``) every gradient
    path in the repo now shares.
    """
    return _run2d(X.gradient(se), x, policy, fused, method, lane_strategy, interpret)


def gradient_1d_tpu(
    x: Array, w: int, *, axis: int = -2, interpret: bool | None = None
) -> Array:
    """Fused 1-D morphological gradient (beyond-paper kernel)."""
    w = check_window(w)
    interpret = resolve_interpret(interpret)
    if x.ndim != 2:
        raise ValueError("gradient_1d_tpu operates on (H, W); vmap for batches")
    if axis % 2 == 0:
        return gradient_linear_sublane(x, w=w, interpret=interpret)
    t = transpose_tiled(x, interpret=interpret)
    g = gradient_linear_sublane(t, w=w, interpret=interpret)
    return transpose_tiled(g, interpret=interpret)
