"""Jit'd public wrappers over the Pallas kernels (the paper's §5.3 hybrid).

``morph_1d_tpu`` selects:

* algorithm — ``linear`` kernel for small windows, ``vhgw`` kernel for
  large ones (paper's w0 dispatch; thresholds from core.dispatch policy);
* axis strategy — the sublane (-2) axis runs natively; the lane (-1) axis
  runs as transpose-kernel -> sublane pass -> transpose-kernel, the paper's
  §5.2 transpose trick (or an XLA transpose, selectable, for §Perf A/B).

The 2-D operators (``erode2d_tpu`` / ``dilate2d_tpu`` / ``opening2d_tpu`` /
``closing2d_tpu`` / ``gradient2d_tpu``) default to the *fused* megakernel
(kernels/morph_fused.py): one ``pallas_call`` doing H pass -> in-VMEM
transpose -> W pass -> store, one HBM read + write per operator, with a
batch grid for (B, H, W) stacks. ``fused=False`` (or
``DispatchPolicy(fused_2d=False)``) selects the legacy two-pass +
double-transpose pipeline for A/B comparison; SEs whose W-wing exceeds the
fused policy range (``morph_fused.fused_supports``) fall back to it
automatically.

All entry points accept ``interpret=``; the default ``None`` defers to the
single resolver (``core.dispatch.resolve_interpret``): explicit argument >
``DispatchPolicy.interpret`` > ``REPRO_PALLAS_INTERPRET`` env var > backend
default (compiled on TPU, interpret elsewhere) — so CPU CI validates the
same code that targets TPU without production ever silently running
interpreted Pallas.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.dispatch import DispatchPolicy, resolve_interpret
from repro.core.types import Array, as_op, check_window
from repro.kernels.fused_gradient import gradient_linear_sublane
from repro.kernels.morph_fused import fused_supports, gradient2d_fused, morph2d_fused
from repro.kernels.morph_linear import morph_linear_sublane
from repro.kernels.morph_vhgw import morph_vhgw_sublane
from repro.kernels.transpose import transpose_tiled

LaneStrategy = Literal["transpose_kernel", "xla"]


def _sublane_pass(x, w, op, method, policy: DispatchPolicy, interpret):
    if method == "auto":
        method = "linear" if w <= policy.w0_major else "vhgw"
    fn = morph_linear_sublane if method == "linear" else morph_vhgw_sublane
    return fn(x, w=w, op=op, interpret=interpret)


def morph_1d_tpu(
    x: Array,
    w: int,
    *,
    axis: int = -2,
    op: str = "min",
    method: str = "auto",
    lane_strategy: LaneStrategy = "transpose_kernel",
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
) -> Array:
    """Kernel-backed running min/max along ``axis`` of a 2-D array."""
    w = check_window(w)
    op = as_op(op).name
    policy = policy or DispatchPolicy.calibrated()
    interpret = resolve_interpret(interpret, policy)
    if x.ndim != 2:
        raise ValueError("morph_1d_tpu operates on (H, W); vmap for batches")
    axis = axis % 2
    if w == 1:
        return x
    if axis == 0:  # sublane axis: native
        return _sublane_pass(x, w, op, method, policy, interpret)
    # lane axis: paper's transpose trick
    if lane_strategy == "transpose_kernel":
        t = transpose_tiled(x, interpret=interpret)
        t = _sublane_pass(t, w, op, method, policy, interpret)
        return transpose_tiled(t, interpret=interpret)
    xt = jnp.swapaxes(x, 0, 1)
    out = _sublane_pass(xt, w, op, method, policy, interpret)
    return jnp.swapaxes(out, 0, 1)


def _use_fused(se, fused: bool | None, policy: DispatchPolicy) -> bool:
    if fused is None:
        fused = policy.fused_2d
    return fused and fused_supports(se)


def _morph2d_two_pass(x, se, op, method, lane_strategy, policy, interpret):
    if x.ndim == 3:  # the fused path's batch grid has no two-pass analog
        return jax.vmap(
            lambda m: _morph2d_two_pass(
                m, se, op, method, lane_strategy, policy, interpret
            )
        )(x)
    w_h, w_w = se
    y = morph_1d_tpu(
        x, w_h, axis=0, op=op, method=method,
        lane_strategy=lane_strategy, policy=policy, interpret=interpret,
    )
    return morph_1d_tpu(
        y, w_w, axis=1, op=op, method=method,
        lane_strategy=lane_strategy, policy=policy, interpret=interpret,
    )


def _morph2d(
    x: Array,
    se,
    op: str,
    *,
    fused: bool | None = None,
    method: str = "auto",
    lane_strategy: LaneStrategy = "transpose_kernel",
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
) -> Array:
    policy = policy or DispatchPolicy.calibrated()
    interpret = resolve_interpret(interpret, policy)
    if _use_fused(se, fused, policy) and x.ndim in (2, 3):
        return morph2d_fused(
            x, tuple(se), op=op, method=method if method in ("auto", "linear", "vhgw") else "auto",
            policy=policy, interpret=interpret,
        )
    return _morph2d_two_pass(x, se, op, method, lane_strategy, policy, interpret)


def erode2d_tpu(x: Array, se=(3, 3), **kw) -> Array:
    """2-D erosion; one fused ``pallas_call`` by default (``fused=False`` A/B)."""
    return _morph2d(x, se, "min", **kw)


def dilate2d_tpu(x: Array, se=(3, 3), **kw) -> Array:
    """2-D dilation; one fused ``pallas_call`` by default (``fused=False`` A/B)."""
    return _morph2d(x, se, "max", **kw)


def opening2d_tpu(x: Array, se=(3, 3), **kw) -> Array:
    """Erode then dilate: two fused launches by default (was eight)."""
    return dilate2d_tpu(erode2d_tpu(x, se, **kw), se, **kw)


def closing2d_tpu(x: Array, se=(3, 3), **kw) -> Array:
    """Dilate then erode: two fused launches by default (was eight)."""
    return erode2d_tpu(dilate2d_tpu(x, se, **kw), se, **kw)


def gradient2d_tpu(
    x: Array,
    se=(3, 3),
    *,
    fused: bool | None = None,
    method: str = "auto",
    lane_strategy: LaneStrategy = "transpose_kernel",
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
) -> Array:
    """2-D morphological gradient (dilate - erode, widened for integers).

    The default fused path shares the haloed strip load between the min and
    max pipelines in a single ``pallas_call``; ``fused=False`` computes the
    two-pass dilate/erode pair and subtracts.
    """
    policy = policy or DispatchPolicy.calibrated()
    interpret = resolve_interpret(interpret, policy)
    if _use_fused(se, fused, policy) and x.ndim in (2, 3):
        return gradient2d_fused(
            x, tuple(se),
            method=method if method in ("auto", "linear", "vhgw") else "auto",
            policy=policy, interpret=interpret,
        )
    kw = dict(
        fused=False, method=method, lane_strategy=lane_strategy,
        policy=policy, interpret=interpret,
    )
    d = dilate2d_tpu(x, se, **kw)
    e = erode2d_tpu(x, se, **kw)
    if jnp.issubdtype(x.dtype, jnp.integer):
        return d.astype(jnp.int32) - e.astype(jnp.int32)
    return d - e


def gradient_1d_tpu(
    x: Array, w: int, *, axis: int = -2, interpret: bool | None = None
) -> Array:
    """Fused 1-D morphological gradient (beyond-paper kernel)."""
    w = check_window(w)
    interpret = resolve_interpret(interpret)
    if x.ndim != 2:
        raise ValueError("gradient_1d_tpu operates on (H, W); vmap for batches")
    if axis % 2 == 0:
        return gradient_linear_sublane(x, w=w, interpret=interpret)
    t = transpose_tiled(x, interpret=interpret)
    g = gradient_linear_sublane(t, w=w, interpret=interpret)
    return transpose_tiled(g, interpret=interpret)
