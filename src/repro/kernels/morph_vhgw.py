"""Pallas kernel: van Herk/Gil-Werman 1-D morphology pass (sublane axis).

Paper §5.1.1 baseline, adapted to TPU (DESIGN.md §2):

* The paper streams the forward/backward running-min buffers F and B
  through two image-sized scratch arrays; here both live entirely in VMEM
  for the current (nseg*w, BW) strip — no HBM round trip.
* The paper computes F/B with a sequential O(1)-per-pixel loop (good on a
  scalar/short-vector core). A sequential loop over sublanes would serialize
  the VPU, so the scans are computed with a Hillis-Steele doubling ladder:
  ceil(log2 w) vector ops per segment instead of w, at full (8,128) width.
  Per-pixel cost: ~2*ceil(log2 w) + 1 vector ops — still O(1)-ish in w and
  independent of window *position*, preserving the paper's key property.

VMEM budget: 3 copies of the (ceil((H+w-1)/w)*w, BW) strip.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.types import Array, as_op, check_window


def _scan_segments(segs, op, neutral, reverse: bool):
    """Inclusive prefix (or suffix) min/max within each length-w segment.

    Hillis-Steele doubling: after step s, F[t] covers segs[t-2s+1 .. t].
    Neutral-element fill keeps the scan confined to its segment.
    """
    nseg, w, bw = segs.shape
    out, s = segs, 1
    while s < w:
        if reverse:
            shifted = jnp.concatenate(
                [out[:, s:, :], jnp.full((nseg, s, bw), neutral, segs.dtype)], axis=1
            )
        else:
            shifted = jnp.concatenate(
                [jnp.full((nseg, s, bw), neutral, segs.dtype), out[:, :-s, :]], axis=1
            )
        out = op.reduce(out, shifted)
        s *= 2
    return out


def _vhgw_kernel(x_ref, o_ref, *, w: int, opname: str, nseg: int):
    op = as_op(opname)
    neutral = op.neutral(x_ref.dtype)
    h = o_ref.shape[0]
    bw = o_ref.shape[1]
    segs = x_ref[...].reshape(nseg, w, bw)
    fwd = _scan_segments(segs, op, neutral, reverse=False).reshape(nseg * w, bw)
    bwd = _scan_segments(segs, op, neutral, reverse=True).reshape(nseg * w, bw)
    # out[i] = op(B[i], F[i + w - 1]): window [i, i+w-1] spans <= 2 segments.
    o_ref[...] = op.reduce(bwd[0:h, :], fwd[w - 1 : w - 1 + h, :])


@functools.partial(jax.jit, static_argnames=("w", "op", "block_w", "interpret"))
def morph_vhgw_sublane(
    x: Array,
    *,
    w: int,
    op: str = "min",
    block_w: int = 128,
    interpret: bool = True,
) -> Array:
    """vHGW running min/max of window ``w`` along axis -2 of a 2-D array."""
    w = check_window(w)
    mop = as_op(op)
    if x.ndim != 2:
        raise ValueError("kernel operates on (H, W); vmap for batches")
    h, wid = x.shape
    if w == 1:
        return x
    wing = (w - 1) // 2
    padded = h + 2 * wing
    nseg = -(-padded // w)
    extra = nseg * w - padded
    pw = -wid % block_w
    xp = jnp.pad(
        x,
        ((wing, wing + extra), (0, pw)),
        constant_values=mop.neutral(x.dtype),
    )
    grid = ((wid + pw) // block_w,)
    out = pl.pallas_call(
        functools.partial(_vhgw_kernel, w=w, opname=mop.name, nseg=nseg),
        grid=grid,
        in_specs=[pl.BlockSpec((nseg * w, block_w), lambda j: (0, j))],
        out_specs=pl.BlockSpec((h, block_w), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((h, wid + pw), x.dtype),
        interpret=interpret,
    )(xp)
    return out[:, :wid]
