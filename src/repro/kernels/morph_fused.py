"""Fused separable 2-D morphology megakernel: one ``pallas_call`` per op.

The paper's core win (§4, §5.2) is that the vertical pass never sees data in
a slow layout: the transpose happens *inside the working set* via the VTRN
in-register ladder, so a full erode/dilate costs one read and one write of
the image. The previous TPU port lost exactly that — ``erode2d_tpu`` issued
two morphology ``pallas_call``s plus two full ``transpose_tiled`` kernels,
i.e. four HBM traversals. This kernel restores the paper's structure:

* grid ``(B, W/BW)`` — a leading batch dimension so ``(B, H, W)`` stacks run
  as one launch instead of ``vmap``-of-kernels;
* per grid cell, a haloed ``(H + w_h - 1, BW + w_w - 1)`` strip is assembled
  in VMEM from the center block plus a narrow pre-gathered halo block
  (``2 * wing_w`` columns per grid cell, built by one cheap XLA gather over
  ~``2*wing_w/BW`` of the image), so each cell reads ``BW + w_w - 1``
  columns — not three full blocks, and not a second HBM traversal;
* the sublane (H) pass runs first — linear ladder for small windows, vHGW
  Hillis-Steele scans for large, per ``DispatchPolicy`` thresholds;
* the block is transposed *inside the kernel* (``.T`` on the VMEM value —
  Mosaic's lane/sublane exchange, the TPU analog of the paper's VTRN ladder,
  i.e. ``transpose_tiled``'s in-tile trick without the HBM round trip);
* the lane-turned-sublane (W) pass runs, the block is transposed back, and
  the single output store happens.

HBM traffic per operator: ~(1 + w_w/BW) reads + 1 write versus 4 full
read+write round trips for the two-pass + double-transpose path.

VMEM budget per grid cell (see DESIGN.md §5): the (Hp, BW) center block,
the (Hp, 2*wing_w) halo block, the assembled (Hp, BW + w_w - 1) strip, and
the transposed (BW + w_w - 1, H) scratch; ``_pick_block_w`` sizes BW
against a 12 MB soft budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.dispatch import DispatchPolicy
from repro.core.types import MAX, MIN, Array, as_op, check_window
from repro.kernels.morph_vhgw import _scan_segments


def _resolve_methods(se, method, policy: DispatchPolicy | None, dtype="uint8"):
    """Per-axis linear-vs-vHGW choice. Both fused passes are sublane passes
    (the W pass runs after the in-kernel transpose), and both work on a
    VMEM-resident strip, so the dedicated ``fused`` axis-kind cost curves
    apply — not the HBM-pass major/minor curves (see DESIGN.md §5). The
    query goes through the per-device cost model
    (``repro.morph.opt.cost.cost_model_for``); without a measured table it
    degrades to the policy's ``w <= w0_fused`` scalar branch exactly."""
    policy = policy or DispatchPolicy.calibrated()
    if method == "auto":
        from repro.morph.opt.cost import cost_model_for

        model = cost_model_for(policy)
        dt = jnp.dtype(dtype).name
        return tuple(
            model.best_method("fused", w, dt, small="linear") for w in se
        )
    if method in ("linear", "vhgw"):
        return (method, method)
    raise ValueError(f"fused kernel supports 'auto'|'linear'|'vhgw', got {method!r}")


def _vmem_pass(block, w: int, op, neutral, method: str, n_out: int):
    """Running min/max of window ``w`` along axis 0 of an in-VMEM value.

    ``block`` has ``n_out + w - 1`` rows (the haloed extent); returns
    ``n_out`` rows. Slices along sublanes are free offset reads of the same
    VMEM value, exactly like the two standalone kernels.
    """
    if w == 1:
        return block
    if method == "linear":
        val = block[0:n_out, :]
        for k in range(1, w):
            val = op.reduce(val, block[k : k + n_out, :])
        return val
    # vHGW: pad rows to a whole number of w-segments, then the forward /
    # backward Hillis-Steele scans of morph_vhgw, all inside VMEM.
    rows, cols = block.shape
    nseg = -(-rows // w)
    extra = nseg * w - rows
    if extra:
        block = jnp.concatenate(
            [block, jnp.full((extra, cols), neutral, block.dtype)], axis=0
        )
    segs = block.reshape(nseg, w, cols)
    fwd = _scan_segments(segs, op, neutral, reverse=False).reshape(nseg * w, cols)
    bwd = _scan_segments(segs, op, neutral, reverse=True).reshape(nseg * w, cols)
    return op.reduce(bwd[0:n_out, :], fwd[w - 1 : w - 1 + n_out, :])


def _assemble_strip(xc, xh, wing_w: int):
    """Haloed strip (Hp, BW + 2*wing_w) from the center block and the
    narrow pre-gathered halo block (Hp, 2*wing_w): left wing first."""
    if wing_w == 0:
        return xc
    return jnp.concatenate([xh[:, :wing_w], xc, xh[:, wing_w:]], axis=1)


def _fused_pipeline(strip, *, w_h, w_w, op, neutral, method_h, method_w, h_out):
    """H pass -> in-VMEM transpose -> W pass -> transpose back."""
    y = _vmem_pass(strip, w_h, op, neutral, method_h, h_out)
    yt = y.T  # in-VMEM transpose: Mosaic's lane/sublane exchange (paper §4)
    bw = yt.shape[0] - (w_w - 1)
    z = _vmem_pass(yt, w_w, op, neutral, method_w, bw)
    return z.T


def _fused_kernel(xc_ref, xh_ref, o_ref, *, w_h, w_w, opname,
                  method_h, method_w, wing_w):
    op = as_op(opname)
    neutral = op.neutral(xc_ref.dtype)
    strip = _assemble_strip(xc_ref[0], xh_ref[0], wing_w)
    o_ref[0] = _fused_pipeline(
        strip, w_h=w_h, w_w=w_w, op=op, neutral=neutral,
        method_h=method_h, method_w=method_w, h_out=o_ref.shape[1],
    )


def _gradient_kernel(nc_ref, nh_ref, xc_ref, xh_ref, o_ref, *,
                     w_h, w_w, method_h, method_w, wing_w):
    """Shared-load fused gradient: the min (erode) and max (dilate) pipelines
    run over the same haloed strip in one kernel; only the pad borders differ
    (each op needs its own neutral element), hence two padded views."""
    h_out = o_ref.shape[1]
    e = _fused_pipeline(
        _assemble_strip(nc_ref[0], nh_ref[0], wing_w),
        w_h=w_h, w_w=w_w, op=MIN, neutral=MIN.neutral(nc_ref.dtype),
        method_h=method_h, method_w=method_w, h_out=h_out,
    )
    d = _fused_pipeline(
        _assemble_strip(xc_ref[0], xh_ref[0], wing_w),
        w_h=w_h, w_w=w_w, op=MAX, neutral=MAX.neutral(xc_ref.dtype),
        method_h=method_h, method_w=method_w, h_out=h_out,
    )
    o_ref[0] = d.astype(o_ref.dtype) - e.astype(o_ref.dtype)


def _pad_for_grid(x, wing_h: int, wing_w: int, block_w: int, neutral):
    """Neutral-pad (B, H, W) to (B, Hp, gw * BW) plus a narrow pre-gathered
    halo array (B, Hp, gw * 2 * wing_w) holding, for each column block, its
    left wing then its right wing. The halo gather is one cheap XLA pass over
    ~2*wing_w/BW of the image, and it is what lets every grid cell read
    BW + 2*wing_w columns instead of three full blocks. Returns
    (padded, halo, gw)."""
    b, _, wid = x.shape
    pw = -wid % block_w
    gw = (wid + pw) // block_w
    xp = jnp.pad(
        x,
        ((0, 0), (wing_h, wing_h), (wing_w, pw + wing_w)),
        constant_values=neutral,
    )
    hp = xp.shape[1]
    if wing_w == 0:
        # degenerate 1-col dummy so the BlockSpec stays well-formed
        return xp, jnp.zeros((b, hp, gw), xp.dtype), gw
    left = jnp.stack(
        [xp[:, :, j * block_w : j * block_w + wing_w] for j in range(gw)], axis=2
    )
    right = jnp.stack(
        [
            xp[:, :, wing_w + (j + 1) * block_w : wing_w + (j + 1) * block_w + wing_w]
            for j in range(gw)
        ],
        axis=2,
    )
    halo = jnp.concatenate([left, right], axis=-1).reshape(b, hp, gw * 2 * wing_w)
    core = xp[:, :, wing_w : wing_w + gw * block_w]
    return core, halo, gw


_VMEM_SOFT_BUDGET = 12 * 2**20  # leave headroom under the ~16 MB/core VMEM
_MAX_AUTO_BLOCK_W = 512  # widest strip _pick_block_w will choose


def fused_supports(se) -> bool:
    """Whether the fused kernel's auto block sizing covers this SE's W-halo
    (the single capability predicate ops.py dispatches on)."""
    return (check_window(se[1]) - 1) // 2 <= _MAX_AUTO_BLOCK_W


def _pick_block_w(wing_w: int, h: int, w_h: int, itemsize: int) -> int:
    """Auto block width: widen the strip until the W-halo overhead
    ((BW + w_w - 1) / BW) is small, then shrink back while the estimated
    VMEM working set exceeds the soft budget (DESIGN.md §5)."""
    min_bw = 128
    while min_bw < wing_w:  # correctness floor: the halo must fit one block
        min_bw *= 2
    bw = min_bw
    while bw < _MAX_AUTO_BLOCK_W and wing_w > bw // 16:
        bw *= 2
    while bw > min_bw:
        hp = h + w_h - 1
        strip_w = bw + 2 * wing_w
        est = (hp * (bw + 2 * wing_w + strip_w) + 2 * strip_w * h) * itemsize
        if est <= _VMEM_SOFT_BUDGET:
            break
        bw //= 2
    return bw


def _check_fusable(se, block_w: int | None) -> tuple[int, int]:
    w_h, w_w = (check_window(w) for w in se)
    if block_w is not None and (w_w - 1) // 2 > block_w:
        raise ValueError(
            f"fused kernel needs wing_w <= block_w ({(w_w - 1) // 2} > {block_w}); "
            "use the two-pass path (fused=False) for such wide SEs"
        )
    return w_h, w_w


@functools.partial(
    jax.jit,
    static_argnames=("se", "op", "method", "policy", "block_w", "interpret"),
)
def morph2d_fused(
    x: Array,
    se=(3, 3),
    *,
    op: str = "min",
    method: str = "auto",
    policy: DispatchPolicy | None = None,
    block_w: int | None = None,
    interpret: bool = True,
) -> Array:
    """Separable 2-D erosion/dilation as a single ``pallas_call``.

    ``x`` is ``(H, W)`` or ``(B, H, W)``; batches run as a leading grid
    dimension, not ``vmap``-of-kernels.
    """
    w_h, w_w = _check_fusable(se, block_w)
    mop = as_op(op)
    if x.ndim == 2:
        return morph2d_fused(
            x[None], se, op=mop.name, method=method, policy=policy,
            block_w=block_w, interpret=interpret,
        )[0]
    if x.ndim != 3:
        raise ValueError("morph2d_fused operates on (H, W) or (B, H, W)")
    if w_h == 1 and w_w == 1:
        return x
    b, h, wid = x.shape
    wing_h, wing_w = (w_h - 1) // 2, (w_w - 1) // 2
    if block_w is None:
        block_w = _pick_block_w(wing_w, h, w_h, jnp.dtype(x.dtype).itemsize)
    method_h, method_w = _resolve_methods((w_h, w_w), method, policy, x.dtype)
    core, halo, gw = _pad_for_grid(x, wing_h, wing_w, block_w, mop.neutral(x.dtype))
    hp = h + 2 * wing_h
    halo_cols = halo.shape[-1] // gw
    out = pl.pallas_call(
        functools.partial(
            _fused_kernel, w_h=w_h, w_w=w_w, opname=mop.name,
            method_h=method_h, method_w=method_w, wing_w=wing_w,
        ),
        grid=(b, gw),
        in_specs=[
            pl.BlockSpec((1, hp, block_w), lambda bi, j: (bi, 0, j)),
            pl.BlockSpec((1, hp, halo_cols), lambda bi, j: (bi, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, h, block_w), lambda bi, j: (bi, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, h, gw * block_w), x.dtype),
        interpret=interpret,
    )(core, halo)
    return out[:, :, :wid]


@functools.partial(
    jax.jit,
    static_argnames=("se", "method", "policy", "block_w", "interpret"),
)
def gradient2d_fused(
    x: Array,
    se=(3, 3),
    *,
    method: str = "auto",
    policy: DispatchPolicy | None = None,
    block_w: int | None = None,
    interpret: bool = True,
) -> Array:
    """Fused 2-D morphological gradient (dilate - erode) in one launch.

    Both pipelines run over the strip inside one kernel, but two padded
    views of the image are shipped (erode and dilate need different neutral
    border values), so the cost is 2 reads + 1 write — versus ~9 traversals
    for two-pass dilate/erode plus the subtraction. Integer inputs widen to
    int32 (i8 differences overflow i8), floats keep their dtype.
    """
    w_h, w_w = _check_fusable(se, block_w)
    if x.ndim == 2:
        return gradient2d_fused(
            x[None], se, method=method, policy=policy,
            block_w=block_w, interpret=interpret,
        )[0]
    if x.ndim != 3:
        raise ValueError("gradient2d_fused operates on (H, W) or (B, H, W)")
    out_dtype = jnp.int32 if jnp.issubdtype(x.dtype, jnp.integer) else x.dtype
    if w_h == 1 and w_w == 1:
        return jnp.zeros_like(x, dtype=out_dtype)
    b, h, wid = x.shape
    wing_h, wing_w = (w_h - 1) // 2, (w_w - 1) // 2
    if block_w is None:
        # gradient holds two strips (min and max pipelines): halve the budget
        block_w = _pick_block_w(wing_w, h, w_h, 2 * jnp.dtype(x.dtype).itemsize)
    method_h, method_w = _resolve_methods((w_h, w_w), method, policy, x.dtype)
    core_min, halo_min, gw = _pad_for_grid(x, wing_h, wing_w, block_w, MIN.neutral(x.dtype))
    core_max, halo_max, _ = _pad_for_grid(x, wing_h, wing_w, block_w, MAX.neutral(x.dtype))
    hp = h + 2 * wing_h
    halo_cols = halo_min.shape[-1] // gw
    core_spec = pl.BlockSpec((1, hp, block_w), lambda bi, j: (bi, 0, j))
    halo_spec = pl.BlockSpec((1, hp, halo_cols), lambda bi, j: (bi, 0, j))
    out = pl.pallas_call(
        functools.partial(
            _gradient_kernel, w_h=w_h, w_w=w_w,
            method_h=method_h, method_w=method_w, wing_w=wing_w,
        ),
        grid=(b, gw),
        in_specs=[core_spec, halo_spec, core_spec, halo_spec],
        out_specs=pl.BlockSpec((1, h, block_w), lambda bi, j: (bi, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, h, gw * block_w), out_dtype),
        interpret=interpret,
    )(core_min, halo_min, core_max, halo_max)
    return out[:, :, :wid]
