"""Synthetic audio-frame pipeline for the Whisper arch (frontend stub).

The conv frontend is stubbed per the assignment: this module produces
log-mel-like frame embeddings directly, plus SpecAugment-style time/freq
masking where the mask widening is a *dilation* along the masked axis
(core.masks.dilate_mask) — the paper's primitive applied to spectrogram
augmentation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dilate_mask


def synth_frames(batch: int, seq: int, d_model: int, *, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    t = np.arange(seq)[None, :, None]
    f = rng.random((batch, 1, d_model))
    x = 0.1 * np.sin(2 * np.pi * (f * t / 50.0)) + 0.01 * rng.standard_normal(
        (batch, seq, d_model)
    )
    return x.astype(np.float32)


def spec_augment(frames: jnp.ndarray, *, n_time_masks: int = 2, time_width: int = 8,
                 n_freq_masks: int = 2, freq_width: int = 4, seed: int = 0) -> jnp.ndarray:
    """Seed masks at random single positions, then *dilate* to target width."""
    b, t, d = frames.shape
    key = jax.random.PRNGKey(seed)
    kt, kf = jax.random.split(key)
    tm = jnp.zeros((b, t), bool)
    pos = jax.random.randint(kt, (b, n_time_masks), 0, t)
    tm = tm.at[jnp.arange(b)[:, None], pos].set(True)
    tm = dilate_mask(tm, time_width // 2, axis=-1)  # paper's dilation
    fm = jnp.zeros((b, d), bool)
    pos = jax.random.randint(kf, (b, n_freq_masks), 0, d)
    fm = fm.at[jnp.arange(b)[:, None], pos].set(True)
    fm = dilate_mask(fm, freq_width // 2, axis=-1)
    out = jnp.where(tm[:, :, None], 0.0, frames)
    return jnp.where(fm[:, None, :], 0.0, out)
