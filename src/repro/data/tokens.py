"""Synthetic-but-deterministic LM token pipeline.

Produces an infinite stream of (tokens, labels) batches with a Zipf-ish
unigram distribution plus short-range structure (bigram coupling), so the
loss actually decreases during the e2e example run. Host-sharded: each
process materializes only its slice of the global batch (process_index /
process_count), which is how the pipeline behaves on a real multi-host pod.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig, *, process_index: int = 0,
                 process_count: int = 1):
        if cfg.global_batch % process_count:
            raise ValueError("global_batch must divide across processes")
        self.cfg = cfg
        self.local_batch = cfg.global_batch // process_count
        self._rng = np.random.default_rng(cfg.seed * 1000 + process_index)
        # Zipf-ish unigram over a capped support for sampling efficiency.
        support = min(cfg.vocab_size, 50_000)
        probs = 1.0 / np.arange(1, support + 1) ** cfg.zipf_a
        self._probs = probs / probs.sum()
        self._support = support

    def __iter__(self) -> Iterator[dict]:
        c = self.cfg
        while True:
            flat = self._rng.choice(
                self._support, size=(self.local_batch, c.seq_len + 1), p=self._probs
            ).astype(np.int32)
            # bigram structure: even positions often copy-shift the previous
            couple = self._rng.random((self.local_batch, c.seq_len + 1)) < 0.3
            flat[:, 1:] = np.where(
                couple[:, 1:], (flat[:, :-1] + 1) % c.vocab_size, flat[:, 1:]
            )
            yield {"tokens": flat[:, :-1], "labels": flat[:, 1:]}
