"""Synthetic document-image pipeline — the paper's morphology in production.

Generates noisy scanned-document-like u8 grayscale images (text strokes +
salt-and-pepper noise + background gradient), then runs the paper's
separable morphology as the cleanup stage:

  1. opening  (erode-dilate) removes salt noise,
  2. closing  (dilate-erode) heals broken strokes,
  3. morphological gradient extracts stroke edges (feature channel),

all via the hybrid vHGW/linear dispatch (core.dispatch). The cleaned image
is then max-pooled (dilation + stride — core.masks.maxpool2d) into a patch
grid and linearly embedded: this is the stub "vision tower" whose output
feeds llama-3.2-vision's cross-attention layers.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import maxpool2d
from repro.morph import Cast, X, lower_xla, op_expr


@dataclasses.dataclass(frozen=True)
class ImagePipelineConfig:
    height: int = 600
    width: int = 800
    noise_frac: float = 0.02
    se_open: tuple = (3, 3)
    se_close: tuple = (5, 5)
    patch: int = 16
    seed: int = 0


def synth_documents(cfg: ImagePipelineConfig, batch: int) -> np.ndarray:
    """(B, H, W) u8, text-like dark strokes on light background."""
    rng = np.random.default_rng(cfg.seed)
    img = np.full((batch, cfg.height, cfg.width), 220, np.uint8)
    # horizontal "text lines"
    for b in range(batch):
        n_lines = rng.integers(10, 25)
        for _ in range(n_lines):
            y = rng.integers(10, cfg.height - 12)
            x0 = rng.integers(0, cfg.width // 3)
            x1 = rng.integers(2 * cfg.width // 3, cfg.width)
            h = rng.integers(2, 6)
            # broken strokes: random gaps
            xs = np.arange(x0, x1)
            keep = rng.random(xs.size) > 0.15
            img[b, y : y + h, xs[keep]] = rng.integers(10, 60)
    # salt & pepper
    mask = rng.random(img.shape) < cfg.noise_frac
    img[mask] = rng.choice([0, 255], size=int(mask.sum()))
    return img


def synth_sparse_masks(
    batch: int,
    height: int,
    width: int,
    *,
    run_density: float = 0.01,
    mean_run: int = 12,
    seed: int = 0,
) -> np.ndarray:
    """(B, H, W) bool masks with a controllable *run density* knob.

    ``run_density`` is foreground runs per pixel — the exact quantity the
    RLE cost curves and the serving gate dispatch on, which ad-hoc
    ``np.random`` thresholding cannot hit (iid pixel noise couples run
    count to pixel density). Each mask scatters ~``run_density * H * W``
    horizontal segments of geometric mean length ``mean_run`` at uniform
    positions — the stroke-fragment structure a thresholded document scan
    has. Overlapping segments merge, so the realized density lands
    slightly under the knob at high settings; tests/benchmarks that need
    the true value should measure it (``estimate_run_density``).
    """
    if not 0.0 <= run_density <= 0.5:
        raise ValueError(f"run_density must be in [0, 0.5], got {run_density}")
    rng = np.random.default_rng(seed)
    out = np.zeros((batch, height, width), np.bool_)
    n_runs = int(round(run_density * height * width))
    if n_runs == 0:
        return out
    flat = out.reshape(batch, height * width)
    for b in range(batch):
        rows = rng.integers(0, height, n_runs)
        starts = rng.integers(0, width, n_runs)
        lens = np.minimum(
            rng.geometric(1.0 / max(1, mean_run), n_runs), width - starts
        )
        # one boolean cumsum-free scatter per mask: mark [start, end) cells
        first = np.cumsum(lens) - lens
        idx = np.repeat(np.arange(n_runs), lens)
        offs = np.arange(int(lens.sum())) - first[idx]
        flat[b, rows[idx] * width + starts[idx] + offs] = True
    return out


# The canonical cleanup chain, as data: (op, se) stages consumed both by
# ``_cleanup`` below and by serve/morph/plans.py (``document_cleanup`` plan),
# so the service and the raw pipeline are verifiably the same computation.
CLEANUP_STEPS: tuple[tuple[str, tuple[int, int]], ...] = (
    ("opening", (3, 3)),   # removes salt noise
    ("closing", (5, 5)),   # heals broken strokes -> "clean" output
    ("gradient", (3, 3)),  # stroke edges (u8) -> "edges" output
)

# The same chain as one expression graph (repro.morph): the direct path
# lowers it through XLA here, the serving plan compiles the identical graph.
_CLEAN_EXPR = op_expr(
    CLEANUP_STEPS[1][0], CLEANUP_STEPS[1][1],
    op_expr(CLEANUP_STEPS[0][0], CLEANUP_STEPS[0][1], X),
)
_EDGES_EXPR = Cast(op_expr(CLEANUP_STEPS[2][0], CLEANUP_STEPS[2][1], _CLEAN_EXPR), "uint8")
CLEANUP_EXPRS = (("clean", _CLEAN_EXPR), ("edges", _EDGES_EXPR))


@jax.jit
def _cleanup(img: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    outs = lower_xla(dict(CLEANUP_EXPRS))(img)
    return outs["clean"], outs["edges"]


def cleanup_batch(img: np.ndarray):
    """Morphological document cleanup: returns (cleaned, edge_features)."""
    return _cleanup(jnp.asarray(img))


def patch_embed_stub(img: jnp.ndarray, d_model: int, *, patch: int = 16,
                     n_tokens: int | None = None) -> jnp.ndarray:
    """Stub vision tower: pool -> patchify -> fixed random projection.

    (B, H, W) u8 -> (B, N, d_model) f32. Deterministic projection matrix
    (PRNG key 0) stands in for the real ViT tower per the assignment.
    """
    x = img.astype(jnp.float32) / 255.0
    x = maxpool2d(x, 2)  # dilation-as-pooling (paper primitive)
    b, h, w = x.shape
    h2, w2 = h - h % patch, w - w % patch
    x = x[:, :h2, :w2].reshape(b, h2 // patch, patch, w2 // patch, patch)
    x = x.transpose(0, 1, 3, 2, 4).reshape(b, -1, patch * patch)
    proj = jax.random.normal(jax.random.PRNGKey(0), (patch * patch, d_model)) * 0.02
    tokens = x @ proj
    if n_tokens is not None:
        tokens = tokens[:, :n_tokens]
        pad = n_tokens - tokens.shape[1]
        if pad > 0:
            tokens = jnp.pad(tokens, ((0, 0), (0, pad), (0, 0)))
    return tokens
