"""Data pipelines: synthetic token stream, document images (morphology
cleanup — the paper's technique in production), audio frames (dilated
SpecAugment masks)."""
from repro.data.audio import spec_augment, synth_frames
from repro.data.images import ImagePipelineConfig, cleanup_batch, patch_embed_stub, synth_documents
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
