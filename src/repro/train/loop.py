"""Training loop with fault tolerance, straggler watchdog and microbatching.

Composes the substrate: model (models/), optimizer (optim/), data (data/),
checkpointing (checkpoint/), sharding (launch/sharding.py). The loop is
deliberately framework-shaped:

* **train_step** — loss + grad + clip + AdamW, jit'd once with explicit
  in/out shardings; optional gradient (micro-batch) accumulation via
  ``lax.scan`` over microbatches.
* **fault tolerance** — resume from the newest committed checkpoint;
  periodic async saves off the critical path; an emergency blocking save
  on any exception (then re-raise), so a preempted worker loses at most
  one interval.
* **straggler watchdog** — per-step wall time is tracked with a running
  median; steps slower than ``straggler_factor`` x median emit a flag
  (on a fleet: feeds the reschedule controller; here: recorded + tested).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager
from repro.models.config import ModelConfig
from repro.models.model import init_params, loss_fn
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, warmup_cosine


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    warmup_steps: int = 10
    peak_lr: float = 3e-4
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    microbatches: int = 1  # grad accumulation
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    keep_checkpoints: int = 3
    straggler_factor: float = 2.0
    log_every: int = 10


def make_train_step(cfg: ModelConfig, loop: TrainLoopConfig) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics). state =
    {"params":..., "opt":...}. Pure; jit it with shardings at call site."""

    def compute_grads(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        return loss, metrics, grads

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        if loop.microbatches > 1:
            def micro(carry, mb):
                loss_sum, grad_sum = carry
                loss, _, grads = compute_grads(params, mb)
                return (
                    loss_sum + loss,
                    jax.tree.map(jnp.add, grad_sum, grads),
                ), None

            mbatch = jax.tree.map(
                lambda a: a.reshape((loop.microbatches, -1) + a.shape[1:]), batch
            )
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0.0), zeros), mbatch)
            loss = loss / loop.microbatches
            grads = jax.tree.map(lambda g: g / loop.microbatches, grads)
            metrics = {"ce": loss, "aux": jnp.float32(0.0)}
        else:
            loss, metrics, grads = compute_grads(params, batch)

        grads, gnorm = clip_by_global_norm(grads, loop.clip_norm)
        lr = warmup_cosine(
            opt.step, peak_lr=loop.peak_lr, warmup_steps=loop.warmup_steps,
            total_steps=loop.total_steps,
        )
        params, opt = adamw_update(
            grads, opt, params, lr=lr, weight_decay=loop.weight_decay
        )
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return {"params": params, "opt": opt}, metrics

    return train_step


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        loop: TrainLoopConfig,
        data: Iterator[dict],
        *,
        jit_kwargs: Optional[dict] = None,
        seed: int = 0,
    ):
        self.cfg, self.loop, self.data = cfg, loop, iter(data)
        self.step_fn = jax.jit(make_train_step(cfg, loop), **(jit_kwargs or {}))
        params = init_params(cfg, jax.random.PRNGKey(seed))
        self.state: Any = {"params": params, "opt": adamw_init(params)}
        self.start_step = 0
        self.step_times: list[float] = []
        self.straggler_flags: list[int] = []
        self.ckpt = (
            CheckpointManager(loop.checkpoint_dir, keep=loop.keep_checkpoints)
            if loop.checkpoint_dir
            else None
        )
        if self.ckpt and self.ckpt.latest_step() is not None:
            s = self.ckpt.latest_step()
            self.state = self.ckpt.restore(s, self.state)
            self.start_step = s
            print(f"[trainer] resumed from step {s}")

    def _watchdog(self, step: int, dt: float):
        self.step_times.append(dt)
        hist = sorted(self.step_times[-50:])
        med = hist[len(hist) // 2]
        if len(hist) >= 5 and dt > self.loop.straggler_factor * med:
            self.straggler_flags.append(step)
            print(f"[watchdog] step {step} took {dt:.3f}s (median {med:.3f}s) "
                  f"— straggler flagged")

    def run(self) -> dict:
        metrics = {}
        step = self.start_step
        try:
            while step < self.loop.total_steps:
                batch = {
                    k: jnp.asarray(v) for k, v in next(self.data).items()
                }
                t0 = time.perf_counter()
                self.state, metrics = self.step_fn(self.state, batch)
                jax.block_until_ready(metrics["loss"])
                self._watchdog(step, time.perf_counter() - t0)
                step += 1
                if step % self.loop.log_every == 0:
                    print(f"[trainer] step {step} loss={float(metrics['loss']):.4f} "
                          f"lr={float(metrics['lr']):.2e}")
                if self.ckpt and step % self.loop.checkpoint_every == 0:
                    self.ckpt.save(step, self.state)
        except Exception:
            if self.ckpt:  # emergency checkpoint, then surface the fault
                self.ckpt.save(step, self.state, blocking=True)
                print(f"[trainer] emergency checkpoint at step {step}")
            raise
        if self.ckpt:
            self.ckpt.save(step, self.state, blocking=True)
        return {k: float(v) for k, v in metrics.items()}
