"""Training loop substrate (fault tolerance, microbatching, watchdog)."""
from repro.train.loop import Trainer, TrainLoopConfig, make_train_step
