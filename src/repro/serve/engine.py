"""Batched decode engine: prefill + sampled generation over the KV cache.

``prefill`` runs the decode cell under ``lax.scan`` across the prompt
(one HLO step body — compile-cheap; a chunked full-seq prefill is a §Perf
note). ``generate`` continues with temperature/greedy sampling. Both are
jit-compatible and mesh-aware: the caller passes sharded params/cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.model import DecodeCache, init_decode_cache, prefill_cross_kv, serve_step


def prefill(cfg: ModelConfig, params, cache: DecodeCache, prompt: jnp.ndarray):
    """prompt: (B, P) i32. Returns (last_logits, cache_after_prompt)."""

    def body(carry, tok_pos):
        cache = carry
        tok, pos = tok_pos
        logits, cache = serve_step(cfg, params, cache, tok[:, None], pos)
        return cache, logits[:, 0]

    toks = prompt.T  # (P, B)
    poss = jnp.arange(prompt.shape[1], dtype=jnp.int32)
    cache, logits_seq = jax.lax.scan(body, cache, (toks, poss))
    return logits_seq[-1], cache


def generate(
    cfg: ModelConfig,
    params,
    prompt: jnp.ndarray,
    *,
    max_new_tokens: int = 16,
    kv_len: Optional[int] = None,
    temperature: float = 0.0,
    seed: int = 0,
    context: Optional[jnp.ndarray] = None,
):
    """Greedy/temperature generation. context = encoder frames (Whisper) or
    image embeddings (VLM); None otherwise."""
    b, p = prompt.shape
    kv_len = kv_len or (p + max_new_tokens)
    cache = init_decode_cache(cfg, b, kv_len)
    if context is not None:
        cache = prefill_cross_kv(cfg, params, cache, context)
    logits, cache = prefill(cfg, params, cache, prompt)
    key = jax.random.PRNGKey(seed)

    def sample(logits, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, logits / temperature, axis=-1).astype(jnp.int32)

    def body(carry, i):
        cache, logits, key = carry
        key, sub = jax.random.split(key)
        tok = sample(logits, sub)
        logits, cache = serve_step(cfg, params, cache, tok[:, None], p + i)
        return (cache, logits[:, 0], key), tok

    (_, _, _), toks = jax.lax.scan(
        body, (cache, logits, key), jnp.arange(max_new_tokens, dtype=jnp.int32)
    )
    return toks.T  # (B, max_new_tokens)
