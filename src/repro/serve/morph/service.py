"""MorphService: the async front door over the fused morphology kernels.

Mirrors the LM engine (serve/engine.py) one layer up: where that engine
batches decode steps over a KV cache, this one batches single-image
morphology requests into (B, H, W) stacks. A request flows:

    submit(img, op/plan)
      -> bucket  (buckets.py: pad up to a fixed (H, W) ladder)   } cache-
      -> batch   (batcher.py: coalesce within a deadline window) } friendly
      -> execute (plans.py executor from the LRU executable cache)
      -> crop + resolve the Future

Images too large for the ladder take the tiled route (tiling.py) through
the same executor cache. The executable cache is keyed on
``(plan, shape, dtype, batch-bucket, policy.cache_token(), backend,
interpret)`` with hit/miss/eviction counters; batch sizes are bucketed to
powers of two so B-variance cannot silently multiply compiles.

Observability (ISSUE 7, ``repro.obs``): every counter/latency surface here
is a view over one :class:`~repro.obs.MetricsRegistry` per service —
``stats()`` derives its dict from registry metrics, and the sharded router
merges registries by metric type instead of re-aggregating stats dicts.
Passing ``ServiceConfig(obs=ObsConfig())`` additionally turns on
per-request tracing (trace ID minted at submit, spans over queue wait /
dispatch / executor, exported via :meth:`MorphService.export_trace` as
Chrome trace-event JSON) and executor profiling (compile-vs-run split per
cache key); ``obs=None`` (default) costs one ``is None`` check per hook.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import DispatchPolicy, resolve_interpret
from repro.obs import (
    MetricsRegistry,
    Observability,
    ObsConfig,
    POW2_BUCKETS,
    cache_stats,
    chrome_trace,
    quantile_from_snapshot,
)
from repro.morph import cost_model_for
from repro.rle import estimate_run_density, lower_rle, plan_rle_eligible
from repro.serve.morph.batcher import MicroBatcher
from repro.serve.morph.buckets import (
    DEFAULT_BUCKETS,
    check_buckets,
    choose_bucket,
    crop_from_bucket,
    valid_rect,
)
from repro.serve.morph.resilience import (
    DeadlineExceeded,
    ExecutorError,
    FaultInjector,
    FaultPlan,
    FailoverPolicy,
    HedgePolicy,
    RetryPolicy,
    ServeError,
)
from repro.serve.morph.tenancy import (
    BrownoutPolicy,
    PRIORITY_NORMAL,
    TenantQuota,
)
from repro.morph.plan_compile import to_plan
from repro.serve.morph.plans import (
    Plan,
    build_executor,
    check_backend,
    get_plan,
    single_op_plan,
)
from repro.serve.morph.tiling import run_tiled


# Run-density histogram bounds (runs per pixel): log-spaced over the range
# the representation gate discriminates on — 0.1% (deep-RLE territory)
# through 50% (checkerboard worst case).
DENSITY_BUCKETS: tuple[float, ...] = (
    0.0005, 0.001, 0.002, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2, 0.5,
)


def _round_up_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class ExecutableCache:
    """LRU over built (jitted) plan executors, with observable counters.

    One entry == one compile of one executable (keys include the padded
    batch size), so ``misses`` is exactly the compile count the service has
    paid — the number the bucket ladder exists to keep small. Counters are
    registry metrics (``cache.*``) so shard merges sum them by type.
    """

    def __init__(self, max_size: int = 128, registry: MetricsRegistry | None = None):
        self.max_size = max_size
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()
        reg = registry if registry is not None else MetricsRegistry()
        self._hits = reg.counter("cache.hits")
        self._misses = reg.counter("cache.misses")
        self._evictions = reg.counter("cache.evictions")
        self._size = reg.gauge("cache.size", mode="sum")

    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    def get(self, key, builder):
        with self._lock:
            if key in self._entries:
                self._hits.inc()
                self._entries.move_to_end(key)
                return self._entries[key]
            self._misses.inc()
        value = builder()  # build outside the lock; benign duplicate on race
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_size:
                self._entries.popitem(last=False)
                self._evictions.inc()
            self._size.set(len(self._entries))
        return value

    def snapshot(self) -> dict:
        with self._lock:
            return cache_stats(
                len(self._entries), self.hits, self.misses, self.evictions
            )


class ServiceStats:
    """Rolling serving metrics: throughput, latency quantiles, occupancy.

    Latencies and batch sizes are fixed-bucket registry histograms
    (``latency_ms``, ``batch_size``): p50/p99 read off the histogram, which
    is what makes the sharded router's cross-shard quantiles well-defined
    (bucket counts add; percentiles never would). Only the throughput
    timestamps stay a rolling deque — img/s needs real arrival times.
    """

    def __init__(self, window: int = 4096, registry: MetricsRegistry | None = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self._lock = threading.Lock()
        self._done_ts = collections.deque(maxlen=window)
        self._requests = self.registry.counter("requests")
        self._batches = self.registry.counter("batches")
        self._tiled = self.registry.counter("tiled_requests")
        self._latency = self.registry.histogram("latency_ms")
        self._batch_sizes = self.registry.histogram("batch_size", POW2_BUCKETS)
        # convergence telemetry from BoundedIter plans (reconstruction):
        # budget is the fixed-trace iteration cap, used what actually ran
        # before the predicated scan converged (interp.py) — the gap is
        # work the convergence-aware serving path reclaims.
        self._bounded_execs = self.registry.counter("bounded_iter.executions")
        self._iters_used = self.registry.counter("bounded_iter.iters_used")
        self._iters_budget = self.registry.counter("bounded_iter.iters_budget")
        # representation gate (repro.rle): one counter per representation
        # decision plus the measured run-density histogram, so the gate's
        # behavior over a traffic mix is auditable from stats()/the registry
        self._rle = self.registry.counter("rle_requests")
        self._repr_dense = self.registry.counter("repr.dense")
        self._repr_rle = self.registry.counter("repr.rle")
        self._density = self.registry.histogram("rle.density", DENSITY_BUCKETS)

    @property
    def requests(self) -> int:
        return self._requests.value

    def record_batch(self, latencies_s) -> None:
        now = time.monotonic()
        with self._lock:
            self._requests.inc(len(latencies_s))
            self._batches.inc()
            self._batch_sizes.observe(len(latencies_s))
            self._latency.observe_many([l * 1e3 for l in latencies_s])
            self._done_ts.extend([now] * len(latencies_s))

    def record_tiled(self, latencies_s) -> None:
        """Tiled requests never ride the batcher's stacks — count their
        latency/throughput but keep them out of the occupancy metrics."""
        now = time.monotonic()
        with self._lock:
            self._requests.inc(len(latencies_s))
            self._tiled.inc(len(latencies_s))
            self._latency.observe_many([l * 1e3 for l in latencies_s])
            self._done_ts.extend([now] * len(latencies_s))

    def record_repr(self, use_rle: bool, density: float) -> None:
        """One representation-gate decision (at submit, before execution)."""
        with self._lock:
            (self._repr_rle if use_rle else self._repr_dense).inc()
            self._density.observe(density)

    def record_rle(self, latencies_s) -> None:
        """RLE-routed requests execute per request on exact-shape run
        buffers — like the tiled route, they never ride the batcher's
        stacks, so they stay out of the occupancy metrics."""
        now = time.monotonic()
        with self._lock:
            self._requests.inc(len(latencies_s))
            self._rle.inc(len(latencies_s))
            self._latency.observe_many([l * 1e3 for l in latencies_s])
            self._done_ts.extend([now] * len(latencies_s))

    def record_bounded(self, used: int, budget: int) -> None:
        with self._lock:
            self._bounded_execs.inc()
            self._iters_used.inc(int(used))
            self._iters_budget.inc(int(budget))

    def snapshot(self, max_batch: int) -> dict:
        with self._lock:
            ts = list(self._done_ts)
            lat = self._latency.snapshot()
            sizes = self._batch_sizes.snapshot()
            # copy under the lock: used/budget must come from one
            # record_bounded or the derived ratio can tear
            bounded_execs = self._bounded_execs.value
            iters_used = self._iters_used.value
            iters_budget = self._iters_budget.value
            density = self._density.snapshot()
        span = (ts[-1] - ts[0]) if len(ts) > 1 else 0.0
        mean_batch = sizes["sum"] / sizes["count"] if sizes["count"] else 0.0
        return {
            "requests": self._requests.value,
            "batches": self._batches.value,
            "tiled_requests": self._tiled.value,
            "rle_requests": self._rle.value,
            "repr": {
                "dense": self._repr_dense.value,
                "rle": self._repr_rle.value,
                "density_p50": quantile_from_snapshot(density, 0.50),
            },
            "bounded_iter": {
                "executions": bounded_execs,
                "iters_used": iters_used,
                "iters_budget": iters_budget,
                "saved_frac": (
                    1.0 - iters_used / iters_budget if iters_budget else 0.0
                ),
            },
            "img_per_s": (len(ts) - 1) / span if span > 0 else 0.0,
            "p50_ms": quantile_from_snapshot(lat, 0.50),
            "p99_ms": quantile_from_snapshot(lat, 0.99),
            "mean_batch": float(mean_batch),
            "occupancy": float(mean_batch) / max_batch,
        }


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    buckets: tuple[tuple[int, int], ...] = DEFAULT_BUCKETS
    max_batch: int = 64
    window_ms: float = 2.0
    # Load-aware deadline window (ROADMAP item): window_ms becomes the MAX;
    # the effective window shrinks toward min_window_ms when dispatches
    # drain below the batcher's low-water mark and grows back toward
    # window_ms under sustained pressure. stats()["effective_window_ms"]
    # reports the current value.
    adaptive_window: bool = True
    min_window_ms: float = 0.0
    tile_interior: tuple[int, int] = (512, 512)
    max_tiles_per_launch: int = 16
    backend: str = "auto"  # "kernel" (fused Pallas) | "jnp" | "auto"
    # Representation gate (repro.rle): boolean requests on run-domain-
    # lowerable plans are probed for run density and routed to RLE when the
    # cost model says runs beat pixels. False = always dense (A/B baseline).
    rle_gate: bool = True
    policy: DispatchPolicy | None = None
    interpret: bool | None = None
    cache_size: int = 128
    stats_window: int = 4096
    # Pin this service's dispatches to one jax device — how the sharded
    # router (repro.shard.router) runs each shard's batcher under its own
    # mesh slot. None = the process default device.
    device: Any = None
    # This service's shard index under a sharded router (labels trace
    # lanes and error context); None for a standalone service.
    shard: int | None = None
    # --- resilience (resilience.py) ---------------------------------------
    # Admission bound on outstanding (queued + in-flight) requests; submit()
    # raises Overloaded past it. None = unbounded (the pre-resilience mode).
    max_queue: int | None = 1024
    # Deadline applied to every request that doesn't pass its own
    # deadline_ms to submit_plan(); None = no deadline.
    default_deadline_ms: float | None = None
    # Retry-with-backoff then bisect for failed dispatch groups.
    retry: RetryPolicy = RetryPolicy()
    # --- tenancy + graduated overload (tenancy.py, ISSUE 9) ---------------
    # Per-tenant admission quotas and fair-share weights; tenants not in
    # the map get DEFAULT_QUOTA (unbounded, weight 1.0). None = single-
    # tenant behavior (the map only matters once submit passes tenant=).
    tenants: "dict[str, TenantQuota] | None" = None
    # Brownout ladder: widen window -> shed low priority (typed
    # BrownoutShed) -> shed all, driven by queue depth + dispatch-latency
    # EWMA. Defaults on: with the default thresholds level 3 can never
    # fire before max_queue itself, so single-tenant behavior is unchanged.
    # None disables the ladder entirely.
    brownout: BrownoutPolicy | None = BrownoutPolicy()
    # Hedged dispatch policy — read by ShardedMorphService (a lone service
    # has no second shard to hedge to), default off.
    hedge: HedgePolicy = HedgePolicy()
    # Circuit breaker / reroute rules — read by ShardedMorphService, inert
    # for a standalone service.
    failover: FailoverPolicy = FailoverPolicy()
    # Deterministic fault injection; None (default) adds zero overhead.
    faults: FaultPlan | None = None
    # Observability (repro.obs): tracing + executor profiling; None
    # (default) adds zero overhead, same contract as ``faults``.
    obs: ObsConfig | None = None


@dataclasses.dataclass
class _Request:
    key: tuple
    img: np.ndarray
    plan: Plan
    bucket: tuple[int, int] | None  # None -> tiled route
    future: Future
    t_submit: float
    deadline: float | None = None  # absolute monotonic seconds
    tag: str | None = None  # caller label; fault injection poisons by tag
    tenant: str | None = None  # tenancy: quota + fair-share identity
    priority: int = PRIORITY_NORMAL  # priority class (lower = more important)
    trace: int | None = None  # obs: request trace ID (minted at submit)
    qspan: Any = None  # obs: open queue-wait span handle


class MorphService:
    """Async morphology serving engine. Use as a context manager:

        with MorphService() as svc:
            fut = svc.submit(img, op="erode", se=(5, 5))
            clean = svc.run_plan(img2, "document_cleanup")["clean"]
    """

    def __init__(self, config: ServiceConfig | None = None):
        self.config = config or ServiceConfig()
        check_buckets(self.config.buckets)
        self.policy = self.config.policy or DispatchPolicy.calibrated()
        self.interpret = resolve_interpret(self.config.interpret, self.policy)
        if self.config.backend == "auto":
            # Compiled Mosaic -> fused megakernel; interpret mode (CPU CI,
            # laptops) -> the pure-XLA separable path, which is bit-exact
            # and far faster than interpreting Pallas.
            self.backend = "jnp" if self.interpret else "kernel"
        else:
            # fail loudly at construction, not inside the batcher thread
            self.backend = check_backend(self.config.backend)
        self.metrics = MetricsRegistry()
        self.cache = ExecutableCache(self.config.cache_size, registry=self.metrics)
        # RLE route caches: structural eligibility per plan (one graph walk)
        # and the host lowering per plan. Plain dicts — host lowerings are a
        # closure over numpy ops, not a compiled artifact worth LRU pressure.
        self._rle_eligible: dict = {}
        self._rle_exec: dict = {}
        self._stats = ServiceStats(self.config.stats_window, registry=self.metrics)
        faults = self.config.faults
        self._injector = (
            FaultInjector(faults) if faults is not None and faults.enabled else None
        )
        obs_cfg = self.config.obs
        shard = self.config.shard
        self._obs = (
            Observability(
                obs_cfg,
                self.metrics,
                pid="0" if shard is None else str(shard),
                name="service" if shard is None else f"shard-{shard}",
            )
            if obs_cfg is not None and obs_cfg.enabled
            else None
        )
        self._batcher = MicroBatcher(
            self._execute_group,
            max_batch=self.config.max_batch,
            window_s=self.config.window_ms / 1e3,
            adaptive=self.config.adaptive_window,
            min_window_s=self.config.min_window_ms / 1e3,
            max_queue=self.config.max_queue,
            retry=self.config.retry,
            tenants=self.config.tenants,
            brownout=self.config.brownout,
            registry=self.metrics,
            obs=self._obs,
        )

    # ------------------------------------------------------------ submission
    def submit(self, img, op: str = "erode", se=(3, 3), **kw) -> Future:
        """Single-op request; resolves to the cropped result array."""
        return self.submit_plan(img, single_op_plan(op, se), **kw)

    def submit_plan(
        self,
        img,
        plan: "str | Plan",
        *,
        deadline_ms: float | None = None,
        tag: str | None = None,
        tenant: str | None = None,
        priority: int = PRIORITY_NORMAL,
        _trace: int | None = None,
    ) -> Future:
        """Plan request; resolves to an array (single-output plans) or a
        ``{name: array}`` dict (plans with named outputs).

        ``deadline_ms`` (default ``config.default_deadline_ms``) bounds how
        long the request may wait: expired requests fail with a typed
        :class:`DeadlineExceeded` instead of occupying the executor, and an
        urgent request pulls its whole group's dispatch forward. ``tag`` is
        a caller label carried on the request (fault injection poisons by
        tag; it never affects routing or batching). ``tenant``/``priority``
        feed admission (quotas, the brownout ladder) and weighted-fair
        dispatch order — see tenancy.py. ``_trace`` is internal: the
        sharded router threads one trace ID through failover hops."""
        plan = get_plan(plan)
        img = np.asarray(img)
        if img.ndim != 2:
            raise ValueError("the service takes single (H, W) images; submit "
                             "each image of a batch separately")
        if deadline_ms is None:
            deadline_ms = self.config.default_deadline_ms
        deadline = None
        if deadline_ms is not None:
            if deadline_ms <= 0:
                raise DeadlineExceeded(
                    f"deadline_ms={deadline_ms} already expired at submit",
                    plan=plan.name,
                )
            deadline = time.monotonic() + deadline_ms / 1e3
        # Admission (queue bound, tenant quota, brownout) is charged BEFORE
        # any routing work: the RLE density probe scans the whole image, and
        # an overloaded service must shed at the door, not after paying a
        # per-request O(H*W) probe for a request it then rejects.
        self._batcher.reserve(tenant, priority)
        try:
            if self._route_rle(img, plan):
                # content-gated representation choice: run-domain execution
                # on exact shapes — no bucket padding, no tiling
                key, bucket = ("rle", plan, img.dtype.str), None
            else:
                bucket = choose_bucket(
                    img.shape[0], img.shape[1], self.config.buckets
                )
                if bucket is None:
                    gh, gw = plan.halo()
                    ext = (self.config.tile_interior[0] + 2 * gh,
                           self.config.tile_interior[1] + 2 * gw)
                    key = ("tiled", plan, ext, img.dtype.str)
                else:
                    key = ("bucket", plan, bucket, img.dtype.str)
            req = _Request(key, img, plan, bucket, Future(), time.monotonic(),
                           deadline=deadline, tag=tag, tenant=tenant,
                           priority=priority, trace=_trace)
            if self._obs is not None:
                self._obs.request_submitted(req, plan.name, bucket,
                                            img.dtype.str)
            try:
                self._batcher.enqueue(req)
            except ServeError as exc:
                # rejected after the span opened (close() raced us): the
                # queue span must still close exactly once
                if self._obs is not None:
                    self._obs.request_failed(req, exc)
                raise
        except BaseException:
            self._batcher.release(tenant)  # slot never reached the queue
            raise
        return req.future

    def submit_expr(self, img, expr, name: str | None = None, **kw) -> Future:
        """Morphology-expression request (``repro.morph``): any graph over
        ``Var("x")`` — including ``BoundedIter`` reconstruction chains — is
        compiled into a plan and served; equal expressions share one cached
        executable. Plan compilation honors the service's policy (notably
        ``opt_level`` — a ``DispatchPolicy(opt_level=0)`` service really
        serves the raw graph)."""
        return self.submit_plan(
            img, to_plan(expr, name=name, policy=self.policy), **kw
        )

    def run(self, img, op: str = "erode", se=(3, 3), **kw):
        return self.submit(img, op, se, **kw).result()

    def run_plan(self, img, plan: "str | Plan", **kw):
        return self.submit_plan(img, plan, **kw).result()

    def run_expr(self, img, expr, name: str | None = None, **kw):
        return self.submit_expr(img, expr, name, **kw).result()

    def run_batch(self, imgs, plan: "str | Plan", **kw) -> list:
        """Synchronous convenience: submit all, wait for all, keep order."""
        futures = [self.submit_plan(im, plan, **kw) for im in imgs]
        return [f.result() for f in futures]

    # ---------------------------------------------------------- RLE routing
    def _route_rle(self, img: np.ndarray, plan: Plan) -> bool:
        """The per-request representation gate: structural eligibility
        (boolean dtype + run-domain-lowerable plan, cached per plan), then
        a measured run-density probe against the cost model's
        representation axis. Every probed request records its decision and
        density so the gate's behavior is auditable from stats()."""
        if not self.config.rle_gate or img.dtype != np.bool_:
            return False
        ok = self._rle_eligible.get(plan)
        if ok is None:
            ok = self._rle_eligible[plan] = plan_rle_eligible(plan)
        if not ok:
            return False
        density = estimate_run_density(img)
        use_rle = cost_model_for(self.policy).rle_wins(
            int(density * img.size), img.size
        )
        self._stats.record_repr(use_rle, density)
        return use_rle

    def _rle_executor(self, plan: Plan):
        key = (plan, self.policy.cache_token())
        fn = self._rle_exec.get(key)
        if fn is None:
            fn = self._rle_exec[key] = lower_rle(
                dict(plan.outputs), mode="host", policy=self.policy
            )
        return fn

    def _expire_mid_group(self, r) -> bool:
        """Serial routes (RLE, tiled) execute one request at a time, so a
        late group member's deadline can lapse while its batch-mates run —
        fail it typed instead of executing work nobody is waiting for.
        Returns True when the request was expired."""
        if r.deadline is None or r.deadline > time.monotonic():
            return False
        exc = DeadlineExceeded(
            "deadline passed mid-group before execution", plan=r.plan.name
        )
        self.metrics.counter("batcher.deadline_expired").inc()
        if self._obs is not None:
            self._obs.request_failed(r, exc)
        if not r.future.done():
            r.future.set_exception(exc)
        return True

    def _execute_rle(self, reqs: list) -> None:
        obs = self._obs
        for r in reqs:
            if r.future.done():
                continue  # already served before a batch-mate failed a retry
            if self._expire_mid_group(r):
                continue
            if self._injector is not None:
                self._injector.before_dispatch([r])
            span = (obs.group_span("executor", [r], plan=r.plan.name,
                                   kind="rle", shard=self.config.shard)
                    if obs is not None else contextlib.nullcontext())
            try:
                with span:
                    outs = self._rle_executor(r.plan)(r.img)
            except ServeError:
                raise
            except Exception as exc:
                raise ExecutorError(
                    f"rle executor failed: {type(exc).__name__}: {exc}",
                    plan=r.plan.name,
                    dtype=np.dtype(r.img.dtype).name,
                    batch=1,
                ) from exc
            names = r.plan.output_names()
            # record before resolving: a caller returning from result()
            # must observe its own request in stats()
            self._stats.record_rle([time.monotonic() - r.t_submit])
            if not r.future.done():
                r.future.set_result(outs["out"] if names == ("out",) else outs)

    # ------------------------------------------------------------- execution
    def _executor_key(self, plan: Plan, shape: tuple[int, int], dtype, batch: int):
        return (
            plan,
            shape,
            np.dtype(dtype).str,
            batch,
            self.policy.cache_token(),
            self.backend,
            self.interpret,
        )

    def _executor_for(self, plan: Plan, shape: tuple[int, int], dtype, batch: int):
        key = self._executor_key(plan, shape, dtype, batch)

        def build():
            if self._obs is not None:
                # the key's next call pays the XLA compile (profiled as the
                # compile-vs-run split)
                self._obs.executor_built(key)
            return build_executor(
                plan,
                backend=self.backend,
                policy=self.policy,
                interpret=self.interpret,
                with_aux=True,
            )

        return self.cache.get(key, build)

    def _device_scope(self):
        if self.config.device is None:
            return contextlib.nullcontext()
        return jax.default_device(self.config.device)

    def _execute_group(self, key, reqs: list) -> None:
        obs = self._obs
        if obs is not None:
            for r in reqs:
                obs.request_dequeued(r)  # queue wait ends here (idempotent)
            span = obs.group_span(
                "dispatch", reqs, kind=key[0], plan=key[1].name,
                shard=self.config.shard,
            )
        else:
            span = contextlib.nullcontext()
        with span, self._device_scope():
            if key[0] == "tiled":
                self._execute_tiled(reqs)
            elif key[0] == "rle":
                self._execute_rle(reqs)
            else:
                self._execute_bucketed(key, reqs)

    def _record_aux(self, aux: dict) -> None:
        budget = int(aux["iters_budget"])
        if budget:
            self._stats.record_bounded(int(aux["iters_used"]), budget)
            if self._obs is not None:
                self._obs.record_bounded(int(aux["iters_used"]), budget)

    def _execute_bucketed(self, key, reqs: list) -> None:
        _, plan, bucket, _ = key
        obs = self._obs
        if self._injector is not None:
            self._injector.before_dispatch(reqs)
        bb = min(_round_up_pow2(len(reqs)), self.config.max_batch)
        batch = np.zeros((bb, *bucket), dtype=reqs[0].img.dtype)
        rects = np.zeros((bb, 4), dtype=np.int32)
        for i, r in enumerate(reqs):
            h, w = r.img.shape
            batch[i, :h, :w] = r.img  # rows past len(reqs) keep an empty rect
            rects[i] = valid_rect(h, w)
        try:
            execute = self._executor_for(plan, bucket, batch.dtype, bb)
            if obs is not None:
                span = obs.group_span(
                    "executor", reqs, plan=plan.name, bucket=bucket,
                    dtype=np.dtype(batch.dtype).name, batch=bb,
                    shard=self.config.shard,
                )
                t0 = time.perf_counter()
            else:
                span = contextlib.nullcontext()
            with span, (obs.dispatch_annotation(plan.name) if obs is not None
                        else contextlib.nullcontext()):
                outs, aux = execute(jnp.asarray(batch), jnp.asarray(rects))
                # np.asarray blocks until ready: the executor span covers
                # dispatch + device run, not just the enqueue
                outs = {k: np.asarray(v) for k, v in outs.items()}
            if obs is not None:
                obs.record_execution(
                    self._executor_key(plan, bucket, batch.dtype, bb),
                    plan.name, time.perf_counter() - t0,
                )
        except ServeError:
            raise
        except Exception as exc:
            raise ExecutorError(
                f"executor failed: {type(exc).__name__}: {exc}",
                plan=plan.name,
                bucket=bucket,
                dtype=np.dtype(batch.dtype).name,
                batch=bb,
            ) from exc
        self._record_aux(aux)
        names = plan.output_names()
        # record stats before resolving futures: a caller returning from
        # result() must observe its own request in stats()
        now = time.monotonic()
        self._stats.record_batch([now - r.t_submit for r in reqs])
        for i, r in enumerate(reqs):
            h, w = r.img.shape
            cropped = {
                name: crop_from_bucket(outs[name][i], h, w) for name in names
            }
            if not r.future.done():
                r.future.set_result(
                    cropped["out"] if names == ("out",) else cropped
                )

    def _execute_tiled(self, reqs: list) -> None:
        obs = self._obs
        for r in reqs:
            if r.future.done():
                continue  # already served before a batch-mate failed a retry
            if self._expire_mid_group(r):
                continue
            if self._injector is not None:
                self._injector.before_dispatch([r])
            gh, gw = r.plan.halo()
            ext = (self.config.tile_interior[0] + 2 * gh,
                   self.config.tile_interior[1] + 2 * gw)

            aux_chunks: list = []

            def execute(tiles, rects):
                fn = self._executor_for(r.plan, ext, tiles.dtype, tiles.shape[0])
                outs, aux = fn(jnp.asarray(tiles), jnp.asarray(rects))
                aux_chunks.append(aux)  # record after all chunks dispatch:
                return outs             # int(aux) here would sync per launch

            span = (obs.group_span("executor", [r], plan=r.plan.name,
                                   bucket=ext, kind="tiled",
                                   shard=self.config.shard)
                    if obs is not None else contextlib.nullcontext())
            try:
                with span, (obs.dispatch_annotation(r.plan.name)
                            if obs is not None else contextlib.nullcontext()):
                    outs = run_tiled(
                        r.img,
                        r.plan,
                        execute,
                        tile_interior=self.config.tile_interior,
                        launch_batch=self.config.max_tiles_per_launch,
                    )
            except ServeError:
                raise
            except Exception as exc:
                raise ExecutorError(
                    f"tiled executor failed: {type(exc).__name__}: {exc}",
                    plan=r.plan.name,
                    bucket=ext,
                    dtype=np.dtype(r.img.dtype).name,
                    batch=self.config.max_tiles_per_launch,
                ) from exc
            names = r.plan.output_names()
            for aux in aux_chunks:
                self._record_aux(aux)
            # record before resolving: a caller returning from result()
            # must observe its own request in stats()
            self._stats.record_tiled([time.monotonic() - r.t_submit])
            if not r.future.done():
                r.future.set_result(outs["out"] if names == ("out",) else outs)

    # -------------------------------------------------------------- lifecycle
    def metrics_snapshot(self) -> dict:
        """Registry snapshot with the point-in-time gauges refreshed — the
        unit the sharded router merges by metric type."""
        self.metrics.gauge("window.effective_ms", mode="max").set(
            self._batcher.window_s * 1e3
        )
        return self.metrics.snapshot()

    def stats(self) -> dict:
        snap = self._stats.snapshot(self.config.max_batch)
        snap["cache"] = self.cache.snapshot()
        snap["backend"] = self.backend
        snap["interpret"] = self.interpret
        snap["window_ms"] = self.config.window_ms
        snap["effective_window_ms"] = self._batcher.window_s * 1e3
        snap["adaptive_window"] = self.config.adaptive_window
        resilience = self._batcher.counters()
        resilience["max_queue"] = self.config.max_queue
        resilience["faults"] = (
            self._injector.snapshot() if self._injector is not None else None
        )
        snap["resilience"] = resilience
        snap["obs"] = self._obs.snapshot() if self._obs is not None else None
        return snap

    def executor_profile(self) -> dict:
        """Per-cache-key compile/run profile (empty unless ``obs`` enables
        executor profiling)."""
        return self._obs.executor_profile() if self._obs is not None else {}

    def export_trace(self) -> dict | None:
        """Chrome trace-event JSON of the finished spans (Perfetto-loadable);
        None when tracing is off."""
        if self._obs is None or self._obs.tracer is None:
            return None
        return chrome_trace([self._obs.tracer])

    def flush(self, timeout: float | None = None) -> bool:
        return self._batcher.flush(timeout)

    def close(self) -> None:
        """Drain in-flight requests and stop the batcher. Idempotent: a
        second close() (or a close() racing __exit__) is a no-op join."""
        self._batcher.close()

    def __enter__(self) -> "MorphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
