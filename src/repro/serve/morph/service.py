"""MorphService: the async front door over the fused morphology kernels.

Mirrors the LM engine (serve/engine.py) one layer up: where that engine
batches decode steps over a KV cache, this one batches single-image
morphology requests into (B, H, W) stacks. A request flows:

    submit(img, op/plan)
      -> bucket  (buckets.py: pad up to a fixed (H, W) ladder)   } cache-
      -> batch   (batcher.py: coalesce within a deadline window) } friendly
      -> execute (plans.py executor from the LRU executable cache)
      -> crop + resolve the Future

Images too large for the ladder take the tiled route (tiling.py) through
the same executor cache. The executable cache is keyed on
``(plan, shape, dtype, batch-bucket, policy.cache_token(), backend,
interpret)`` with hit/miss/eviction counters; batch sizes are bucketed to
powers of two so B-variance cannot silently multiply compiles.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import DispatchPolicy, resolve_interpret
from repro.serve.morph.batcher import MicroBatcher
from repro.serve.morph.buckets import (
    DEFAULT_BUCKETS,
    check_buckets,
    choose_bucket,
    crop_from_bucket,
    valid_rect,
)
from repro.serve.morph.resilience import (
    DeadlineExceeded,
    ExecutorError,
    FaultInjector,
    FaultPlan,
    FailoverPolicy,
    RetryPolicy,
    ServeError,
)
from repro.morph.plan_compile import to_plan
from repro.serve.morph.plans import (
    Plan,
    build_executor,
    check_backend,
    get_plan,
    single_op_plan,
)
from repro.serve.morph.tiling import run_tiled


def _round_up_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


class ExecutableCache:
    """LRU over built (jitted) plan executors, with observable counters.

    One entry == one compile of one executable (keys include the padded
    batch size), so ``misses`` is exactly the compile count the service has
    paid — the number the bucket ladder exists to keep small.
    """

    def __init__(self, max_size: int = 128):
        self.max_size = max_size
        self._entries: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def get(self, key, builder):
        with self._lock:
            if key in self._entries:
                self.hits += 1
                self._entries.move_to_end(key)
                return self._entries[key]
            self.misses += 1
        value = builder()  # build outside the lock; benign duplicate on race
        with self._lock:
            self._entries[key] = value
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_size:
                self._entries.popitem(last=False)
                self.evictions += 1
        return value

    def snapshot(self) -> dict:
        with self._lock:
            total = self.hits + self.misses
            return {
                "size": len(self._entries),
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0,
            }


class ServiceStats:
    """Rolling serving metrics: throughput, latency quantiles, occupancy."""

    def __init__(self, window: int = 4096):
        self._lock = threading.Lock()
        self._latencies = collections.deque(maxlen=window)
        self._done_ts = collections.deque(maxlen=window)
        self._batch_sizes = collections.deque(maxlen=window)
        self.requests = 0
        self.batches = 0
        self.tiled_requests = 0
        # convergence telemetry from BoundedIter plans (reconstruction):
        # budget is the fixed-trace iteration cap, used what actually ran
        # before the predicated scan converged (interp.py) — the gap is
        # work the convergence-aware serving satellite reclaims.
        self.bounded_execs = 0
        self.iters_used_total = 0
        self.iters_budget_total = 0

    def record_batch(self, latencies_s) -> None:
        now = time.monotonic()
        with self._lock:
            self.requests += len(latencies_s)
            self.batches += 1
            self._batch_sizes.append(len(latencies_s))
            self._latencies.extend(latencies_s)
            self._done_ts.extend([now] * len(latencies_s))

    def record_tiled(self, latencies_s) -> None:
        """Tiled requests never ride the batcher's stacks — count their
        latency/throughput but keep them out of the occupancy metrics."""
        now = time.monotonic()
        with self._lock:
            self.requests += len(latencies_s)
            self.tiled_requests += len(latencies_s)
            self._latencies.extend(latencies_s)
            self._done_ts.extend([now] * len(latencies_s))

    def record_bounded(self, used: int, budget: int) -> None:
        with self._lock:
            self.bounded_execs += 1
            self.iters_used_total += int(used)
            self.iters_budget_total += int(budget)

    def snapshot(self, max_batch: int) -> dict:
        with self._lock:
            lat = np.asarray(self._latencies, dtype=np.float64)
            ts = list(self._done_ts)
            sizes = np.asarray(self._batch_sizes, dtype=np.float64)
            # copy under the lock: used/budget must come from one
            # record_bounded or the derived ratio can tear
            bounded_execs = self.bounded_execs
            iters_used = self.iters_used_total
            iters_budget = self.iters_budget_total
        span = (ts[-1] - ts[0]) if len(ts) > 1 else 0.0
        return {
            "requests": self.requests,
            "batches": self.batches,
            "tiled_requests": self.tiled_requests,
            "bounded_iter": {
                "executions": bounded_execs,
                "iters_used": iters_used,
                "iters_budget": iters_budget,
                "saved_frac": (
                    1.0 - iters_used / iters_budget if iters_budget else 0.0
                ),
            },
            "img_per_s": (len(ts) - 1) / span if span > 0 else 0.0,
            "p50_ms": float(np.percentile(lat, 50) * 1e3) if lat.size else 0.0,
            "p99_ms": float(np.percentile(lat, 99) * 1e3) if lat.size else 0.0,
            "mean_batch": float(sizes.mean()) if sizes.size else 0.0,
            "occupancy": float(sizes.mean()) / max_batch if sizes.size else 0.0,
        }


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    buckets: tuple[tuple[int, int], ...] = DEFAULT_BUCKETS
    max_batch: int = 64
    window_ms: float = 2.0
    # Load-aware deadline window (ROADMAP item): window_ms becomes the MAX;
    # the effective window shrinks toward min_window_ms when dispatches
    # drain below the batcher's low-water mark and grows back toward
    # window_ms under sustained pressure. stats()["effective_window_ms"]
    # reports the current value.
    adaptive_window: bool = True
    min_window_ms: float = 0.0
    tile_interior: tuple[int, int] = (512, 512)
    max_tiles_per_launch: int = 16
    backend: str = "auto"  # "kernel" (fused Pallas) | "jnp" | "auto"
    policy: DispatchPolicy | None = None
    interpret: bool | None = None
    cache_size: int = 128
    stats_window: int = 4096
    # Pin this service's dispatches to one jax device — how the sharded
    # router (repro.shard.router) runs each shard's batcher under its own
    # mesh slot. None = the process default device.
    device: Any = None
    # --- resilience (resilience.py) ---------------------------------------
    # Admission bound on outstanding (queued + in-flight) requests; submit()
    # raises Overloaded past it. None = unbounded (the pre-resilience mode).
    max_queue: int | None = 1024
    # Deadline applied to every request that doesn't pass its own
    # deadline_ms to submit_plan(); None = no deadline.
    default_deadline_ms: float | None = None
    # Retry-with-backoff then bisect for failed dispatch groups.
    retry: RetryPolicy = RetryPolicy()
    # Circuit breaker / reroute rules — read by ShardedMorphService, inert
    # for a standalone service.
    failover: FailoverPolicy = FailoverPolicy()
    # Deterministic fault injection; None (default) adds zero overhead.
    faults: FaultPlan | None = None


@dataclasses.dataclass
class _Request:
    key: tuple
    img: np.ndarray
    plan: Plan
    bucket: tuple[int, int] | None  # None -> tiled route
    future: Future
    t_submit: float
    deadline: float | None = None  # absolute monotonic seconds
    tag: str | None = None  # caller label; fault injection poisons by tag


class MorphService:
    """Async morphology serving engine. Use as a context manager:

        with MorphService() as svc:
            fut = svc.submit(img, op="erode", se=(5, 5))
            clean = svc.run_plan(img2, "document_cleanup")["clean"]
    """

    def __init__(self, config: ServiceConfig | None = None):
        self.config = config or ServiceConfig()
        check_buckets(self.config.buckets)
        self.policy = self.config.policy or DispatchPolicy.calibrated()
        self.interpret = resolve_interpret(self.config.interpret, self.policy)
        if self.config.backend == "auto":
            # Compiled Mosaic -> fused megakernel; interpret mode (CPU CI,
            # laptops) -> the pure-XLA separable path, which is bit-exact
            # and far faster than interpreting Pallas.
            self.backend = "jnp" if self.interpret else "kernel"
        else:
            # fail loudly at construction, not inside the batcher thread
            self.backend = check_backend(self.config.backend)
        self.cache = ExecutableCache(self.config.cache_size)
        self._stats = ServiceStats(self.config.stats_window)
        faults = self.config.faults
        self._injector = (
            FaultInjector(faults) if faults is not None and faults.enabled else None
        )
        self._batcher = MicroBatcher(
            self._execute_group,
            max_batch=self.config.max_batch,
            window_s=self.config.window_ms / 1e3,
            adaptive=self.config.adaptive_window,
            min_window_s=self.config.min_window_ms / 1e3,
            max_queue=self.config.max_queue,
            retry=self.config.retry,
        )

    # ------------------------------------------------------------ submission
    def submit(self, img, op: str = "erode", se=(3, 3), **kw) -> Future:
        """Single-op request; resolves to the cropped result array."""
        return self.submit_plan(img, single_op_plan(op, se), **kw)

    def submit_plan(
        self,
        img,
        plan: "str | Plan",
        *,
        deadline_ms: float | None = None,
        tag: str | None = None,
    ) -> Future:
        """Plan request; resolves to an array (single-output plans) or a
        ``{name: array}`` dict (plans with named outputs).

        ``deadline_ms`` (default ``config.default_deadline_ms``) bounds how
        long the request may wait: expired requests fail with a typed
        :class:`DeadlineExceeded` instead of occupying the executor, and an
        urgent request pulls its whole group's dispatch forward. ``tag`` is
        a caller label carried on the request (fault injection poisons by
        tag; it never affects routing or batching)."""
        plan = get_plan(plan)
        img = np.asarray(img)
        if img.ndim != 2:
            raise ValueError("the service takes single (H, W) images; submit "
                             "each image of a batch separately")
        if deadline_ms is None:
            deadline_ms = self.config.default_deadline_ms
        deadline = None
        if deadline_ms is not None:
            if deadline_ms <= 0:
                raise DeadlineExceeded(
                    f"deadline_ms={deadline_ms} already expired at submit",
                    plan=plan.name,
                )
            deadline = time.monotonic() + deadline_ms / 1e3
        bucket = choose_bucket(img.shape[0], img.shape[1], self.config.buckets)
        if bucket is None:
            gh, gw = plan.halo()
            ext = (self.config.tile_interior[0] + 2 * gh,
                   self.config.tile_interior[1] + 2 * gw)
            key = ("tiled", plan, ext, img.dtype.str)
        else:
            key = ("bucket", plan, bucket, img.dtype.str)
        req = _Request(key, img, plan, bucket, Future(), time.monotonic(),
                       deadline=deadline, tag=tag)
        self._batcher.submit(req)
        return req.future

    def submit_expr(self, img, expr, name: str | None = None, **kw) -> Future:
        """Morphology-expression request (``repro.morph``): any graph over
        ``Var("x")`` — including ``BoundedIter`` reconstruction chains — is
        compiled into a plan and served; equal expressions share one cached
        executable. Plan compilation honors the service's policy (notably
        ``opt_level`` — a ``DispatchPolicy(opt_level=0)`` service really
        serves the raw graph)."""
        return self.submit_plan(
            img, to_plan(expr, name=name, policy=self.policy), **kw
        )

    def run(self, img, op: str = "erode", se=(3, 3), **kw):
        return self.submit(img, op, se, **kw).result()

    def run_plan(self, img, plan: "str | Plan", **kw):
        return self.submit_plan(img, plan, **kw).result()

    def run_expr(self, img, expr, name: str | None = None, **kw):
        return self.submit_expr(img, expr, name, **kw).result()

    def run_batch(self, imgs, plan: "str | Plan", **kw) -> list:
        """Synchronous convenience: submit all, wait for all, keep order."""
        futures = [self.submit_plan(im, plan, **kw) for im in imgs]
        return [f.result() for f in futures]

    # ------------------------------------------------------------- execution
    def _executor_for(self, plan: Plan, shape: tuple[int, int], dtype, batch: int):
        key = (
            plan,
            shape,
            np.dtype(dtype).str,
            batch,
            self.policy.cache_token(),
            self.backend,
            self.interpret,
        )
        return self.cache.get(
            key,
            lambda: build_executor(
                plan,
                backend=self.backend,
                policy=self.policy,
                interpret=self.interpret,
                with_aux=True,
            ),
        )

    def _device_scope(self):
        if self.config.device is None:
            return contextlib.nullcontext()
        return jax.default_device(self.config.device)

    def _execute_group(self, key, reqs: list) -> None:
        with self._device_scope():
            if key[0] == "tiled":
                self._execute_tiled(reqs)
            else:
                self._execute_bucketed(key, reqs)

    def _record_aux(self, aux: dict) -> None:
        budget = int(aux["iters_budget"])
        if budget:
            self._stats.record_bounded(int(aux["iters_used"]), budget)

    def _execute_bucketed(self, key, reqs: list) -> None:
        _, plan, bucket, _ = key
        if self._injector is not None:
            self._injector.before_dispatch(reqs)
        bb = min(_round_up_pow2(len(reqs)), self.config.max_batch)
        batch = np.zeros((bb, *bucket), dtype=reqs[0].img.dtype)
        rects = np.zeros((bb, 4), dtype=np.int32)
        for i, r in enumerate(reqs):
            h, w = r.img.shape
            batch[i, :h, :w] = r.img  # rows past len(reqs) keep an empty rect
            rects[i] = valid_rect(h, w)
        try:
            execute = self._executor_for(plan, bucket, batch.dtype, bb)
            outs, aux = execute(jnp.asarray(batch), jnp.asarray(rects))
            outs = {k: np.asarray(v) for k, v in outs.items()}
        except ServeError:
            raise
        except Exception as exc:
            raise ExecutorError(
                f"executor failed: {type(exc).__name__}: {exc}",
                plan=plan.name,
                bucket=bucket,
                dtype=np.dtype(batch.dtype).name,
                batch=bb,
            ) from exc
        self._record_aux(aux)
        names = plan.output_names()
        # record stats before resolving futures: a caller returning from
        # result() must observe its own request in stats()
        now = time.monotonic()
        self._stats.record_batch([now - r.t_submit for r in reqs])
        for i, r in enumerate(reqs):
            h, w = r.img.shape
            cropped = {
                name: crop_from_bucket(outs[name][i], h, w) for name in names
            }
            if not r.future.done():
                r.future.set_result(
                    cropped["out"] if names == ("out",) else cropped
                )

    def _execute_tiled(self, reqs: list) -> None:
        for r in reqs:
            if r.future.done():
                continue  # already served before a batch-mate failed a retry
            if self._injector is not None:
                self._injector.before_dispatch([r])
            gh, gw = r.plan.halo()
            ext = (self.config.tile_interior[0] + 2 * gh,
                   self.config.tile_interior[1] + 2 * gw)

            aux_chunks: list = []

            def execute(tiles, rects):
                fn = self._executor_for(r.plan, ext, tiles.dtype, tiles.shape[0])
                outs, aux = fn(jnp.asarray(tiles), jnp.asarray(rects))
                aux_chunks.append(aux)  # record after all chunks dispatch:
                return outs             # int(aux) here would sync per launch

            try:
                outs = run_tiled(
                    r.img,
                    r.plan,
                    execute,
                    tile_interior=self.config.tile_interior,
                    launch_batch=self.config.max_tiles_per_launch,
                )
            except ServeError:
                raise
            except Exception as exc:
                raise ExecutorError(
                    f"tiled executor failed: {type(exc).__name__}: {exc}",
                    plan=r.plan.name,
                    bucket=ext,
                    dtype=np.dtype(r.img.dtype).name,
                    batch=self.config.max_tiles_per_launch,
                ) from exc
            names = r.plan.output_names()
            for aux in aux_chunks:
                self._record_aux(aux)
            # record before resolving: a caller returning from result()
            # must observe its own request in stats()
            self._stats.record_tiled([time.monotonic() - r.t_submit])
            if not r.future.done():
                r.future.set_result(outs["out"] if names == ("out",) else outs)

    # -------------------------------------------------------------- lifecycle
    def stats(self) -> dict:
        snap = self._stats.snapshot(self.config.max_batch)
        snap["cache"] = self.cache.snapshot()
        snap["backend"] = self.backend
        snap["interpret"] = self.interpret
        snap["window_ms"] = self.config.window_ms
        snap["effective_window_ms"] = self._batcher.window_s * 1e3
        snap["adaptive_window"] = self.config.adaptive_window
        resilience = self._batcher.counters()
        resilience["max_queue"] = self.config.max_queue
        resilience["faults"] = (
            self._injector.snapshot() if self._injector is not None else None
        )
        snap["resilience"] = resilience
        return snap

    def flush(self, timeout: float | None = None) -> bool:
        return self._batcher.flush(timeout)

    def close(self) -> None:
        """Drain in-flight requests and stop the batcher. Idempotent: a
        second close() (or a close() racing __exit__) is a no-op join."""
        self._batcher.close()

    def __enter__(self) -> "MorphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
