"""Node-health state machine: circuit breakers + slow-state (gray) marks.

Extracted from ``repro.shard.router`` (ISSUE 10) so the same machinery
drives both routing tiers:

* ``ShardedMorphService`` tracks per-*shard* health inside one process;
* the ingress ``Frontier`` (``repro.serve.ingress``) tracks per-*worker
  process* health across the fleet.

Both route by the stable crc32 of a ``(plan, bucket, dtype)`` group token
and both want identical semantics — breakers open on consecutive errors,
half-open probes test recovery, slow-but-alive nodes drain without ever
being declared dead — so the state machine lives here once and each tier
holds a :class:`HealthTracker` over its own node list.

The tracker owns one lock. ``pick`` / ``record_success`` /
``record_failure`` / ``observe_latency`` take it internally; callers that
need to read node state atomically with their own counters (the shard
router's stats path) may hold ``tracker.lock`` themselves — the class is
deliberately lock-visible rather than lock-hidden.

State vocabulary (``NodeHealth.snapshot()["state"]``):

* ``"closed"`` — healthy, routable;
* ``"open"`` — breaker tripped by ``failure_threshold`` consecutive
  node-level errors (or an abrupt ``mark_dead``); traffic reroutes
  deterministically to survivors;
* ``"half-open"`` — one live probe in flight after ``probe_interval_s``;
* ``"slow"`` — alive (breaker closed) but its completion-latency EWMA
  reads worse than ``slow_factor`` x the healthy-peer median; new traffic
  routes away, a trickle probe keeps the EWMA fed so recovery is
  observable. Slow is never dead: the breaker state machine ignores it.
"""
from __future__ import annotations

import threading
import time
import zlib

from repro.serve.morph.resilience import FailoverPolicy, ShardUnavailable


class NodeHealth:
    """Breaker + slow-state fields for one node. All mutation happens under
    the owning tracker's lock; reads for stats() take the same lock."""

    def __init__(self):
        self.state = "closed"  # "closed" (healthy) | "open" (broken)
        self.consecutive_failures = 0
        self.opened_at: float | None = None
        self.probing = False  # one half-open probe in flight
        self.trips = 0
        self.probes = 0
        self.recoveries = 0
        # slow-state (gray-failure) tracking — orthogonal to the breaker:
        # `state` only ever moves on errors, `slow` only on latency
        self.latency_ewma_ms: float | None = None
        self.latency_samples = 0
        self.slow = False
        self.last_slow_probe = 0.0
        self.samples_at_mark = 0
        self.slow_marks = 0
        self.slow_recoveries = 0

    def snapshot(self) -> dict:
        state = "half-open" if self.probing else self.state
        if state == "closed" and self.slow:
            state = "slow"  # alive, deprioritized — never "open"
        return {
            "state": state,
            "consecutive_failures": self.consecutive_failures,
            "trips": self.trips,
            "probes": self.probes,
            "recoveries": self.recoveries,
            "slow": self.slow,
            "slow_marks": self.slow_marks,
            "slow_recoveries": self.slow_recoveries,
            "latency_ewma_ms": (
                round(self.latency_ewma_ms, 3)
                if self.latency_ewma_ms is not None else None
            ),
        }


class HealthTracker:
    """The breaker/slow-mark state machine over ``n`` routable nodes.

    ``noun`` names the node kind in error messages (``"shard"`` for the
    in-process router, ``"worker"`` for the ingress frontier) so a caller
    reading a :class:`ShardUnavailable` knows which tier gave up.
    """

    def __init__(self, n: int, policy: FailoverPolicy, *, noun: str = "shard"):
        if n < 1:
            raise ValueError(f"HealthTracker needs at least one {noun}")
        self.policy = policy
        self.noun = noun
        self.lock = threading.Lock()
        self.nodes = [NodeHealth() for _ in range(n)]
        self.reroutes = 0
        self.trips = 0  # total breaker openings across all nodes

    def __len__(self) -> int:
        return len(self.nodes)

    # ------------------------------------------------------------- routing
    def healthy_locked(self, i: int) -> bool:
        """Breaker-closed check; caller holds ``self.lock``."""
        return self.nodes[i].state == "closed"

    def pick(self, token: bytes, excluded: frozenset) -> tuple[int, bool]:
        """Deterministic node choice for a group token: the crc32 primary
        when healthy, else the same hash over the healthy survivors — a
        broken node's groups all move, each to one stable survivor. Returns
        ``(index, is_probe)``; may promote the call into a half-open probe
        of the primary. Raises :class:`ShardUnavailable` when nothing is
        routable."""
        h = zlib.crc32(token)
        n = len(self.nodes)
        primary = h % n
        now = time.monotonic()
        with self.lock:
            hp = self.nodes[primary]
            if primary not in excluded:
                if hp.state == "closed":
                    if not hp.slow:
                        return primary, False
                    # slow primary: a trickle probe keeps its latency EWMA
                    # fed, so recovery is observable — otherwise the node
                    # drains and its last (inflated) EWMA pins it slow
                    # forever; everything else reroutes away below
                    if (
                        now - hp.last_slow_probe
                        >= self.policy.slow_probe_interval_s
                    ):
                        hp.last_slow_probe = now
                        return primary, False
                # broken primary: probe it if the interval elapsed and no
                # probe is already in flight
                elif (
                    not hp.probing
                    and hp.opened_at is not None
                    and now - hp.opened_at >= self.policy.probe_interval_s
                ):
                    hp.probing = True
                    hp.probes += 1
                    return primary, True
            candidates = [
                i for i in range(n)
                if i not in excluded and i != primary
                and self.healthy_locked(i)
            ]
            # prefer survivors that aren't themselves slow; slowness never
            # makes a group unroutable (slow < dead, by construction)
            fast = [i for i in candidates if not self.nodes[i].slow]
            survivors = fast or candidates
            if not survivors:
                if primary not in excluded and hp.state == "closed":
                    return primary, False  # slow primary beats nothing
                raise ShardUnavailable(
                    f"no healthy {self.noun} for group (primary {primary} "
                    f"{hp.state}, {len(excluded)} excluded of {n})"
                )
            self.reroutes += 1
            return survivors[h % len(survivors)], False

    # ------------------------------------------------------------- outcomes
    def record_success(self, idx: int, was_probe: bool) -> None:
        with self.lock:
            h = self.nodes[idx]
            h.consecutive_failures = 0
            if was_probe:
                h.probing = False
            if h.state != "closed":
                h.state = "closed"
                h.opened_at = None
                h.recoveries += 1

    def record_failure(self, idx: int, was_probe: bool) -> bool:
        """Count a node-level failure; returns True when this failure
        tripped the breaker (open from closed) so the caller can kick off
        reroute-time work (the shard router's cache rewarm)."""
        with self.lock:
            h = self.nodes[idx]
            h.consecutive_failures += 1
            if was_probe:
                h.probing = False
            tripped = (
                h.state == "closed"
                and h.consecutive_failures >= self.policy.failure_threshold
            )
            if tripped or was_probe:
                if h.state == "closed":
                    h.trips += 1
                    self.trips += 1
                h.state = "open"
                h.opened_at = time.monotonic()
            return tripped

    def mark_dead(self, idx: int) -> bool:
        """Open a node's breaker immediately — the ingress tier's verdict
        for a lost TCP connection, which is definitive in a way a single
        request error is not. Returns True if the breaker newly opened."""
        with self.lock:
            h = self.nodes[idx]
            h.consecutive_failures += 1
            h.probing = False
            newly = h.state == "closed"
            if newly:
                h.trips += 1
                self.trips += 1
            h.state = "open"
            h.opened_at = time.monotonic()
            return newly

    # ------------------------------------------------- slow-state (gray)
    def observe_latency(self, idx: int, ms: float) -> None:
        """Feed one successful attempt's residence latency (submit to
        resolution, queue wait included — that is what the caller feels)
        into the node's EWMA, then re-score every node against the peer
        median. Errors never reach here: the breaker owns those."""
        po = self.policy
        if not po.slow_detection:
            return
        with self.lock:
            h = self.nodes[idx]
            a = po.slow_ewma_alpha
            h.latency_ewma_ms = (
                ms if h.latency_ewma_ms is None
                else (1.0 - a) * h.latency_ewma_ms + a * ms
            )
            h.latency_samples += 1
            self._rescore_slow_locked()

    def _rescore_slow_locked(self) -> None:
        """Under ``self.lock``: mark/unmark slow by comparing each node's
        EWMA to the median over breaker-closed nodes with data.
        Peer-relative scoring is the point — an absolute threshold can't
        tell a slow node from a slow traffic mix, but one outlier against
        its own peers on the same mix is a gray failure."""
        po = self.policy
        # only settled EWMAs join the peer pool — the bar is symmetric with
        # being markable: a survivor's single compile-spike sample must not
        # drag the median up and un-mark a genuinely slow node
        vals = sorted(
            h.latency_ewma_ms for h in self.nodes
            if h.latency_ewma_ms is not None and h.state == "closed"
            and h.latency_samples >= po.slow_min_count
        )
        if len(vals) < 2:
            return  # one data point has no peers to be slow against
        # lower-middle median: with few reporting nodes the upper middle
        # can BE the outlier (2 nodes: upper median = max, and nothing
        # could ever score slow against itself)
        median = vals[(len(vals) - 1) // 2]
        for h in self.nodes:
            e = h.latency_ewma_ms
            if e is None:
                continue
            if not h.slow:
                if (
                    h.latency_samples >= po.slow_min_count
                    and e > po.slow_factor * median
                    and e > po.slow_min_ms
                ):
                    h.slow = True
                    h.slow_marks += 1
                    h.samples_at_mark = h.latency_samples
                    # trickle probing starts one full interval from the
                    # mark (not from process start): the first drained
                    # requests all reroute, then one probe feeds the EWMA
                    h.last_slow_probe = time.monotonic()
            elif (
                # recovery takes evidence from the node itself (a probe or
                # hedge completion since the mark) — a drained node's
                # frozen EWMA must not "recover" just because its peers'
                # median drifted up under load
                h.latency_samples > h.samples_at_mark
                and (e <= po.slow_exit_factor * median or e <= po.slow_min_ms)
            ):
                h.slow = False
                h.slow_recoveries += 1

    # ------------------------------------------------------------- reading
    def snapshot(self) -> list[dict]:
        with self.lock:
            return [h.snapshot() for h in self.nodes]


__all__ = ["NodeHealth", "HealthTracker"]
