"""Tenant-aware overload control for the serving tier (ISSUE 9).

PR 6 gave the serving tier one overload answer: a global ``max_queue``
that sheds indiscriminately with ``Overloaded``. At a multi-tenant front
door that is the wrong shape twice over — one noisy tenant can occupy the
whole admission budget, and the service falls off a single cliff instead
of degrading. This module holds the three mechanisms the batcher composes
into a graduated answer:

* :class:`TenantQuota` — per-tenant admission budget (``max_outstanding``)
  and a fair-share ``weight``. ``ServiceConfig.tenants`` maps tenant name
  -> quota; tenants not in the map get :data:`DEFAULT_QUOTA` (unbounded,
  weight 1.0), so quotas are opt-in per tenant, not a registration wall.
* :class:`FairScheduler` — start-time fair queuing (SFQ) over tenants at
  dispatch-group granularity. Each tenant carries a virtual time that
  advances by ``1 / effective_weight`` per dispatched request; due groups
  dispatch min-tag first. A backlogged tenant's tag holds still while
  serviced tenants' tags grow past it, which is the classic SFQ liveness
  argument: any tenant with positive weight is dispatched within a
  bounded number of rounds (property-tested in tests/test_tenancy.py).
  Priority classes fold into the weight (each class above doubles the
  share) rather than forming strict tiers — strict tiers would reintroduce
  starvation, which shedding already handles better (the brownout ladder
  drops whole low classes with *typed* errors instead of queueing them to
  death silently).
* :class:`BrownoutController` — the load controller behind the brownout
  ladder. It watches queue depth (outstanding / ``max_queue``) and an EWMA
  of dispatch latency and degrades in steps instead of PR 6's single
  cliff:

      level 0  normal
      level 1  widen the batching window (trade latency for occupancy)
      level 2  shed the lowest priority classes with typed
               :class:`~repro.serve.morph.resilience.BrownoutShed`
      level 3  shed everything (global typed Overloaded behavior)

  Transitions carry hysteresis (exit thresholds sit below entry
  thresholds) so the ladder doesn't flap at a boundary. The active level
  is visible in ``stats()["resilience"]["brownout"]``.

Priority classes are small ints, lower = more important:
:data:`PRIORITY_HIGH` (0), :data:`PRIORITY_NORMAL` (1, the default),
:data:`PRIORITY_LOW` (2). Anything >= ``BrownoutPolicy.shed_priority``
sheds first.
"""
from __future__ import annotations

import dataclasses

PRIORITY_HIGH = 0
PRIORITY_NORMAL = 1
PRIORITY_LOW = 2

# Effective-weight multiplier per priority class (index-clamped): one class
# up doubles the fair share. Folding priority into the weight keeps the
# scheduler starvation-free for every positive-weight tenant — a strictly
# tiered sort would let sustained high-priority load park lower classes
# forever, silently.
PRIORITY_BOOST = (4.0, 2.0, 1.0)


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Admission budget and fair share for one tenant.

    ``max_outstanding`` bounds this tenant's queued + in-flight requests
    (``None`` = bounded only by the global ``max_queue``); past it,
    ``submit`` raises :class:`~repro.serve.morph.resilience.QuotaExceeded`
    — a typed ``Overloaded`` that names the tenant, so one noisy tenant
    sheds alone instead of eating the shared budget. ``weight`` is the
    relative share the fair scheduler grants under contention.
    """

    max_outstanding: int | None = None
    weight: float = 1.0

    def __post_init__(self):
        if self.max_outstanding is not None and self.max_outstanding < 1:
            raise ValueError("max_outstanding must be >= 1 (or None)")
        if self.weight <= 0.0:
            raise ValueError("weight must be > 0 (use quotas to block a tenant)")


DEFAULT_QUOTA = TenantQuota()


def effective_weight(quota: TenantQuota, priority: int) -> float:
    """Tenant weight x priority boost — the rate a tenant's virtual time
    advances at, and therefore its share of dispatch order under load."""
    idx = min(max(int(priority), 0), len(PRIORITY_BOOST) - 1)
    return quota.weight * PRIORITY_BOOST[idx]


class FairScheduler:
    """Start-time fair queuing over tenants, at group granularity.

    Not thread-safe by itself: the batcher calls it only from the worker
    thread (``order``/``account``); construction-time state is immutable.
    ``order`` never mutates, so it is also directly drivable by the
    hypothesis property tests.
    """

    def __init__(self, tenants: "dict[str, TenantQuota] | None" = None):
        self.tenants = dict(tenants) if tenants else {}
        self._vt: dict[str | None, float] = {}
        # Virtual-time floor: the tag of the most recently dispatched
        # group. A tenant going idle stops accumulating credit — on return
        # it re-enters at max(own tag, floor), the standard SFQ rule that
        # stops an idle tenant from bursting ahead of everyone.
        self._floor = 0.0

    def quota(self, tenant: str | None) -> TenantQuota:
        return self.tenants.get(tenant, DEFAULT_QUOTA)

    def tag(self, tenant: str | None) -> float:
        return max(self._vt.get(tenant, 0.0), self._floor)

    def group_key(self, members: "list[tuple[str | None, int]]",
                  deadline: float) -> tuple:
        """Sort key for one due group: min member tag first (weighted-fair),
        dispatch deadline as the tiebreak (urgency within equal fairness)."""
        vt = min((self.tag(t) for t, _ in members), default=self._floor)
        return (vt, deadline)

    def order(self, items):
        """Order due groups for dispatch. ``items`` is an iterable of
        ``(deadline, key, members)`` with ``members = [(tenant, priority)]``;
        returns the keys, most-deserving group first."""
        return [
            key for _, key, _ in sorted(
                items, key=lambda it: self.group_key(it[2], it[0])
            )
        ]

    def account(self, members: "list[tuple[str | None, int]]") -> None:
        """Charge one dispatched group: each member advances its tenant's
        virtual time by ``1 / effective_weight`` and the floor rises to the
        group's tag."""
        if members:
            self._floor = min(self.tag(t) for t, _ in members)
        for tenant, priority in members:
            w = effective_weight(self.quota(tenant), priority)
            self._vt[tenant] = self.tag(tenant) + 1.0 / w


@dataclasses.dataclass(frozen=True)
class BrownoutPolicy:
    """Thresholds for the brownout ladder, as fractions of ``max_queue``
    (queue depth is the primary signal; with ``max_queue=None`` only the
    latency trigger can escalate).

    ``latency_ms`` optionally escalates one extra level whenever the
    dispatch-latency EWMA exceeds it — the queue can look shallow while
    every dispatch is slow (the single-service face of a gray failure).
    """

    enter_widen: float = 0.50   # level 1: widen the batching window
    enter_shed: float = 0.75    # level 2: shed priority >= shed_priority
    enter_global: float = 0.95  # level 3: shed everything
    hysteresis: float = 0.10    # exit = enter - hysteresis (no flapping)
    shed_priority: int = PRIORITY_LOW
    window_widen: float = 2.0   # level >= 1 window multiplier
    latency_ms: float | None = None
    latency_alpha: float = 0.2

    def __post_init__(self):
        if not 0.0 < self.enter_widen <= self.enter_shed <= self.enter_global:
            raise ValueError("brownout thresholds must be ordered and > 0")
        if self.hysteresis < 0.0:
            raise ValueError("hysteresis must be >= 0")


class BrownoutController:
    """Mutable ladder state over one :class:`BrownoutPolicy`.

    ``update(outstanding)`` is called under the batcher's admission lock
    (submit path); ``observe_latency`` from the worker thread. The level
    is a plain int read — torn reads are impossible under the GIL and a
    one-request-late transition is harmless.
    """

    def __init__(self, policy: BrownoutPolicy, max_queue: int | None):
        self.policy = policy
        self.max_queue = max_queue
        self.level = 0
        self.transitions = 0
        self._latency_ewma_ms: float | None = None

    def observe_latency(self, ms: float) -> None:
        a = self.policy.latency_alpha
        prev = self._latency_ewma_ms
        self._latency_ewma_ms = ms if prev is None else (1 - a) * prev + a * ms

    @property
    def latency_ewma_ms(self) -> float | None:
        return self._latency_ewma_ms

    def _level_for(self, frac: float) -> int:
        p = self.policy
        enters = (p.enter_widen, p.enter_shed, p.enter_global)
        level = 0
        for i, enter in enumerate(enters, start=1):
            # hysteresis: a level already held only releases below its
            # exit threshold, so the ladder doesn't flap at a boundary
            threshold = enter - (p.hysteresis if self.level >= i else 0.0)
            if frac >= threshold:
                level = i
        return level

    def update(self, outstanding: int) -> int:
        """Recompute and return the active level from current queue depth
        (plus the latency escalation, when configured)."""
        frac = (
            outstanding / self.max_queue
            if self.max_queue else 0.0
        )
        level = self._level_for(frac)
        p = self.policy
        if (
            p.latency_ms is not None
            and self._latency_ewma_ms is not None
            and self._latency_ewma_ms >= p.latency_ms
        ):
            level = min(level + 1, 3)
        if level != self.level:
            self.transitions += 1
            self.level = level
        return level

    def window_multiplier(self) -> float:
        return self.policy.window_widen if self.level >= 1 else 1.0

    def sheds(self, priority: int) -> bool:
        """Would the active level shed a request of this priority class?"""
        if self.level >= 3:
            return True
        return self.level >= 2 and priority >= self.policy.shed_priority

    def snapshot(self) -> dict:
        return {
            "level": self.level,
            "transitions": self.transitions,
            "latency_ewma_ms": (
                round(self._latency_ewma_ms, 3)
                if self._latency_ewma_ms is not None else None
            ),
        }


__all__ = [
    "PRIORITY_HIGH",
    "PRIORITY_NORMAL",
    "PRIORITY_LOW",
    "PRIORITY_BOOST",
    "TenantQuota",
    "DEFAULT_QUOTA",
    "effective_weight",
    "FairScheduler",
    "BrownoutPolicy",
    "BrownoutController",
]
