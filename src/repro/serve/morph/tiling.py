"""Halo-correct tiled execution for images beyond one launch's budget.

An image too large for the bucket ladder is split into a grid of interior
tiles of fixed size ``(th, tw)``; each tile is read with a halo of the
plan's total contamination radius (``Plan.halo()`` — SE wings summed over
sequential passes), executed through the same masked executor as bucketed
requests, and only the tile *interior* is stitched back. Because:

* the halo supplies exact neighbor data for every sequential pass, and
* the part of a border tile's halo that falls outside the image is masked
  to each op's neutral element before every pass (plans.mask_outside),

the stitched result is bit-exact against running the plan on the whole
image — including when an SE is larger than the halo-free tile interior.

Tile gather and stitch are **device-resident**: the image is padded once on
device and every halo tile is a ``lax.dynamic_slice`` view of it; outputs
assemble via ``lax.dynamic_update_slice`` and cross to the host once per
output at the end. (The original implementation assembled tiles in host
numpy — one host round trip per oversized image, the ROADMAP "streamed tile
gather" item. This is also the single-device degenerate case of
``repro.shard.halo``: same halo algebra, ``dynamic_slice`` standing in for
``ppermute``.) Everything stays eager — per-image shapes vary freely
without compiling per-shape gather executables; only the plan executor
itself is jitted, exactly as before.

Every extended tile has the same shape ``(th + 2*gh, tw + 2*gw)`` and tiles
are executed in fixed-size launch batches (the last one padded with dummy
tiles whose valid rect is empty), so tiled traffic reuses a single cached
executable per (plan, tile shape, dtype) exactly like bucketed traffic.
"""
from __future__ import annotations

import math

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.serve.morph.plans import Plan


def tile_counts(h: int, w: int, interior: tuple[int, int]) -> tuple[int, int]:
    th, tw = interior
    return math.ceil(h / th), math.ceil(w / tw)


def tile_layout(
    h: int, w: int, gh: int, gw: int, interior: tuple[int, int]
) -> tuple[list[tuple[int, int]], np.ndarray, list[tuple[int, int, int, int]]]:
    """Static per-tile geometry: padded-image slice origins, valid rects in
    extended-tile coordinates, and the (y0, x0, ih, iw) image region each
    tile owns."""
    th, tw = interior
    eh, ew = th + 2 * gh, tw + 2 * gw
    ny, nx = tile_counts(h, w, interior)
    origins, rects, interiors = [], [], []
    for ty in range(ny):
        for tx in range(nx):
            y0, x0 = ty * th, tx * tw
            origins.append((y0, x0))
            rects.append(
                [
                    max(0, gh - y0),
                    min(eh, h - y0 + gh),
                    max(0, gw - x0),
                    min(ew, w - x0 + gw),
                ]
            )
            interiors.append((y0, x0, min(th, h - y0), min(tw, w - x0)))
    return origins, np.asarray(rects, dtype=np.int32), interiors


def extract_tiles(
    img, plan: Plan, interior: tuple[int, int]
) -> tuple[jnp.ndarray, np.ndarray, list[tuple[int, int, int, int]]]:
    """Split (H, W) into halo-extended tiles, gathered on device.

    Returns ``(tiles (N, eh, ew) device array, rects (N, 4), interiors)``
    where ``rects`` are the in-image valid rectangles in extended-tile
    coordinates and ``interiors`` the (y0, x0, ih, iw) image regions each
    tile owns. The image crosses to the device once; each tile is a
    ``dynamic_slice`` of the padded copy — no host-side assembly.
    """
    if img.ndim != 2:
        raise ValueError("extract_tiles operates on a single (H, W) image")
    gh, gw = plan.halo()
    th, tw = interior
    eh, ew = th + 2 * gh, tw + 2 * gw
    h, w = img.shape
    ny, nx = tile_counts(h, w, interior)
    origins, rects, interiors = tile_layout(h, w, gh, gw, interior)
    # One zero-padded device copy; the fill never leaks because the executor
    # masks outside each tile's valid rect before every pass.
    padded = jnp.pad(
        jnp.asarray(img),
        ((gh, gh + ny * th - h), (gw, gw + nx * tw - w)),
    )
    tiles = jnp.stack(
        [lax.dynamic_slice(padded, (y0, x0), (eh, ew)) for y0, x0 in origins]
    )
    return tiles, rects, interiors


def run_tiled(
    img,
    plan: Plan,
    execute,
    *,
    tile_interior: tuple[int, int],
    launch_batch: int,
) -> dict[str, np.ndarray]:
    """Execute ``plan`` over ``img`` in halo tiles and stitch the interiors.

    ``execute(tiles (B, eh, ew), rects (B, 4)) -> {name: (B, eh, ew)}`` is
    the (cached, jitted) executor call — always invoked with ``B`` from the
    power-of-two ladder below ``launch_batch``, short chunks padded with
    dummy tiles (empty valid rect), so a handful of executables serves any
    image size instead of one compile per distinct tile count. Tiles arrive
    as device arrays and interiors stitch on device; each named output
    crosses to the host exactly once.
    """
    gh, gw = plan.halo()
    tiles, rects, interiors = extract_tiles(img, plan, tile_interior)
    n = int(tiles.shape[0])
    h, w = img.shape
    ny, nx = tile_counts(h, w, tile_interior)
    launch_batch = max(1, min(launch_batch, 1 << (n - 1).bit_length() if n else 1))
    crops: dict[str, list] = {}
    for i0 in range(0, n, launch_batch):
        chunk = tiles[i0 : i0 + launch_batch]
        crect = rects[i0 : i0 + launch_batch]
        pad = launch_batch - int(chunk.shape[0])
        if pad:
            chunk = jnp.concatenate(
                [chunk, jnp.zeros((pad, *chunk.shape[1:]), chunk.dtype)]
            )
            crect = np.concatenate([crect, np.zeros((pad, 4), np.int32)])
        res = execute(chunk, crect)
        for name, val in res.items():
            slots = crops.setdefault(name, [None] * n)
            for j in range(min(launch_batch, n - i0)):
                _, _, ih, iw = interiors[i0 + j]
                slots[i0 + j] = lax.slice(val[j], (gh, gw), (gh + ih, gw + iw))
    # Stitch by row-wise concatenation — O(H*W) total, vs a full-image copy
    # per tile that eager dynamic_update_slice would cost — still device-
    # side; each named output crosses to the host exactly once.
    outs: dict[str, np.ndarray] = {}
    for name, slots in crops.items():
        rows = [
            jnp.concatenate(slots[r * nx : (r + 1) * nx], axis=1)
            if nx > 1 else slots[r * nx]
            for r in range(ny)
        ]
        outs[name] = np.asarray(
            jnp.concatenate(rows, axis=0) if ny > 1 else rows[0]
        )
    return outs
