"""Halo-correct tiled execution for images beyond one launch's budget.

An image too large for the bucket ladder is split into a grid of interior
tiles of fixed size ``(th, tw)``; each tile is read with a halo of the
plan's total contamination radius (``Plan.halo()`` — SE wings summed over
sequential passes), executed through the same masked executor as bucketed
requests, and only the tile *interior* is stitched back. Because:

* the halo supplies exact neighbor data for every sequential pass, and
* the part of a border tile's halo that falls outside the image is masked
  to each op's neutral element before every pass (plans.mask_outside),

the stitched result is bit-exact against running the plan on the whole
image — including when an SE is larger than the halo-free tile interior.

Every extended tile has the same shape ``(th + 2*gh, tw + 2*gw)`` and tiles
are executed in fixed-size launch batches (the last one padded with dummy
tiles whose valid rect is empty), so tiled traffic reuses a single cached
executable per (plan, tile shape, dtype) exactly like bucketed traffic.
"""
from __future__ import annotations

import math

import numpy as np

from repro.serve.morph.plans import Plan


def tile_counts(h: int, w: int, interior: tuple[int, int]) -> tuple[int, int]:
    th, tw = interior
    return math.ceil(h / th), math.ceil(w / tw)


def extract_tiles(
    img: np.ndarray, plan: Plan, interior: tuple[int, int]
) -> tuple[np.ndarray, np.ndarray, list[tuple[int, int, int, int]]]:
    """Split (H, W) into halo-extended tiles.

    Returns ``(tiles (N, eh, ew), rects (N, 4), interiors)`` where ``rects``
    are the in-image valid rectangles in extended-tile coordinates and
    ``interiors`` the (y0, x0, ih, iw) image regions each tile owns.
    """
    if img.ndim != 2:
        raise ValueError("extract_tiles operates on a single (H, W) image")
    gh, gw = plan.halo()
    th, tw = interior
    eh, ew = th + 2 * gh, tw + 2 * gw
    h, w = img.shape
    ny, nx = tile_counts(h, w, interior)
    # One zero-padded copy; the fill never leaks because the executor masks
    # outside each tile's valid rect before every pass.
    padded = np.zeros((gh + ny * th + gh, gw + nx * tw + gw), dtype=img.dtype)
    padded[gh : gh + h, gw : gw + w] = img
    tiles, rects, interiors = [], [], []
    for ty in range(ny):
        for tx in range(nx):
            y0, x0 = ty * th, tx * tw
            tiles.append(padded[y0 : y0 + eh, x0 : x0 + ew])
            rects.append(
                [
                    max(0, gh - y0),
                    min(eh, h - y0 + gh),
                    max(0, gw - x0),
                    min(ew, w - x0 + gw),
                ]
            )
            interiors.append((y0, x0, min(th, h - y0), min(tw, w - x0)))
    return (
        np.stack(tiles),
        np.asarray(rects, dtype=np.int32),
        interiors,
    )


def run_tiled(
    img: np.ndarray,
    plan: Plan,
    execute,
    *,
    tile_interior: tuple[int, int],
    launch_batch: int,
) -> dict[str, np.ndarray]:
    """Execute ``plan`` over ``img`` in halo tiles and stitch the interiors.

    ``execute(tiles (B, eh, ew), rects (B, 4)) -> {name: (B, eh, ew)}`` is
    the (cached, jitted) executor call — always invoked with ``B`` from the
    power-of-two ladder below ``launch_batch``, short chunks padded with
    dummy tiles (empty valid rect), so a handful of executables serves any
    image size instead of one compile per distinct tile count.
    """
    gh, gw = plan.halo()
    th, tw = tile_interior
    tiles, rects, interiors = extract_tiles(img, plan, tile_interior)
    n = tiles.shape[0]
    launch_batch = max(1, min(launch_batch, 1 << (n - 1).bit_length() if n else 1))
    outs: dict[str, np.ndarray] = {}
    h, w = img.shape
    for i0 in range(0, n, launch_batch):
        chunk = tiles[i0 : i0 + launch_batch]
        crect = rects[i0 : i0 + launch_batch]
        pad = launch_batch - chunk.shape[0]
        if pad:
            chunk = np.concatenate([chunk, np.zeros((pad, *chunk.shape[1:]), chunk.dtype)])
            crect = np.concatenate([crect, np.zeros((pad, 4), np.int32)])
        res = execute(chunk, crect)
        for name, val in res.items():
            val = np.asarray(val)
            if name not in outs:
                outs[name] = np.empty((h, w), dtype=val.dtype)
            for j in range(min(launch_batch, n - i0)):
                y0, x0, ih, iw = interiors[i0 + j]
                outs[name][y0 : y0 + ih, x0 : x0 + iw] = val[
                    j, gh : gh + ih, gw : gw + iw
                ]
    return outs
