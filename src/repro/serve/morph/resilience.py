"""Resilience primitives for the morphology serving tier.

The serving engine's failure story before this module: one exception inside
a dispatched group poisoned every batch-mate's future, queues grew without
bound until the host OOMed, and a dead shard simply stopped answering. This
module holds the typed vocabulary and policies the batcher, service, and
sharded router use to do better:

* :class:`ServeError` and its family — every failure a caller can observe
  carries (plan, bucket, dtype, batch, shard) context instead of a bare
  XLA traceback, and a ``retryable`` flag the batcher's retry loop honors;
* :class:`RetryPolicy` — bounded exponential backoff for transient dispatch
  failures, after which the batcher *bisects* the group so one poison
  request fails alone while its batch-mates complete;
* :class:`FailoverPolicy` — the sharded router's consecutive-failure
  circuit breaker (open after N failures, half-open probe after an
  interval, close on probe success) and reroute budget;
* :class:`FaultPlan` / :class:`FaultInjector` — a deterministic fault
  harness: fail shard N starting at dispatch K, inject latency, poison one
  tagged request. Counting is by dispatch ordinal (never random, never
  wall-clock), so chaos tests replay exactly. A service with ``faults=None``
  never constructs an injector — the off path is one ``is None`` check.
"""
from __future__ import annotations

import dataclasses
import threading
import time


# --------------------------------------------------------------------- errors
class ServeError(Exception):
    """Base class for every typed serving failure.

    ``retryable`` tells the batcher whether re-dispatching the same group
    can possibly succeed (transient device trouble: yes; a poisoned request
    or an expired deadline: no). Context fields render into the message so
    a bare ``str(exc)`` in a log is already actionable.
    """

    retryable = True

    def __init__(
        self,
        message: str,
        *,
        plan: str | None = None,
        bucket: "tuple[int, int] | None" = None,
        dtype: str | None = None,
        batch: int | None = None,
        shard: int | None = None,
    ):
        self.plan = plan
        self.bucket = bucket
        self.dtype = dtype
        self.batch = batch
        self.shard = shard
        ctx = ", ".join(
            f"{k}={v}"
            for k, v in (
                ("plan", plan),
                ("bucket", bucket),
                ("dtype", dtype),
                ("batch", batch),
                ("shard", shard),
            )
            if v is not None
        )
        super().__init__(f"{message} [{ctx}]" if ctx else message)


class Overloaded(ServeError):
    """Admission control: the submit queue is at ``max_queue``. Shed load —
    the caller should back off or downgrade, not wait."""

    retryable = False


class QuotaExceeded(Overloaded):
    """Per-tenant admission control: this tenant is at its
    ``TenantQuota.max_outstanding``. An ``Overloaded`` that names the
    tenant, so a noisy tenant sheds alone while the shared queue — and
    every other tenant — keeps flowing."""

    def __init__(self, message: str, *, tenant: str | None = None, **kw):
        super().__init__(message, **kw)
        self.tenant = tenant


class BrownoutShed(Overloaded):
    """Brownout ladder: the load controller is shedding this request's
    priority class (level 2) or everything (level 3). An ``Overloaded``
    carrying the active level and the request's priority, so callers can
    tell "you specifically were downgraded away" from "the queue is
    full"."""

    def __init__(self, message: str, *, level: int | None = None,
                 priority: int | None = None, **kw):
        super().__init__(message, **kw)
        self.level = level
        self.priority = priority


class DeadlineExceeded(ServeError):
    """The request's deadline passed before (or while) it could dispatch."""

    retryable = False


class ServiceClosed(ServeError, RuntimeError):
    """``submit()`` after ``close()``. Subclasses RuntimeError so callers
    that guarded against the old opaque queue failure keep working."""

    retryable = False


class ExecutorError(ServeError):
    """An executor build (trace/compile) or run failed; wraps the original
    exception (``__cause__``) with the group's full serving context."""


class PoisonedRequest(ServeError):
    """Fault injection: this specific request is marked to fail. Never
    retryable — bisection must isolate it instead."""

    retryable = False

    def __init__(self, message: str, *, tag: str | None = None, **kw):
        super().__init__(message, **kw)
        self.tag = tag


class InjectedFault(ServeError):
    """Fault injection: a simulated transient dispatch failure (a dying
    shard, a flaky device). Retryable, like the real thing."""


class ShardUnavailable(ServeError):
    """The sharded router has no healthy shard left to route to."""

    retryable = False


# ------------------------------------------------------------------- policies
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry-then-bisect for failed dispatch groups.

    A failed group is re-dispatched up to ``max_retries`` times with
    exponential backoff (``backoff_ms * 2**attempt``, capped). If it still
    fails — or the error is not retryable — groups of more than one request
    split in half and each half dispatches independently, recursively, so a
    single poison request ends up failing alone (O(log batch) extra
    dispatches) while every batch-mate completes.
    """

    max_retries: int = 1
    backoff_ms: float = 2.0
    backoff_cap_ms: float = 100.0
    bisect: bool = True

    def backoff_s(self, attempt: int) -> float:
        return min(self.backoff_ms * (2.0 ** attempt), self.backoff_cap_ms) / 1e3


@dataclasses.dataclass(frozen=True)
class FailoverPolicy:
    """Per-shard circuit breaker + reroute rules for the sharded router.

    ``failure_threshold`` consecutive shard-level failures open the breaker;
    while open, the shard's groups reroute deterministically to survivors.
    After ``probe_interval_s`` one live request is allowed through as a
    half-open probe — success closes the breaker (the shard's groups return
    home), failure re-opens it and restarts the interval.

    The ``slow_*`` knobs add the gray-failure defense (ISSUE 9): breakers
    only move on *errors*, so a shard that is slow-but-alive never trips
    one. The router keeps a per-shard latency EWMA from completed attempts
    and scores it against the healthy peers' median — a shard reading
    worse than ``slow_factor`` x the peer median (and above
    ``slow_min_ms`` absolute, so quiet services don't flag on noise)
    enters a ``"slow"`` state: new traffic routes away, but every
    ``slow_probe_interval_s`` one request is let through so the EWMA can
    decay and the shard can recover. Slow is not dead — the breaker state
    machine never sees it.
    """

    failure_threshold: int = 3
    probe_interval_s: float = 5.0
    rewarm: bool = True  # pre-compile a rerouted group on its survivor
    # --- slowness-aware health (gray failures) --------------------------
    slow_detection: bool = True
    slow_factor: float = 3.0        # x peer-median EWMA that marks "slow"
    slow_exit_factor: float = 1.5   # recovery threshold (hysteresis)
    slow_min_ms: float = 10.0       # absolute floor before anyone is slow
    slow_ewma_alpha: float = 0.25
    slow_probe_interval_s: float = 0.25
    # A shard can only be *marked* slow after this many completed attempts
    # fed its EWMA (recovery has no such gate): a cold EWMA is one sample,
    # and one compile spike must not read as a gray failure.
    slow_min_count: int = 16


@dataclasses.dataclass(frozen=True)
class HedgePolicy:
    """Tail-latency hedging for the sharded router (ISSUE 9).

    After a request has waited ``delay`` on its primary shard — derived
    from the observed cross-shard p``quantile`` of request latency, clamped
    to ``[min_delay_ms, max_delay_ms]`` — the router resubmits it to the
    next healthy shard with the *same* trace ID; the first result wins and
    resolves the caller's future exactly once. Hedges are capped per
    request (``max_hedges``) and the late loser's result is dropped (both
    lowerings are bit-exact, so which copy wins is unobservable in the
    payload). Hedging is what bounds the tail when a shard is degraded in
    the window *before* slow-state detection has drained it.
    """

    enabled: bool = False
    quantile: float = 0.99
    min_delay_ms: float = 5.0
    max_delay_ms: float = 1000.0
    max_hedges: int = 1
    # How long a computed hedge delay is reused before re-reading the
    # latency histograms (submit-path cost control).
    refresh_s: float = 1.0


# ------------------------------------------------------------ fault injection
@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative, deterministic fault schedule (all counting is by
    dispatch ordinal within one service — replayable, never random).

    * ``fail_after``/``fail_for``: dispatches ``[fail_after, fail_after +
      fail_for)`` raise :class:`InjectedFault` (``fail_for=None`` = forever).
      ``fail_shard`` scopes the failures to one shard of a router (``None``
      = every service the plan reaches).
    * ``latency_ms`` sleeps before dispatches (``latency_shard`` scopes it
      the same way) — the knob for degraded-but-alive experiments. The
      gray-failure clauses (ISSUE 9) shape *which* dispatches pay it:
      ``latency_after`` starts the slowness at that dispatch ordinal
      (a shard that degrades mid-traffic, not from birth), and
      ``latency_every`` makes it intermittent — only ordinals ``n`` with
      ``(n - latency_after) % latency_every == 0`` sleep (``None`` =
      every dispatch past ``latency_after``, the persistent gray failure).
      Both count by dispatch ordinal, so gray chaos replays exactly.
    * ``poison_tags``: any request submitted with a matching ``tag`` raises
      :class:`PoisonedRequest` for the group it rides in; bisection must
      isolate it.
    """

    fail_shard: int | None = None
    fail_after: int | None = None
    fail_for: int | None = None
    latency_ms: float = 0.0
    latency_shard: int | None = None
    latency_after: int = 0
    latency_every: int | None = None
    poison_tags: frozenset = frozenset()

    def __post_init__(self):
        # normalize so tests can pass a list/set/tuple of tags
        if not isinstance(self.poison_tags, frozenset):
            object.__setattr__(self, "poison_tags", frozenset(self.poison_tags))

    @property
    def enabled(self) -> bool:
        return (
            self.fail_after is not None
            or self.latency_ms > 0.0
            or bool(self.poison_tags)
        )

    def scoped(self, shard_index: int) -> "FaultPlan":
        """The plan as seen by shard ``shard_index`` of a router: shard-
        scoped clauses drop unless they name this shard; poison tags apply
        wherever the tagged request lands."""
        fail_after = (
            self.fail_after
            if self.fail_shard is None or self.fail_shard == shard_index
            else None
        )
        latency = (
            self.latency_ms
            if self.latency_shard is None or self.latency_shard == shard_index
            else 0.0
        )
        return dataclasses.replace(
            self,
            fail_after=fail_after,
            latency_ms=latency,
            fail_shard=None,
            latency_shard=None,
        )


class FaultInjector:
    """Runtime counterpart of a :class:`FaultPlan` — one per service, its
    dispatch counter advanced under a lock so concurrent executors see a
    single deterministic ordinal sequence."""

    def __init__(self, plan: FaultPlan, *, shard: int | None = None):
        self.plan = plan
        self.shard = shard
        self.dispatches = 0
        self.injected_faults = 0
        self.injected_latency_s = 0.0
        self._lock = threading.Lock()

    def _latency_due(self, n: int) -> bool:
        """Gray-failure schedule: does dispatch ordinal ``n`` pay the
        injected latency? (Persistent past ``latency_after``, or every
        ``latency_every``-th dispatch when intermittent.)"""
        p = self.plan
        if n < p.latency_after:
            return False
        if p.latency_every is None:
            return True
        return (n - p.latency_after) % p.latency_every == 0

    def before_dispatch(self, reqs) -> None:
        """Called by the executor with the group about to run; raises the
        scheduled fault (if any) *before* any compute happens."""
        with self._lock:
            n = self.dispatches
            self.dispatches += 1
        if self.plan.latency_ms > 0.0 and self._latency_due(n):
            time.sleep(self.plan.latency_ms / 1e3)
            with self._lock:
                self.injected_latency_s += self.plan.latency_ms / 1e3
        fa, ff = self.plan.fail_after, self.plan.fail_for
        if fa is not None and n >= fa and (ff is None or n < fa + ff):
            with self._lock:
                self.injected_faults += 1
            raise InjectedFault(
                f"injected fault at dispatch {n}", shard=self.shard
            )
        if self.plan.poison_tags:
            for r in reqs:
                tag = getattr(r, "tag", None)
                if tag in self.plan.poison_tags:
                    with self._lock:
                        self.injected_faults += 1
                    raise PoisonedRequest(
                        f"injected poison for tag {tag!r}",
                        tag=tag,
                        shard=self.shard,
                    )

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "dispatches": self.dispatches,
                "injected_faults": self.injected_faults,
                "injected_latency_s": round(self.injected_latency_s, 6),
            }


__all__ = [
    "ServeError",
    "Overloaded",
    "QuotaExceeded",
    "BrownoutShed",
    "DeadlineExceeded",
    "ServiceClosed",
    "ExecutorError",
    "PoisonedRequest",
    "InjectedFault",
    "ShardUnavailable",
    "RetryPolicy",
    "FailoverPolicy",
    "HedgePolicy",
    "FaultPlan",
    "FaultInjector",
]
