"""Shape bucketing: pad every request up to a small fixed set of (H, W).

A jitted morphology executable is specialized on its input shape, so serving
raw request shapes means one compile per novel (H, W) — fatal under real
traffic. Instead each image is padded up to the smallest bucket that holds
it and the executable cache is keyed on the bucket, keeping a handful of hot
executables for an unbounded space of request shapes.

Correctness does NOT depend on the pad fill value: the plan executor
(plans.py) re-masks everything outside each request's valid rectangle with
the *next op's* neutral element before every primitive pass, which makes the
pad region behave exactly like the kernels' own virtual neutral border —
so cropping the bucket result back to (h, w) is bit-exact against running
the op on the unpadded image, even for composed plans where a single fill
value could not serve both min and max stages.
"""
from __future__ import annotations

import numpy as np

from repro.serve.morph.resilience import ServeError

# Ladder of (H, W) buckets. Lane-friendly widths (multiples of 128) so the
# fused kernel's column grid pads nothing on top; (608, 896) covers the
# paper's 600x800 experimental image with <2% waste.
DEFAULT_BUCKETS: tuple[tuple[int, int], ...] = (
    (64, 128),
    (128, 128),
    (128, 256),
    (256, 256),
    (256, 512),
    (512, 512),
    (608, 896),
    (1024, 1024),
)


def check_buckets(
    buckets: tuple[tuple[int, int], ...]
) -> tuple[tuple[int, int], ...]:
    """Validate a bucket ladder loudly at service construction — a malformed
    ladder must not surface later as an opaque shape error on the batcher
    thread (where it would poison whole dispatch groups)."""
    if not buckets:
        raise ServeError(
            "empty bucket ladder: every request would take the tiled route; "
            "pass at least one (H, W) bucket"
        )
    for b in buckets:
        if len(b) != 2 or any(int(s) < 1 for s in b):
            raise ServeError(f"malformed bucket {b!r}: want (H >= 1, W >= 1)")
    return buckets


def choose_bucket(
    h: int, w: int, buckets: tuple[tuple[int, int], ...] = DEFAULT_BUCKETS
) -> tuple[int, int] | None:
    """Smallest-area bucket holding (h, w); None if nothing fits (-> tiling)."""
    best = None
    for bh, bw in buckets:
        if bh >= h and bw >= w and (best is None or bh * bw < best[0] * best[1]):
            best = (bh, bw)
    return best


def pad_to_bucket(img: np.ndarray, bucket: tuple[int, int]) -> np.ndarray:
    """Zero-pad (h, w) bottom/right to bucket shape (fill value is irrelevant:
    the executor masks outside the valid rect before every pass)."""
    h, w = img.shape
    bh, bw = bucket
    if (h, w) == (bh, bw):
        return img
    out = np.zeros((bh, bw), dtype=img.dtype)
    out[:h, :w] = img
    return out


def valid_rect(h: int, w: int) -> np.ndarray:
    """[y0, y1, x0, x1) of the real data inside a bucket, for the executor."""
    return np.array([0, h, 0, w], dtype=np.int32)


def crop_from_bucket(out: np.ndarray, h: int, w: int) -> np.ndarray:
    return out[:h, :w]
