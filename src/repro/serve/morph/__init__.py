"""Async morphology serving: shape-bucketed micro-batching, an LRU
executable cache, and halo-correct tiling over the fused 2-D kernels.

    with MorphService() as svc:
        edges = svc.run_plan(img, "document_cleanup")["edges"]
"""
from repro.serve.morph.batcher import MicroBatcher
from repro.serve.morph.buckets import (
    DEFAULT_BUCKETS,
    check_buckets,
    choose_bucket,
    crop_from_bucket,
    pad_to_bucket,
    valid_rect,
)
from repro.serve.morph.plans import (
    PLANS,
    Backend,
    Plan,
    Step,
    UnknownPlan,
    VALID_BACKENDS,
    build_executor,
    check_backend,
    document_cleanup_plan,
    get_plan,
    register_plan,
    single_op_plan,
    to_plan,
)
from repro.serve.morph.resilience import (
    DeadlineExceeded,
    ExecutorError,
    FailoverPolicy,
    FaultInjector,
    FaultPlan,
    InjectedFault,
    Overloaded,
    PoisonedRequest,
    RetryPolicy,
    ServeError,
    ServiceClosed,
    ShardUnavailable,
)
from repro.serve.morph.service import (
    ExecutableCache,
    MorphService,
    ServiceConfig,
    ServiceStats,
)
from repro.serve.morph.tiling import extract_tiles, run_tiled

__all__ = [
    "MicroBatcher",
    "DEFAULT_BUCKETS",
    "check_buckets",
    "UnknownPlan",
    "DeadlineExceeded",
    "ExecutorError",
    "FailoverPolicy",
    "FaultInjector",
    "FaultPlan",
    "InjectedFault",
    "Overloaded",
    "PoisonedRequest",
    "RetryPolicy",
    "ServeError",
    "ServiceClosed",
    "ShardUnavailable",
    "choose_bucket",
    "crop_from_bucket",
    "pad_to_bucket",
    "valid_rect",
    "PLANS",
    "Backend",
    "VALID_BACKENDS",
    "Plan",
    "Step",
    "build_executor",
    "check_backend",
    "to_plan",
    "document_cleanup_plan",
    "get_plan",
    "register_plan",
    "single_op_plan",
    "ExecutableCache",
    "MorphService",
    "ServiceConfig",
    "ServiceStats",
    "extract_tiles",
    "run_tiled",
]
