"""Deadline-based micro-batcher: coalesce concurrent requests into stacks.

Requests carry a *group key* (plan + bucket + dtype — anything that must
match for images to share an executable). The single worker thread collects
arrivals per key and dispatches a group when it reaches ``max_batch`` or its
oldest member has waited ``window_s``, whichever comes first — the standard
serving trade of a bounded latency tax for batch occupancy. All JAX
dispatch happens on the worker thread; callers only touch numpy arrays and
``concurrent.futures.Future`` results.

With ``adaptive=True`` the window is load-aware: ``window_s`` becomes the
*effective* window, bounded by ``[min_window_s, max_window_s]``. Each
deadline dispatch that drains below the low-water mark halves the window
(light load: the latency tax buys nothing), and each dispatch at or above
the high-water mark doubles it toward the configured max (sustained
pressure: coalescing pays). Mostly-idle services converge to near-zero
added latency; saturated ones to full-window occupancy.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable

_STOP = object()


class MicroBatcher:
    """Groups submitted requests by ``req.key`` and hands each group to
    ``execute_group(key, requests)`` on a dedicated worker thread.

    ``execute_group`` owns success paths (setting ``req.future`` results);
    the batcher guarantees every request's future is resolved — exceptions
    escaping ``execute_group`` are fanned out to the group's futures.
    """

    def __init__(
        self,
        execute_group: Callable[[Any, list], None],
        *,
        max_batch: int = 64,
        window_s: float = 0.002,
        adaptive: bool = False,
        min_window_s: float = 0.0,
        name: str = "morph-batcher",
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self._execute = execute_group
        self.max_batch = max_batch
        self.window_s = window_s
        self.max_window_s = window_s
        self.min_window_s = min(min_window_s, window_s)
        self.adaptive = adaptive
        # hysteresis marks: <= low water after a deadline expiry -> shrink,
        # >= high water -> grow (a full batch always grows)
        self._low_water = max(1, max_batch // 8)
        self._high_water = max(2, max_batch // 2)
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._cv = threading.Condition()
        self._outstanding = 0
        self._closed = False
        self._thread = threading.Thread(target=self._loop, name=name, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------ public API
    def submit(self, req) -> None:
        # put() while holding the lock: close() also takes it before
        # enqueueing _STOP, so a request can never land behind a _STOP the
        # worker has already consumed (SimpleQueue.put never blocks).
        with self._cv:
            if self._closed:
                raise RuntimeError("batcher is closed")
            self._outstanding += 1
            self._q.put(req)

    def flush(self, timeout: float | None = None) -> bool:
        """Block until every submitted request has been dispatched."""
        with self._cv:
            return self._cv.wait_for(lambda: self._outstanding == 0, timeout=timeout)

    def close(self) -> None:
        """Drain remaining requests, then stop the worker."""
        with self._cv:
            if self._closed:
                self._thread.join()
                return
            self._closed = True
            self._q.put(_STOP)
        self._thread.join()

    # ---------------------------------------------------------- worker loop
    def _poll(self, pending: dict, draining: bool):
        if draining:
            try:
                return self._q.get_nowait()
            except queue.Empty:
                return None
        if pending:
            earliest = min(deadline for deadline, _ in pending.values())
            timeout = max(0.0, earliest - time.monotonic())
            try:
                return self._q.get(timeout=timeout)
            except queue.Empty:
                return None
        return self._q.get()  # idle: block until work or _STOP arrives

    def _loop(self) -> None:
        pending: dict[Any, tuple[float, list]] = {}
        draining = False
        while True:
            item = self._poll(pending, draining)
            if item is _STOP:
                draining = True
            elif item is not None:
                if item.key not in pending:
                    pending[item.key] = (time.monotonic() + self.window_s, [])
                pending[item.key][1].append(item)
            now = time.monotonic()
            due = [
                key
                for key, (deadline, reqs) in pending.items()
                if draining or deadline <= now or len(reqs) >= self.max_batch
            ]
            for key in due:
                _, reqs = pending.pop(key)
                if not draining:  # drain flushes partials; don't learn from it
                    # backlog = work already queued behind this group; at a
                    # zero-width window every group is size 1 by construction,
                    # so size alone could never signal pressure and the window
                    # would absorb at 0 — queued arrivals are the escape
                    self._adapt(len(reqs), backlog=not self._q.empty() or bool(pending))
                for i in range(0, len(reqs), self.max_batch):
                    self._dispatch(key, reqs[i : i + self.max_batch])
            # submit() and close() enqueue under one lock, so every request
            # precedes _STOP in the FIFO: seeing _STOP means the queue holds
            # nothing else, and pending empty means everything dispatched.
            if draining and not pending:
                return

    def _adapt(self, group_size: int, *, backlog: bool = False) -> None:
        """Multiplicative-increase / multiplicative-decrease window control,
        driven by how full each dispatched group was and whether more work
        was already queued behind it. Worker-thread only; ``window_s`` is
        read lock-free elsewhere (a float store is atomic under the GIL)."""
        if not self.adaptive:
            return
        if backlog or group_size >= self._high_water:
            grown = max(self.window_s * 2.0, self.max_window_s / 32.0)
            self.window_s = min(self.max_window_s, grown)
        elif group_size <= self._low_water:
            shrunk = self.window_s / 2.0
            if shrunk < self.max_window_s / 64.0:
                shrunk = self.min_window_s
            self.window_s = max(self.min_window_s, shrunk)

    def _dispatch(self, key, reqs: list) -> None:
        try:
            self._execute(key, reqs)
        except BaseException as exc:  # noqa: BLE001 — fan failure out to callers
            for r in reqs:
                if not r.future.done():
                    r.future.set_exception(exc)
        finally:
            with self._cv:
                self._outstanding -= len(reqs)
                self._cv.notify_all()
