"""Deadline-based micro-batcher: coalesce concurrent requests into stacks.

Requests carry a *group key* (plan + bucket + dtype — anything that must
match for images to share an executable). The single worker thread collects
arrivals per key and dispatches a group when it reaches ``max_batch`` or its
dispatch deadline passes, whichever comes first — the standard serving trade
of a bounded latency tax for batch occupancy. All JAX dispatch happens on
the worker thread; callers only touch numpy arrays and
``concurrent.futures.Future`` results.

Resilience (see resilience.py for the vocabulary):

* **Admission control** — ``max_queue`` bounds outstanding (queued +
  in-flight) requests; ``submit`` raises :class:`Overloaded` past it, so
  overload sheds load instead of growing the queue until the host OOMs.
  Admission is split into ``reserve`` (the atomic accept/reject decision)
  and ``enqueue`` so the service can charge admission *before* doing any
  per-request work (the RLE density probe), and release the slot if
  routing fails.
* **Tenancy** (ISSUE 9; tenancy.py) — requests carry ``tenant`` and
  ``priority``. Per-tenant ``TenantQuota.max_outstanding`` rejects with
  the typed :class:`QuotaExceeded` so one noisy tenant sheds alone, and
  due groups dispatch in start-time-fair order over tenant weights
  (``FairScheduler``) instead of plain deadline order, so a flooding
  tenant cannot monopolize the worker.
* **Brownout ladder** (tenancy.py) — a load controller over queue depth
  and the dispatch-latency EWMA degrades in steps: level 1 widens the
  batching window, level 2 sheds the lowest priority classes with typed
  :class:`BrownoutShed`, level 3 sheds everything. The old single cliff
  (``Overloaded`` at ``max_queue``) remains the backstop.
* **Deadlines** — a request may carry ``req.deadline`` (absolute monotonic
  seconds). A group's dispatch deadline is the *earlier* of its batching
  window and its most urgent member, members whose deadline already passed
  fail with :class:`DeadlineExceeded` instead of occupying the executor,
  and retry backoff never sleeps past a live member's remaining slack.
* **Failure isolation** — a failed group retries with exponential backoff
  (``RetryPolicy``; only for ``retryable`` errors), then *bisects*: each
  half re-dispatches independently, recursively, so one poison request
  fails alone while every batch-mate still completes. Exceptions never fan
  out across a whole cohort anymore unless every member really fails.

With ``adaptive=True`` the window is load-aware: ``window_s`` becomes the
*effective* window, bounded by ``[min_window_s, max_window_s]``. Each
deadline dispatch that drains below the low-water mark halves the window
(light load: the latency tax buys nothing), and each dispatch at or above
the high-water mark doubles it toward the configured max (sustained
pressure: coalescing pays). Mostly-idle services converge to near-zero
added latency; saturated ones to full-window occupancy. Brownout level 1
stacks a further multiplier on top.
"""
from __future__ import annotations

import contextlib
import queue
import threading
import time
from typing import Any, Callable

from repro.obs import MetricsRegistry
from repro.serve.morph.resilience import (
    BrownoutShed,
    DeadlineExceeded,
    Overloaded,
    QuotaExceeded,
    RetryPolicy,
    ServiceClosed,
)
from repro.serve.morph.tenancy import (
    BrownoutController,
    BrownoutPolicy,
    FairScheduler,
    PRIORITY_NORMAL,
    TenantQuota,
)

_STOP = object()


def _member(req) -> tuple:
    """(tenant, priority) of a request; raw test doubles default to the
    anonymous tenant at normal priority."""
    return (getattr(req, "tenant", None),
            getattr(req, "priority", PRIORITY_NORMAL))


class MicroBatcher:
    """Groups submitted requests by ``req.key`` and hands each group to
    ``execute_group(key, requests)`` on a dedicated worker thread.

    ``execute_group`` owns success paths (setting ``req.future`` results);
    the batcher guarantees every request's future is resolved exactly once —
    exceptions escaping ``execute_group`` are retried/bisected per
    ``retry``, and whatever still fails is fanned out to the (sub)group's
    futures.
    """

    def __init__(
        self,
        execute_group: Callable[[Any, list], None],
        *,
        max_batch: int = 64,
        window_s: float = 0.002,
        adaptive: bool = False,
        min_window_s: float = 0.0,
        max_queue: int | None = None,
        retry: RetryPolicy | None = None,
        tenants: "dict[str, TenantQuota] | None" = None,
        brownout: BrownoutPolicy | None = None,
        name: str = "morph-batcher",
        registry: MetricsRegistry | None = None,
        obs=None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None for unbounded)")
        self._execute = execute_group
        self.max_batch = max_batch
        self.max_queue = max_queue
        self.retry = retry
        self.window_s = window_s
        self.max_window_s = window_s
        self.min_window_s = min(min_window_s, window_s)
        self.adaptive = adaptive
        # hysteresis marks: <= low water after a deadline expiry -> shrink,
        # >= high water -> grow (a full batch always grows)
        self._low_water = max(1, max_batch // 8)
        self._high_water = max(2, max_batch // 2)
        self._q: queue.SimpleQueue = queue.SimpleQueue()
        self._cv = threading.Condition()
        self._outstanding = 0
        self._closed = False
        self._obs = obs  # repro.obs.Observability or None (zero-overhead off)
        # tenancy: scheduler state is worker-thread-only; the admission-side
        # per-tenant outstanding map mutates under the cv lock
        self._scheduler = FairScheduler(tenants)
        self._tenant_outstanding: dict = {}
        self._brownout = (
            BrownoutController(brownout, max_queue)
            if brownout is not None else None
        )
        # resilience counters (worker/submit threads; registry counters
        # mutated under the cv lock or the worker thread only)
        reg = registry if registry is not None else MetricsRegistry()
        self._registry = reg
        self._rejected = reg.counter("batcher.rejected_overloaded")
        self._rejected_quota = reg.counter("batcher.rejected_quota")
        self._shed_brownout = reg.counter("batcher.shed_brownout")
        self._expired = reg.counter("batcher.deadline_expired")
        self._retries = reg.counter("batcher.retries")
        self._bisections = reg.counter("batcher.bisections")
        self._request_failures = reg.counter("batcher.request_failures")
        self._brownout_level = reg.gauge("brownout.level", mode="max")
        self._thread = threading.Thread(target=self._loop, name=name, daemon=True)
        self._thread.start()

    # ------------------------------------------------------------ admission
    def _tenant_counter(self, tenant, event: str):
        return self._registry.counter(f"tenant.{tenant or '_'}.{event}")

    def reserve(self, tenant: str | None = None,
                priority: int = PRIORITY_NORMAL) -> None:
        """Atomically claim one admission slot (global queue bound, tenant
        quota, brownout ladder) or raise the typed rejection. The caller
        must follow with exactly one ``enqueue`` or ``release``."""
        with self._cv:
            if self._closed:
                raise ServiceClosed("service is closed; submit() rejected")
            if self.max_queue is not None and self._outstanding >= self.max_queue:
                self._rejected.inc()
                raise Overloaded(
                    f"submit queue full ({self._outstanding} outstanding, "
                    f"max_queue={self.max_queue})"
                )
            quota = self._scheduler.quota(tenant)
            held = self._tenant_outstanding.get(tenant, 0)
            if (
                quota.max_outstanding is not None
                and held >= quota.max_outstanding
            ):
                self._rejected_quota.inc()
                self._tenant_counter(tenant, "rejected_quota").inc()
                raise QuotaExceeded(
                    f"tenant {tenant!r} at quota ({held} outstanding, "
                    f"max_outstanding={quota.max_outstanding})",
                    tenant=tenant,
                )
            if self._brownout is not None:
                level = self._brownout.update(self._outstanding)
                self._brownout_level.set(level)
                if self._brownout.sheds(priority):
                    self._shed_brownout.inc()
                    self._tenant_counter(tenant, "shed_brownout").inc()
                    raise BrownoutShed(
                        f"brownout level {level} shedding priority "
                        f"{priority} ({self._outstanding} outstanding)",
                        level=level,
                        priority=priority,
                    )
            self._outstanding += 1
            self._tenant_outstanding[tenant] = held + 1

    def release(self, tenant: str | None = None) -> None:
        """Return a reserved slot that never made it into the queue
        (routing raised between reserve and enqueue)."""
        with self._cv:
            self._outstanding -= 1
            self._tenant_outstanding[tenant] = (
                self._tenant_outstanding.get(tenant, 1) - 1
            )
            self._cv.notify_all()

    def enqueue(self, req) -> None:
        """Queue a request whose slot is already reserved. On failure the
        caller still holds the slot and must ``release`` it."""
        # put() while holding the lock: close() also takes it before
        # enqueueing _STOP, so a request can never land behind a _STOP the
        # worker has already consumed (SimpleQueue.put never blocks).
        with self._cv:
            if self._closed:
                # raced close() between reserve and enqueue
                raise ServiceClosed("service is closed; submit() rejected")
            self._q.put(req)

    # ------------------------------------------------------------ public API
    def submit(self, req) -> None:
        tenant, priority = _member(req)
        self.reserve(tenant, priority)
        try:
            self.enqueue(req)
        except BaseException:
            self.release(tenant)
            raise

    def flush(self, timeout: float | None = None) -> bool:
        """Block until every submitted request has been dispatched."""
        with self._cv:
            return self._cv.wait_for(lambda: self._outstanding == 0, timeout=timeout)

    def close(self) -> None:
        """Drain remaining requests, then stop the worker. Idempotent —
        concurrent/double close() both join the same drained worker."""
        with self._cv:
            if self._closed:
                self._thread.join()
                return
            self._closed = True
            self._q.put(_STOP)
        self._thread.join()

    def counters(self) -> dict:
        with self._cv:
            out = {
                "rejected_overloaded": self._rejected.value,
                "rejected_quota": self._rejected_quota.value,
                "shed_brownout": self._shed_brownout.value,
                "deadline_expired": self._expired.value,
                "retries": self._retries.value,
                "bisections": self._bisections.value,
                "request_failures": self._request_failures.value,
                "brownout": (
                    self._brownout.snapshot()
                    if self._brownout is not None else None
                ),
                "tenants": {
                    t: {
                        "outstanding": n,
                        "rejected_quota": self._tenant_counter(
                            t, "rejected_quota").value,
                        "shed_brownout": self._tenant_counter(
                            t, "shed_brownout").value,
                        "dispatched": self._tenant_counter(
                            t, "dispatched").value,
                    }
                    for t, n in sorted(
                        self._tenant_outstanding.items(),
                        key=lambda kv: str(kv[0]),
                    )
                    if t is not None
                },
            }
        return out

    # ---------------------------------------------------------- worker loop
    def _poll(self, pending: dict, draining: bool):
        if draining:
            try:
                return self._q.get_nowait()
            except queue.Empty:
                return None
        if pending:
            earliest = min(deadline for deadline, _ in pending.values())
            timeout = max(0.0, earliest - time.monotonic())
            try:
                return self._q.get(timeout=timeout)
            except queue.Empty:
                return None
        return self._q.get()  # idle: block until work or _STOP arrives

    def _window_now(self) -> float:
        """The batching window a newly opened group gets: the adaptive
        window, widened by the brownout ladder under load (level >= 1
        trades extra latency for occupancy instead of shedding)."""
        w = self.window_s
        if self._brownout is not None:
            w *= self._brownout.window_multiplier()
        return w

    def _loop(self) -> None:
        pending: dict[Any, tuple[float, list]] = {}
        draining = False
        while True:
            item = self._poll(pending, draining)
            if item is _STOP:
                draining = True
            elif item is not None:
                if item.key not in pending:
                    pending[item.key] = (time.monotonic() + self._window_now(), [])
                deadline, reqs = pending[item.key]
                reqs.append(item)
                # a member more urgent than the batching window pulls the
                # whole group's dispatch forward — to HALF its remaining
                # slack, not the deadline itself, so it leaves the queue with
                # time left to execute (a deadline bounds queue wait; a
                # dispatched request can't be preempted mid-executor)
                req_deadline = getattr(item, "deadline", None)
                if req_deadline is not None:
                    now = time.monotonic()
                    urgent = now + max(0.0, req_deadline - now) / 2.0
                    if urgent < deadline:
                        pending[item.key] = (urgent, reqs)
            now = time.monotonic()
            due = {
                key: (deadline, [_member(r) for r in reqs])
                for key, (deadline, reqs) in pending.items()
                if draining or deadline <= now or len(reqs) >= self.max_batch
            }
            # weighted-fair over tenants (min virtual tag first, dispatch
            # deadline as the urgency tiebreak) — plain deadline order
            # would let a flooding tenant's groups always cut the line.
            # One group at a time: each dispatch advances its tenant's
            # virtual time, which re-ranks the rest of the due set — sorting
            # the whole set up front would hand a flood the original order.
            while due:
                key = self._scheduler.order(
                    [(d, k, m) for k, (d, m) in due.items()]
                )[0]
                del due[key]
                _, reqs = pending.pop(key)
                if not draining:  # drain flushes partials; don't learn from it
                    # backlog = work already queued behind this group; at a
                    # zero-width window every group is size 1 by construction,
                    # so size alone could never signal pressure and the window
                    # would absorb at 0 — queued arrivals are the escape
                    self._adapt(len(reqs), backlog=not self._q.empty() or bool(pending))
                self._scheduler.account([_member(r) for r in reqs])
                for r in reqs:
                    tenant = _member(r)[0]
                    if tenant is not None:
                        self._tenant_counter(tenant, "dispatched").inc()
                for i in range(0, len(reqs), self.max_batch):
                    self._dispatch(key, reqs[i : i + self.max_batch])
            # submit() and close() enqueue under one lock, so every request
            # precedes _STOP in the FIFO: seeing _STOP means the queue holds
            # nothing else, and pending empty means everything dispatched.
            if draining and not pending:
                return

    def _adapt(self, group_size: int, *, backlog: bool = False) -> None:
        """Multiplicative-increase / multiplicative-decrease window control,
        driven by how full each dispatched group was and whether more work
        was already queued behind it. Worker-thread only; ``window_s`` is
        read lock-free elsewhere (a float store is atomic under the GIL)."""
        if not self.adaptive:
            return
        if backlog or group_size >= self._high_water:
            grown = max(self.window_s * 2.0, self.max_window_s / 32.0)
            self.window_s = min(self.max_window_s, grown)
        elif group_size <= self._low_water:
            shrunk = self.window_s / 2.0
            if shrunk < self.max_window_s / 64.0:
                shrunk = self.min_window_s
            self.window_s = max(self.min_window_s, shrunk)

    # ------------------------------------------------------ failure handling
    def _fail(self, reqs: list, exc: BaseException) -> None:
        for r in reqs:
            if self._obs is not None:
                self._obs.request_failed(r, exc)  # close queue spans
            if not r.future.done():
                r.future.set_exception(exc)
        with self._cv:
            self._request_failures.inc(len(reqs))

    def _drop_expired(self, reqs: list) -> list:
        now = time.monotonic()
        live = []
        expired = []
        for r in reqs:
            deadline = getattr(r, "deadline", None)
            if deadline is not None and deadline <= now:
                expired.append(r)
            else:
                live.append(r)
        if expired:
            with self._cv:
                self._expired.inc(len(expired))
            self._fail(
                expired,
                DeadlineExceeded(
                    f"deadline passed before dispatch "
                    f"({len(expired)} of {len(reqs)} in group)"
                ),
            )
        return live

    @staticmethod
    def _min_slack(reqs: list) -> float | None:
        """Smallest remaining deadline slack among the group, in seconds;
        None when no member carries a deadline."""
        now = time.monotonic()
        slacks = [
            r.deadline - now
            for r in reqs
            if getattr(r, "deadline", None) is not None
        ]
        return min(slacks) if slacks else None

    def _try_execute(
        self, key, reqs: list, *, retry: bool
    ) -> tuple[BaseException | None, list]:
        """One dispatch plus bounded retries; returns ``(exc, live)`` where
        ``exc`` is the final exception (None on success) and ``live`` the
        members still unresolved — retries re-drop expired members and cap
        backoff at the group's remaining deadline slack, so a retry can
        never sleep a request past its own deadline and then dispatch it
        anyway."""
        policy = self.retry if retry else None
        attempts = 1 + (policy.max_retries if policy else 0)
        last: BaseException | None = None
        for attempt in range(attempts):
            span = contextlib.nullcontext()
            backoff = 0.0
            if attempt:
                # a retry re-enters the queue, effectively: members whose
                # deadline lapsed during the failed attempt fail fast typed
                # instead of riding a doomed re-dispatch
                reqs = self._drop_expired(reqs)
                if not reqs:
                    return None, reqs
                with self._cv:
                    self._retries.inc()
                backoff = policy.backoff_s(attempt - 1)
                slack = self._min_slack(reqs)
                if slack is not None:
                    backoff = min(backoff, max(0.0, slack))
                if self._obs is not None:
                    # the retry span covers backoff sleep + re-dispatch, so
                    # chaos traces show where a retried request's time went
                    span = self._obs.group_span(
                        "retry", reqs, attempt=attempt, backoff_ms=backoff * 1e3
                    )
            try:
                with span:
                    if backoff > 0:
                        time.sleep(backoff)
                        # the cap above means this only trims the group at
                        # the boundary where slack ran out mid-sleep
                        reqs = self._drop_expired(reqs)
                        if not reqs:
                            return None, reqs
                    self._execute(key, reqs)
                return None, reqs
            except BaseException as exc:  # noqa: BLE001 — classified below
                last = exc
                if not getattr(exc, "retryable", True):
                    return exc, reqs
        return last, reqs

    def _run_group(self, key, reqs: list, *, retry: bool) -> None:
        """Execute with retry; on persistent failure bisect so only the
        smallest failing subset carries the exception."""
        reqs = self._drop_expired(reqs)
        if not reqs:
            return
        exc, reqs = self._try_execute(key, reqs, retry=retry)
        if exc is None or not reqs:
            return
        if len(reqs) == 1 or not (self.retry and self.retry.bisect):
            self._fail(reqs, exc)
            return
        with self._cv:
            self._bisections.inc()
        mid = len(reqs) // 2
        span = (
            self._obs.group_span(
                "bisect", reqs, left=mid, right=len(reqs) - mid,
                error=type(exc).__name__,
            )
            if self._obs is not None
            else contextlib.nullcontext()
        )
        # halves dispatch without further retries: the top-level retry
        # already ran, and O(log n) isolation must stay O(log n) dispatches
        with span:
            self._run_group(key, reqs[:mid], retry=False)
            self._run_group(key, reqs[mid:], retry=False)

    def _dispatch(self, key, reqs: list) -> None:
        t0 = time.monotonic()
        try:
            self._run_group(key, reqs, retry=True)
        except BaseException as exc:  # noqa: BLE001 — belt and braces: never
            for r in reqs:            # leave a future hanging
                if not r.future.done():
                    r.future.set_exception(exc)
        finally:
            if self._brownout is not None:
                self._brownout.observe_latency((time.monotonic() - t0) * 1e3)
            with self._cv:
                self._outstanding -= len(reqs)
                for r in reqs:
                    tenant = _member(r)[0]
                    self._tenant_outstanding[tenant] = (
                        self._tenant_outstanding.get(tenant, len(reqs)) - 1
                    )
                self._cv.notify_all()
