"""Morphology plans: named expression outputs compiled as one executable.

A :class:`Plan` is the serving-side unit of work: ordered named outputs,
each a morphology expression (``repro.morph``) over the single input
``Var("x")``. Plans come from two surfaces:

* the legacy :class:`Step` chain (string op + SE + optional save/cast) —
  kept as a deprecation shim; ``__post_init__`` re-expresses the steps as
  IR outputs via ``repro.morph.steps_to_outputs``;
* :func:`repro.morph.to_plan` — any expression, including ``BoundedIter``
  reconstruction chains, becomes servable.

``Plan.halo()`` and the per-stage neutral masking are *derived from the
graph* (``repro.morph.analyze``): no per-op multiplier table, no
special-cased gradient. The executor masks everything outside each image's
valid rect with the op's own neutral element before every primitive pass
(``core.types.MorphOp.neutral``), which makes the pad region
indistinguishable from the kernels' virtual neutral border at every stage
of a composed plan. That buys:

* bucket padding that is bit-exact after cropping, with an arbitrary fill
  value (a single fill could never serve both min and max stages);
* halo-correct tiling (tiling.py), where edge tiles mask the out-of-image
  part of their halo the same way.

A graph that needs *both* neutrals on one value — ``gradient`` is
``Sub(Dilate(c), Erode(c))`` — just works: each primitive node masks its own
input. The raw pipeline ``data/images.py::cleanup_batch`` is ported here as
the ``document_cleanup`` plan (built from the same ``CLEANUP_STEPS``
constant), so the service and the direct path are verifiably the same
computation.

Executors are plain jitted functions; the per-key cache with hit/miss
counters lives in service.py.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import erode as core_erode
from repro.core import dilate as core_dilate
from repro.core.dispatch import DispatchPolicy, resolve_interpret
from repro.core.types import check_window
from repro.data.images import CLEANUP_STEPS
from repro.kernels import dilate2d_tpu, erode2d_tpu
from repro.morph.analyze import halo as expr_halo
from repro.morph.expr import MorphExpr
from repro.morph.interp import evaluate
from repro.morph.plan_compile import steps_to_outputs, to_plan
from repro.serve.morph.resilience import ServeError

_OPS = ("erode", "dilate", "opening", "closing", "gradient")

Backend = Literal["jnp", "kernel"]
VALID_BACKENDS = ("jnp", "kernel")


def check_backend(backend: str) -> Backend:
    """Validate a backend name loudly (a typo must not fall through to some
    default path at execution time)."""
    if backend not in VALID_BACKENDS:
        raise ValueError(
            f"backend must be one of {VALID_BACKENDS}, got {backend!r}"
        )
    return backend


@dataclasses.dataclass(frozen=True)
class Step:
    """One legacy plan stage: a morphology op, its SE, and optional output
    tagging. Kept as a shim — steps are re-expressed as IR outputs at plan
    construction; prefer building expressions and ``repro.morph.to_plan``."""

    op: str
    se: tuple[int, int]
    save_as: str | None = None
    astype: str | None = None  # dtype name cast applied to the saved output

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown plan op {self.op!r}; expected one of {_OPS}")
        object.__setattr__(self, "se", (check_window(self.se[0]), check_window(self.se[1])))


@dataclasses.dataclass(frozen=True)
class Plan:
    name: str
    steps: tuple[Step, ...] = ()
    # Ordered (name, expr) outputs over Var("x"); derived from ``steps`` when
    # not given, so legacy construction and expression construction produce
    # the same kind of plan (and equal plans hash equal for the cache).
    outputs: tuple[tuple[str, MorphExpr], ...] = ()

    def __post_init__(self):
        if not self.outputs:
            if not self.steps:
                raise ValueError("a Plan needs steps or expression outputs")
            object.__setattr__(self, "outputs", steps_to_outputs(self.steps))

    def halo(self) -> tuple[int, int]:
        """Per-axis halo a tile needs so its interior is exact after the
        whole chain — derived by graph traversal (sequential primitives sum
        their wings, parallel branches take the max, bounded iteration
        multiplies), not by a per-op multiplier table."""
        gh = gw = 0
        for _, e in self.outputs:
            h, w = expr_halo(e)
            gh, gw = max(gh, h), max(gw, w)
        return gh, gw

    def output_names(self) -> tuple[str, ...]:
        return tuple(n for n, _ in self.outputs)


def single_op_plan(op: str, se: tuple[int, int]) -> Plan:
    """The Plan a bare erode/dilate/opening/closing/gradient request becomes."""
    return Plan(op, (Step(op, (int(se[0]), int(se[1]))),))


def document_cleanup_plan() -> Plan:
    """data/images.py::cleanup_batch as a Plan: opening -> closing (saved as
    ``clean``) -> gradient cast to u8 (saved as ``edges``)."""
    (op0, se0), (op1, se1), (op2, se2) = CLEANUP_STEPS
    return Plan(
        "document_cleanup",
        (
            Step(op0, se0),
            Step(op1, se1, save_as="clean"),
            Step(op2, se2, save_as="edges", astype="uint8"),
        ),
    )


PLANS: dict[str, Plan] = {"document_cleanup": document_cleanup_plan()}


class UnknownPlan(ServeError, KeyError):
    """Typed lookup failure from :func:`get_plan`; subclasses KeyError so
    pre-resilience callers that caught the registry miss keep working."""

    retryable = False

    def __str__(self):  # KeyError.__str__ repr()s the message; keep it plain
        return self.args[0] if self.args else ""


def get_plan(plan: "str | Plan") -> Plan:
    if isinstance(plan, Plan):
        return plan
    try:
        return PLANS[plan]
    except KeyError:
        raise UnknownPlan(
            f"unknown plan {plan!r}; registered: {sorted(PLANS)}"
        ) from None


def register_plan(plan: Plan) -> Plan:
    PLANS[plan.name] = plan
    return plan


def mask_outside(x: jax.Array, rect: jax.Array, neutral) -> jax.Array:
    """Overwrite everything outside each image's [y0,y1)x[x0,x1) with
    ``neutral`` — the trace-time-shaped, data-dependent analog of the
    kernels' virtual border padding."""
    _, h, w = x.shape
    rows = jnp.arange(h, dtype=jnp.int32)[None, :, None]
    cols = jnp.arange(w, dtype=jnp.int32)[None, None, :]
    y0, y1, x0, x1 = (rect[:, i][:, None, None] for i in range(4))
    valid = (rows >= y0) & (rows < y1) & (cols >= x0) & (cols < x1)
    return jnp.where(valid, x, jnp.asarray(neutral, x.dtype))


def build_executor(
    plan: Plan,
    *,
    backend: Backend = "jnp",
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
    with_aux: bool = False,
):
    """Jitted ``(x (B,H,W), rect (B,4)) -> {name: (B,H,W) array}`` executor.

    ``backend="kernel"`` routes primitives through the fused Pallas
    megakernel (PR 1); ``"jnp"`` through the pure-XLA separable passes —
    bit-exact by the kernels' oracle contract, so the choice is purely a
    deployment decision (service.py picks per backend/interpret mode).

    The plan's output expressions are evaluated with a masking hook: each
    primitive's input has everything outside the valid rect overwritten with
    that op's neutral element, the graph-derived generalization of the old
    per-step masking loop (and of its special-cased dual-neutral gradient).

    ``with_aux=True`` returns ``(outs, aux)`` instead, where ``aux`` carries
    convergence telemetry summed over the plan's ``BoundedIter`` nodes
    (``iters_used`` actually executed vs the static ``iters_budget``) — the
    service reads it to expose convergence depth in ``stats()``. Plans with
    no bounded iteration report both as 0.
    """
    backend = check_backend(backend)
    policy = policy or DispatchPolicy.calibrated()
    interpret = resolve_interpret(interpret, policy)

    def prim(mop, x, se):
        if backend == "kernel":
            fn = erode2d_tpu if mop.name == "min" else dilate2d_tpu
            return fn(x, se, policy=policy, interpret=interpret)
        fn = core_erode if mop.name == "min" else core_dilate
        return fn(x, se, policy=policy)

    def run(x, rect):
        def pre(v, mop):
            return mask_outside(v, rect, mop.neutral(v.dtype))

        reports: list = []

        def report(used, budget):
            reports.append((used, budget))

        memo: dict = {}
        outs = {
            name: evaluate(
                e, {"x": x}, prim=prim, pre_prim=pre, memo=memo,
                iter_report=report if with_aux else None,
            )
            for name, e in plan.outputs
        }
        if with_aux:
            aux = {
                "iters_used": sum(
                    (u for u, _ in reports), jnp.int32(0)
                ),
                "iters_budget": jnp.int32(sum(b for _, b in reports)),
            }
            return outs, aux
        return outs

    return jax.jit(run)


__all__ = [
    "Backend",
    "VALID_BACKENDS",
    "check_backend",
    "Step",
    "Plan",
    "single_op_plan",
    "document_cleanup_plan",
    "PLANS",
    "UnknownPlan",
    "get_plan",
    "register_plan",
    "mask_outside",
    "build_executor",
    "to_plan",
]
