"""Morphology plans: named multi-op chains compiled as one executable.

A :class:`Plan` is the serving-side unit of work — a tuple of
:class:`Step`s (``erode``/``dilate``/``opening``/``closing``/``gradient``,
each with its own SE), with optional named outputs. The raw pipeline
``data/images.py::cleanup_batch`` is ported here as the ``document_cleanup``
plan (built from the same ``CLEANUP_STEPS`` constant), so the service and
the direct path are verifiably the same computation.

**Valid-rect masking.** Executors take ``(x, rect)`` where ``x`` is a
``(B, H, W)`` bucket (or halo-extended tile) stack and ``rect`` a ``(B, 4)``
``[y0, y1, x0, x1)`` per-image valid rectangle. Before *every* primitive
pass, everything outside the rect is overwritten with that op's neutral
element (max for erosion, min for dilation — ``core.types.MorphOp.neutral``).
That makes the pad region indistinguishable from the kernels' own virtual
neutral border at every stage of a composed plan, which is what buys:

* bucket padding that is bit-exact after cropping, with an arbitrary fill
  value (a single fill could never serve both min and max stages);
* halo-correct tiling (tiling.py), where edge tiles mask the out-of-image
  part of their halo the same way.

The ``gradient`` step needs *both* neutrals on the same input, so it is
executed as dilate(mask_min(x)) - erode(mask_max(x)) with the same integer
widening as ``core.morphology.gradient`` / ``gradient2d_tpu``.

Executors are plain jitted functions; the per-key cache with hit/miss
counters lives in service.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import erode as core_erode
from repro.core import dilate as core_dilate
from repro.core.dispatch import DispatchPolicy, resolve_interpret
from repro.core.types import MAX, MIN, check_window
from repro.data.images import CLEANUP_STEPS
from repro.kernels import dilate2d_tpu, erode2d_tpu

_OPS = ("erode", "dilate", "opening", "closing", "gradient")
Backend = str  # "jnp" (pure-XLA separable passes) | "kernel" (fused Pallas)


@dataclasses.dataclass(frozen=True)
class Step:
    """One plan stage: a morphology op, its SE, and optional output tagging."""

    op: str
    se: tuple[int, int]
    save_as: str | None = None
    astype: str | None = None  # dtype name cast applied to the saved output

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"unknown plan op {self.op!r}; expected one of {_OPS}")
        object.__setattr__(self, "se", (check_window(self.se[0]), check_window(self.se[1])))

    def wings(self) -> tuple[int, int]:
        return ((self.se[0] - 1) // 2, (self.se[1] - 1) // 2)


@dataclasses.dataclass(frozen=True)
class Plan:
    name: str
    steps: tuple[Step, ...]

    def halo(self) -> tuple[int, int]:
        """Per-axis halo a tile needs so its interior is exact after the whole
        chain: contamination marches in one SE wing per sequential pass, so
        wings sum over expanded primitives — opening/closing count twice,
        gradient once (its min and max branches run in parallel)."""
        gh = gw = 0
        for s in self.steps:
            wh, ww = s.wings()
            mult = 2 if s.op in ("opening", "closing") else 1
            gh += mult * wh
            gw += mult * ww
        return gh, gw

    def output_names(self) -> tuple[str, ...]:
        names = tuple(s.save_as for s in self.steps if s.save_as)
        return names if names else ("out",)


def single_op_plan(op: str, se: tuple[int, int]) -> Plan:
    """The Plan a bare erode/dilate/opening/closing/gradient request becomes."""
    return Plan(op, (Step(op, (int(se[0]), int(se[1]))),))


def document_cleanup_plan() -> Plan:
    """data/images.py::cleanup_batch as a Plan: opening -> closing (saved as
    ``clean``) -> gradient cast to u8 (saved as ``edges``)."""
    (op0, se0), (op1, se1), (op2, se2) = CLEANUP_STEPS
    return Plan(
        "document_cleanup",
        (
            Step(op0, se0),
            Step(op1, se1, save_as="clean"),
            Step(op2, se2, save_as="edges", astype="uint8"),
        ),
    )


PLANS: dict[str, Plan] = {"document_cleanup": document_cleanup_plan()}


def get_plan(plan: "str | Plan") -> Plan:
    if isinstance(plan, Plan):
        return plan
    try:
        return PLANS[plan]
    except KeyError:
        raise KeyError(f"unknown plan {plan!r}; registered: {sorted(PLANS)}") from None


def register_plan(plan: Plan) -> Plan:
    PLANS[plan.name] = plan
    return plan


def _expand(step: Step) -> tuple[tuple[str, tuple[int, int]], ...]:
    """Composite -> primitive (min/max, se) sequence. ``gradient`` stays
    special-cased in the executor (parallel branches, widened difference)."""
    if step.op == "erode":
        return (("min", step.se),)
    if step.op == "dilate":
        return (("max", step.se),)
    if step.op == "opening":
        return (("min", step.se), ("max", step.se))
    if step.op == "closing":
        return (("max", step.se), ("min", step.se))
    raise ValueError(f"_expand does not handle {step.op!r}")


def mask_outside(x: jnp.ndarray, rect: jnp.ndarray, neutral) -> jnp.ndarray:
    """Overwrite everything outside each image's [y0,y1)x[x0,x1) with
    ``neutral`` — the trace-time-shaped, data-dependent analog of the
    kernels' virtual border padding."""
    _, h, w = x.shape
    rows = jnp.arange(h, dtype=jnp.int32)[None, :, None]
    cols = jnp.arange(w, dtype=jnp.int32)[None, None, :]
    y0, y1, x0, x1 = (rect[:, i][:, None, None] for i in range(4))
    valid = (rows >= y0) & (rows < y1) & (cols >= x0) & (cols < x1)
    return jnp.where(valid, x, jnp.asarray(neutral, x.dtype))


def build_executor(
    plan: Plan,
    *,
    backend: Backend = "jnp",
    policy: DispatchPolicy | None = None,
    interpret: bool | None = None,
):
    """Jitted ``(x (B,H,W), rect (B,4)) -> {name: (B,H,W) array}`` executor.

    ``backend="kernel"`` routes primitives through the fused Pallas
    megakernel (PR 1); ``"jnp"`` through the pure-XLA separable passes —
    bit-exact by the kernels' oracle contract, so the choice is purely a
    deployment decision (service.py picks per backend/interpret mode).
    """
    policy = policy or DispatchPolicy.calibrated()
    interpret = resolve_interpret(interpret, policy)
    if backend not in ("jnp", "kernel"):
        raise ValueError(f"backend must be 'jnp'|'kernel', got {backend!r}")

    def prim(x, opname, se):
        if backend == "kernel":
            fn = erode2d_tpu if opname == "min" else dilate2d_tpu
            return fn(x, se, policy=policy, interpret=interpret)
        fn = core_erode if opname == "min" else core_dilate
        return fn(x, se, policy=policy)

    def run(x, rect):
        outs = {}
        for step in plan.steps:
            if step.op == "gradient":
                d = prim(mask_outside(x, rect, MAX.neutral(x.dtype)), "max", step.se)
                e = prim(mask_outside(x, rect, MIN.neutral(x.dtype)), "min", step.se)
                if jnp.issubdtype(x.dtype, jnp.integer):
                    y = d.astype(jnp.int32) - e.astype(jnp.int32)
                else:
                    y = d - e
            else:
                y = x
                for opname, se in _expand(step):
                    op = MIN if opname == "min" else MAX
                    y = prim(mask_outside(y, rect, op.neutral(y.dtype)), opname, se)
            if step.save_as:
                outs[step.save_as] = y.astype(step.astype) if step.astype else y
            x = y
        if not outs:
            outs["out"] = x
        return outs

    return jax.jit(run)
