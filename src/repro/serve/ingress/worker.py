"""Worker host: a morphology service behind a socket, speaking proto.py.

``WorkerHost`` wraps any service-like object — a :class:`MorphService`, a
:class:`ShardedMorphService`, or the ingress :class:`Frontier` itself
(which is how the frontier exposes its own client port: the ingress stack
is ``client -> WorkerHost(Frontier) -> Connection -> WorkerHost(service)``,
one protocol everywhere) — behind a stdlib TCP listener. No framework, no
new dependencies: one accept thread, one reader thread per connection,
responses written by whichever thread resolves the future, serialized per
connection by a write lock so frames never interleave.

Remote requests are *real* requests: ``tenant``, ``priority``,
``deadline_ms``, ``tag``, and the frontier-minted ``trace`` ID all thread
from the wire into ``service.submit_plan``, so quotas, brownout, hedging,
deadline scheduling, and tracing apply to ingress traffic exactly as they
do in-process, and every typed rejection rides back as the same exception
type via ``proto.encode_error``.

Shutdown is **drain-then-reject** (ISSUE 10 satellite): ``close()``

1. flips the host to *closing* — submits that arrive from here on are
   answered with a typed :class:`ServiceClosed` frame (never a dropped
   connection, which a client could not tell from a crash);
2. waits until every already-accepted submit has written its response
   (the service stays open, so in-flight work completes normally);
3. closes the service (idempotent batcher drain), then the sockets.

So every outstanding client future resolves exactly once: accepted work
with its result, late work with ``ServiceClosed``, and only a genuinely
killed worker ever surfaces :class:`ConnectionLost`. ``kill()`` is that
crash, for chaos tests: sockets drop with no drain and no typed goodbye.

The module is also the subprocess entry point::

    python -m repro.serve.ingress.worker --config '{"max_batch": 16}'

which prints ``INGRESS_WORKER_READY <host> <port>`` once serving;
:func:`spawn_worker` wraps the Popen + handshake for benchmarks/tests.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import threading
import time

from repro.serve.ingress import proto
from repro.serve.morph.resilience import FaultPlan, ServiceClosed
from repro.serve.morph.service import MorphService, ServiceConfig
from repro.serve.morph.tenancy import PRIORITY_NORMAL, TenantQuota

READY_SENTINEL = "INGRESS_WORKER_READY"


def _open_spans(service) -> int:
    """Open-span count across a service-like object's tracers (0 when obs
    is off) — the number the acceptance gate asserts is zero post-drain."""
    if hasattr(service, "open_spans"):
        return service.open_spans()
    total = 0
    obs = getattr(service, "_obs", None)
    if obs is not None and getattr(obs, "tracer", None) is not None:
        total += obs.tracer.open_count()
    for s in getattr(service, "shards", ()):
        o = getattr(s, "_obs", None)
        if o is not None and getattr(o, "tracer", None) is not None:
            total += o.tracer.open_count()
    return total


class WorkerHost:
    """Serve one service-like object over the ingress protocol.

    ``service`` may be passed ready-made (the frontier does this; tests
    wrap pre-configured services); otherwise one ``MorphService(config)``
    is constructed and owned. ``worker_id`` labels health/stats responses
    so a frontier can tell its workers apart in merged views.
    """

    def __init__(self, service=None, *, config: ServiceConfig | None = None,
                 host: str = "127.0.0.1", port: int = 0,
                 worker_id: int | None = None):
        self.service = service if service is not None else MorphService(
            config or ServiceConfig()
        )
        self.worker_id = worker_id
        self._lock = threading.Lock()
        self._drained = threading.Condition(self._lock)
        self._closing = False
        self._closed = threading.Event()
        self._outstanding = 0  # accepted submits whose response isn't written
        self.requests = 0
        self._conns: set[socket.socket] = set()
        self._listener = socket.create_server((host, port))
        self.address: tuple[str, int] = self._listener.getsockname()[:2]
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="ingress-accept", daemon=True
        )
        self._accept_thread.start()

    # ------------------------------------------------------------ connections
    def _accept_loop(self) -> None:
        while True:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return  # listener closed
            with self._lock:
                if self._closing:
                    conn.close()
                    continue
                self._conns.add(conn)
            threading.Thread(
                target=self._serve_conn, args=(conn,),
                name="ingress-conn", daemon=True,
            ).start()

    def _serve_conn(self, conn: socket.socket) -> None:
        conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        rfile = conn.makefile("rb")
        wlock = threading.Lock()

        def send(header: dict, payload: bytes = b"") -> None:
            buf = proto.encode_frame(header, payload)
            try:
                with wlock:
                    conn.sendall(buf)
            except OSError:
                pass  # client went away; its futures died with it

        try:
            while True:
                try:
                    frame = proto.read_frame(rfile)
                except proto.ProtocolError as exc:
                    # the bad frame was consumed; answer typed and keep going
                    send(proto.error_message(None, exc)[0])
                    continue
                except (proto.ConnectionLost, OSError, ValueError):
                    return
                if frame is None:
                    return  # clean EOF
                self._dispatch(frame[0], frame[1], send)
        finally:
            rfile.close()
            conn.close()
            with self._lock:
                self._conns.discard(conn)

    # -------------------------------------------------------------- messages
    def _dispatch(self, header: dict, payload: bytes, send) -> None:
        mtype = header.get("type")
        rid = header.get("id")
        if mtype == "submit":
            self._handle_submit(header, payload, send)
        elif mtype == "stats":
            send({
                "type": "stats_result", "id": rid,
                "worker": self.worker_id,
                "metrics": self.service.metrics_snapshot(),
                "stats": self.service.stats(),
            })
        elif mtype == "health":
            with self._lock:
                closing, requests = self._closing, self.requests
            send({
                "type": "health_result", "id": rid,
                "worker": self.worker_id,
                "t": header.get("t"),
                "t_local": time.perf_counter(),
                "closing": closing,
                "requests": requests,
            })
        elif mtype == "trace":
            doc = (
                self.service.export_trace()
                if hasattr(self.service, "export_trace") else None
            )
            send({
                "type": "trace_result", "id": rid,
                "worker": self.worker_id,
                "trace": doc,
                "open_spans": _open_spans(self.service),
                "clock": time.perf_counter(),
            })
        elif mtype == "shutdown":
            # ack first (the requester's RPC must resolve), then drain in
            # the background — drain waits on responses, including this one
            send({"type": "shutdown_result", "id": rid})
            threading.Thread(
                target=self.close, name="ingress-shutdown", daemon=True
            ).start()
        else:
            send(proto.error_message(
                rid, proto.ProtocolError(f"unknown message type {mtype!r}")
            )[0])

    def _handle_submit(self, header: dict, payload: bytes, send) -> None:
        rid = header.get("id")
        with self._lock:
            if self._closing:
                # drain-then-reject: late submits get the same typed error
                # a local caller gets after close(), not a dead socket
                send(proto.error_message(rid, ServiceClosed(
                    "worker host is draining for shutdown"
                ))[0])
                return
            self._outstanding += 1
            self.requests += 1

        def finish_with(header2: dict, payload2: bytes = b"") -> None:
            # the response is written BEFORE the outstanding count drops:
            # close() waiting on zero therefore waits for the bytes, which
            # is what "every client future resolves" means on the wire
            try:
                send(header2, payload2)
            finally:
                with self._lock:
                    self._outstanding -= 1
                    self._drained.notify_all()

        try:
            plan = proto.plan_from_wire(header.get("plan") or {})
            img = proto.decode_tensor(header.get("tensor") or {}, payload)
            fut = self.service.submit_plan(
                img, plan,
                deadline_ms=header.get("deadline_ms"),
                tag=header.get("tag"),
                tenant=header.get("tenant"),
                priority=header.get("priority", PRIORITY_NORMAL),
                _trace=header.get("trace"),
            )
        except BaseException as exc:  # noqa: BLE001 — typed over the wire
            finish_with(proto.error_message(rid, exc)[0])
            return

        def done(f) -> None:
            exc = f.exception()
            if exc is None:
                finish_with(*proto.result_message(rid, f.result()))
            else:
                finish_with(proto.error_message(rid, exc)[0])

        fut.add_done_callback(done)

    # ------------------------------------------------------------- lifecycle
    def _close_listener(self) -> None:
        # shutdown() before close(): on Linux, close() alone does not wake
        # a thread blocked in accept() — the stuck syscall keeps the socket
        # description (and the LISTEN port) alive after the fd is gone.
        # shutdown() fails accept() with EINVAL, so the thread exits and
        # the port is actually released.
        try:
            self._listener.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._listener.close()
        except OSError:
            pass

    def close(self, timeout: float = 60.0) -> None:
        """Drain-then-reject shutdown; idempotent (later calls wait for the
        first to finish)."""
        with self._lock:
            first = not self._closing
            self._closing = True
        if not first:
            self._closed.wait(timeout)
            return
        # 1) no new connections
        self._close_listener()
        # 2) drain: every accepted submit writes its response (the service
        #    is still open, so in-flight work completes normally)
        deadline = time.monotonic() + timeout
        with self._lock:
            while self._outstanding > 0:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._drained.wait(remaining)
        # 3) the service itself (drains its batcher; idempotent)
        self.service.close()
        # 4) sockets — clients have all their responses by now
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()
        self._closed.set()

    def kill(self) -> None:
        """Abrupt death for chaos tests: drop every socket with no drain
        and no typed goodbye — in-flight remote callers see
        :class:`ConnectionLost`, exactly like a SIGKILL'd process."""
        with self._lock:
            self._closing = True
            conns = list(self._conns)
        self._close_listener()
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()
        self.service.close()
        self._closed.set()

    def wait_closed(self, timeout: float | None = None) -> bool:
        return self._closed.wait(timeout)

    def __enter__(self) -> "WorkerHost":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ------------------------------------------------------------- configuration
def config_from_json(d: dict) -> ServiceConfig:
    """A ServiceConfig from a JSON-safe dict (the subprocess handshake).
    Only wire-expressible knobs are mapped; unknown keys are ignored, the
    same additive-evolution rule the protocol itself follows."""
    kw: dict = {}
    if "buckets" in d:
        kw["buckets"] = tuple((int(h), int(w)) for h, w in d["buckets"])
    for k in ("max_batch", "cache_size", "shard"):
        if d.get(k) is not None:
            kw[k] = int(d[k])
    for k in ("window_ms", "default_deadline_ms"):
        if d.get(k) is not None:
            kw[k] = float(d[k])
    if "max_queue" in d:
        kw["max_queue"] = None if d["max_queue"] is None else int(d["max_queue"])
    for k in ("backend",):
        if d.get(k) is not None:
            kw[k] = d[k]
    for k in ("rle_gate", "adaptive_window"):
        if d.get(k) is not None:
            kw[k] = bool(d[k])
    if d.get("interpret") is not None:
        kw["interpret"] = bool(d["interpret"])
    if d.get("tenants"):
        kw["tenants"] = {
            name: TenantQuota(
                max_outstanding=q.get("max_outstanding"),
                weight=float(q.get("weight", 1.0)),
            )
            for name, q in d["tenants"].items()
        }
    if d.get("brownout") is False:
        kw["brownout"] = None
    if d.get("faults"):
        kw["faults"] = FaultPlan(**d["faults"])
    if d.get("obs"):
        from repro.obs import ObsConfig
        kw["obs"] = ObsConfig()
    return ServiceConfig(**kw)


def spawn_worker(config: dict | None = None, *, worker_id: int = 0,
                 host: str = "127.0.0.1", env: dict | None = None,
                 timeout: float = 120.0):
    """Launch a worker subprocess and wait for its READY handshake.
    Returns ``(Popen, (host, port))``. The child inherits this process's
    environment (plus ``PYTHONPATH`` pointing at this repro checkout, so
    callers don't have to re-derive it)."""
    src_root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    child_env = dict(os.environ)
    if env:
        child_env.update(env)
    pp = child_env.get("PYTHONPATH", "")
    if src_root not in pp.split(os.pathsep):
        child_env["PYTHONPATH"] = (
            f"{src_root}{os.pathsep}{pp}" if pp else src_root
        )
    cfg = dict(config or {})
    cfg.setdefault("shard", worker_id)  # labels the worker's trace lane
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serve.ingress.worker",
         "--host", host, "--config", json.dumps(cfg),
         "--worker-id", str(worker_id)],
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
        text=True, env=child_env,
    )
    deadline = time.monotonic() + timeout
    while True:
        line = proc.stdout.readline()
        if not line:
            raise RuntimeError(
                f"ingress worker {worker_id} exited before READY "
                f"(returncode {proc.poll()})"
            )
        if line.startswith(READY_SENTINEL):
            _, h, p = line.split()
            return proc, (h, int(p))
        if time.monotonic() > deadline:
            proc.kill()
            raise RuntimeError(f"ingress worker {worker_id} READY timeout")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="morphology ingress worker")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0)
    ap.add_argument("--config", default="{}",
                    help="JSON ServiceConfig subset (see config_from_json)")
    ap.add_argument("--worker-id", type=int, default=None)
    ap.add_argument("--sharded", action="store_true",
                    help="wrap a ShardedMorphService over all local devices")
    args = ap.parse_args(argv)
    cfg = config_from_json(json.loads(args.config))
    if args.sharded:
        from repro.shard.router import ShardedMorphService
        service = ShardedMorphService(cfg)
    else:
        service = MorphService(cfg)
    host = WorkerHost(
        service, host=args.host, port=args.port, worker_id=args.worker_id
    )
    print(f"{READY_SENTINEL} {host.address[0]} {host.address[1]}", flush=True)
    try:
        while not host.wait_closed(1.0):
            pass
    except KeyboardInterrupt:
        host.close()
    return 0


if __name__ == "__main__":
    sys.exit(main())
