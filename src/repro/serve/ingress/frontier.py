"""Frontier: the front-tier router over a fleet of worker processes.

The in-process ``ShardedMorphService`` routes (plan, bucket, dtype) groups
across per-device shards; the frontier applies the *same* discipline one
level up, across worker **processes**:

* **affinity** — a group token hashes (crc32) to one worker, so
  micro-batches keep coalescing across process boundaries: every request
  for a given (plan, bucket, dtype) lands on the same worker's batcher,
  exactly as it would land on the same shard in-process. The frontier
  buckets with its own ladder, which must match the workers' (the default
  on both sides) for the affinity to align with worker-side batching.
* **health** — the per-worker breaker/slow-mark state machine is the
  extracted :class:`HealthTracker` (serve/morph/health.py), the identical
  code the shard router runs. Worker-level errors (``InjectedFault``,
  ``ExecutorError``, a worker-side ``ShardUnavailable``) count toward the
  breaker; a lost TCP connection is ``mark_dead`` — immediately open,
  because a vanished process is definitive in a way one failed request is
  not. Recovery is the standard half-open probe: after
  ``probe_interval_s`` one request is let through, and the link
  reconnects lazily, so a restarted worker on the same address rejoins.
* **reroute** — on worker death every in-flight request the dead
  connection was carrying fails over: ``Connection`` resolves them all
  with ``ConnectionLost``, the frontier's done-callbacks re-``_attempt``
  on the survivors (same hash over the healthy subset — deterministic),
  and the caller's future resolves with the rerouted result. Zero lost
  futures is a structural property, not a retry loop.
* **stats/traces** — ``stats()`` merges worker ``metrics_snapshot()``s
  with the registry merge semantics (ingress/stats.py) into one
  fleet-wide view; ``export_trace()`` stitches worker Chrome traces onto
  the frontier timeline using per-link clock offsets, so one trace ID
  minted here is followable from the frontier hop span into the owning
  worker's queue/dispatch/executor spans.

``serve()`` wraps the frontier in a :class:`WorkerHost` — the frontier
speaks the same protocol it consumes, so clients connect to one address
and the whole stack is recursively composed.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.obs import MetricsRegistry, Observability, new_trace_id
from repro.serve.ingress import proto
from repro.serve.ingress.client import Connection
from repro.serve.ingress.stats import (
    fleet_stats,
    merge_process_traces,
    merge_worker_metrics,
)
from repro.serve.ingress.worker import WorkerHost
from repro.serve.morph.buckets import DEFAULT_BUCKETS, choose_bucket
from repro.serve.morph.health import HealthTracker
from repro.serve.morph.plans import Plan, single_op_plan
from repro.serve.morph.resilience import (
    DeadlineExceeded,
    ExecutorError,
    FailoverPolicy,
    InjectedFault,
    ServiceClosed,
    ShardUnavailable,
)
from repro.serve.morph.tenancy import PRIORITY_NORMAL

# Failures that indict the *worker* (move its breaker / reroute the
# request). ConnectionLost is the process-death signal and ServiceClosed
# is the worker announcing its own drain — both are definitive (mark_dead),
# unlike a single failed request; a worker-side ShardUnavailable means
# that worker's whole internal router gave up, so for this group the
# worker is as good as down. Everything else is about the request and
# propagates typed without penalizing the worker. Note the asymmetry with
# the in-process router, which treats ServiceClosed as final: one process
# closing IS the end of its shards, but a fleet outlives any one worker's
# shutdown, so the frontier moves the traffic instead of spreading the
# goodbye to callers.
WORKER_LEVEL_ERRORS = (
    proto.ConnectionLost, ServiceClosed, InjectedFault, ExecutorError,
    ShardUnavailable,
)


class WorkerLink:
    """Frontier-side handle on one worker address: a lazily (re)connected
    :class:`Connection` plus the measured clock offset."""

    def __init__(self, index: int, address: tuple[str, int]):
        self.index = index
        self.address = (address[0], int(address[1]))
        self._lock = threading.Lock()
        self.conn: Connection | None = None

    def ensure(self) -> Connection:
        """The live connection, reconnecting if the previous one died —
        which is how a half-open probe of a restarted worker succeeds.
        Raises :class:`ConnectionLost` when the worker is unreachable."""
        with self._lock:
            if self.conn is not None and not self.conn.closed:
                return self.conn
            try:
                self.conn = Connection(self.address)
                self.conn.ping()  # liveness + clock offset in one round trip
            except OSError as exc:
                self.conn = None
                raise proto.ConnectionLost(
                    f"worker {self.index} at {self.address} unreachable: {exc}"
                ) from None
            return self.conn

    @property
    def clock_offset_s(self) -> float | None:
        c = self.conn
        return c.clock_offset_s if c is not None else None

    def close(self) -> None:
        with self._lock:
            if self.conn is not None:
                self.conn.close()
                self.conn = None


class _RequestCtx:
    __slots__ = ("tried",)

    def __init__(self):
        self.tried: set[int] = set()


class Frontier:
    """Route ingress traffic across worker processes. Service-shaped: the
    submit/run/stats/close surface matches ``MorphService``, which is what
    lets ``WorkerHost`` serve a frontier without knowing it is one."""

    def __init__(self, workers, *, buckets=DEFAULT_BUCKETS,
                 failover: FailoverPolicy = FailoverPolicy(),
                 default_deadline_ms: float | None = None,
                 obs=None, connect: bool = True):
        if not workers:
            raise ValueError("Frontier needs at least one worker address")
        self.links = [WorkerLink(i, a) for i, a in enumerate(workers)]
        self.buckets = buckets
        self.failover = failover
        self.default_deadline_ms = default_deadline_ms
        self.tracker = HealthTracker(len(self.links), failover, noun="worker")
        self.metrics = MetricsRegistry()
        self._obs = (
            Observability(obs, self.metrics, pid="frontier", name="frontier")
            if obs is not None and obs.enabled
            else None
        )
        self._lock = threading.Lock()
        self._idle = threading.Condition(self._lock)
        self._inflight = 0
        self._requests_ok = 0
        self._closed = False
        if connect:
            for link in self.links:
                try:
                    link.ensure()
                except proto.ConnectionLost:
                    self.tracker.mark_dead(link.index)

    # ------------------------------------------------------------- routing
    @staticmethod
    def _token(plan_name: str, bucket, dtype_str: str) -> bytes:
        return f"{plan_name}|{bucket}|{dtype_str}".encode()

    # ---------------------------------------------------------- submission
    def submit(self, img, op: str = "erode", se=(3, 3), **kw) -> Future:
        return self.submit_plan(img, single_op_plan(op, se), **kw)

    def submit_plan(self, img, plan, *, deadline_ms: float | None = None,
                    tag: str | None = None, tenant: str | None = None,
                    priority: int = PRIORITY_NORMAL,
                    _trace: int | None = None) -> Future:
        with self._lock:
            if self._closed:
                raise ServiceClosed("frontier is closed")
            self._inflight += 1
        try:
            spec = proto.plan_to_wire(plan)
            plan_name = (
                plan.name if isinstance(plan, Plan) else str(spec.get("name"))
            )
            img = np.asarray(img)
            if img.ndim != 2:
                raise ValueError(
                    "the service takes single (H, W) images; submit each "
                    "image of a batch separately"
                )
            bucket = choose_bucket(img.shape[0], img.shape[1], self.buckets)
            token = self._token(plan_name, bucket, img.dtype.str)
            if deadline_ms is None:
                deadline_ms = self.default_deadline_ms
            deadline_at = (
                time.monotonic() + deadline_ms / 1e3
                if deadline_ms is not None else None
            )
            if _trace is not None:
                trace = _trace
            else:
                # minted HERE: the ID every hop span, worker queue span,
                # and executor span carries — across process boundaries
                trace = new_trace_id() if self._obs is not None else None
            outer: Future = Future()
            outer.add_done_callback(self._request_done)
            self._attempt(outer, img, spec, plan_name, token, deadline_at,
                          tag, tenant, priority, trace, frozenset(),
                          _RequestCtx())
            return outer
        except BaseException:
            with self._lock:
                self._inflight -= 1
                self._idle.notify_all()
            raise

    def _request_done(self, fut: Future) -> None:
        with self._lock:
            self._inflight -= 1
            if fut.exception() is None:
                self._requests_ok += 1
            self._idle.notify_all()

    def _resolve(self, outer: Future, *, exc=None, result=None) -> None:
        # attempts are strictly sequential (no hedging at this tier yet),
        # so the future resolves exactly once by construction
        if exc is not None:
            outer.set_exception(exc)
        else:
            outer.set_result(result)

    def _attempt(self, outer: Future, img, spec: dict, plan_name: str,
                 token: bytes, deadline_at: float | None, tag, tenant,
                 priority: int, trace, excluded: frozenset,
                 ctx: _RequestCtx) -> None:
        deadline_ms = None
        if deadline_at is not None:
            deadline_ms = (deadline_at - time.monotonic()) * 1e3
            if deadline_ms <= 0:
                self._resolve(outer, exc=DeadlineExceeded(
                    "deadline expired during worker failover", plan=plan_name
                ))
                return
        try:
            idx, was_probe = self.tracker.pick(token, excluded)
        except ShardUnavailable as exc:
            if self._obs is not None:
                self._obs.instant(
                    "unroutable", trace=trace, plan=plan_name,
                    excluded=sorted(excluded),
                )
            self._resolve(outer, exc=exc)
            return
        ctx.tried.add(idx)
        tracer = self._obs.tracer if self._obs is not None else None
        hop = (
            tracer.begin("hop", trace=trace, worker=idx, probe=was_probe,
                         plan=plan_name, attempt=len(excluded))
            if tracer is not None else None
        )
        t0 = time.monotonic()

        def worker_failed(exc: BaseException) -> None:
            if isinstance(exc, (proto.ConnectionLost, ServiceClosed)):
                # a dead process — or one announcing its drain — is
                # definitive; don't wait for a failure threshold
                self.tracker.mark_dead(idx)
            else:
                self.tracker.record_failure(idx, was_probe)
            nxt = excluded | {idx}
            if self._obs is not None:
                self._obs.instant(
                    "failover", trace=trace, worker=idx,
                    error=type(exc).__name__,
                    exhausted=len(nxt) >= len(self.links),
                )
            if len(nxt) < len(self.links):
                self._attempt(outer, img, spec, plan_name, token,
                              deadline_at, tag, tenant, priority, trace,
                              nxt, ctx)
            else:
                self._resolve(outer, exc=exc)

        try:
            fut = self.links[idx].ensure().submit_plan(
                img, spec, deadline_ms=deadline_ms, tag=tag, tenant=tenant,
                priority=priority, trace=trace,
            )
        except proto.ConnectionLost as exc:
            if hop is not None:
                tracer.end(hop, error=type(exc).__name__)
            worker_failed(exc)
            return

        def done(f) -> None:
            exc = f.exception()
            if hop is not None:
                tracer.end(hop, error=type(exc).__name__ if exc else None)
            if exc is None:
                self.tracker.record_success(idx, was_probe)
                self.tracker.observe_latency(
                    idx, (time.monotonic() - t0) * 1e3
                )
                self._resolve(outer, result=f.result())
            elif isinstance(exc, WORKER_LEVEL_ERRORS):
                worker_failed(exc)
            else:  # request-level: typed, final, worker not indicted
                self._resolve(outer, exc=exc)

        fut.add_done_callback(done)

    # -------------------------------------------------------- conveniences
    def run(self, img, op: str = "erode", se=(3, 3), **kw):
        return self.submit(img, op, se, **kw).result()

    def run_plan(self, img, plan, **kw):
        return self.submit_plan(img, plan, **kw).result()

    def run_batch(self, imgs, plan, **kw) -> list:
        futures = [self.submit_plan(im, plan, **kw) for im in imgs]
        return [f.result() for f in futures]

    # ------------------------------------------------------------- metrics
    def _worker_rpcs(self, mtype: str) -> list[dict | None]:
        """One control-plane RPC per worker; dead workers contribute None
        (the fleet view must not require every process alive)."""
        out: list[dict | None] = []
        for link in self.links:
            try:
                out.append(link.ensure().rpc(mtype))
            except (proto.ConnectionLost, proto.ServeError, OSError,
                    TimeoutError):
                out.append(None)
        return out

    def metrics_snapshot(self) -> dict:
        snaps = [
            (r.get("metrics") or {}) for r in self._worker_rpcs("stats") if r
        ]
        snaps.append(self.metrics.snapshot())
        return merge_worker_metrics(snaps)

    def stats(self) -> dict:
        replies = self._worker_rpcs("stats")
        merged = merge_worker_metrics(
            [(r.get("metrics") or {}) for r in replies if r]
        )
        with self._lock:
            requests_ok = self._requests_ok
        return fleet_stats(
            merged,
            health=self.tracker.snapshot(),
            counters={
                "requests": requests_ok,
                "reroutes": self.tracker.reroutes,
                "failovers": self.tracker.trips,
            },
            per_worker=[r.get("stats") if r else None for r in replies],
        )

    def export_trace(self) -> dict | None:
        """The fleet-wide Chrome trace: frontier events + every reachable
        worker's, clock-shifted onto this process's timebase; None when
        tracing is off at the frontier."""
        if self._obs is None or self._obs.tracer is None:
            return None
        worker_traces = []
        for link, reply in zip(self.links, self._worker_rpcs("trace")):
            if reply is not None:
                worker_traces.append(
                    (reply.get("trace"), link.clock_offset_s)
                )
        return merge_process_traces(
            self._obs.tracer.chrome_events(), worker_traces
        )

    def open_spans(self) -> int:
        """Frontier + reachable-worker open span count (the post-drain
        zero the bench asserts)."""
        total = (
            self._obs.tracer.open_count()
            if self._obs is not None and self._obs.tracer is not None else 0
        )
        for reply in self._worker_rpcs("trace"):
            if reply is not None:
                total += int(reply.get("open_spans") or 0)
        return total

    # ------------------------------------------------------------ lifecycle
    def serve(self, host: str = "127.0.0.1", port: int = 0) -> WorkerHost:
        """Expose this frontier over the ingress protocol (clients dial
        one address; the stack composes recursively)."""
        return WorkerHost(self, host=host, port=port)

    def flush(self, timeout: float | None = None) -> bool:
        deadline = (
            time.monotonic() + timeout if timeout is not None else None
        )
        with self._lock:
            while self._inflight > 0:
                remaining = (
                    deadline - time.monotonic()
                    if deadline is not None else None
                )
                if remaining is not None and remaining <= 0:
                    return False
                self._idle.wait(remaining)
            return True

    def close(self, *, close_workers: bool = False,
              timeout: float = 30.0) -> None:
        """Stop routing (in-flight requests drain first). The frontier
        does not own worker lifecycles by default; ``close_workers`` asks
        each reachable worker host to drain-then-close too."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self.flush(timeout)
        if close_workers:
            for link in self.links:
                try:
                    link.ensure().rpc("shutdown", timeout=timeout)
                except (proto.ConnectionLost, proto.ServeError, OSError,
                        TimeoutError):
                    pass
        for link in self.links:
            link.close()

    def __enter__(self) -> "Frontier":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["Frontier", "WorkerLink", "WORKER_LEVEL_ERRORS"]
