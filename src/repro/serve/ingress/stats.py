"""Cross-process stats: fleet metrics merge + multi-process Chrome traces.

Workers serve their ``metrics_snapshot()`` over the wire as plain JSON —
which is exactly what a registry snapshot already is, so the existing
by-type merge semantics (``repro.obs.merge_snapshots``: counters sum,
gauges by mode, histograms add bucket counts) apply to decoded frames
unchanged. ``fleet_stats`` builds the frontier's one fleet-wide ``stats()``
view from those merged snapshots, mirroring the sharded router's schema
(health list, merged quantiles, per-node detail riding along) so tooling
written against one tier reads the other.

Traces are the one thing that does *not* merge as-is: every process
timestamps spans with its own ``time.perf_counter()``, and two processes'
perf_counter bases are unrelated. The frontier therefore measures a clock
offset per worker on its control-plane ping (NTP-style midpoint estimate,
see ``Connection.ping``) and :func:`merge_process_traces` shifts each
worker's event timestamps by it before merging — so a frontier-minted
trace ID's spans line up on one timeline: ``hop`` on the frontier lane,
queue/dispatch/executor spans on the worker lanes, microseconds apart the
way they really were. Negative shifted timestamps clamp to zero (the
Chrome trace format rejects negative ``ts``; sub-microsecond offset error
near the epoch is noise, not signal).
"""
from __future__ import annotations

from repro.obs import MetricsRegistry, cache_stats, quantile_from_snapshot


def merge_worker_metrics(snapshots: list[dict]) -> dict:
    """Fleet-wide registry view: the same ``merge_snapshots`` the sharded
    router uses, applied to wire-decoded worker snapshots."""
    return MetricsRegistry.merge([s for s in snapshots if s])


def fleet_stats(merged: dict, *, health: list[dict], counters: dict,
                per_worker: list[dict]) -> dict:
    """The frontier's ``stats()`` dict from a merged fleet snapshot —
    schema-aligned with ``ShardedMorphService.stats()`` (workers for
    shards) so dashboards and benchmarks read both tiers identically."""

    def value(name: str):
        m = merged.get(name)
        return m["value"] if m is not None else 0

    lat = merged.get("latency_ms")
    out = {
        "workers": len(health),
        "healthy_workers": sum(h["state"] == "closed" for h in health),
        "slow_workers": sum(h["state"] == "slow" for h in health),
        "health": health,
        "batches": value("batches"),
        "tiled_requests": value("tiled_requests"),
        "rle_requests": value("rle_requests"),
        "p50_ms": quantile_from_snapshot(lat, 0.50) if lat else 0.0,
        "p99_ms": quantile_from_snapshot(lat, 0.99) if lat else 0.0,
        "cache": cache_stats(
            value("cache.size"), value("cache.hits"),
            value("cache.misses"), value("cache.evictions"),
        ),
        "resilience": {
            k: value(f"batcher.{k}")
            for k in ("rejected_overloaded", "rejected_quota",
                      "shed_brownout", "deadline_expired", "retries",
                      "bisections", "request_failures")
        },
        "per_worker": per_worker,
    }
    # per-tenant counters merge by name across workers; rebuild the map
    tenants: dict[str, dict] = {}
    for name, m in merged.items():
        if not name.startswith("tenant."):
            continue
        t, event = name[len("tenant."):].rsplit(".", 1)
        if t != "_":
            tenants.setdefault(t, {})[event] = m["value"]
    out["resilience"]["tenants"] = tenants
    out.update(counters)
    return out


def shift_events(events: list[dict], offset_s: float) -> list[dict]:
    """Worker trace events re-based onto the frontier clock: ``ts`` (and
    nothing else) moves by ``-offset_s`` where ``offset_s`` is the
    worker-minus-frontier clock offset. Metadata events (``ph: "M"``,
    ``ts`` 0) stay put — they label lanes, not moments."""
    shifted = []
    for ev in events:
        if ev.get("ph") == "M":
            shifted.append(ev)
            continue
        ev = dict(ev)
        ev["ts"] = max(0.0, round(ev.get("ts", 0.0) - offset_s * 1e6, 3))
        shifted.append(ev)
    return shifted


def merge_process_traces(
    local_events: list[dict],
    worker_traces: list[tuple[dict | None, float | None]],
) -> dict:
    """One Chrome-trace document spanning processes: the frontier's own
    events plus each worker's, shifted by that worker's measured clock
    offset (workers whose offset was never measured shift by 0 — better a
    skewed lane than a dropped one)."""
    events = list(local_events)
    for doc, offset_s in worker_traces:
        if not doc:
            continue
        events.extend(shift_events(doc.get("traceEvents", []), offset_s or 0.0))
    events.sort(key=lambda e: e["ts"])
    return {"traceEvents": events, "displayTimeUnit": "ms"}


__all__ = ["merge_worker_metrics", "fleet_stats", "shift_events",
           "merge_process_traces"]
