"""Ingress client: futures over the wire, typed errors reconstructed.

:class:`Connection` is the low-level unit — one socket, one reader thread,
a request-id -> Future map. Submits return immediately with a
``concurrent.futures.Future`` that the reader thread resolves when the
matching ``result``/``error`` frame arrives, so the remote API is
shape-identical to the local one: ``submit_plan(img, plan) -> Future``
resolving to an array or ``{name: array}`` dict, and every failure is the
*same* typed exception a local caller would catch (``QuotaExceeded`` with
its ``.tenant``, ``DeadlineExceeded``, ``ServiceClosed``, …) rebuilt by
``proto.decode_error``. A dead transport fails every outstanding future
exactly once with :class:`ConnectionLost` — no future ever hangs on a
vanished worker.

:class:`IngressClient` pools ``Connection``s round-robin (one socket
serializes frame writes; several keep a multi-MB image upload from
head-of-line-blocking everyone else) and adds the synchronous conveniences
(``run``, ``run_plan``, ``run_batch``, ``stats``) mirroring the service
API. The frontier's per-worker links are plain ``Connection``s too — one
transport implementation for every hop of the ingress stack.
"""
from __future__ import annotations

import itertools
import socket
import threading
import time
from concurrent.futures import Future

import numpy as np

from repro.serve.ingress import proto
from repro.serve.morph.plans import single_op_plan
from repro.serve.morph.tenancy import PRIORITY_NORMAL


class Connection:
    """One protocol connection. Thread-safe: submits may come from any
    thread; the dedicated reader thread resolves futures."""

    def __init__(self, address: tuple[str, int], *,
                 connect_timeout: float = 10.0):
        self.address = (address[0], int(address[1]))
        self.sock = socket.create_connection(
            self.address, timeout=connect_timeout
        )
        self.sock.settimeout(None)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._rfile = self.sock.makefile("rb")
        self._wlock = threading.Lock()
        self._lock = threading.Lock()
        self._pending: dict[int, Future] = {}
        self._ids = itertools.count(1)
        self.closed = False
        # worker perf_counter minus the midpoint of our send/recv clocks,
        # measured by ping(); the frontier uses it to shift worker trace
        # timestamps onto its own timebase
        self.clock_offset_s: float | None = None
        self._reader = threading.Thread(
            target=self._read_loop, name="ingress-reader", daemon=True
        )
        self._reader.start()

    # --------------------------------------------------------------- reading
    def _read_loop(self) -> None:
        while True:
            try:
                frame = proto.read_frame(self._rfile)
            except Exception as exc:  # noqa: BLE001 — transport is dead
                self._fail_all(self._as_lost(exc))
                return
            if frame is None:
                self._fail_all(proto.ConnectionLost(
                    f"connection to {self.address} closed by peer"
                ))
                return
            header, payload = frame
            rid = header.get("id")
            with self._lock:
                fut = self._pending.pop(rid, None)
            if fut is None:
                continue  # response for a request nobody waits on anymore
            mtype = header.get("type")
            if mtype == "error":
                fut.set_exception(proto.decode_error(header.get("error") or {}))
            elif mtype == "result":
                fut.set_result(
                    proto.decode_result(header.get("result") or {}, payload)
                )
            else:
                fut.set_result(header)  # raw RPC (stats/health/trace/…)

    @staticmethod
    def _as_lost(exc: BaseException) -> proto.ConnectionLost:
        if isinstance(exc, proto.ConnectionLost):
            return exc
        lost = proto.ConnectionLost(f"transport error: {exc}")
        lost.__cause__ = exc
        return lost

    def _fail_all(self, exc: BaseException) -> None:
        with self._lock:
            self.closed = True
            pending, self._pending = self._pending, {}
        for fut in pending.values():
            # each future was popped exactly once; set_exception is safe
            fut.set_exception(exc)
        try:
            self.sock.close()
        except OSError:
            pass

    # --------------------------------------------------------------- writing
    def _register(self) -> tuple[int, Future]:
        with self._lock:
            if self.closed:
                raise proto.ConnectionLost(
                    f"connection to {self.address} is closed"
                )
            rid = next(self._ids)
            fut: Future = Future()
            self._pending[rid] = fut
        return rid, fut

    def _send(self, rid: int, header: dict, payload: bytes = b"") -> None:
        buf = proto.encode_frame(header, payload)
        try:
            with self._wlock:
                self.sock.sendall(buf)
        except OSError as exc:
            with self._lock:
                self._pending.pop(rid, None)
            raise self._as_lost(exc) from None

    # ------------------------------------------------------------------- API
    def submit_plan(self, img, plan, *, deadline_ms: float | None = None,
                    tag: str | None = None, tenant: str | None = None,
                    priority: int = PRIORITY_NORMAL,
                    trace: int | None = None) -> Future:
        """Submit one image; the Future resolves with the decoded result
        or raises the reconstructed typed error."""
        spec = plan if isinstance(plan, dict) else proto.plan_to_wire(plan)
        rid, fut = self._register()
        header, payload = proto.submit_message(
            rid, spec, np.asarray(img), deadline_ms=deadline_ms, tag=tag,
            tenant=tenant, priority=priority, trace=trace,
        )
        self._send(rid, header, payload)
        return fut

    def rpc(self, mtype: str, *, timeout: float = 30.0, **fields) -> dict:
        """Synchronous control-plane round trip (stats/health/trace/…)."""
        rid, fut = self._register()
        self._send(rid, {"type": mtype, "id": rid, **fields})
        return fut.result(timeout)

    def ping(self, *, timeout: float = 30.0) -> dict:
        """Health round trip; as a side effect measures the peer clock
        offset (NTP-style: the peer's clock is read at the midpoint of our
        send/receive timestamps, the unbiased estimate for a symmetric
        link — and loopback is as symmetric as links get)."""
        t0 = time.perf_counter()
        h = self.rpc("health", timeout=timeout, t=t0)
        t1 = time.perf_counter()
        if h.get("t_local") is not None:
            self.clock_offset_s = h["t_local"] - (t0 + t1) / 2.0
        return h

    def close(self) -> None:
        self._fail_all(proto.ConnectionLost(
            f"connection to {self.address} closed locally"
        ))

    def __enter__(self) -> "Connection":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class IngressClient:
    """Application-facing handle on an ingress endpoint (a worker host or
    a frontier — same protocol either way)."""

    def __init__(self, address: tuple[str, int], *, pool: int = 2,
                 connect_timeout: float = 10.0):
        if pool < 1:
            raise ValueError("pool must be >= 1")
        self._conns = [
            Connection(address, connect_timeout=connect_timeout)
            for _ in range(pool)
        ]
        self._rr = itertools.count()

    def _conn(self) -> Connection:
        n = len(self._conns)
        start = next(self._rr)
        for i in range(n):
            c = self._conns[(start + i) % n]
            if not c.closed:
                return c
        raise proto.ConnectionLost("every pooled connection is closed")

    # ------------------------------------------------------------ data plane
    def submit(self, img, op: str = "erode", se=(3, 3), **kw) -> Future:
        return self.submit_plan(img, single_op_plan(op, se), **kw)

    def submit_plan(self, img, plan, **kw) -> Future:
        return self._conn().submit_plan(img, plan, **kw)

    def run(self, img, op: str = "erode", se=(3, 3), **kw):
        return self.submit(img, op, se, **kw).result()

    def run_plan(self, img, plan, **kw):
        return self.submit_plan(img, plan, **kw).result()

    def run_batch(self, imgs, plan, **kw) -> list:
        futures = [self.submit_plan(im, plan, **kw) for im in imgs]
        return [f.result() for f in futures]

    # --------------------------------------------------------- control plane
    def stats(self, *, timeout: float = 30.0) -> dict:
        return self._conn().rpc("stats", timeout=timeout).get("stats") or {}

    def metrics_snapshot(self, *, timeout: float = 30.0) -> dict:
        return self._conn().rpc("stats", timeout=timeout).get("metrics") or {}

    def health(self, *, timeout: float = 30.0) -> dict:
        return self._conn().ping(timeout=timeout)

    def export_trace(self, *, timeout: float = 30.0) -> dict | None:
        return self._conn().rpc("trace", timeout=timeout).get("trace")

    def shutdown_server(self, *, timeout: float = 30.0) -> None:
        """Ask the remote host to drain and close (its drain-then-reject
        shutdown; this client's outstanding futures resolve first)."""
        self._conn().rpc("shutdown", timeout=timeout)

    def close(self) -> None:
        for c in self._conns:
            c.close()

    def __enter__(self) -> "IngressClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


__all__ = ["Connection", "IngressClient"]
