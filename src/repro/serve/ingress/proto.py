"""Ingress wire protocol: versioned length-prefixed JSON + raw-tensor frames.

One frame is::

    !4s B I I         magic b"MRF1" | version | header_len | payload_len
    header_len bytes  UTF-8 JSON header (the message)
    payload_len bytes raw little-endian tensor bytes (may be empty)

The JSON header carries everything structured (message type, request id,
plan spec, tensor dtype/shape, tenancy fields, error payloads); the binary
payload carries only tensor data, so a 4 Mpx uint8 image costs 4 MB on the
wire, not 4 MB of base64. The version byte sits *outside* the JSON: a
reader can always finish framing a message it refuses to parse, reply with
a typed :class:`ProtocolError`, and keep the connection — which is what
the version-skew tests pin down. Skew rules:

* unknown **fields** in a known-version header are ignored (decoders read
  with ``.get``), so additive protocol evolution is free;
* an unknown **version** is rejected with a typed :class:`ProtocolError`
  after the frame is consumed — never by dropping the connection.

Message types (the frozen-schema tests snapshot these key sets):

* ``submit``    — plan spec + tensor meta (+ payload), ``deadline_ms``,
  ``tag``, ``tenant``, ``priority``, ``trace``;
* ``result``    — named output tensors, concatenated in the payload;
* ``error``     — a :func:`encode_error` dict; :func:`decode_error`
  reconstructs the *same* typed exception client-side;
* ``stats`` / ``stats_result`` — a worker's ``metrics_snapshot()`` (the
  cross-process merge unit) plus its ``stats()`` view;
* ``health`` / ``health_result`` — liveness + the clock handshake
  (``t_local`` is the worker's ``perf_counter``) the frontier uses to
  shift worker trace timestamps onto its own timebase;
* ``trace`` / ``trace_result`` — a worker's Chrome-trace export + open
  span count;
* ``shutdown`` / ``shutdown_result`` — ask a worker host to drain and
  close (the remote handle on its drain-then-reject shutdown).

Error transport is lossless by construction: :func:`decode_error` rebuilds
the exception via ``cls.__new__`` + attribute restore instead of calling
``__init__``, so the message (which already embeds the ``[plan=…, …]``
context suffix composed at raise time) is not re-composed, and
``type(exc)``, ``str(exc)``, ``retryable``, the five context fields, and
the subtype extras (``tenant``, ``level``, ``priority``, ``tag``) all
round-trip bit-for-bit. Unknown error type names degrade to the base
:class:`ServeError` with ``retryable`` carried as data — old clients stay
correct against newer servers.
"""
from __future__ import annotations

import json
import struct

import numpy as np

from repro.serve.morph.plans import Plan, Step, UnknownPlan, get_plan
from repro.serve.morph.resilience import (
    BrownoutShed,
    DeadlineExceeded,
    ExecutorError,
    InjectedFault,
    Overloaded,
    PoisonedRequest,
    QuotaExceeded,
    ServeError,
    ServiceClosed,
    ShardUnavailable,
)

PROTOCOL_VERSION = 1
MAGIC = b"MRF1"

_FRAME = struct.Struct("!4sBII")
# sanity bounds: a corrupt length prefix must fail loudly, not allocate
MAX_HEADER = 16 << 20
MAX_PAYLOAD = 1 << 30


# --------------------------------------------------------------------- errors
class ProtocolError(ServeError):
    """The peer sent something this protocol version cannot parse: bad
    magic, an unknown version byte, or a structurally invalid message.
    Not retryable — resending the same bytes cannot help."""

    retryable = False


class ConnectionLost(ServeError):
    """The transport died with requests outstanding. Retryable: the
    morphology plans are pure functions of their input, so re-running a
    request whose first attempt may or may not have executed is sound."""

    retryable = True


# ------------------------------------------------------------------- framing
def encode_frame(header: dict, payload: bytes = b"") -> bytes:
    """One wire frame as bytes (write with a single ``sendall`` so frames
    from concurrent responders never interleave)."""
    hdr = json.dumps(header, default=_json_default).encode()
    return b"".join(
        (_FRAME.pack(MAGIC, PROTOCOL_VERSION, len(hdr), len(payload)),
         hdr, payload)
    )


def read_frame(rfile) -> tuple[dict, bytes] | None:
    """Read one frame from a buffered binary file-like. Returns ``(header,
    payload)``; ``None`` on clean EOF at a frame boundary. Raises
    :class:`ProtocolError` for bad magic/version/JSON (the offending frame
    is consumed first, so the connection survives and can carry the typed
    error back) and :class:`ConnectionLost` for EOF mid-frame."""
    prefix = rfile.read(_FRAME.size)
    if not prefix:
        return None  # clean EOF between frames
    if len(prefix) < _FRAME.size:
        raise ConnectionLost("EOF inside a frame prefix")
    magic, version, hlen, plen = _FRAME.unpack(prefix)
    if magic != MAGIC:
        # nothing after a framing desync can be trusted; no recovery
        raise ProtocolError(f"bad frame magic {magic!r}")
    if hlen > MAX_HEADER or plen > MAX_PAYLOAD:
        raise ProtocolError(f"frame lengths out of range ({hlen}, {plen})")
    body = rfile.read(hlen + plen)
    if len(body) < hlen + plen:
        raise ConnectionLost("EOF inside a frame body")
    if version != PROTOCOL_VERSION:
        raise ProtocolError(
            f"unsupported protocol version {version} "
            f"(this side speaks {PROTOCOL_VERSION})"
        )
    try:
        header = json.loads(body[:hlen])
    except ValueError as exc:
        raise ProtocolError(f"unparseable frame header: {exc}") from None
    if not isinstance(header, dict):
        raise ProtocolError("frame header must be a JSON object")
    return header, body[hlen:]


def _json_default(v):
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, np.ndarray):
        return v.tolist()
    if isinstance(v, (set, frozenset)):
        return sorted(v)
    return str(v)


# ------------------------------------------------------------------- tensors
def encode_tensor(arr: np.ndarray) -> tuple[dict, bytes]:
    """``(meta, bytes)`` for one array. ``dtype.str`` carries the byte
    order, so bool (``|b1``) and every multi-byte dtype reconstruct
    exactly."""
    arr = np.ascontiguousarray(arr)
    return {"dtype": arr.dtype.str, "shape": list(arr.shape)}, arr.tobytes()


def decode_tensor(meta: dict, buf) -> np.ndarray:
    dt = np.dtype(meta["dtype"])
    shape = tuple(meta["shape"])
    n = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
    if len(buf) < n:
        raise ProtocolError(
            f"tensor payload short: {len(buf)} bytes for {shape} {dt}"
        )
    return np.frombuffer(buf[:n], dtype=dt).reshape(shape)


def encode_result(result) -> tuple[dict, bytes]:
    """A service result — a bare array (single-output plans) or a
    ``{name: array}`` dict — as ``(meta, payload)``. The meta records which
    shape it was so the client-side API mirrors the local one exactly."""
    if isinstance(result, dict):
        items = [(str(k), np.asarray(v)) for k, v in result.items()]
        kind = "dict"
    else:
        items = [("out", np.asarray(result))]
        kind = "array"
    outputs, chunks = [], []
    for name, arr in items:
        meta, raw = encode_tensor(arr)
        meta["name"] = name
        outputs.append(meta)
        chunks.append(raw)
    return {"kind": kind, "outputs": outputs}, b"".join(chunks)


def decode_result(meta: dict, payload: bytes):
    out, off = {}, 0
    for m in meta.get("outputs", ()):
        dt = np.dtype(m["dtype"])
        n = int(np.prod(tuple(m["shape"]), dtype=np.int64)) * dt.itemsize
        out[m["name"]] = decode_tensor(m, payload[off:off + n])
        off += n
    if meta.get("kind") == "array":
        return next(iter(out.values()))
    return out


# -------------------------------------------------------------------- errors
# Every typed exception a service can raise, by wire name. decode_error
# falls back to ServeError for names minted by a newer peer.
WIRE_ERRORS: dict[str, type] = {
    cls.__name__: cls
    for cls in (
        ServeError, Overloaded, QuotaExceeded, BrownoutShed,
        DeadlineExceeded, ServiceClosed, ExecutorError, PoisonedRequest,
        InjectedFault, ShardUnavailable, UnknownPlan,
        ProtocolError, ConnectionLost,
    )
}

_CONTEXT_FIELDS = ("plan", "bucket", "dtype", "batch", "shard")
_EXTRA_FIELDS = ("tenant", "level", "priority", "tag")


def encode_error(exc: BaseException) -> dict:
    """Any exception as a wire dict. Typed :class:`ServeError` subclasses
    keep their exact identity; anything else (a stray ValueError inside a
    handler) degrades to the base type with the original class named in
    the message — remote callers always get *a* typed error."""
    if isinstance(exc, ServeError):
        name = type(exc).__name__
        if name not in WIRE_ERRORS:
            name = "ServeError"
        message = exc.args[0] if exc.args else str(exc)
    else:
        name = "ServeError"
        message = f"{type(exc).__name__}: {exc}"
    d: dict = {
        "name": name,
        "message": message,
        "retryable": bool(getattr(exc, "retryable", False)),
        "context": {
            k: v for k in _CONTEXT_FIELDS
            if (v := getattr(exc, k, None)) is not None
        },
    }
    extra = {
        k: v for k in _EXTRA_FIELDS
        if (v := getattr(exc, k, None)) is not None
    }
    if extra:
        d["extra"] = extra
    return d


def decode_error(d: dict) -> ServeError:
    """The typed exception back from its wire dict. Reconstruction skips
    ``__init__`` (which would re-compose the ``[ctx]`` message suffix) and
    restores attributes directly, so ``str``, type, and every field match
    the original exactly."""
    cls = WIRE_ERRORS.get(d.get("name"), ServeError)
    exc = cls.__new__(cls)
    Exception.__init__(exc, d.get("message", ""))
    ctx = d.get("context") or {}
    for k in _CONTEXT_FIELDS:
        v = ctx.get(k)
        if k == "bucket" and isinstance(v, list):
            v = tuple(v)
        setattr(exc, k, v)
    for k in _EXTRA_FIELDS:
        if k in (d.get("extra") or {}):
            setattr(exc, k, d["extra"][k])
    if cls is ServeError and "retryable" in d:
        # unknown subtype from a newer peer: honor its retryability as data
        exc.retryable = bool(d["retryable"])
    return exc


# --------------------------------------------------------------------- plans
def plan_to_wire(plan) -> dict:
    """A plan reference as a wire spec: registered plans go by name (the
    worker resolves against its own registry — a miss comes back as a
    typed :class:`UnknownPlan`), step-built plans ship their steps.
    Expression-built plans have no wire form — register them on the worker
    and submit by name."""
    if isinstance(plan, str):
        return {"name": plan}
    plan = get_plan(plan)
    if plan.steps:
        return {
            "name": plan.name,
            "steps": [
                {"op": s.op, "se": [s.se[0], s.se[1]],
                 "save_as": s.save_as, "astype": s.astype}
                for s in plan.steps
            ],
        }
    return {"name": plan.name}


def plan_from_wire(spec: dict):
    """The worker-side resolution of a wire spec: explicit steps rebuild a
    :class:`Plan`; a bare name resolves against the worker's registry
    (so ``submit_plan`` raises :class:`UnknownPlan` typed)."""
    steps = spec.get("steps")
    if steps:
        return Plan(
            str(spec.get("name") or "wire_plan"),
            tuple(
                Step(s["op"], tuple(s["se"]),
                     save_as=s.get("save_as"), astype=s.get("astype"))
                for s in steps
            ),
        )
    name = spec.get("name")
    if not name:
        raise ProtocolError("plan spec needs 'name' or 'steps'")
    return name


# ------------------------------------------------------------------ messages
def submit_message(req_id: int, plan_spec: dict, arr: np.ndarray, *,
                   deadline_ms: float | None = None, tag: str | None = None,
                   tenant: str | None = None, priority: int = 0,
                   trace: int | None = None) -> tuple[dict, bytes]:
    meta, payload = encode_tensor(arr)
    return (
        {
            "type": "submit",
            "id": req_id,
            "plan": plan_spec,
            "tensor": meta,
            "deadline_ms": deadline_ms,
            "tag": tag,
            "tenant": tenant,
            "priority": priority,
            "trace": trace,
        },
        payload,
    )


def result_message(req_id: int, result) -> tuple[dict, bytes]:
    meta, payload = encode_result(result)
    return {"type": "result", "id": req_id, "result": meta}, payload


def error_message(req_id, exc: BaseException) -> tuple[dict, bytes]:
    return {"type": "error", "id": req_id, "error": encode_error(exc)}, b""


__all__ = [
    "PROTOCOL_VERSION",
    "MAGIC",
    "ProtocolError",
    "ConnectionLost",
    "encode_frame",
    "read_frame",
    "encode_tensor",
    "decode_tensor",
    "encode_result",
    "decode_result",
    "WIRE_ERRORS",
    "encode_error",
    "decode_error",
    "plan_to_wire",
    "plan_from_wire",
    "submit_message",
    "result_message",
    "error_message",
]
