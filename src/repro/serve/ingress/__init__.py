"""Front-tier ingress (ISSUE 10): the serving tier as a deployable
multi-process service.

    worker processes:  python -m repro.serve.ingress.worker --config '{…}'
    frontier:          Frontier([(host, port), …]).serve()
    clients:           IngressClient(frontier_address).submit_plan(img, plan)

Four layers, one protocol:

* ``proto``    — versioned length-prefixed JSON + raw-tensor framing;
  the typed ``ServeError`` family round-trips losslessly, so a remote
  ``QuotaExceeded`` is the same exception (type, message, ``.tenant``)
  a local caller catches;
* ``worker``   — a ``MorphService``/``ShardedMorphService`` (or a
  ``Frontier``) behind a stdlib socket server, with drain-then-reject
  shutdown: ``close()`` mid-request surfaces ``ServiceClosed``, never a
  dropped connection;
* ``frontier`` — crc32 (plan, bucket, dtype) affinity routing across
  workers, per-worker breakers/slow marks (the shard router's state
  machine, extracted to serve/morph/health.py), deterministic reroute on
  worker death with zero lost futures;
* ``stats``    — fleet-wide metrics merge (the registry's cross-process
  semantics applied to wire snapshots) and Chrome traces stitched across
  processes via per-worker clock offsets.

``benchmarks/bench_router.py`` drives a multi-tenant QPS/SLO load mix
against a live 2–4 process fleet; ``examples/remote_cleanup.py`` is the
minimal end-to-end fleet walkthrough.
"""
from repro.serve.ingress.client import Connection, IngressClient
from repro.serve.ingress.frontier import (
    WORKER_LEVEL_ERRORS,
    Frontier,
    WorkerLink,
)
from repro.serve.ingress.proto import (
    MAGIC,
    PROTOCOL_VERSION,
    ConnectionLost,
    ProtocolError,
    decode_error,
    decode_result,
    decode_tensor,
    encode_error,
    encode_frame,
    encode_result,
    encode_tensor,
    plan_from_wire,
    plan_to_wire,
    read_frame,
)
from repro.serve.ingress.stats import (
    fleet_stats,
    merge_process_traces,
    merge_worker_metrics,
    shift_events,
)
from repro.serve.ingress.worker import (
    READY_SENTINEL,
    WorkerHost,
    config_from_json,
    spawn_worker,
)

__all__ = [
    "PROTOCOL_VERSION",
    "MAGIC",
    "ProtocolError",
    "ConnectionLost",
    "encode_frame",
    "read_frame",
    "encode_tensor",
    "decode_tensor",
    "encode_result",
    "decode_result",
    "encode_error",
    "decode_error",
    "plan_to_wire",
    "plan_from_wire",
    "Connection",
    "IngressClient",
    "WorkerHost",
    "READY_SENTINEL",
    "config_from_json",
    "spawn_worker",
    "Frontier",
    "WorkerLink",
    "WORKER_LEVEL_ERRORS",
    "merge_worker_metrics",
    "fleet_stats",
    "shift_events",
    "merge_process_traces",
]
