"""Serving substrate: batched prefill + generate over the KV cache."""
from repro.serve.engine import generate, prefill
