"""Serving substrate — two engines and the process boundary:

* ``serve.engine``: batched LM decode (prefill + generate over the KV cache);
* ``serve.morph``: async morphology serving (micro-batching, shape buckets,
  executable cache, halo-correct tiling) over the fused 2-D kernels;
* ``serve.ingress``: the morphology tier as a deployable multi-process
  service — wire protocol, worker hosts, the affinity-routing frontier,
  and cross-process stats/trace merge (imported on demand; it pulls in no
  extra dependencies but has no business loading for in-process users).
"""
from repro.serve.engine import generate, prefill
