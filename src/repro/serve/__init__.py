"""Serving substrate — two engines, one story:

* ``serve.engine``: batched LM decode (prefill + generate over the KV cache);
* ``serve.morph``: async morphology serving (micro-batching, shape buckets,
  executable cache, halo-correct tiling) over the fused 2-D kernels.
"""
from repro.serve.engine import generate, prefill
