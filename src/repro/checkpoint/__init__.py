"""Checkpointing: sharded async save/restore with elastic reshard."""
from repro.checkpoint.manager import CheckpointManager
