"""Sharded, async checkpointing with restart + elastic reshard.

Design (DESIGN.md §5, fault tolerance):

* **Layout** — one .npz per host per step (leaves flattened by pytree
  path), plus a small JSON manifest written *last* (commit marker): a
  checkpoint without a manifest is incomplete and ignored on restore,
  which makes a crash mid-write harmless.
* **Async** — `save()` snapshots leaves to host memory (device_get) on the
  critical path, then a writer thread does the file I/O. `wait()` joins.
* **Elastic restore** — leaves are saved *unsharded per-host slice-free*
  (host gathers only what it owns on real fleets via process-local
  addressable shards; in this single-process environment it owns all).
  Restore takes target shardings and `jax.device_put`s into them, so a
  run can resume on a different mesh shape (elastic re-scale).
* **Retention** — keep the newest `keep` checkpoints, GC the rest.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _unflatten_into(tree, flat: dict[str, np.ndarray]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3, process_index: int = 0):
        self.dir = directory
        self.keep = keep
        self.process_index = process_index
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, *, blocking: bool = False) -> None:
        """Snapshot on the caller thread, write on a background thread."""
        self.wait()  # one outstanding write at a time
        flat = _flatten(state)  # device->host copy happens here

        def _write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"host_{self.process_index}.npz"), **flat)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                # wall-clock time is correct here (and only here): manifest
                # timestamps identify checkpoints across process restarts,
                # which a monotonic/perf counter cannot do. Durations
                # elsewhere use time.perf_counter (repro.obs.now_s).
                json.dump({"step": step, "time": time.time(),
                           "n_leaves": len(flat)}, f)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)  # manifest inside => atomic commit
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if name.startswith("step_") and os.path.exists(
                os.path.join(full, "manifest.json")
            ):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: Any, *, shardings: Any = None) -> Any:
        """Restore into the structure of ``target``; optionally re-shard.

        ``shardings`` (same pytree structure, jax.sharding.Sharding leaves)
        enables elastic resume onto a different mesh: leaves are placed
        with device_put into the new sharding regardless of how the run
        that wrote them was laid out.
        """
        path = os.path.join(self.dir, f"step_{step:08d}",
                            f"host_{self.process_index}.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(target, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, shardings
            )
        return tree
