"""Device meshes over the image plane for sharded morphology.

The LM stack's production meshes (``launch/mesh.py``) partition *parameter*
axes ("data" / "model"); morphology wants the *image plane* partitioned —
strips of rows (1-D, the common case: the lane-axis pass stays local and
only the sublane-axis pass exchanges halos) or a rows x cols grid (2-D, for
images so tall *and* wide that one axis cannot absorb all devices).

Axis names are fixed (:data:`ROWS` / :data:`COLS`) so the halo-exchange and
lowering layers can address collectives without threading names through
every call. Mesh construction is a function, never an import side effect —
jax device state locks at first use, same rule as ``launch/mesh.py``.
"""
from __future__ import annotations

import jax

ROWS = "rows"
COLS = "cols"


def available_shards() -> int:
    """Local device count — the max useful 1-D shard count on this host."""
    return len(jax.devices())


def image_mesh(shards: "int | tuple[int, int] | None" = None):
    """Build a mesh over the image plane.

    ``shards``: an int (or None = all local devices) gives a 1-D
    ``(n,) -> ("rows",)`` mesh; a ``(rows, cols)`` pair gives a 2-D grid.
    A 1-element axis is dropped (a ``(n, 1)`` request builds the 1-D mesh),
    so degenerate configurations don't pay for dead collective axes.
    """
    if shards is None:
        shards = available_shards()
    if isinstance(shards, int):
        shape: tuple[int, ...] = (shards,)
        axes: tuple[str, ...] = (ROWS,)
    else:
        r, c = int(shards[0]), int(shards[1])
        if c == 1:
            shape, axes = (r,), (ROWS,)
        elif r == 1:
            shape, axes = (c,), (COLS,)
        else:
            shape, axes = (r, c), (ROWS, COLS)
    n = 1
    for s in shape:
        if s < 1:
            raise ValueError(f"shard counts must be >= 1, got {shape}")
        n *= s
    if n > available_shards():
        raise ValueError(
            f"image_mesh{shape} needs {n} devices; only "
            f"{available_shards()} available (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N to emulate on CPU)"
        )
    return jax.make_mesh(shape, axes)


def mesh_axis_sizes(mesh) -> tuple[int, int]:
    """``(rows, cols)`` shard counts of an image mesh (1 for absent axes)."""
    names = set(mesh.axis_names)
    extra = names - {ROWS, COLS}
    if extra:
        raise ValueError(
            f"image meshes use axes {ROWS!r}/{COLS!r}; got extra {sorted(extra)}"
        )
    return (
        int(mesh.shape.get(ROWS, 1)),
        int(mesh.shape.get(COLS, 1)),
    )
