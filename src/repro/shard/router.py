"""ShardedMorphService: route shape buckets across per-device shards.

The serving engine (PR 2) runs one ``MorphService`` per host; this router
scales it across a device mesh. Each mesh device gets its own full
``MorphService`` — batcher thread, bucket ladder, executable cache — pinned
to that device (``ServiceConfig.device``), and requests route by a stable
hash of ``(plan, bucket, dtype)``:

* every (plan, bucket) group lands on exactly one shard, so micro-batching
  coalesces exactly as on a single service (scattering a group would
  fragment its batches and multiply compiles);
* distinct groups spread across shards, so a diverse traffic mix keeps all
  devices busy while each device holds only its own groups' executables —
  the aggregate cache is N times the single-service VMEM/HBM budget, which
  is the point of sharding the engine.

Failure handling (ISSUE 6; vocabulary in serve/morph/resilience.py): each
shard carries a consecutive-failure **circuit breaker**
(``ServiceConfig.failover``). Shard-level failures (``InjectedFault``,
``ExecutorError``) trip it after ``failure_threshold`` consecutive hits;
while open, the shard's groups **reroute deterministically** to survivors —
the same crc32 hashed over the healthy subset, so a given (plan, bucket,
dtype) group keeps landing on one survivor and its batching stays coherent
— and the router **rewarms** the survivor's executable cache in the
background so rerouted traffic doesn't pay the compile in-line. After
``probe_interval_s`` one live request is let through as a **half-open
probe**: success closes the breaker (the shard's groups return home),
failure re-opens it. A request that fails on a shard is transparently
resubmitted to the next healthy shard (its caller future resolves with the
rerouted result); request-level failures (deadline, poison, overload)
propagate typed to the caller and never move the breaker. ``stats()``
surfaces per-shard health and the reroute/rewarm/probe counters.

Gray-failure defense (ISSUE 9): a shard that is *slow but alive* never
trips the error-driven breaker, so two further mechanisms cover it.
**Slow-state health** — every successful attempt feeds a per-shard
residence-latency EWMA; a shard whose EWMA exceeds
``failover.slow_factor`` times the peer median (and ``slow_min_ms``)
is marked ``"slow"``: new traffic routes away exactly like a reroute,
but the breaker does not move and the shard is never declared dead — a
trickle probe (one request per ``slow_probe_interval_s``) keeps its EWMA
fresh so recovery (below ``slow_exit_factor`` x median) is observable.
**Hedged dispatch** (``ServiceConfig.hedge``) — after a p99-derived delay
read from the *peer* shards' latency histograms (the shard the request is
riding on is excluded, so a gray shard's own slow completions can't
inflate the trigger that is supposed to rescue requests stuck on it), a
still-unresolved request is resubmitted to the next healthy shard; first
result wins, the caller's
future resolves exactly once (a per-request lock arbitrates the race),
and the router's own ``requests`` count ticks once per caller request no
matter how many shards raced on it. Both are driven by the replayable
chaos harness via ``FaultPlan``'s gray clauses (``latency_after`` /
``latency_every``).

Tiled (oversized) traffic routes the same way; each shard's device-side
tile gather (serve/morph/tiling.py) keeps it off the host. For one giant
image where *latency* matters more than engine throughput, use
``repro.shard.to_sharded`` directly — that is mesh parallelism inside a
single computation, not across the request stream.

``stats()`` merges per-shard engines by metric type (``repro.obs``):
counters sum, gauges apply their declared mode (cache sizes add, the
adaptive window takes the worst shard), histograms add bucket counts so the
merged p50/p99 are true cross-shard quantiles — and the full per-shard list
rides along. With ``ServiceConfig.obs`` set, the router also traces: one
trace ID is minted per request and threaded through every failover hop
(each hop is a span on the router's ``"router"`` lane; shard-side queue/
dispatch/executor/retry spans carry the same ID), and ``export_trace()``
merges the router and all shard tracers onto one Chrome-trace timeline.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import zlib
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import (
    MetricsRegistry,
    Observability,
    cache_stats,
    chrome_trace,
    new_trace_id,
    quantile_from_snapshot,
)
from repro.serve.morph.buckets import choose_bucket
from repro.serve.morph.health import HealthTracker
from repro.serve.morph.plans import Plan, get_plan, single_op_plan
from repro.serve.morph.resilience import (
    DeadlineExceeded,
    ExecutorError,
    InjectedFault,
    ServeError,
    ShardUnavailable,
)
from repro.serve.morph.service import MorphService, ServiceConfig
from repro.serve.morph.tenancy import PRIORITY_NORMAL

# Failures that indict the *shard* (move its breaker); everything else —
# deadline, poison, overload, closed — is about the request or the caller
# and propagates without penalizing the shard that reported it.
SHARD_LEVEL_ERRORS = (InjectedFault, ExecutorError)


class _RequestCtx:
    """Per-caller-request arbitration state: exactly-once resolution of the
    outer future across the primary chain and any hedges, plus the hedge
    timer and the set of shards already racing on this request."""

    __slots__ = ("lock", "resolved", "hedges", "timer", "tried")

    def __init__(self):
        self.lock = threading.Lock()
        self.resolved = False
        self.hedges = 0
        self.timer: threading.Timer | None = None
        self.tried: set[int] = set()


class ShardedMorphService:
    """Mesh-sharded morphology serving. Use as a context manager:

        with ShardedMorphService() as svc:          # one shard per device
            fut = svc.submit(img, op="erode", se=(5, 5))
            outs = svc.run_plan(img2, "document_cleanup")
    """

    def __init__(self, config: ServiceConfig | None = None, *,
                 mesh=None, devices=None):
        if mesh is not None and devices is not None:
            raise ValueError("pass mesh or devices, not both")
        if mesh is not None:
            devices = list(mesh.devices.flat)
        elif devices is None:
            devices = jax.devices()
        if not devices:
            raise ValueError("ShardedMorphService needs at least one device")
        self.config = config or ServiceConfig()
        self.failover = self.config.failover
        self.devices = tuple(devices)
        self.shards = tuple(
            MorphService(dataclasses.replace(
                self.config,
                device=d,
                shard=i,  # labels the shard's trace lane and error context
                # shard-scoped fault clauses apply only to their shard
                faults=(self.config.faults.scoped(i)
                        if self.config.faults is not None else None),
            ))
            for i, d in enumerate(self.devices)
        )
        obs_cfg = self.config.obs
        self._obs = (
            Observability(obs_cfg, MetricsRegistry(), pid="router", name="router")
            if obs_cfg is not None and obs_cfg.enabled
            else None
        )
        # breaker + slow-state machinery shared with the ingress frontier
        # (serve/morph/health.py). The router's own counters share the
        # tracker's lock — the pre-extraction code had exactly one health
        # lock, and keeping that invariant means no new lock-ordering to
        # reason about. Methods below never call a self-locking tracker
        # method while holding _hlock.
        self._tracker = HealthTracker(
            len(self.shards), self.failover, noun="shard"
        )
        self._hlock = self._tracker.lock
        self._health = self._tracker.nodes
        # groups seen (token -> (plan, bucket, dtype)), for failover rewarm
        self._groups: dict[bytes, tuple[Plan, tuple | None, str]] = {}
        self._rewarmed: set[tuple[int, bytes]] = set()
        self.rewarms = 0
        # hedging (ISSUE 9): counters + the cached peer-quantile delays
        self.hedges = 0
        self.hedge_wins = 0
        self._requests_ok = 0  # caller requests resolved with a result —
        # ticks once per request however many shards raced on it, which is
        # what keeps stats()["requests"] single-count under hedging
        # hedge-delay cache, keyed by the excluded (hedge-target) shard:
        # exclude -> (delay_ms, computed_at)
        self._hedge_delay: dict[int | None, tuple[float, float]] = {}
        self._hedge_delay_last_ms = 0.0

    @property
    def reroutes(self) -> int:
        return self._tracker.reroutes

    @property
    def failovers(self) -> int:
        """Breaker trips observed at routing level."""
        return self._tracker.trips

    # ------------------------------------------------------------- routing
    @staticmethod
    def _token(plan: Plan, bucket, dtype_str: str) -> bytes:
        return f"{plan.name}|{bucket}|{dtype_str}".encode()

    def _route(self, plan: Plan, img: np.ndarray) -> MorphService:
        """The shard a request routes to right now (stable while health is
        stable); kept for tests/benchmarks that pin a group's primary."""
        bucket = choose_bucket(img.shape[0], img.shape[1], self.config.buckets)
        idx, _ = self._pick(self._token(plan, bucket, img.dtype.str), frozenset())
        return self.shards[idx]

    def _healthy(self, i: int) -> bool:
        return self._health[i].state == "closed"

    def _pick(self, token: bytes, excluded: frozenset) -> tuple[int, bool]:
        """Deterministic shard choice for a group token — the breaker/
        slow-state machine lives in :class:`HealthTracker` (shared with the
        ingress frontier). Raises :class:`ShardUnavailable` when nothing is
        routable."""
        return self._tracker.pick(token, excluded)

    def _record_success(self, idx: int, was_probe: bool) -> None:
        self._tracker.record_success(idx, was_probe)

    # ------------------------------------------------- slow-state (gray)
    def _observe_latency(self, idx: int, ms: float) -> None:
        """Feed one successful attempt's residence latency (submit to
        resolution, queue wait included — that is what the caller feels)
        into the shard's EWMA; the tracker re-scores every shard against
        the peer median. Errors never reach here: the breaker owns those."""
        self._tracker.observe_latency(idx, ms)

    # --------------------------------------------------------- hedging
    def _hedge_delay_s(self, exclude: int | None = None) -> float:
        """The hedge trigger delay: the configured quantile of the latency
        histograms merged over every shard EXCEPT ``exclude`` — the shard
        the request is currently riding on, i.e. the hedge target. The
        exclusion is the fix for the survivor-bias debt (ROADMAP, PR 9):
        the merged histogram includes the gray shard's own slow
        completions, so the moment one shard degrades, the merged p99
        climbs toward that shard's latency and the hedge that was supposed
        to rescue its requests never fires before they finish the slow
        way. Measured against healthy peers only, the delay stays at the
        fleet's actual service quantile and the gray shard's requests
        hedge out. Clamped to the policy's bounds and cached per excluded
        shard for ``refresh_s`` (the merge walks every peer registry)."""
        policy = self.config.hedge
        now = time.monotonic()
        delay_ms, at = self._hedge_delay.get(exclude, (0.0, 0.0))
        if now - at < policy.refresh_s and at > 0.0:
            return delay_ms / 1e3
        snaps = [
            s.metrics_snapshot()
            for i, s in enumerate(self.shards) if i != exclude
        ]
        lat = (
            MetricsRegistry.merge(snaps).get("latency_ms") if snaps else None
        )
        q = quantile_from_snapshot(lat, policy.quantile) if lat else 0.0
        delay_ms = min(max(q, policy.min_delay_ms), policy.max_delay_ms)
        self._hedge_delay[exclude] = (delay_ms, now)
        self._hedge_delay_last_ms = delay_ms
        return delay_ms / 1e3

    def _resolve(self, ctx: _RequestCtx, outer: Future, *,
                 exc: BaseException | None = None, result=None) -> bool:
        """Resolve the caller's future exactly once across every racing
        attempt; returns True for the attempt that won."""
        with ctx.lock:
            if ctx.resolved:
                return False
            ctx.resolved = True
            timer, ctx.timer = ctx.timer, None
        if timer is not None:
            timer.cancel()
        if exc is not None:
            outer.set_exception(exc)
        else:
            with self._hlock:
                self._requests_ok += 1
            outer.set_result(result)
        return True

    def _hedge(self, ctx: _RequestCtx, outer: Future, img, plan: Plan,
               token: bytes, deadline_at: float | None, tag: str | None,
               tenant: str | None, priority: int, trace: int | None) -> None:
        """Timer body: the primary chain is still unresolved after the
        hedge delay — race a duplicate on the next healthy shard."""
        with ctx.lock:
            if ctx.resolved:
                return
            ctx.hedges += 1
            ctx.timer = None
        with self._hlock:
            self.hedges += 1
        if self._obs is not None:
            self._obs.instant(
                "hedge", trace=trace, plan=plan.name, tried=sorted(ctx.tried)
            )
        self._attempt(outer, img, plan, token, deadline_at, tag,
                      frozenset(ctx.tried), trace, ctx=ctx, hedge=True,
                      tenant=tenant, priority=priority)

    def _record_failure(self, idx: int, was_probe: bool) -> list:
        """Count a shard-level failure; on breaker trip, return the rewarm
        work ((survivor, plan, bucket, dtype) tuples) to run outside the
        lock."""
        tripped = self._tracker.record_failure(idx, was_probe)
        rewarm: list = []
        if tripped and self.failover.rewarm:
            with self._hlock:
                rewarm = self._rewarm_targets(idx)
        return rewarm

    # ------------------------------------------------------------- rewarm
    def _rewarm_targets(self, dead: int) -> list:
        """Under _hlock: every known bucketed group whose primary is the
        dead shard, paired with the survivor it will deterministically
        reroute to."""
        n = len(self.shards)
        survivors = [i for i in range(n) if i != dead and self._healthy(i)]
        out = []
        for token, (plan, bucket, dtype_str) in self._groups.items():
            if bucket is None:  # tiled groups compile per image; skip
                continue
            h = zlib.crc32(token)
            if h % n != dead or not survivors:
                continue
            target = survivors[h % len(survivors)]
            if (target, token) not in self._rewarmed:
                self._rewarmed.add((target, token))
                out.append((target, plan, bucket, dtype_str))
        return out

    def _rewarm_async(self, targets: list) -> None:
        """Compile a rerouted group's executable on its survivor off the
        routing path, so the first rerouted request doesn't pay the compile
        in-line. Batch bucket 1 — the smallest real executable; larger
        batch buckets compile on demand as coalescing resumes."""
        if not targets:
            return

        def warm():
            for idx, plan, bucket, dtype_str in targets:
                try:
                    svc = self.shards[idx]
                    with svc._device_scope():
                        fn = svc._executor_for(plan, bucket, np.dtype(dtype_str), 1)
                        fn(
                            jnp.zeros((1, *bucket), np.dtype(dtype_str)),
                            jnp.zeros((1, 4), np.int32),
                        )
                    with self._hlock:
                        self.rewarms += 1
                except Exception:  # noqa: BLE001 — warm is advisory only
                    pass

        threading.Thread(target=warm, name="shard-rewarm", daemon=True).start()

    # ---------------------------------------------------------- submission
    def submit(self, img, op: str = "erode", se=(3, 3), **kw):
        return self.submit_plan(img, single_op_plan(op, se), **kw)

    def submit_plan(self, img, plan: "str | Plan", *,
                    deadline_ms: float | None = None, tag: str | None = None,
                    tenant: str | None = None,
                    priority: int = PRIORITY_NORMAL,
                    _trace: int | None = None):
        plan = get_plan(plan)
        img = np.asarray(img)
        if img.ndim != 2:
            raise ValueError("the service takes single (H, W) images; submit "
                             "each image of a batch separately")
        bucket = choose_bucket(img.shape[0], img.shape[1], self.config.buckets)
        token = self._token(plan, bucket, img.dtype.str)
        with self._hlock:
            self._groups.setdefault(token, (plan, bucket, img.dtype.str))
        if deadline_ms is None:
            deadline_ms = self.config.default_deadline_ms
        deadline_at = (
            time.monotonic() + deadline_ms / 1e3 if deadline_ms is not None else None
        )
        outer: Future = Future()
        # one trace ID per caller request, minted here so it survives every
        # failover hop and hedge (shards see it via _trace and must not
        # re-mint — which is also what keeps per-request obs single-count).
        # An ingress worker host passes the frontier's ID through `_trace`,
        # so a trace spans processes the same way it spans hops.
        if _trace is not None:
            trace = _trace
        else:
            trace = new_trace_id() if self._obs is not None else None
        ctx = _RequestCtx()
        self._attempt(outer, img, plan, token, deadline_at, tag, frozenset(),
                      trace, ctx=ctx, tenant=tenant, priority=priority)
        return outer

    def _attempt(self, outer: Future, img, plan: Plan, token: bytes,
                 deadline_at: float | None, tag: str | None,
                 excluded: frozenset, trace: int | None = None, *,
                 ctx: _RequestCtx, hedge: bool = False,
                 tenant: str | None = None,
                 priority: int = PRIORITY_NORMAL) -> None:
        """Route one attempt; the done callback reroutes shard-level
        failures to the next survivor until every shard has been tried, so
        the caller's future always resolves — with the rerouted result or a
        typed error. A ``hedge`` attempt is opportunistic: only a result
        may resolve the caller (through ``_resolve``, exactly once); its
        failures still feed shard health but neither recurse nor resolve —
        the primary chain stays authoritative for errors."""
        deadline_ms = None
        if deadline_at is not None:
            deadline_ms = (deadline_at - time.monotonic()) * 1e3
            if deadline_ms <= 0:
                if not hedge:
                    self._resolve(ctx, outer, exc=DeadlineExceeded(
                        "deadline expired during failover", plan=plan.name))
                return
        try:
            idx, was_probe = self._pick(token, excluded)
        except ShardUnavailable as exc:
            if self._obs is not None:
                self._obs.instant(
                    "unroutable", trace=trace, plan=plan.name,
                    excluded=sorted(excluded), error=type(exc).__name__,
                )
            if not hedge:
                self._resolve(ctx, outer, exc=exc)
            return
        ctx.tried.add(idx)
        # the hop span covers shard submit through future resolution — its
        # duration is this attempt's full shard-side residence time
        tracer = self._obs.tracer if self._obs is not None else None
        hop = (
            tracer.begin("hop", trace=trace, shard=idx, probe=was_probe,
                         plan=plan.name, attempt=len(excluded), hedge=hedge)
            if tracer is not None else None
        )
        t0 = time.monotonic()
        try:
            fut = self.shards[idx].submit_plan(
                img, plan, deadline_ms=deadline_ms, tag=tag, _trace=trace,
                tenant=tenant, priority=priority,
            )
        except ServeError as exc:
            if hop is not None:
                tracer.end(hop, error=type(exc).__name__)
            # submit-time rejection (Overloaded, QuotaExceeded, brownout,
            # ServiceClosed): back-pressure or shutdown, not a shard fault —
            # shedding load is the point, don't spread the spill. Resolve
            # the caller's future (this path may run inside a done callback,
            # where a raise would vanish into the futures machinery and hang
            # the caller).
            if was_probe:
                with self._hlock:
                    self._health[idx].probing = False
            if not hedge:
                self._resolve(ctx, outer, exc=exc)
            return

        def done(f, idx=idx, was_probe=was_probe, hop=hop, t0=t0):
            exc = f.exception()
            if hop is not None:
                tracer.end(hop, error=type(exc).__name__ if exc else None)
            if exc is None:
                self._record_success(idx, was_probe)
                self._observe_latency(idx, (time.monotonic() - t0) * 1e3)
                if self._resolve(ctx, outer, result=f.result()) and hedge:
                    with self._hlock:
                        self.hedge_wins += 1
            elif isinstance(exc, SHARD_LEVEL_ERRORS):
                rewarm = self._record_failure(idx, was_probe)
                self._rewarm_async(rewarm)
                nxt = excluded | {idx}
                if self._obs is not None:
                    self._obs.instant(
                        "failover", trace=trace, shard=idx,
                        error=type(exc).__name__, hedge=hedge,
                        exhausted=len(nxt) >= len(self.shards),
                    )
                if hedge:
                    return  # health recorded; the primary chain owns errors
                if len(nxt) < len(self.shards):
                    self._attempt(outer, img, plan, token, deadline_at, tag,
                                  nxt, trace, ctx=ctx, tenant=tenant,
                                  priority=priority)
                else:
                    self._resolve(ctx, outer, exc=exc)
            else:  # request-level failure: typed, final, shard not indicted
                if not hedge:
                    self._resolve(ctx, outer, exc=exc)

        fut.add_done_callback(done)
        # arm (or re-arm, for multi-hedge policies) the hedge timer once a
        # real attempt is in flight and a second shard exists to race on
        policy = self.config.hedge
        if (
            policy.enabled
            and len(self.shards) > 1
            and ctx.hedges < policy.max_hedges
        ):
            with ctx.lock:
                if ctx.resolved or ctx.timer is not None:
                    return
                timer = threading.Timer(
                    # the delay excludes THIS attempt's shard: a hedge is
                    # scored against the peers it would run on, never
                    # against the (possibly gray) shard it rescues from
                    self._hedge_delay_s(exclude=idx), self._hedge,
                    args=(ctx, outer, img, plan, token, deadline_at, tag,
                          tenant, priority, trace),
                )
                timer.daemon = True
                ctx.timer = timer
            timer.start()

    def submit_expr(self, img, expr, name: str | None = None, **kw):
        from repro.morph.plan_compile import to_plan

        policy = self.shards[0].policy
        return self.submit_plan(img, to_plan(expr, name=name, policy=policy), **kw)

    def run(self, img, op: str = "erode", se=(3, 3), **kw):
        return self.submit(img, op, se, **kw).result()

    def run_plan(self, img, plan: "str | Plan", **kw):
        return self.submit_plan(img, plan, **kw).result()

    def run_expr(self, img, expr, name: str | None = None, **kw):
        return self.submit_expr(img, expr, name, **kw).result()

    def run_batch(self, imgs, plan: "str | Plan", **kw) -> list:
        futures = [self.submit_plan(im, plan, **kw) for im in imgs]
        return [f.result() for f in futures]

    # ------------------------------------------------------------- metrics
    def metrics_snapshot(self) -> dict:
        """The by-type merge of every shard's registry snapshot — the raw
        form ``stats()`` derives its aggregates from."""
        return MetricsRegistry.merge(
            [s.metrics_snapshot() for s in self.shards]
        )

    def stats(self) -> dict:
        per = [s.stats() for s in self.shards]
        merged = self.metrics_snapshot()

        def value(name: str):
            # merged counter or gauge scalar (0 before first registration)
            m = merged.get(name)
            return m["value"] if m is not None else 0

        # one merge rule per metric type replaces the old hand-coded
        # key-by-key sums: counters summed, the cache-size gauge summed, the
        # window gauge max'd, latency histograms added bucket-wise — so the
        # merged p50/p99 are real cross-shard quantiles, not the worst
        # shard's local estimate.
        cache = cache_stats(
            value("cache.size"), value("cache.hits"),
            value("cache.misses"), value("cache.evictions"),
        )
        iters_used = value("bounded_iter.iters_used")
        iters_budget = value("bounded_iter.iters_budget")
        bounded = {
            "executions": value("bounded_iter.executions"),
            "iters_used": iters_used,
            "iters_budget": iters_budget,
            "saved_frac": (
                1.0 - iters_used / iters_budget if iters_budget else 0.0
            ),
        }
        resilience = {
            k: value(f"batcher.{k}")
            for k in ("rejected_overloaded", "rejected_quota", "shed_brownout",
                      "deadline_expired", "retries", "bisections",
                      "request_failures")
        }
        # worst shard's active brownout level (the gauge merges with max)
        resilience["brownout_level"] = value("brownout.level")
        # per-tenant counters merge by name across shards; rebuild the map
        tenants: dict[str, dict] = {}
        for name, m in merged.items():
            if not name.startswith("tenant."):
                continue
            t, event = name[len("tenant."):].rsplit(".", 1)
            if t != "_":  # the anonymous tenant stays out of the map
                tenants.setdefault(t, {})[event] = m["value"]
        resilience["tenants"] = tenants
        with self._hlock:
            health = [h.snapshot() for h in self._health]
            resilience.update(
                reroutes=self.reroutes,
                rewarms=self.rewarms,
                failovers=self.failovers,
                hedges=self.hedges,
                hedge_wins=self.hedge_wins,
                hedge_delay_ms=self._hedge_delay_last_ms,
            )
            requests_ok = self._requests_ok
        lat = merged.get("latency_ms")
        dens = merged.get("rle.density")
        return {
            "shards": len(self.shards),
            "healthy_shards": sum(h["state"] == "closed" for h in health),
            "slow_shards": sum(h["state"] == "slow" for h in health),
            "health": health,
            # the router's own resolved-with-a-result count: one tick per
            # caller request however many shards raced on it under hedging
            # (per-shard "requests" counters still count shard-side work)
            "requests": requests_ok,
            "batches": value("batches"),
            "tiled_requests": value("tiled_requests"),
            "rle_requests": value("rle_requests"),
            "repr": {
                "dense": value("repr.dense"),
                "rle": value("repr.rle"),
                "density_p50": (
                    quantile_from_snapshot(dens, 0.50) if dens else 0.0
                ),
            },
            "img_per_s": sum(p["img_per_s"] for p in per),
            "p50_ms": quantile_from_snapshot(lat, 0.50) if lat else 0.0,
            "p99_ms": quantile_from_snapshot(lat, 0.99) if lat else 0.0,
            "cache": cache,
            "bounded_iter": bounded,
            "resilience": resilience,
            "effective_window_ms": merged["window.effective_ms"]["value"],
            "backend": per[0]["backend"],
            "interpret": per[0]["interpret"],
            "obs": self._obs.snapshot() if self._obs is not None else None,
            "per_shard": per,
        }

    def export_trace(self) -> dict | None:
        """Router + all shard tracers merged onto one Chrome-trace timeline
        (every tracer timestamps with the same process clock); None when
        tracing is off."""
        if self._obs is None or self._obs.tracer is None:
            return None
        tracers = [self._obs.tracer] + [
            s._obs.tracer for s in self.shards if s._obs is not None
        ]
        return chrome_trace(tracers)

    # ------------------------------------------------------------ lifecycle
    def flush(self, timeout: float | None = None) -> bool:
        return all(s.flush(timeout) for s in self.shards)

    def close(self) -> None:
        """Idempotent: each shard's close() joins an already-drained
        batcher on repeat calls."""
        for s in self.shards:
            s.close()

    def __enter__(self) -> "ShardedMorphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
