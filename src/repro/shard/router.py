"""ShardedMorphService: route shape buckets across per-device shards.

The serving engine (PR 2) runs one ``MorphService`` per host; this router
scales it across a device mesh. Each mesh device gets its own full
``MorphService`` — batcher thread, bucket ladder, executable cache — pinned
to that device (``ServiceConfig.device``), and requests route by a stable
hash of ``(plan, bucket, dtype)``:

* every (plan, bucket) group lands on exactly one shard, so micro-batching
  coalesces exactly as on a single service (scattering a group would
  fragment its batches and multiply compiles);
* distinct groups spread across shards, so a diverse traffic mix keeps all
  devices busy while each device holds only its own groups' executables —
  the aggregate cache is N times the single-service VMEM/HBM budget, which
  is the point of sharding the engine.

Tiled (oversized) traffic routes the same way; each shard's device-side
tile gather (serve/morph/tiling.py) keeps it off the host. For one giant
image where *latency* matters more than engine throughput, use
``repro.shard.to_sharded`` directly — that is mesh parallelism inside a
single computation, not across the request stream.

``stats()`` merges per-shard engines: counters and cache hits/misses/
evictions sum, throughput adds, latency quantiles and the adaptive window
take the worst shard (max), and the full per-shard list rides along.
"""
from __future__ import annotations

import zlib

import jax
import numpy as np

from repro.serve.morph.buckets import choose_bucket
from repro.serve.morph.plans import Plan, get_plan, single_op_plan
from repro.serve.morph.service import MorphService, ServiceConfig


class ShardedMorphService:
    """Mesh-sharded morphology serving. Use as a context manager:

        with ShardedMorphService() as svc:          # one shard per device
            fut = svc.submit(img, op="erode", se=(5, 5))
            outs = svc.run_plan(img2, "document_cleanup")
    """

    def __init__(self, config: ServiceConfig | None = None, *,
                 mesh=None, devices=None):
        import dataclasses

        if mesh is not None and devices is not None:
            raise ValueError("pass mesh or devices, not both")
        if mesh is not None:
            devices = list(mesh.devices.flat)
        elif devices is None:
            devices = jax.devices()
        if not devices:
            raise ValueError("ShardedMorphService needs at least one device")
        self.config = config or ServiceConfig()
        self.devices = tuple(devices)
        self.shards = tuple(
            MorphService(dataclasses.replace(self.config, device=d))
            for d in self.devices
        )

    # ------------------------------------------------------------- routing
    def _route(self, plan: Plan, img: np.ndarray) -> MorphService:
        """Stable bucket-affine routing (see module docstring)."""
        bucket = choose_bucket(img.shape[0], img.shape[1], self.config.buckets)
        token = f"{plan.name}|{bucket}|{img.dtype.str}".encode()
        return self.shards[zlib.crc32(token) % len(self.shards)]

    # ---------------------------------------------------------- submission
    def submit(self, img, op: str = "erode", se=(3, 3)):
        return self.submit_plan(img, single_op_plan(op, se))

    def submit_plan(self, img, plan: "str | Plan"):
        plan = get_plan(plan)
        img = np.asarray(img)
        if img.ndim != 2:
            raise ValueError("the service takes single (H, W) images; submit "
                             "each image of a batch separately")
        return self._route(plan, img).submit_plan(img, plan)

    def submit_expr(self, img, expr, name: str | None = None):
        from repro.morph.plan_compile import to_plan

        policy = self.shards[0].policy
        return self.submit_plan(img, to_plan(expr, name=name, policy=policy))

    def run(self, img, op: str = "erode", se=(3, 3)):
        return self.submit(img, op, se).result()

    def run_plan(self, img, plan: "str | Plan"):
        return self.submit_plan(img, plan).result()

    def run_expr(self, img, expr, name: str | None = None):
        return self.submit_expr(img, expr, name).result()

    def run_batch(self, imgs, plan: "str | Plan") -> list:
        futures = [self.submit_plan(im, plan) for im in imgs]
        return [f.result() for f in futures]

    # ------------------------------------------------------------- metrics
    def stats(self) -> dict:
        per = [s.stats() for s in self.shards]
        cache = {
            k: sum(p["cache"][k] for p in per)
            for k in ("size", "hits", "misses", "evictions")
        }
        total = cache["hits"] + cache["misses"]
        cache["hit_rate"] = cache["hits"] / total if total else 0.0
        bounded = {
            k: sum(p["bounded_iter"][k] for p in per)
            for k in ("executions", "iters_used", "iters_budget")
        }
        bounded["saved_frac"] = (
            1.0 - bounded["iters_used"] / bounded["iters_budget"]
            if bounded["iters_budget"] else 0.0
        )
        return {
            "shards": len(self.shards),
            "requests": sum(p["requests"] for p in per),
            "batches": sum(p["batches"] for p in per),
            "tiled_requests": sum(p["tiled_requests"] for p in per),
            "img_per_s": sum(p["img_per_s"] for p in per),
            "p50_ms": max(p["p50_ms"] for p in per),
            "p99_ms": max(p["p99_ms"] for p in per),
            "cache": cache,
            "bounded_iter": bounded,
            "effective_window_ms": max(p["effective_window_ms"] for p in per),
            "backend": per[0]["backend"],
            "interpret": per[0]["interpret"],
            "per_shard": per,
        }

    # ------------------------------------------------------------ lifecycle
    def flush(self, timeout: float | None = None) -> bool:
        return all(s.flush(timeout) for s in self.shards)

    def close(self) -> None:
        for s in self.shards:
            s.close()

    def __enter__(self) -> "ShardedMorphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
