"""Mesh-partitioned morphology: device-side halo exchange + sharded serving.

The paper's separable erode/dilate passes parallelize by splitting the image
plane into independent strips whose only coupling is a halo of ``wing``
pixels (the same structure Bailey et al. exploit for parallel geodesic
transforms on multi-core CPUs). This package makes that structure a
first-class execution mode:

* :mod:`repro.shard.mesh`  — 1-D / 2-D device meshes over the image plane;
* :mod:`repro.shard.halo`  — device-side halo exchange (``shard_map`` +
  ``lax.ppermute``; neutral fill at global boundaries, multi-hop when an SE
  wing exceeds a shard's interior);
* :mod:`repro.shard.lower` — ``to_sharded(expr, mesh)``: the fourth lowering
  of the morphology IR, next to ``lower_xla`` / ``lower_kernel`` /
  ``to_plan``; per-pass halo-exchange-vs-reshard choice via the measured
  cost model's ``collective`` axis kind;
* :mod:`repro.shard.router` — :class:`ShardedMorphService`: shape buckets
  routed to per-device ``MorphService`` shards, stats merged.

Everything is bit-exact against the single-device ``lower_xla`` path
(property-tested in tests/test_shard.py, including shapes not divisible by
the shard count and SE wings wider than a shard's interior).
"""
from repro.shard.halo import exchange_halo
from repro.shard.lower import ShardStrategy, to_sharded
from repro.shard.mesh import (
    COLS,
    ROWS,
    available_shards,
    image_mesh,
    mesh_axis_sizes,
)
from repro.shard.router import ShardedMorphService

__all__ = [
    "COLS",
    "ROWS",
    "ShardStrategy",
    "ShardedMorphService",
    "available_shards",
    "exchange_halo",
    "image_mesh",
    "mesh_axis_sizes",
    "to_sharded",
]
