"""Device-side halo exchange for mesh-partitioned separable passes.

A 1-D morphology pass of window ``2*wing + 1`` along a sharded axis needs
``wing`` rows of each neighbor's slab — nothing else couples the shards.
:func:`exchange_halo` runs *inside* ``shard_map`` and extends the local slab
with exactly those rows via ``lax.ppermute`` pairs (one send up, one send
down per hop), entirely device-resident — the sharded analog of the serving
layer's host-side tile gather, with no host round trip.

Boundary semantics: shards at the global edge fill their missing halo with
the op's **neutral element**, which is bit-identical to the single-device
kernels' virtual neutral border (``core/linear_pass.py`` / ``core/vhgw.py``
pad with the same neutral). It is also equivalent to edge-replication for
these ops: min/max are idempotent and the boundary row is already inside
any window that overhangs the edge, so replicated copies can never change
the reduction — neutral fill is simply the cheaper identical choice.

Wings wider than a shard's interior take **multi-hop** exchange: with slab
height ``R`` and ``k = ceil(wing / R)``, hop ``d`` fetches the slab of the
shard ``d`` away (full slabs for ``d < k``, the trailing ``wing - (k-1)*R``
rows for the farthest hop), so the extended slab is exact for any SE — the
property the tiling layer already guarantees for oversized images.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def _hop(x, d: int, axis: int, axis_name: str, size: int, *, up: bool):
    """Slab received from the shard ``d`` positions before (``up``) / after
    this one, or ``None`` when no shard can be that far away."""
    if d >= size:
        return None
    if up:
        perm = [(i, i + d) for i in range(size - d)]
    else:
        perm = [(i, i - d) for i in range(d, size)]
    return lax.ppermute(x, axis_name, perm)


def exchange_halo(
    x,
    wing: int,
    *,
    axis: int,
    axis_name: str,
    size: int,
    neutral,
):
    """Extend a local slab with ``wing`` halo rows from mesh neighbors.

    Call inside ``shard_map``. ``x`` is the local slab, ``axis`` the sharded
    axis (typically -2 for rows, -1 for cols), ``size`` the static mesh axis
    size, ``neutral`` the fill for halo regions beyond the global image
    (the op's own neutral — see module docstring). Returns ``x`` grown by
    ``wing`` on both sides of ``axis``; run the 1-D pass on the result and
    slice ``[wing : wing + R]`` back out.
    """
    if wing <= 0 or size <= 1:
        return x
    axis = axis % x.ndim
    r = x.shape[axis]
    idx = lax.axis_index(axis_name)
    k = -(-wing // r)  # hops needed to cover the wing
    need = wing - (k - 1) * r  # rows taken from the farthest hop

    def fill_like(block):
        return jnp.full(block.shape, neutral, dtype=x.dtype)

    above = []  # farthest neighbor first: global order i-k, ..., i-1
    for d in range(k, 0, -1):
        block = x if d < k else lax.slice_in_dim(x, r - need, r, axis=axis)
        recv = _hop(block, d, axis, axis_name, size, up=True)
        if recv is None:
            above.append(fill_like(block))
        else:
            above.append(jnp.where(idx >= d, recv, fill_like(block)))
    below = []  # nearest neighbor first: global order i+1, ..., i+k
    for d in range(1, k + 1):
        block = x if d < k else lax.slice_in_dim(x, 0, need, axis=axis)
        recv = _hop(block, d, axis, axis_name, size, up=False)
        if recv is None:
            below.append(fill_like(block))
        else:
            below.append(jnp.where(idx <= size - 1 - d, recv, fill_like(block)))
    return jnp.concatenate(above + [x] + below, axis=axis)
