"""``to_sharded``: lower a morphology expression onto an image-plane mesh.

The fourth lowering of the IR, next to ``lower_xla`` / ``lower_kernel`` /
``to_plan``: the same evaluator walk, with primitives that partition each
separable pass across the mesh. Per 1-D pass along a sharded axis there are
two legal schedules:

* **exchange** — keep the standing sharding and extend each slab with the
  pass's ``wing`` halo rows via ``lax.ppermute``
  (:func:`repro.shard.halo.exchange_halo`; multi-hop when the wing exceeds
  a slab, neutral fill at the global boundary);
* **reshard** — ``lax.all_to_all`` the slab so the pass's axis becomes
  fully local (rows-sharded data resharding to column strips for the
  vertical pass), run the pass halo-free, and ``all_to_all`` back.

``strategy="auto"`` picks per pass via the cost model's ``collective`` axis
kind (:meth:`repro.morph.opt.cost.CostModel.exchange_wins`): measured
ppermute/all_to_all curves when ``bench_shard --fit-collective`` has run,
else the byte-count heuristic (exchange until the wing exceeds the shard
interior). Passes along unsharded axes are local and free of collectives.

Bit-exactness against ``lower_xla`` holds for *any* input shape and graph:

* non-divisible extents pad up to the mesh grid, and every primitive's
  input is re-masked with that op's neutral outside the true image — the
  serving executor's valid-rect mechanism, reused verbatim, so composed
  graphs needing both neutrals (gradient) just work;
* halo fill at global boundaries is the op's neutral — identical to the
  1-D kernels' virtual border;
* ``BoundedIter`` convergence checks are made *global* (``lax.psum`` of the
  changed flag over the mesh axes) so every shard runs the same iteration
  count and the collectives inside the loop body stay in lockstep.
"""
from __future__ import annotations

from typing import Literal

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.dispatch import DispatchPolicy, morph_1d
from repro.morph.expr import MorphExpr
from repro.morph.interp import evaluate
from repro.shard.halo import exchange_halo
from repro.shard.mesh import COLS, ROWS, image_mesh, mesh_axis_sizes

ShardStrategy = Literal["auto", "exchange", "reshard"]
_STRATEGIES = ("auto", "exchange", "reshard")


def _check_strategy(strategy: str) -> str:
    if strategy not in _STRATEGIES:
        raise ValueError(
            f"strategy must be one of {_STRATEGIES}, got {strategy!r}"
        )
    return strategy


def _reshard_pass(v, w: int, op, axis_name: str, policy) -> jnp.ndarray:
    """Run the sublane-axis pass halo-free by resharding rows -> cols.

    ``all_to_all`` turns a ``(..., R, W)`` row slab into ``(..., H, W/n)``
    column strips (full height locally), the pass runs with zero halo, and
    the inverse ``all_to_all`` restores row sharding. Requires the padded
    width to be divisible by the mesh axis (``to_sharded`` pads for it).
    """
    nd = v.ndim
    t = lax.all_to_all(v, axis_name, split_axis=nd - 1, concat_axis=nd - 2,
                       tiled=True)
    t = morph_1d(t, w, axis=-2, op=op, policy=policy)
    return lax.all_to_all(t, axis_name, split_axis=nd - 2, concat_axis=nd - 1,
                          tiled=True)


def _exchange_pass(v, w: int, op, *, axis: int, axis_name: str, size: int,
                   policy) -> jnp.ndarray:
    wing = (w - 1) // 2
    ext = exchange_halo(
        v, wing, axis=axis, axis_name=axis_name, size=size,
        neutral=op.neutral(v.dtype),
    )
    out = morph_1d(ext, w, axis=axis, op=op, policy=policy)
    r = v.shape[axis % v.ndim]
    return lax.slice_in_dim(out, wing, wing + r, axis=axis % v.ndim)


def to_sharded(
    outputs,
    mesh=None,
    *,
    policy: DispatchPolicy | None = None,
    strategy: ShardStrategy = "auto",
):
    """``expr | {name: expr}`` -> ``fn(x=None, **vars) -> array | {name: array}``
    executing across ``mesh`` (default: all local devices on a 1-D rows
    axis), bit-identical to ``lower_xla`` on the same inputs.

    All inputs must share one ``(..., H, W)`` shape; leading batch dims are
    replicated (each shard sees the full batch of its strip — morphology
    batches are small next to the image plane). ``strategy`` picks the
    halo-exchange-vs-reshard schedule per pass (see module docstring);
    resharding applies only to 1-D row meshes, where the width axis is free
    to re-partition.
    """
    policy = policy or DispatchPolicy.calibrated()
    strategy = _check_strategy(strategy)
    from repro.morph.opt import cost_model_for, optimize

    single = isinstance(outputs, MorphExpr)
    outs = {"out": outputs} if single else dict(outputs)
    outs = optimize(outs, policy=policy, kinds=("major", "minor"))

    mesh = mesh if mesh is not None else image_mesh()
    nr, nc = mesh_axis_sizes(mesh)
    # Resharding re-partitions the width axis across the row shards; a 2-D
    # mesh already owns that axis, so only 1-D row meshes may reshard.
    may_reshard = strategy != "exchange" and nr > 1 and nc == 1
    if strategy == "reshard" and not may_reshard:
        raise ValueError(
            "strategy='reshard' needs a 1-D rows mesh with >1 shard "
            f"(got rows={nr}, cols={nc})"
        )
    model = cost_model_for(policy)
    axis_names = tuple(
        n for n, sz in ((ROWS, nr), (COLS, nc)) if sz > 1
    )

    def fn(x=None, **env):
        if x is not None:
            env.setdefault("x", x)
        if not env:
            raise ValueError("to_sharded functions need at least one input")
        shapes = {v.shape for v in env.values()}
        if len(shapes) != 1:
            raise ValueError(
                f"all sharded inputs must share one shape, got {sorted(shapes)}"
            )
        (shape,) = shapes
        if len(shape) < 2:
            raise ValueError(f"inputs must be (..., H, W), got shape {shape}")
        h, w = int(shape[-2]), int(shape[-1])
        nd = len(shape)
        rl = -(-h // nr)  # local slab rows
        wdiv = nc * (nr if may_reshard else 1)  # all_to_all splits width by nr
        wl_total = -(-w // wdiv) * wdiv
        hp, wp = rl * nr, wl_total
        cl = wp // nc  # local slab cols
        pad = [(0, 0)] * (nd - 2) + [(0, hp - h), (0, wp - w)]
        env_p = {k: jnp.pad(jnp.asarray(v), pad) for k, v in env.items()}

        spec = P(*([None] * (nd - 2)
                   + [ROWS if nr > 1 else None, COLS if nc > 1 else None]))
        masked = hp != h or wp != w

        def local(env_l):
            r0 = lax.axis_index(ROWS) * rl if nr > 1 else 0
            c0 = lax.axis_index(COLS) * cl if nc > 1 else 0

            def pre(v, op):
                # serving's valid-rect masking, shard-local: everything past
                # the true image reads as this op's own neutral before every
                # primitive — what keeps grid padding bit-exact for composed
                # graphs (a single fill could not serve both min and max).
                rows = r0 + jnp.arange(v.shape[-2], dtype=jnp.int32)
                cols = c0 + jnp.arange(v.shape[-1], dtype=jnp.int32)
                valid = (rows < h)[:, None] & (cols < w)[None, :]
                return jnp.where(valid, v, jnp.asarray(op.neutral(v.dtype)))

            def prim(op, v, se):
                wh, ww = int(se[0]), int(se[1])
                wing_h = (wh - 1) // 2
                if nr > 1 and wing_h > 0:
                    if may_reshard and (
                        strategy == "reshard"
                        or not model.exchange_wins(
                            wing_h, rl, wp, jnp.dtype(v.dtype).name
                        )
                    ):
                        v = _reshard_pass(v, wh, op, ROWS, policy)
                    else:
                        v = _exchange_pass(
                            v, wh, op, axis=-2, axis_name=ROWS, size=nr,
                            policy=policy,
                        )
                else:
                    v = morph_1d(v, wh, axis=-2, op=op, policy=policy)
                wing_w = (ww - 1) // 2
                if nc > 1 and wing_w > 0:
                    v = _exchange_pass(
                        v, ww, op, axis=-1, axis_name=COLS, size=nc,
                        policy=policy,
                    )
                else:
                    v = morph_1d(v, ww, axis=-1, op=op, policy=policy)
                return v

            def stable_reduce(changed):
                # global convergence: every shard must agree on the loop
                # trip count or the body's collectives deadlock
                return lax.psum(changed.astype(jnp.int32), axis_names) > 0

            memo: dict = {}
            return {
                k: evaluate(
                    e, env_l, prim=prim,
                    pre_prim=pre if masked else None,
                    stable_reduce=stable_reduce if axis_names else None,
                    memo=memo,
                )
                for k, e in outs.items()
            }

        run = shard_map(
            local, mesh=mesh,
            in_specs=({k: spec for k in env_p},),
            out_specs={k: spec for k in outs},
            check_rep=False,
        )
        res = run(env_p)
        crop = (Ellipsis, slice(0, h), slice(0, w))
        res = {k: v[crop] for k, v in res.items()}
        return res["out"] if single else res

    return fn
