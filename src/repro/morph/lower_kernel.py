"""Lower a morphology expression to the fused Pallas TPU kernels.

Erode/Dilate nodes dispatch through ``kernels.ops.raw_morph2d`` (the fused
single-``pallas_call`` megakernel when the policy, SE and per-node cost
model allow, the legacy two-pass + transpose pipeline otherwise — all
governed by :class:`DispatchPolicy`). Graphs are optimized first
(``repro.morph.opt.optimize``): the optimizer's canonical pattern pass
rewrites ``Sub(Dilate(c, se), Erode(c, se))`` into the first-class
``Gradient`` node, which lowers to the single-launch fused gradient kernel
— 2 reads + 1 write instead of two full operators plus a subtraction. The
evaluator's legacy ``gradient_prim`` pattern hook is kept so *unoptimized*
graphs (``opt_level=0`` A/B runs) still fuse the way they always did.

Kernel modules are imported lazily inside the primitives: ``kernels.ops``
itself builds its public entry points on this pass, and the morph package
must stay importable without dragging the kernel stack in first.
"""
from __future__ import annotations

from repro.core.dispatch import DispatchPolicy
from repro.morph.interp import make_lowering


def lower_kernel(
    outputs, *, policy: DispatchPolicy | None = None, interpret: bool | None = None
):
    """``expr | {name: expr}`` -> ``fn(x=None, **vars) -> array | {name: array}``."""
    policy = policy or DispatchPolicy.calibrated()
    from repro.morph.opt import optimize

    outputs = optimize(outputs, policy=policy, kinds=("fused", "fused"))

    def prim(op, x, se):
        from repro.kernels.ops import raw_morph2d

        return raw_morph2d(x, se, op.name, policy=policy, interpret=interpret)

    def gradient_prim(x, se):
        from repro.kernels.ops import raw_gradient2d

        return raw_gradient2d(x, se, policy=policy, interpret=interpret)

    return make_lowering(outputs, prim=prim, gradient_prim=gradient_prim)
