"""Lower a morphology expression to the fused Pallas TPU kernels.

Erode/Dilate nodes dispatch through ``kernels.ops.raw_morph2d`` (the fused
single-``pallas_call`` megakernel when the policy and SE allow, the legacy
two-pass + transpose pipeline otherwise — all governed by
:class:`DispatchPolicy`), and the evaluator's pattern hook rewrites
``Sub(Dilate(c, se), Erode(c, se))`` into the single-launch fused gradient
kernel, so ``X.gradient(se)`` costs 2 reads + 1 write instead of two full
operators plus a subtraction.

Kernel modules are imported lazily inside the primitives: ``kernels.ops``
itself builds its public entry points on this pass, and the morph package
must stay importable without dragging the kernel stack in first.
"""
from __future__ import annotations

from repro.core.dispatch import DispatchPolicy
from repro.morph.interp import make_lowering


def lower_kernel(
    outputs, *, policy: DispatchPolicy | None = None, interpret: bool | None = None
):
    """``expr | {name: expr}`` -> ``fn(x=None, **vars) -> array | {name: array}``."""
    policy = policy or DispatchPolicy.calibrated()

    def prim(op, x, se):
        from repro.kernels.ops import raw_morph2d

        return raw_morph2d(x, se, op.name, policy=policy, interpret=interpret)

    def gradient_prim(x, se):
        from repro.kernels.ops import raw_gradient2d

        return raw_gradient2d(x, se, policy=policy, interpret=interpret)

    return make_lowering(outputs, prim=prim, gradient_prim=gradient_prim)
