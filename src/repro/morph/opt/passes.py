"""Rewrite passes over the morphology expression IR.

Every pass is semantics-preserving on the *lowered arrays* — bit-identical
outputs across ``lower_xla`` / ``lower_kernel`` / served plans, and a
per-axis halo never larger than the input graph's (both properties are under
property test in ``tests/test_morph_opt.py``). The pipeline
(:func:`optimize`) runs, in order:

1. **CSE via structural hashing** — every node is interned in a
   hash-consing table, so structurally equal subgraphs become *one object*.
   The evaluator memoizes on object identity; after interning, a
   multi-output graph like ``{open, tophat, grad}`` computes its shared
   erosion once instead of three times.
2. **Dead-output elimination** — with ``keep=...``, outputs a caller never
   reads are dropped and their exclusive subgraphs vanish with them (the
   rebuild only reaches live roots).
3. **Erode-of-erode / dilate-of-dilate folding** — nested same-op
   primitives over rectangular SEs merge; wings add
   (``w = w1 + w2 - 1`` per axis), turning two passes into one. Guarded by
   reference counts: an inner primitive another consumer still reads is
   left shared rather than recomputed inside a bigger window.
4. **Gradient canonicalization** — ``Sub(Dilate(c, se), Erode(c, se))``
   over one shared child becomes the first-class :class:`~repro.morph.expr.
   Gradient` node (this is the rewrite ``lower_kernel`` used to do as an
   ad-hoc evaluator hook). Also refcount-guarded: if either branch feeds
   another output, fusing would un-share it, so the ``Sub`` form stays.
5. **SE decomposition** (level >= 2) — a large-window primitive is
   rewritten as k iterated small-window primitives when the cost model
   (:mod:`repro.morph.opt.cost`) says the small-window ladder beats one
   large pass — the paper's §5.3 hybrid insight as a graph rewrite. The
   analytic fallback model never decomposes (its curves have zero per-pass
   overhead), so behavior only changes once a measured table exists.

``BoundedIter`` bodies are rewritten through the same pipeline; the loop
variable is just a ``Var``, and no rule rewrites across the loop boundary.
"""
from __future__ import annotations

import dataclasses

from repro.core.dispatch import DispatchPolicy
from repro.morph.expr import (
    BoundedIter,
    Cast,
    Clip,
    Dilate,
    Erode,
    Gradient,
    Max,
    Mean,
    Min,
    MorphExpr,
    StructuringElement,
    Sub,
    Var,
)
from repro.morph.opt.cost import CostModel, cost_model_for

_UNARY_CHILD = (Erode, Dilate, Gradient, Clip, Cast)
_BINARY = (Sub, Min, Max, Mean)
_FOLDABLE = (Erode, Dilate)


def children(node: MorphExpr) -> tuple[MorphExpr, ...]:
    if isinstance(node, _UNARY_CHILD):
        return (node.child,)
    if isinstance(node, _BINARY):
        return (node.a, node.b)
    if isinstance(node, BoundedIter):
        return (node.init, node.body)
    if isinstance(node, Var):
        return ()
    raise TypeError(f"unknown expression node {type(node).__name__}")


def with_children(node: MorphExpr, kids: tuple) -> MorphExpr:
    if isinstance(node, _UNARY_CHILD):
        return dataclasses.replace(node, child=kids[0])
    if isinstance(node, _BINARY):
        return dataclasses.replace(node, a=kids[0], b=kids[1])
    if isinstance(node, BoundedIter):
        return dataclasses.replace(node, init=kids[0], body=kids[1])
    return node


def _as_outputs(outputs) -> tuple[bool, tuple[tuple[str, MorphExpr], ...]]:
    if isinstance(outputs, MorphExpr):
        return True, (("out", outputs),)
    items = tuple(dict(outputs).items())
    for name, e in items:
        if not isinstance(e, MorphExpr):
            raise TypeError(f"output {name!r} is not a MorphExpr")
    return False, items


class _Rewriter:
    """One bottom-up rewriting walk: children first, then ``rule`` at the
    node, then interning in the shared hash-consing table. ``counts`` maps
    ``id(node) -> consumer count`` and follows rewrites, so refcount-guarded
    rules (fold, gradient fuse) see the count of the node a rewrite product
    replaced."""

    def __init__(self, interner: dict, counts: dict, rule=None):
        self.interner = interner
        self.counts = counts
        self.rule = rule
        self.memo: dict[int, MorphExpr] = {}

    def __call__(self, node: MorphExpr) -> MorphExpr:
        key = id(node)
        if key in self.memo:
            return self.memo[key]
        kids = children(node)
        new_kids = tuple(self(k) for k in kids)
        m = node
        if any(a is not b for a, b in zip(kids, new_kids)):
            m = with_children(node, new_kids)
        if self.rule is not None:
            m = self.rule(m, self.counts)
        m = self.interner.setdefault(m, m)
        self.counts.setdefault(id(m), self.counts.get(key, 1))
        self.memo[key] = m
        return m


def _intern_outputs(items, interner: dict, counts: dict, rule=None):
    rw = _Rewriter(interner, counts, rule)
    return tuple((name, rw(e)) for name, e in items)


def _refcounts(items) -> dict[int, int]:
    """Consumer count per (interned) node; each named output counts as one
    consumer of its root."""
    counts: dict[int, int] = {}
    seen: set[int] = set()

    def go(n: MorphExpr) -> None:
        for k in children(n):
            counts[id(k)] = counts.get(id(k), 0) + 1
            if id(k) not in seen:
                seen.add(id(k))
                go(k)

    for _, e in items:
        counts[id(e)] = counts.get(id(e), 0) + 1
        if id(e) not in seen:
            seen.add(id(e))
            go(e)
    return counts


def _merged_se(a: StructuringElement, b: StructuringElement) -> StructuringElement:
    # sequential flat rectangular SEs compose by Minkowski sum: wings add
    return StructuringElement(a.h + b.h - 1, a.w + b.w - 1)


def fold_rule(node: MorphExpr, counts: dict) -> MorphExpr:
    """Erode(Erode(c, se1), se2) -> Erode(c, se1 (+) se2); same for Dilate."""
    if (
        isinstance(node, _FOLDABLE)
        and type(node.child) is type(node)
        and counts.get(id(node.child), 1) == 1
    ):
        inner = node.child
        return type(node)(inner.child, _merged_se(inner.se, node.se))
    return node


def gradient_rule(node: MorphExpr, counts: dict) -> MorphExpr:
    """Sub(Dilate(c, se), Erode(c, se)) -> Gradient(c, se) when neither
    branch has another consumer (post-CSE, the shared child is one object)."""
    if (
        isinstance(node, Sub)
        and isinstance(node.a, Dilate)
        and isinstance(node.b, Erode)
        and node.a.se == node.b.se
        and node.a.child is node.b.child
        and counts.get(id(node.a), 1) == 1
        and counts.get(id(node.b), 1) == 1
    ):
        return Gradient(node.a.child, node.a.se)
    return node


def make_decompose_rule(model: CostModel, *, dtype: str, kinds):
    """A rule rewriting a large-SE primitive into the cost model's iterated
    small-SE schedule (wings sum exactly -> bit-identical, equal halo)."""

    def rule(node: MorphExpr, counts: dict) -> MorphExpr:
        if not isinstance(node, _FOLDABLE):
            return node
        sched = model.decompose(node.se.pair, dtype, kinds=kinds)
        if not sched:
            return node
        out = node.child
        for se in sched:
            out = type(node)(out, StructuringElement.of(se))
        return out

    return rule


def prim_count(outputs) -> int:
    """Primitive launches a lowering would issue for this graph as-is:
    Erode/Dilate/Gradient nodes deduplicated by *object identity* — the
    evaluator memoizes on ``id``, so structurally equal but distinct nodes
    (what CSE exists to merge) each cost a launch. The benchmark's cost
    proxy: ``prim_count(raw) - prim_count(optimize(raw))`` is the number of
    launches the optimizer removed."""
    _, items = _as_outputs(outputs)
    seen: set[int] = set()
    prims = 0

    def go(n: MorphExpr) -> None:
        nonlocal prims
        if id(n) in seen:
            return
        seen.add(id(n))
        if isinstance(n, (Erode, Dilate, Gradient)):
            prims += 1
        for k in children(n):
            go(k)

    for _, e in items:
        go(e)
    return prims


def optimize(
    outputs,
    *,
    level: int | None = None,
    cost_model: CostModel | None = None,
    policy: DispatchPolicy | None = None,
    keep=None,
    dtype: str = "uint8",
    kinds=("major", "minor"),
):
    """Optimize ``expr | {name: expr}``; returns the same shape it was given.

    ``level`` (default: ``policy.opt_level``): 0 = identity, 1 = structural
    passes (CSE, dead-output elimination, folding, gradient
    canonicalization), 2 = plus cost-model-driven SE decomposition.
    ``keep`` restricts a multi-output graph to the named outputs.
    ``dtype``/``kinds`` seed the cost queries (the graph itself is
    shapeless); ``cost_model`` defaults to :func:`cost_model_for` on the
    policy — measured table when calibrated, analytic otherwise.
    """
    single, items = _as_outputs(outputs)
    if keep is not None:
        if single:
            raise ValueError("keep= only applies to {name: expr} outputs")
        keep = set(keep)
        missing = keep - {n for n, _ in items}
        if missing:
            raise KeyError(f"keep names not in outputs: {sorted(missing)}")
        items = tuple((n, e) for n, e in items if n in keep)
    if level is None:
        level = (policy or DispatchPolicy.calibrated()).opt_level
    if level <= 0:
        return outputs if keep is None else dict(items)
    interner: dict = {}
    # pass 1+2: hash-consing CSE over the (kept) outputs
    items = _intern_outputs(items, interner, {})
    # pass 3: same-op folding, guarded by consumer counts
    items = _intern_outputs(items, interner, _refcounts(items), fold_rule)
    # pass 4: canonicalize the gradient pattern
    items = _intern_outputs(items, interner, _refcounts(items), gradient_rule)
    if level >= 2:
        model = cost_model or cost_model_for(policy)
        rule = make_decompose_rule(model, dtype=dtype, kinds=kinds)
        items = _intern_outputs(items, interner, _refcounts(items), rule)
    if single:
        return items[0][1]
    return dict(items)
