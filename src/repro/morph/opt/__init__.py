"""Cost-model-driven optimizer for the morphology expression IR.

``optimize(expr | {name: expr}, *, level, cost_model)`` is the single public
entry; all three lowerings (``lower_xla`` / ``lower_kernel`` / ``to_plan``)
run it by default at ``DispatchPolicy.opt_level`` (opt out with
``DispatchPolicy(opt_level=0)``). Passes live in
:mod:`repro.morph.opt.passes`; the per-device measured/analytic cost model
in :mod:`repro.morph.opt.cost` (fit via
``python -m benchmarks.bench_hybrid --fit-cost-table``).
"""
from repro.morph.opt.cost import (
    COST_TABLE_FILE,
    CostModel,
    cost_model_for,
    device_kind,
    fit_affine,
    load_measured,
    save_measured,
)
from repro.morph.opt.passes import optimize, prim_count

__all__ = [
    "COST_TABLE_FILE",
    "CostModel",
    "cost_model_for",
    "device_kind",
    "fit_affine",
    "load_measured",
    "save_measured",
    "optimize",
    "prim_count",
]
