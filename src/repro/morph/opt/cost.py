"""Calibrated per-device cost model for morphology dispatch.

The paper picks linear-vs-vHGW per pass from a *measured* crossover (§5.3);
until now that insight lived in three hand-edited scalars on
``DispatchPolicy`` (``w0_minor`` / ``w0_major`` / ``w0_fused``). This module
replaces the scalars with per-``(axis kind, method, dtype)`` affine cost
curves fit from real sweeps:

    cost_us(w) = c0 + c1 * feature(method, w)

where the feature is the method's complexity driver — ``w`` for the linear
accumulator ladder, ``ceil(log2 w)`` for the doubling tree, ``w^2`` for
vHGW (amortized-flat in theory, but its strided reshapes bend upward with
``w`` in practice, and that convexity is what makes SE decomposition
winnable — see :func:`feature`). The intercept ``c0`` is the per-pass overhead
(launch + padding + layout), which is exactly the term that decides whether
decomposing one large-window pass into k small ones can ever win.

Tables are fit by ``python -m benchmarks.bench_hybrid --fit-cost-table`` and
persisted in ``cost_table.json`` next to ``calibration.json``, keyed by JAX
device kind so a checkout shared between a laptop and a TPU host keeps one
table per device. Loading is memoized on file mtime.

When no table exists (or a policy carries hand-set thresholds that disagree
with the measured crossovers) the **analytic fallback** reconstructs cost
curves *from the policy's own thresholds*, so every consumer below degrades
to exactly the historical scalar-threshold behavior:

* ``best_method`` — queried by ``core.dispatch.morph_1d`` (axis kinds
  ``major``/``minor``) and the fused megakernel's per-axis choice
  (axis kind ``fused``, replacing the bare ``w <= w0_fused`` branch);
* ``fused_wins`` — the per-node fused-vs-two-pass decision in
  ``kernels.ops.raw_morph2d`` / ``raw_gradient2d``;
* ``decompose`` — the optimizer's SE-decomposition pass (a large-window
  primitive as k iterated small-window primitives), the paper's hybrid
  insight promoted from a runtime branch to a graph rewrite.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os

import jax

from repro.core.dispatch import DispatchPolicy

COST_TABLE_FILE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
    "core",
    "cost_table.json",
)

AXIS_KINDS = ("major", "minor", "fused", "collective", "repr")
_SMALL_METHODS = ("linear", "linear_paired", "linear_tree")
# Methods under the "collective" axis kind (sharded execution, repro.shard):
# affine in *elements moved*, fit by bench_shard --fit-collective.
COLLECTIVE_METHODS = ("ppermute", "all_to_all")
# Methods under the "repr" axis kind (representation choice for boolean
# plans, repro.rle): "rle" is affine in the *run count*, "dense" in the
# *pixel count* — the drivers differ per method, which is the whole point
# of the axis. Fit by bench_rle --fit-cost-table.
REPR_METHODS = ("rle", "dense")


def feature(method: str, w: int) -> float:
    """The per-method complexity driver the affine cost model is linear in.

    ``linear``/``linear_paired`` walk the window (feature ``w``);
    ``linear_tree`` is a doubling ladder (``ceil(log2 w)``). vHGW is
    amortized O(1) per element in theory, but its strided segment reshapes
    bend measurably upward with ``w`` on both backends — and *convexity* is
    the one thing that can make an iterated-small-SE schedule beat a single
    large pass (affine-in-``w`` curves are subadditive over Minkowski
    composition, so they provably never decompose). The quadratic feature
    lets a fit capture that bend where it is real; flat sweeps simply fit
    ``c1 ~ 0`` and decomposition stays off.
    """
    if w <= 1:
        return 0.0
    if method == "linear_tree":
        return float(math.ceil(math.log2(w)))
    if method == "vhgw":
        return float(w) * float(w)
    # linear / linear_paired accumulator ladders (driver: window), and the
    # collective methods (driver: elements moved — callers pass elems as w)
    return float(w)


def fit_affine(points) -> tuple[float, float]:
    """Least-squares ``(c0, c1)`` for ``t = c0 + c1 * f`` over ``(f, t)``
    pairs; degenerate sweeps (single distinct feature) fit a constant."""
    pts = [(float(f), float(t)) for f, t in points]
    if not pts:
        raise ValueError("cannot fit a cost curve from zero samples")
    n = len(pts)
    mf = sum(f for f, _ in pts) / n
    mt = sum(t for _, t in pts) / n
    var = sum((f - mf) ** 2 for f, _ in pts)
    if var == 0.0:
        return mt, 0.0
    c1 = sum((f - mf) * (t - mt) for f, t in pts) / var
    return mt - c1 * mf, c1


def device_kind() -> str:
    """Cost tables are keyed by this (e.g. ``cpu``, ``TPU v4``)."""
    try:
        return str(jax.devices()[0].device_kind)
    except Exception:  # pragma: no cover - no backend at all
        return jax.default_backend()


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Per-(axis kind, method, dtype) affine cost curves, in microseconds.

    ``entries`` maps ``(kind, method, dtype_name) -> (c0, c1)``; lookups
    fall back dtype -> ``uint8`` -> analytic-from-thresholds, so a table fit
    only on the paper's u8 image still covers every dtype. ``crossovers``
    records the thresholds the curves imply (what ``calibrated()`` adopts);
    ``source`` is ``"measured"`` or ``"analytic"``.
    """

    entries: "dict[tuple[str, str, str], tuple[float, float]]"
    crossovers: "dict[str, object]"
    source: str = "analytic"
    # measured whole-op 2-D costs: (path, dtype) -> (c0, c1) affine in h+w,
    # path in {"fused", "two_pass", "gradient_fused", "gradient_two_pass"}
    op2d: "dict[tuple[str, str], tuple[float, float]]" = dataclasses.field(
        default_factory=dict
    )

    # ------------------------------------------------------------ construction
    @classmethod
    def analytic(cls, policy: DispatchPolicy | None = None) -> "CostModel":
        """Cost curves reconstructed from a policy's thresholds.

        Normalized so the small method and vHGW cost exactly 1.0 at the
        threshold (ties prefer the small method), reproducing the historical
        ``w <= w0`` dispatch bit-for-bit. Intercepts are zero: with no
        measured per-pass overhead, k small passes always cost more than one
        large pass, so the analytic model never decomposes and always says
        the fused kernel wins — the pre-cost-model defaults.
        """
        policy = policy or DispatchPolicy.calibrated()
        entries: dict[tuple[str, str, str], tuple[float, float]] = {}
        for kind, w0 in (
            ("major", policy.w0_major),
            ("minor", policy.w0_minor),
            ("fused", policy.w0_fused),
        ):
            small = policy.small_method if kind != "fused" else "linear"
            f0 = max(feature(small, int(w0)), 1.0)
            entries[(kind, small, "uint8")] = (0.0, 1.0 / f0)
            entries[(kind, "vhgw", "uint8")] = (1.0, 0.0)
            # the fused kernel only knows the plain linear ladder; keep a
            # curve for it too so "fused"/"linear" lookups always resolve
            if small != "linear":
                fl = max(feature("linear", int(w0)), 1.0)
                entries[(kind, "linear", "uint8")] = (0.0, 1.0 / fl)
        crossovers = {
            "w0_major": policy.w0_major,
            "w0_minor": policy.w0_minor,
            "w0_fused": policy.w0_fused,
            "small_method": policy.small_method,
        }
        return cls(entries=entries, crossovers=crossovers, source="analytic")

    @classmethod
    def from_table(cls, table: dict) -> "CostModel":
        entries = {
            tuple(k.split("/")): tuple(v) for k, v in table["entries"].items()
        }
        op2d = {
            tuple(k.split("/")): tuple(v)
            for k, v in table.get("op2d", {}).items()
        }
        return cls(
            entries=entries,
            crossovers=dict(table.get("crossovers", {})),
            source="measured",
            op2d=op2d,
        )

    # ----------------------------------------------------------------- queries
    def _entry(self, kind: str, method: str, dtype: str):
        e = self.entries.get((kind, method, dtype))
        if e is None:
            e = self.entries.get((kind, method, "uint8"))
        return e

    def cost_1d(self, kind: str, method: str, w: int, dtype: str = "uint8") -> float:
        """Modeled cost (µs for measured tables, threshold-normalized units
        for analytic ones) of one 1-D pass of window ``w``.

        A *measured* model never mixes units: an unmeasured linear-family
        method borrows the measured ``linear`` curve (same family, same
        crossover side), and a method with no measured family proxy costs
        +inf — conservatively never chosen — rather than comparing analytic
        ~1.0-unit numbers against microsecond curves.
        """
        e = self._entry(kind, method, dtype)
        if e is None and self.source == "measured":
            if method in _SMALL_METHODS:
                proxy = self._entry(kind, "linear", dtype)
                if proxy is not None:
                    c0, c1 = proxy
                    return c0 + c1 * feature("linear", w)
            return float("inf")
        if e is None:
            e = (0.0, 1.0)  # analytic model missing a kind: benign default
        c0, c1 = e
        # clamp: a flat sweep can fit a tiny negative slope that the w^2
        # feature amplifies into nonsense-negative costs when extrapolated
        return max(0.0, c0 + c1 * feature(method, w))

    def best_method(
        self, kind: str, w: int, dtype: str = "uint8", *, small: str = "linear_tree"
    ) -> str:
        """Cheapest of ``small`` vs ``vhgw`` at window ``w`` (ties -> small,
        preserving the historical ``w <= w0`` inclusive threshold). The
        analytic model dispatches on its thresholds directly — bit-for-bit
        the old scalar branch (the log feature's coarse buckets would
        otherwise blur the crossover by up to one doubling)."""
        if w <= 1:
            return small
        if self.source == "analytic":
            w0 = int(self.crossovers.get(f"w0_{kind}", 0))
            return small if w <= w0 else "vhgw"
        cs = self.cost_1d(kind, small, w, dtype)
        cv = self.cost_1d(kind, "vhgw", w, dtype)
        if math.isinf(cs) and math.isinf(cv):
            # a measured table sparse in this axis kind (e.g. only the
            # collective curves were fit, bench_shard --fit-collective):
            # degrade to the recorded crossover thresholds — bit-for-bit
            # the scalar branch, never an arbitrary inf-vs-inf tie
            w0 = int(self.crossovers.get(f"w0_{kind}", 0))
            return small if w <= w0 else "vhgw"
        return small if cs <= cv else "vhgw"

    def crossover(self, kind: str, *, small: str = "linear_tree",
                  dtype: str = "uint8", sweep=None) -> int:
        """First odd w where vHGW beats ``small`` (the scalar a table
        distills to — what ``DispatchPolicy.calibrated()`` adopts)."""
        ws = sweep or range(3, 1026, 2)
        for w in ws:
            if self.best_method(kind, w, dtype, small=small) == "vhgw":
                return int(w)
        return int(max(ws))

    def prim_cost_2d(
        self, se, dtype: str = "uint8", *, kinds=("major", "fused"),
        small: str = "linear_tree",
    ) -> float:
        """Modeled cost of one separable 2-D primitive: the H pass at
        ``kinds[0]`` plus the W pass at ``kinds[1]``, each with its best
        method. Intercepts make this launch-count aware."""
        w_h, w_w = int(se[0]), int(se[1])
        total = 0.0
        for kind, w in zip(kinds, (w_h, w_w)):
            s = small if kind != "fused" else "linear"
            m = self.best_method(kind, w, dtype, small=s)
            total += self.cost_1d(kind, m, w, dtype)
        return total

    def collective_cost(self, method: str, elems: int, dtype: str = "uint8"):
        """Modeled µs for moving ``elems`` elements via ``method``
        (``"ppermute"`` / ``"all_to_all"``), or ``None`` when this model has
        no measured curve — collectives have no analytic reconstruction (the
        scalar thresholds never described them), so absence means "fall back
        to the byte-count heuristic", not "cost zero".
        """
        if method not in COLLECTIVE_METHODS:
            raise ValueError(
                f"collective method must be one of {COLLECTIVE_METHODS}, "
                f"got {method!r}"
            )
        e = self._entry("collective", method, dtype)
        if e is None:
            return None
        c0, c1 = e
        return max(0.0, c0 + c1 * float(elems))

    def exchange_wins(
        self, wing: int, interior: int, row_elems: int, dtype: str = "uint8"
    ) -> bool:
        """Halo-exchange vs reshard for one sharded separable pass.

        Exchange moves ``2 * wing`` rows total but issues ``2 * k`` ppermute
        launches for ``k = ceil(wing / interior)`` hops (halo.exchange_halo's
        multi-hop form), so each extra launch pays the intercept again;
        resharding moves the whole slab through two ``all_to_all``s
        (``2 * interior`` rows per shard). Measured collective curves decide
        when both exist; otherwise the byte-count heuristic: exchange wins
        unless the wing exceeds the shard interior — exactly the regime
        (SE wider than a slab) where multi-hop exchange degenerates into an
        all-gather anyway.
        """
        pc = self.collective_cost("ppermute", 2 * wing * row_elems, dtype)
        launch = self.collective_cost("ppermute", 0, dtype)
        ac = self.collective_cost("all_to_all", 2 * interior * row_elems, dtype)
        if pc is None or ac is None:
            return wing <= interior
        k = max(1, -(-wing // max(1, interior)))
        # collective_cost already includes one intercept; exchange issues
        # 2k launches (one pair per hop), all_to_all issues two
        pc = pc + (2 * k - 1) * launch
        ac = ac + self.collective_cost("all_to_all", 0, dtype)
        return pc <= ac

    def repr_cost(self, method: str, driver: int, dtype: str = "bool"):
        """Modeled µs for one boolean-plan execution under ``method``
        (``"rle"`` driven by run count, ``"dense"`` by pixel count), or
        ``None`` when unmeasured — like the collectives, representation
        curves have no analytic reconstruction (no historical scalar ever
        described them), so absence means "use the density heuristic".
        """
        if method not in REPR_METHODS:
            raise ValueError(
                f"representation method must be one of {REPR_METHODS}, "
                f"got {method!r}"
            )
        e = self._entry("repr", method, dtype)
        if e is None:
            return None
        c0, c1 = e
        return max(0.0, c0 + c1 * float(driver))

    def rle_wins(self, runs: int, pixels: int, dtype: str = "bool") -> bool:
        """Representation choice for one boolean request: run-domain vs
        dense, given the request's measured run count and its pixel count.

        Measured curves (``bench_rle --fit-cost-table``) decide when both
        exist; otherwise the density heuristic: run-domain work is a few
        vector ops per run against a few elementwise passes per pixel, so
        RLE wins comfortably below ~5% runs/pixel on every host we have
        measured — a deliberately conservative default (the measured
        crossover is usually higher).
        """
        rc = self.repr_cost("rle", runs, dtype)
        dc = self.repr_cost("dense", pixels, dtype)
        if rc is None or dc is None:
            return runs <= 0.05 * pixels
        return rc <= dc

    def fused_wins(self, se, dtype: str = "uint8", *, gradient: bool = False) -> bool:
        """Per-node fused-megakernel vs two-pass+transpose decision.

        Measured tables compare the whole-op affine fits; without them the
        answer is True (the fused kernel's 1-vs-4 HBM-traversal structure),
        which is the pre-cost-model behavior ``policy.fused_2d`` encoded.
        """
        a = "gradient_fused" if gradient else "fused"
        b = "gradient_two_pass" if gradient else "two_pass"
        fa = self.op2d.get((a, dtype)) or self.op2d.get((a, "uint8"))
        fb = self.op2d.get((b, dtype)) or self.op2d.get((b, "uint8"))
        if fa is None or fb is None:
            return True
        s = float(int(se[0]) + int(se[1]))
        return fa[0] + fa[1] * s <= fb[0] + fb[1] * s

    def decompose(
        self, se, dtype: str = "uint8", *, kinds=("major", "fused"),
        small: str = "linear_tree", margin: float = 0.9,
        max_step_wing: int = 7,
    ):
        """Schedule a large-SE primitive as iterated small-SE primitives.

        Returns a list of SE pairs whose per-axis wings sum to the
        original's (so the chain is bit-identical and halo-preserving), or
        ``None`` when one direct pass is modeled cheaper. A candidate must
        beat direct cost by ``margin`` to win — the hysteresis that keeps
        borderline fits from flapping between schedules across refits.
        """
        wing_h, wing_w = (int(se[0]) - 1) // 2, (int(se[1]) - 1) // 2
        if max(wing_h, wing_w) <= 1:
            return None
        direct = self.prim_cost_2d(se, dtype, kinds=kinds, small=small)
        best_cost, best_sched = direct * margin, None
        for step in range(1, max_step_wing + 1):
            k = max(-(-wing_h // step) if wing_h else 0,
                    -(-wing_w // step) if wing_w else 0)
            if k <= 1:
                continue
            sched = []
            for i in range(k):
                hw = wing_h * (i + 1) // k - wing_h * i // k
                ww = wing_w * (i + 1) // k - wing_w * i // k
                sched.append((2 * hw + 1, 2 * ww + 1))
            cost = sum(
                self.prim_cost_2d(s, dtype, kinds=kinds, small=small)
                for s in sched
            )
            if cost < best_cost:
                best_cost, best_sched = cost, sched
        return best_sched

    def matches(self, policy: DispatchPolicy) -> bool:
        """Whether this model's implied thresholds are the policy's — i.e.
        the policy was not hand-tuned away from the measured table."""
        c = self.crossovers
        return (
            int(c.get("w0_major", -1)) == policy.w0_major
            and int(c.get("w0_minor", -1)) == policy.w0_minor
            and int(c.get("w0_fused", -1)) == policy.w0_fused
            and c.get("small_method", policy.small_method) == policy.small_method
        )


# --------------------------------------------------------------- persistence
_TABLE_CACHE: dict[tuple, "CostModel | None"] = {}


def load_measured(path: str | None = None, device: str | None = None):
    """The measured :class:`CostModel` for this device, or ``None``.

    Memoized on (path, mtime, device); a refit (new mtime) reloads, exactly
    like the calibration-scalar cache in ``core.dispatch``.
    """
    path = path or COST_TABLE_FILE
    device = device or device_kind()
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        return None
    key = (path, mtime, device)
    if key not in _TABLE_CACHE:
        try:
            with open(path) as f:
                table = json.load(f)
            per_dev = table.get("devices", {}).get(device)
            _TABLE_CACHE[key] = (
                CostModel.from_table(per_dev) if per_dev else None
            )
        except (OSError, ValueError, KeyError):
            _TABLE_CACHE[key] = None
    return _TABLE_CACHE[key]


def save_measured(
    entries: dict, crossovers: dict, *, op2d: dict | None = None,
    path: str | None = None, device: str | None = None,
) -> str:
    """Merge one device's fitted table into ``cost_table.json``.

    ``entries`` keys are ``(kind, method, dtype)`` tuples (stored as
    ``kind/method/dtype`` strings); other devices' tables are preserved.
    """
    path = path or COST_TABLE_FILE
    device = device or device_kind()
    table: dict = {"version": 1, "devices": {}}
    try:
        with open(path) as f:
            table = json.load(f)
    except (OSError, ValueError):
        pass
    table.setdefault("devices", {})[device] = {
        "entries": {"/".join(k): list(v) for k, v in entries.items()},
        "op2d": {"/".join(k): list(v) for k, v in (op2d or {}).items()},
        "crossovers": dict(crossovers),
    }
    with open(path, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    return path


_MODEL_CACHE: dict = {}


def cost_model_for(policy: DispatchPolicy | None = None) -> CostModel:
    """The model dispatch decisions should consult for this policy.

    The measured table applies only when the policy's thresholds agree with
    it (``DispatchPolicy.calibrated()`` adopts the table's crossovers, so
    calibrated policies match); a hand-tuned policy — tests pinning
    ``w0_fused=5``, A/B harnesses — gets the analytic model built from its
    own scalars, preserving explicit overrides exactly.

    Memoized on (policy, table mtime): ``morph_1d`` calls this twice per
    primitive during tracing, so the steady-state cost must be one dict
    lookup plus a stat — not a fresh analytic-model build per pass (the
    same per-call overhead class the ``calibrated()`` memo removed).
    """
    policy = policy or DispatchPolicy.calibrated()
    try:
        mtime = os.stat(COST_TABLE_FILE).st_mtime_ns
    except OSError:
        mtime = None
    key = (policy, COST_TABLE_FILE, mtime)
    model = _MODEL_CACHE.get(key)
    if model is None:
        measured = load_measured()
        if measured is not None and measured.matches(policy):
            model = measured
        else:
            model = CostModel.analytic(policy)
        _MODEL_CACHE[key] = model
    return model
