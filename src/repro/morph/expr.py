"""Morphology expression IR: the paper's §2 algebra as a small graph.

The paper builds every operator from two primitives — erosion and dilation —
plus arithmetic ("other morphological operations ... can be expressed via
erosion, dilation and arithmetical operations"). This module makes that
algebra a first-class, hashable value:

* :class:`StructuringElement` — a flat rectangular SE with odd extents;
* primitive nodes :class:`Erode` / :class:`Dilate`;
* arithmetic combinators :class:`Sub` (integer widening centralized in
  ``core.types.widened_sub``), :class:`Min`, :class:`Max`, :class:`Clip`,
  :class:`Mean` (integer-safe midpoint), :class:`Cast`;
* :class:`BoundedIter` — bounded (optionally until-stable) iteration for
  geodesic / reconstruction chains, the node that makes iterative operators
  servable;
* :class:`Var` leaves, so multi-input operators (marker/mask) are
  expressible; the canonical single input is :data:`X` (``Var("x")``).

Every node is a frozen dataclass: expressions compare structurally, hash
stably within a process, and can key executable caches. Lowering lives in
``lower_xla`` / ``lower_kernel``; serving compilation in ``plan_compile``;
graph analyses (halo, free vars, masking requirements) in ``analyze``.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.types import check_window


@dataclasses.dataclass(frozen=True)
class StructuringElement:
    """A flat w_h x w_w rectangle, odd extents, anchor at center."""

    h: int
    w: int

    def __post_init__(self):
        object.__setattr__(self, "h", check_window(self.h))
        object.__setattr__(self, "w", check_window(self.w))

    @classmethod
    def of(cls, se) -> "StructuringElement":
        """Coerce ``(h, w)`` tuples, bare ints (square SE) or SEs."""
        if isinstance(se, StructuringElement):
            return se
        if isinstance(se, int):
            return cls(se, se)
        h, w = se
        return cls(int(h), int(w))

    @property
    def pair(self) -> tuple[int, int]:
        return (self.h, self.w)

    @property
    def wings(self) -> tuple[int, int]:
        return ((self.h - 1) // 2, (self.w - 1) // 2)


class MorphExpr:
    """Base class for expression nodes; carries the fluent builder API."""

    # -------------------------------------------------------- primitives
    def erode(self, se=(3, 3)) -> "Erode":
        return Erode(self, StructuringElement.of(se))

    def dilate(self, se=(3, 3)) -> "Dilate":
        return Dilate(self, StructuringElement.of(se))

    # ------------------------------------------------- derived operators
    def opening(self, se=(3, 3)) -> "MorphExpr":
        return self.erode(se).dilate(se)

    def closing(self, se=(3, 3)) -> "MorphExpr":
        return self.dilate(se).erode(se)

    def gradient(self, se=(3, 3)) -> "Sub":
        """Dilate - erode over a *shared* child: lowering recognizes this
        shape and can emit the fused gradient kernel."""
        return Sub(self.dilate(se), self.erode(se))

    def tophat(self, se=(3, 3)) -> "Sub":
        return Sub(self, self.opening(se))

    def blackhat(self, se=(3, 3)) -> "Sub":
        return Sub(self.closing(se), self)

    # ------------------------------------------------------- arithmetic
    def __sub__(self, other: "MorphExpr") -> "Sub":
        return Sub(self, other)

    def minimum(self, other: "MorphExpr") -> "Min":
        return Min(self, other)

    def maximum(self, other: "MorphExpr") -> "Max":
        return Max(self, other)

    def clip(self, lo=None, hi=None) -> "Clip":
        return Clip(self, lo, hi)

    def astype(self, dtype) -> "Cast":
        return Cast(self, dtype)


def _check_expr(e, what: str) -> None:
    if not isinstance(e, MorphExpr):
        raise TypeError(f"{what} must be a MorphExpr, got {type(e).__name__}")


@dataclasses.dataclass(frozen=True)
class Var(MorphExpr):
    """An expression input. Single-input operators use ``X = Var('x')``."""

    name: str = "x"


@dataclasses.dataclass(frozen=True)
class Erode(MorphExpr):
    child: MorphExpr
    se: StructuringElement

    def __post_init__(self):
        _check_expr(self.child, "Erode.child")
        object.__setattr__(self, "se", StructuringElement.of(self.se))


@dataclasses.dataclass(frozen=True)
class Dilate(MorphExpr):
    child: MorphExpr
    se: StructuringElement

    def __post_init__(self):
        _check_expr(self.child, "Dilate.child")
        object.__setattr__(self, "se", StructuringElement.of(self.se))


@dataclasses.dataclass(frozen=True)
class Gradient(MorphExpr):
    """First-class morphological gradient: ``dilate(c, se) - erode(c, se)``
    over one shared child, in the centralized widened dtype.

    The builder API still writes gradients as ``Sub(Dilate, Erode)`` (the
    paper's algebra); the optimizer's canonicalization pass
    (``morph.opt.passes.fuse_gradients``) rewrites that pattern into this
    node when fusing cannot lose sharing, which is what lets the kernel
    lowering emit the single-launch fused gradient kernel without the old
    ad-hoc evaluator hook. Under masked (serving) evaluation the node
    expands back into its two primitives so each gets its own neutral.
    """

    child: MorphExpr
    se: StructuringElement

    def __post_init__(self):
        _check_expr(self.child, "Gradient.child")
        object.__setattr__(self, "se", StructuringElement.of(self.se))


@dataclasses.dataclass(frozen=True)
class Sub(MorphExpr):
    """``a - b`` in the centralized widened dtype (core.types.widened_sub)."""

    a: MorphExpr
    b: MorphExpr

    def __post_init__(self):
        _check_expr(self.a, "Sub.a")
        _check_expr(self.b, "Sub.b")


@dataclasses.dataclass(frozen=True)
class Min(MorphExpr):
    a: MorphExpr
    b: MorphExpr

    def __post_init__(self):
        _check_expr(self.a, "Min.a")
        _check_expr(self.b, "Min.b")


@dataclasses.dataclass(frozen=True)
class Max(MorphExpr):
    a: MorphExpr
    b: MorphExpr

    def __post_init__(self):
        _check_expr(self.a, "Max.a")
        _check_expr(self.b, "Max.b")


@dataclasses.dataclass(frozen=True)
class Mean(MorphExpr):
    """Integer-safe midpoint ``(a + b) // 2`` (the OCCO combiner); computed
    widened, returned in the inputs' common dtype."""

    a: MorphExpr
    b: MorphExpr

    def __post_init__(self):
        _check_expr(self.a, "Mean.a")
        _check_expr(self.b, "Mean.b")


@dataclasses.dataclass(frozen=True)
class Clip(MorphExpr):
    child: MorphExpr
    lo: float | int | None = None
    hi: float | int | None = None

    def __post_init__(self):
        _check_expr(self.child, "Clip.child")


@dataclasses.dataclass(frozen=True)
class Cast(MorphExpr):
    child: MorphExpr
    dtype: str = "uint8"

    def __post_init__(self):
        _check_expr(self.child, "Cast.child")
        object.__setattr__(self, "dtype", jnp.dtype(self.dtype).name)


_STATE = "__iter__"


@dataclasses.dataclass(frozen=True)
class BoundedIter(MorphExpr):
    """Apply ``body`` to ``init`` at most ``iters`` times.

    ``body`` references the loop-carried value as ``Var(var)``; any other
    free variables resolve against the enclosing environment, so geodesic
    chains keep their mask as a plain input. ``until_stable=True`` adds the
    classic convergence early-exit (a ``while_loop`` still bounded by
    ``iters`` — the form core/derived.py reconstruction uses);
    ``until_stable=False`` lowers to a fixed ``fori_loop``, the
    fixed-trace shape the serving engine wants.
    """

    init: MorphExpr
    body: MorphExpr
    iters: int
    var: str = _STATE
    until_stable: bool = True

    def __post_init__(self):
        _check_expr(self.init, "BoundedIter.init")
        _check_expr(self.body, "BoundedIter.body")
        if int(self.iters) < 1:
            raise ValueError(f"BoundedIter.iters must be >= 1, got {self.iters}")
        object.__setattr__(self, "iters", int(self.iters))


X = Var("x")


# ----------------------------------------------------------------- combinators
def geodesic_dilate_expr(marker: MorphExpr, mask: MorphExpr, se=(3, 3)) -> MorphExpr:
    """One geodesic step: dilate the marker, clamp under the mask."""
    return Min(Dilate(marker, StructuringElement.of(se)), mask)


def geodesic_erode_expr(marker: MorphExpr, mask: MorphExpr, se=(3, 3)) -> MorphExpr:
    return Max(Erode(marker, StructuringElement.of(se)), mask)


def reconstruct_by_dilation_expr(
    marker: MorphExpr, mask: MorphExpr, se=(3, 3), *,
    iters: int = 256, until_stable: bool = True,
) -> BoundedIter:
    """Morphological reconstruction by dilation as a bounded-iteration graph."""
    return BoundedIter(
        init=Min(marker, mask),
        body=geodesic_dilate_expr(Var(_STATE), mask, se),
        iters=iters,
        until_stable=until_stable,
    )


def reconstruct_by_erosion_expr(
    marker: MorphExpr, mask: MorphExpr, se=(3, 3), *,
    iters: int = 256, until_stable: bool = True,
) -> BoundedIter:
    return BoundedIter(
        init=Max(marker, mask),
        body=geodesic_erode_expr(Var(_STATE), mask, se),
        iters=iters,
        until_stable=until_stable,
    )


def occo_expr(x: MorphExpr, se=(3, 3)) -> MorphExpr:
    """OCCO smoothing: midpoint of open-close and close-open."""
    return Mean(x.opening(se).closing(se), x.closing(se).opening(se))
