"""Shared evaluator for the morphology IR.

``evaluate`` walks an expression once (shared subgraphs memoized, so
``gradient``'s common child is computed a single time) and is parameterized
by three hooks that the lowering passes and the serving executor inject:

* ``prim(op, x, se)`` — how Erode/Dilate run (separable jnp passes for
  ``lower_xla``, the fused Pallas megakernel for ``lower_kernel``, masked
  variants for serving). ``op`` is a ``core.types.MorphOp``.
* ``pre_prim(x, op)`` — optional transform of every primitive's input; the
  serving executor uses it to overwrite out-of-rect data with the op's own
  neutral element. Because it runs per *node*, a graph that needs both
  neutrals on one value (gradient) just works — no special cases.
* ``gradient_prim(x, se)`` — optional pattern hook: ``Sub(Dilate(c, se),
  Erode(c, se))`` with a shared child is recognized and handed here, which
  is how ``lower_kernel`` emits the single-launch fused gradient kernel.
  Unused when masking is active (the two branches need different neutrals
  on the same input, so they cannot share one kernel input).

Arithmetic nodes centralize the integer-widening rule via
``core.types.widened_sub`` — the one copy the whole repo now shares.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import MAX, MIN, widen_dtype, widened_sub
from repro.morph.expr import (
    BoundedIter,
    Cast,
    Clip,
    Dilate,
    Erode,
    Gradient,
    Max,
    Mean,
    Min,
    MorphExpr,
    Sub,
    Var,
)


def is_gradient(node: MorphExpr) -> bool:
    """``Sub(Dilate(c, se), Erode(c, se))`` over a shared child and SE."""
    return (
        isinstance(node, Sub)
        and isinstance(node.a, Dilate)
        and isinstance(node.b, Erode)
        and node.a.se == node.b.se
        and node.a.child == node.b.child
    )


def evaluate(
    expr: MorphExpr,
    env: dict,
    *,
    prim,
    pre_prim=None,
    gradient_prim=None,
    memo: dict | None = None,
    stable_reduce=None,
    iter_report=None,
):
    """Evaluate ``expr`` with inputs ``env`` (name -> array).

    Pass the same ``memo`` dict across several ``evaluate`` calls to share
    work between a plan's named outputs (later outputs typically extend the
    chain that produced earlier ones).

    ``stable_reduce`` post-processes every ``BoundedIter`` "changed" flag
    (a bool scalar). Mesh-sharded lowerings must make convergence *global*
    (``lax.psum`` over the mesh axes): a shard exiting its loop early while
    neighbors still iterate would desynchronize the collectives inside the
    body. ``iter_report(used, budget)`` is called once per top-level
    ``BoundedIter`` with the traced iteration count actually executed and
    the static budget — how serving surfaces convergence depth in
    ``stats()``. Loops nested inside another loop's body do not report
    (their count is a tracer of the outer loop's scope).
    """
    memo = {} if memo is None else memo

    def ev(node: MorphExpr):
        key = id(node)
        if key not in memo:
            memo[key] = _eval(node)
        return memo[key]

    def run_prim(op, node):
        x = ev(node.child)
        if pre_prim is not None:
            x = pre_prim(x, op)
        return prim(op, x, node.se.pair)

    def _eval(node: MorphExpr):
        if isinstance(node, Var):
            try:
                return env[node.name]
            except KeyError:
                raise KeyError(
                    f"expression input {node.name!r} not provided; "
                    f"have {sorted(env)}"
                ) from None
        if isinstance(node, Erode):
            return run_prim(MIN, node)
        if isinstance(node, Dilate):
            return run_prim(MAX, node)
        if isinstance(node, Gradient):
            # First-class gradient (produced by the optimizer's canonical
            # pattern pass). With a gradient hook and no masking it is one
            # fused launch; under masked evaluation it expands to its two
            # primitives so each pass gets its own neutral — exactly the
            # semantics of the Sub(Dilate, Erode) form it replaced.
            x = ev(node.child)
            se = node.se.pair
            if gradient_prim is not None and pre_prim is None:
                return gradient_prim(x, se)
            xd = pre_prim(x, MAX) if pre_prim is not None else x
            xe = pre_prim(x, MIN) if pre_prim is not None else x
            return widened_sub(prim(MAX, xd, se), prim(MIN, xe, se))
        if isinstance(node, Sub):
            if gradient_prim is not None and pre_prim is None and is_gradient(node):
                return gradient_prim(ev(node.a.child), node.a.se.pair)
            return widened_sub(ev(node.a), ev(node.b))
        if isinstance(node, Min):
            return jnp.minimum(ev(node.a), ev(node.b))
        if isinstance(node, Max):
            return jnp.maximum(ev(node.a), ev(node.b))
        if isinstance(node, Mean):
            a, b = ev(node.a), ev(node.b)
            out_dt = jnp.result_type(a, b)
            if jnp.issubdtype(out_dt, jnp.integer):
                wide = widen_dtype(out_dt)
                return ((a.astype(wide) + b.astype(wide)) // 2).astype(out_dt)
            return ((a + b) / 2).astype(out_dt)
        if isinstance(node, Clip):
            return jnp.clip(ev(node.child), node.lo, node.hi)
        if isinstance(node, Cast):
            return ev(node.child).astype(node.dtype)
        if isinstance(node, BoundedIter):
            return _bounded_iter(node)
        raise TypeError(f"unknown expression node {type(node).__name__}")

    def _bounded_iter(node: BoundedIter):
        init = ev(node.init)

        def step(cur):
            sub_env = dict(env)
            sub_env[node.var] = cur
            # fresh memo: the loop body re-traces per lax iteration variable.
            # iter_report stays top-level only (a nested loop's count would
            # be a tracer of this body's scope); stable_reduce propagates —
            # nested sharded loops need global convergence too.
            return evaluate(
                node.body, sub_env,
                prim=prim, pre_prim=pre_prim, gradient_prim=gradient_prim,
                stable_reduce=stable_reduce,
            )

        def changed(prev, cur):
            c = jnp.any(prev != cur)
            return stable_reduce(c) if stable_reduce is not None else c

        if not node.until_stable:
            # Fixed-trace serving form: still a fori_loop over the full
            # budget (the executable's shape never depends on the data), but
            # the carry holds a convergence flag and the body is predicated
            # on it — a converged reconstruction stops paying for its
            # remaining budget. Bit-exact with the unpredicated loop: `done`
            # only sets once step(cur) == cur, and a deterministic step is
            # constant on its own fixpoint.
            def body(_, state):
                cur, done, used = state

                def advance(st):
                    c, _, u = st
                    nxt = step(c)
                    return nxt, jnp.logical_not(changed(c, nxt)), u + 1

                return jax.lax.cond(done, lambda st: st, advance, state)

            out, _, used = jax.lax.fori_loop(
                0, node.iters, body,
                (init, jnp.bool_(False), jnp.int32(0)),
            )
            if iter_report is not None:
                iter_report(used, node.iters)
            return out

        # until-stable: the exact loop shape core/derived.py reconstruction
        # has always used, so IR-lowered reconstruction is bit-identical.
        def cond(state):
            prev, cur, i = state
            return jnp.logical_and(i < node.iters, changed(prev, cur))

        def body(state):
            _, cur, i = state
            return cur, step(cur), i + 1

        _, out, used = jax.lax.while_loop(
            cond, body, (init, step(init), jnp.int32(0))
        )
        if iter_report is not None:
            # the loop state is seeded with one step(init) application, so
            # steps computed = loop trips + 1 and the cap is iters + 1 —
            # the same convention analyze.halo uses for this form
            iter_report(used + 1, node.iters + 1)
        return out

    return ev(expr)


def make_lowering(outputs, *, prim, pre_prim=None, gradient_prim=None):
    """Shared entry-point plumbing for the lowering passes.

    ``outputs`` is a single expression or a ``{name: expr}`` mapping; the
    returned ``fn(x=None, **vars)`` evaluates all outputs over one shared
    memo (named outputs typically extend each other's chains) and unwraps
    the single-expression case to a bare array.
    """
    single = isinstance(outputs, MorphExpr)
    outs = {"out": outputs} if single else dict(outputs)

    def fn(x=None, **env):
        if x is not None:
            env.setdefault("x", x)
        memo: dict = {}
        res = {
            k: evaluate(
                e, env,
                prim=prim, pre_prim=pre_prim, gradient_prim=gradient_prim,
                memo=memo,
            )
            for k, e in outs.items()
        }
        return res["out"] if single else res

    return fn
