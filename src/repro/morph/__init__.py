"""Unified morphology expression API — one graph IR from core ops to fused
kernels and serving plans.

Build an expression once, run it anywhere:

    from repro.morph import X, lower_xla, lower_kernel, to_plan

    expr = X.opening((3, 3)).closing((5, 5)).gradient((3, 3))
    y = lower_xla(expr)(img)                   # pure-XLA separable passes
    y = lower_kernel(expr)(img)                # fused Pallas megakernel
    y = lower_rle(expr)(mask)                  # run-domain (bool-only graphs)
    plan = to_plan(expr, name="edges")         # servable via MorphService

``core.morphology``, ``core.derived``, the five 2-D kernel entry points and
the serving plans are all thin wrappers over this package; ``analyze``
derives halo and neutral-masking requirements from the graph.
"""
from repro.morph.analyze import free_vars, halo, masking_requirements, node_count
from repro.morph.expr import (
    BoundedIter,
    Cast,
    Clip,
    Dilate,
    Erode,
    Gradient,
    Max,
    Mean,
    Min,
    MorphExpr,
    StructuringElement,
    Sub,
    Var,
    X,
    geodesic_dilate_expr,
    geodesic_erode_expr,
    occo_expr,
    reconstruct_by_dilation_expr,
    reconstruct_by_erosion_expr,
)
from repro.morph.interp import evaluate, is_gradient
from repro.morph.lower_kernel import lower_kernel
from repro.morph.lower_xla import lower_xla
from repro.morph.opt import CostModel, cost_model_for, optimize, prim_count
from repro.morph.plan_compile import op_expr, steps_to_outputs, to_plan


def __getattr__(name):
    # lazy: repro.rle builds on this package, so an eager import would cycle
    if name == "lower_rle":
        from repro.rle import lower_rle

        return lower_rle
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "BoundedIter",
    "Cast",
    "Clip",
    "CostModel",
    "Dilate",
    "Erode",
    "Gradient",
    "Max",
    "Mean",
    "Min",
    "MorphExpr",
    "StructuringElement",
    "Sub",
    "Var",
    "X",
    "geodesic_dilate_expr",
    "geodesic_erode_expr",
    "occo_expr",
    "reconstruct_by_dilation_expr",
    "reconstruct_by_erosion_expr",
    "free_vars",
    "halo",
    "masking_requirements",
    "node_count",
    "evaluate",
    "is_gradient",
    "lower_kernel",
    "lower_rle",
    "lower_xla",
    "cost_model_for",
    "optimize",
    "prim_count",
    "op_expr",
    "steps_to_outputs",
    "to_plan",
]
