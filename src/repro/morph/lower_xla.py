"""Lower a morphology expression to pure-XLA separable passes.

Every Erode/Dilate node becomes the paper's two 1-D hybrid passes
(``core.dispatch.morph_1d`` — sublane axis first, then lane axis), so an
IR-lowered operator is the *same computation* as the legacy
``core.morphology`` functions, which are now thin wrappers over this pass.

``lower_xla`` accepts a single expression or a ``{name: expr}`` mapping
(named outputs share one memoized walk) and returns a plain function —
callers jit. Works for any ``(..., H, W)`` leading-batch layout, exactly
like the jnp primitives underneath.

Graphs are optimized first (``repro.morph.opt.optimize`` at
``policy.opt_level``; bit-exact by contract, opt out with
``DispatchPolicy(opt_level=0)``), so shared subgraphs are computed once and
cost-model-approved rewrites apply before any tracing.
"""
from __future__ import annotations

from repro.core.dispatch import DispatchPolicy, morph_1d
from repro.morph.interp import make_lowering


def lower_xla(outputs, *, policy: DispatchPolicy | None = None):
    """``expr | {name: expr}`` -> ``fn(x=None, **vars) -> array | {name: array}``."""
    policy = policy or DispatchPolicy.calibrated()
    from repro.morph.opt import optimize

    outputs = optimize(outputs, policy=policy, kinds=("major", "minor"))

    def prim(op, x, se):
        y = morph_1d(x, se[0], axis=-2, op=op, policy=policy)
        return morph_1d(y, se[1], axis=-1, op=op, policy=policy)

    return make_lowering(outputs, prim=prim)
