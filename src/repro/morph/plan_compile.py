"""Compile morphology expressions into serving plans.

The serving engine's unit of work is a :class:`repro.serve.morph.plans.Plan`
— named output expressions over the single input ``Var("x")``, with halo and
per-stage masking derived by graph traversal (``analyze``). This module owns
the two construction paths:

* :func:`to_plan` — any expression (or ``{name: expr}`` mapping) becomes a
  plan; this is how iterative operators (``reconstruct_by_dilation_expr``
  with bounded iterations, OCCO) reach :class:`MorphService`.
* :func:`steps_to_outputs` — the legacy ``Step`` chain (string op + SE +
  optional save/cast) re-expressed as IR outputs, so existing plans keep
  their exact semantics (the running value feeds the next step *un-cast*;
  ``astype`` applies only to the saved output).

The plan dataclass itself stays in ``serve/morph/plans.py`` (the IR layer
does not import the serving stack); ``to_plan`` imports it lazily.
"""
from __future__ import annotations

from repro.morph.analyze import free_vars
from repro.morph.expr import Cast, MorphExpr, StructuringElement, X

_OP_BUILDERS = {
    "erode": lambda c, se: c.erode(se),
    "dilate": lambda c, se: c.dilate(se),
    "opening": lambda c, se: c.opening(se),
    "closing": lambda c, se: c.closing(se),
    "gradient": lambda c, se: c.gradient(se),
    "tophat": lambda c, se: c.tophat(se),
    "blackhat": lambda c, se: c.blackhat(se),
}


def op_expr(op: str, se, child: MorphExpr = X) -> MorphExpr:
    """Named-operator shorthand -> IR (the string surface of plans/steps)."""
    try:
        builder = _OP_BUILDERS[op]
    except KeyError:
        raise ValueError(
            f"unknown morphology op {op!r}; expected one of {sorted(_OP_BUILDERS)}"
        ) from None
    return builder(child, StructuringElement.of(se))


def steps_to_outputs(steps) -> tuple[tuple[str, MorphExpr], ...]:
    """Legacy Step chain -> ordered ``(name, expr)`` outputs.

    Mirrors the historical executor: each step transforms the running value;
    ``save_as`` tags an output (``astype`` casting only the saved copy); a
    plan with no tagged outputs returns its final value as ``"out"``.
    """
    cur: MorphExpr = X
    outs: list[tuple[str, MorphExpr]] = []
    for s in steps:
        cur = op_expr(s.op, s.se, cur)
        if s.save_as:
            outs.append((s.save_as, Cast(cur, s.astype) if s.astype else cur))
    if not outs:
        outs.append(("out", cur))
    return tuple(outs)


def _normalize_outputs(outputs) -> tuple[tuple[str, MorphExpr], ...]:
    if isinstance(outputs, MorphExpr):
        items: tuple = (("out", outputs),)
    else:
        items = tuple(dict(outputs).items())
    if not items:
        raise ValueError("a plan needs at least one output expression")
    for name, e in items:
        if not isinstance(e, MorphExpr):
            raise TypeError(f"output {name!r} is not a MorphExpr")
        extra = free_vars(e) - {"x"}
        if extra:
            raise ValueError(
                f"servable expressions take the single input Var('x'); output "
                f"{name!r} also reads {sorted(extra)}"
            )
    return items


def to_plan(
    outputs,
    name: str | None = None,
    *,
    policy=None,
    keep=None,
):
    """Compile ``expr | {name: expr}`` into a serving ``Plan``.

    Outputs must be closed over the single input ``Var('x')`` (that is what
    the service feeds); halo and masking needs come from graph traversal,
    so any composition — including ``BoundedIter`` chains — is servable
    without per-op tables.

    Graphs are optimized first (``repro.morph.opt.optimize`` at
    ``policy.opt_level``; opt out via ``DispatchPolicy(opt_level=0)``):
    shared erosions across named outputs compute once, nested same-op
    primitives fold, the gradient pattern canonicalizes, and ``keep=``
    drops outputs the caller never reads — so served plans get shorter
    pass lists and tighter derived halos for free, while staying bit-exact
    with the raw graph after cropping.
    """
    from repro.morph.opt import optimize
    from repro.serve.morph.plans import Plan

    outputs = optimize(outputs, policy=policy, keep=keep)
    items = _normalize_outputs(outputs)
    if name is None:
        name = f"expr_{abs(hash(items)) % 16**10:010x}"
    return Plan(name, steps=(), outputs=items)


def is_gradient_expr(e: MorphExpr) -> bool:
    """Re-export of the evaluator's gradient pattern (for introspection)."""
    from repro.morph.interp import is_gradient

    return is_gradient(e)


__all__ = [
    "op_expr",
    "steps_to_outputs",
    "to_plan",
    "is_gradient_expr",
]
