"""Graph analyses over the morphology IR.

These traversals replace hand-maintained per-op tables on the serving side:

* :func:`halo` — the per-axis contamination radius of an expression,
  derived structurally (each sequential Erode/Dilate adds its SE wings;
  parallel branches take the max; bounded iteration multiplies the body's
  per-iteration growth). The old serving rule ("opening/closing count
  twice, gradient once") falls out as a theorem instead of a table.
* :func:`masking_requirements` — which neutral element each primitive pass
  needs on out-of-image data, in evaluation order. A composed graph can
  need *both* neutrals at the same depth (gradient); deriving this from the
  graph is what removed the executor's special-cased dual-neutral step.
* :func:`free_vars` / :func:`node_count` — inputs and (deduplicated)
  graph size.
"""
from __future__ import annotations

from repro.morph.expr import (
    BoundedIter,
    Cast,
    Clip,
    Dilate,
    Erode,
    Gradient,
    Max,
    Mean,
    Min,
    MorphExpr,
    Sub,
    Var,
)

_BINARY = (Sub, Min, Max, Mean)
_UNARY = (Clip, Cast)
# Gradient is a primitive for analysis purposes: one child, SE wings of
# contamination, and (being dilate - erode over one value) both neutrals.
_PRIMS = (Erode, Dilate, Gradient)


def halo(expr: MorphExpr) -> tuple[int, int]:
    """Per-axis radius outside a region that can influence its values.

    ``Var`` leaves are 0; Erode/Dilate add their wings to the child's halo
    (sequential contamination marches one wing per pass); elementwise
    combinators run their branches in parallel, so the max dominates;
    ``BoundedIter`` contributes ``halo(init) + iters * halo(body)`` — the
    body's growth accrues once per iteration, and any direct reference to an
    outer variable inside the body is covered by the same bound.
    """
    memo: dict[int, tuple[int, int]] = {}

    def go(e: MorphExpr) -> tuple[int, int]:
        key = id(e)
        if key in memo:
            return memo[key]
        if isinstance(e, Var):
            out = (0, 0)
        elif isinstance(e, _PRIMS):
            ch, cw = go(e.child)
            wh, ww = e.se.wings
            out = (ch + wh, cw + ww)
        elif isinstance(e, _BINARY):
            ah, aw = go(e.a)
            bh, bw = go(e.b)
            out = (max(ah, bh), max(aw, bw))
        elif isinstance(e, _UNARY):
            out = go(e.child)
        elif isinstance(e, BoundedIter):
            ih, iw = go(e.init)
            bh, bw = go(e.body)
            # until_stable seeds the loop state with one body application
            # before the bounded loop runs, so it can apply iters + 1 total.
            n = e.iters + 1 if e.until_stable else e.iters
            out = (ih + n * bh, iw + n * bw)
        else:
            raise TypeError(f"unknown expression node {type(e).__name__}")
        memo[key] = out
        return out

    return go(expr)


def masking_requirements(expr: MorphExpr) -> tuple[tuple[str, tuple[int, int]], ...]:
    """``(op_name, se)`` per primitive pass, in evaluation order.

    ``op_name`` is ``"min"`` (erosion: out-of-image data must read as the
    dtype max / +inf) or ``"max"`` (dilation: dtype min / -inf). Serving
    executors mask the pad region with exactly these neutrals before each
    pass; ``BoundedIter`` bodies repeat per iteration (reported once).
    """
    seen: set[int] = set()
    out: list[tuple[str, tuple[int, int]]] = []

    def go(e: MorphExpr) -> None:
        if id(e) in seen:
            return
        seen.add(id(e))
        if isinstance(e, Var):
            return
        if isinstance(e, _PRIMS):
            go(e.child)
            if isinstance(e, Gradient):  # dilate - erode: both neutrals
                out.append(("max", e.se.pair))
                out.append(("min", e.se.pair))
            else:
                out.append(("min" if isinstance(e, Erode) else "max", e.se.pair))
        elif isinstance(e, _BINARY):
            go(e.a)
            go(e.b)
        elif isinstance(e, _UNARY):
            go(e.child)
        elif isinstance(e, BoundedIter):
            go(e.init)
            go(e.body)
        else:
            raise TypeError(f"unknown expression node {type(e).__name__}")

    go(expr)
    return tuple(out)


def free_vars(expr: MorphExpr) -> frozenset[str]:
    """Input names the expression reads (loop-state vars are bound)."""
    seen: set[tuple[int, frozenset[str]]] = set()
    names: set[str] = set()

    def go(e: MorphExpr, bound: frozenset[str]) -> None:
        if (id(e), bound) in seen:
            return
        seen.add((id(e), bound))
        if isinstance(e, Var):
            if e.name not in bound:
                names.add(e.name)
        elif isinstance(e, _PRIMS + _UNARY):
            go(e.child, bound)
        elif isinstance(e, _BINARY):
            go(e.a, bound)
            go(e.b, bound)
        elif isinstance(e, BoundedIter):
            go(e.init, bound)
            go(e.body, bound | {e.var})
        else:
            raise TypeError(f"unknown expression node {type(e).__name__}")

    go(expr, frozenset())
    return frozenset(names)


def node_count(expr: MorphExpr) -> int:
    """Number of distinct nodes (shared subgraphs counted once)."""
    seen: set[int] = set()

    def go(e: MorphExpr) -> None:
        if id(e) in seen:
            return
        seen.add(id(e))
        if isinstance(e, _PRIMS + _UNARY):
            go(e.child)
        elif isinstance(e, _BINARY):
            go(e.a)
            go(e.b)
        elif isinstance(e, BoundedIter):
            go(e.init)
            go(e.body)

    go(expr)
    return len(seen)
