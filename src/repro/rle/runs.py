"""Run-domain morphology: vectorized interval arithmetic on host buffers.

Every operator here is O(runs) numpy (plus an O(runs log runs) sort inside
:func:`transpose`) — no per-pixel work anywhere, which is the entire point
of the backend (arXiv 1504.01052). The separable structure mirrors the
dense path exactly:

* **horizontal pass** — per-run coordinate arithmetic: erosion shrinks each
  run by the SE wing (runs shorter than the window vanish), dilation grows
  and merges. Out-of-image data carries each op's own neutral element, the
  same virtual border the dense kernels pad with: erosion treats runs
  touching a side as extending past it (neutral True), dilation clips to
  the image (neutral False).
* **vertical pass** — the transpose trick the fused kernel uses in VMEM,
  lifted to the run representation: :func:`transpose` re-expresses row runs
  as column runs *without a dense round trip*, so a vertical pass is
  transpose -> horizontal pass -> transpose.

The transpose is interval set algebra: a cell starts a vertical run iff its
row covers it and the row above does not, so the vertical-run start cells
are the per-row set differences ``row_p \\ row_{p-1}`` (ends symmetric with
the row below). Differences for all rows at once fall out of one event
sweep: every run emits +/-1 coverage edges keyed by (pair, position), a
global cumsum recovers per-pair coverage (each pair's edges sum to zero, so
the running sum self-resets at pair boundaries), and the difference is the
coverage == 1 segments. Start and end cells, each sorted by (column, row),
then zip into the transposed runs.
"""
from __future__ import annotations

import numpy as np

from repro.rle.image import RLEImage, _I32, decode, encode


def _host(im: RLEImage) -> RLEImage:
    return im if isinstance(im.rows, np.ndarray) and int(im.n) == im.capacity else im.to_host()


def _make(rows, starts, ends, shape, overflow) -> RLEImage:
    return RLEImage(
        rows=rows.astype(_I32, copy=False),
        starts=starts.astype(_I32, copy=False),
        ends=ends.astype(_I32, copy=False),
        n=int(rows.size),
        shape=shape,
        overflow=overflow,
    )


def erode_h(im: RLEImage, window: int) -> RLEImage:
    """Horizontal erosion: shrink every run by the wing on both sides.

    Runs touching an image border virtually extend past it (the erosion
    neutral is True out of image); runs shorter than the window die. Never
    merges, never reorders — pure elementwise coordinate arithmetic.
    """
    im = _host(im)
    wing = (int(window) - 1) // 2
    if wing == 0 or im.n == 0:
        return im
    _, w = im.shape
    sv = np.where(im.starts == 0, -wing, im.starts)
    ev = np.where(im.ends == w, w + wing, im.ends)
    ns, ne = sv + wing, ev - wing
    keep = ne > ns
    return _make(im.rows[keep], ns[keep], ne[keep], im.shape, im.overflow)


def dilate_h(im: RLEImage, window: int) -> RLEImage:
    """Horizontal dilation: grow every run by the wing, clip to the image
    (dilation neutral is False out of image), merge overlapping/adjacent
    runs of a row. Grown ends stay nondecreasing within a row, so each
    merged group's extent is (first start, last end)."""
    im = _host(im)
    wing = (int(window) - 1) // 2
    if wing == 0 or im.n == 0:
        return im
    _, w = im.shape
    ns = np.maximum(im.starts - wing, 0)
    ne = np.minimum(im.ends + wing, w)
    head = np.empty(im.n, dtype=bool)
    head[0] = True
    head[1:] = (im.rows[1:] != im.rows[:-1]) | (ns[1:] > ne[:-1])
    hi = np.flatnonzero(head)
    last = np.append(hi[1:], im.n) - 1
    return _make(im.rows[hi], ns[hi], ne[last], im.shape, im.overflow)


def _diff_rows(im: RLEImage, d: int):
    """Set-difference intervals ``row_p \\ row_{p+d}`` for every row ``p``,
    via one coverage-event sweep (module docstring). Returns sorted
    ``(pair, start, end)`` interval arrays.

    Events sort on the single combined key ``pair * (W + 1) + pos`` — one
    unstable int64 argsort, several times faster than a two-key lexsort,
    and safe: order within an equal (pair, pos) event group only permutes
    partial sums at indices the ``pos`` strict-increase test already
    discards, while every group-final sum is order-independent.
    """
    h, w = im.shape
    pair = np.concatenate([im.rows, im.rows, im.rows - d, im.rows - d])
    pos = np.concatenate([im.starts, im.ends, im.starts, im.ends])
    wts = np.concatenate([
        np.ones(im.n, _I32), -np.ones(im.n, _I32),
        -np.ones(im.n, _I32), np.ones(im.n, _I32),
    ])
    ok = (pair >= 0) & (pair < h)
    pair, pos, wts = pair[ok], pos[ok], wts[ok]
    order = np.argsort(pair.astype(np.int64) * (w + 1) + pos)
    pair, pos, wts = pair[order], pos[order], wts[order]
    cov = np.cumsum(wts)
    keep = (cov[:-1] == 1) & (pair[:-1] == pair[1:]) & (pos[1:] > pos[:-1])
    return pair[:-1][keep], pos[:-1][keep], pos[1:][keep]


def _cells(rows, starts, ends):
    """Expand intervals into (row, col) cell arrays — O(cells emitted),
    which for the transpose differences is the vertical-run count."""
    lens = ends - starts
    total = int(lens.sum())
    first = np.cumsum(lens) - lens
    reps = np.repeat(np.arange(rows.size), lens)
    offset = np.arange(total, dtype=np.int64) - first[reps]
    return rows[reps], starts[reps] + offset.astype(_I32)


def transpose(im: RLEImage) -> RLEImage:
    """Column runs of the same image: ``(H, W)`` row-RLE -> ``(W, H)``
    row-RLE of the transposed mask, entirely in the run domain.

    A vertical run per (column, consecutive-rows) segment: its start cell
    is covered by its row but not the row above, its end cell by its row
    but not the row below; the k-th start and k-th end of a column bound
    the k-th run. Cost: O(runs_in + runs_out) with one lexsort each side.
    """
    im = _host(im)
    h, w = im.shape
    if im.n == 0:
        return _make(im.rows, im.starts, im.ends, (w, h), im.overflow)
    # The event sweep is O(r log r) in the *vertical* run count r, which for
    # thin horizontal strokes approaches the foreground pixel count. Past
    # the point where r's sorts cost more than an O(pixels) elementwise
    # sweep, a dense round trip is the faster transpose; foreground size is
    # an O(n) upper-bound proxy for r, and pixels/16 lands near the
    # measured numpy crossover (sort throughput vs boolean-pass throughput).
    fg = int((im.ends - im.starts).sum())
    if fg * 16 > h * w:
        out = encode(np.ascontiguousarray(decode(im).T))
        return _make(out.rows, out.starts, out.ends, (w, h), im.overflow)
    s_rows, s_cols = _cells(*_diff_rows(im, -1))
    e_rows, e_cols = _cells(*_diff_rows(im, +1))
    so = np.argsort(s_cols.astype(np.int64) * h + s_rows)
    eo = np.argsort(e_cols.astype(np.int64) * h + e_rows)
    assert s_rows.size == e_rows.size, "unbalanced vertical run boundaries"
    return _make(
        s_cols[so], s_rows[so], e_rows[eo] + 1, (w, h), im.overflow
    )


def _separable(im: RLEImage, se, hpass) -> RLEImage:
    """Width pass in place, height pass through the transpose trick."""
    se_h, se_w = int(se[0]), int(se[1])
    out = hpass(im, se_w)
    if se_h > 1:
        out = transpose(hpass(transpose(out), se_h))
    return out


def erode(im: RLEImage, se) -> RLEImage:
    return _separable(im, se, erode_h)


def dilate(im: RLEImage, se) -> RLEImage:
    return _separable(im, se, dilate_h)


def opening(im: RLEImage, se) -> RLEImage:
    return dilate(erode(im, se), se)


def closing(im: RLEImage, se) -> RLEImage:
    return erode(dilate(im, se), se)
