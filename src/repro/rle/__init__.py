"""Run-length-encoded binary morphology backend.

Cost scales with foreground *runs*, not pixels (arXiv 1504.01052): for the
sparse thresholded masks document-cleanup traffic carries, the run-domain
operators beat any dense path — separable, fused, or sharded — by the
density ratio. ``lower_rle`` is the fifth MorphExpr lowering (boolean
flat graphs only); the serving tier picks it per request via a measured
run-density probe against the cost model's representation axis.
"""
from repro.rle.image import (
    RLEImage,
    check_binary,
    decode,
    default_capacity,
    encode,
    estimate_run_density,
)
from repro.rle.lower import (
    RLEUnsupported,
    check_supported,
    lower_rle,
    plan_rle_eligible,
    supports_expr,
)
from repro.rle.runs import closing, dilate, erode, opening, transpose

__all__ = [
    "RLEImage",
    "RLEUnsupported",
    "check_binary",
    "check_supported",
    "closing",
    "decode",
    "default_capacity",
    "dilate",
    "encode",
    "erode",
    "estimate_run_density",
    "lower_rle",
    "opening",
    "plan_rle_eligible",
    "supports_expr",
    "transpose",
]
