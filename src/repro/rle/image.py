"""Run-length representation of binary images.

The dense separable passes (and the fused Pallas megakernel) pay per-pixel
cost regardless of content. *Fast algorithms for morphological operations
using run-length encoded binary images* (arXiv 1504.01052, PAPERS.md) shows
that for binary masks the cost can instead scale with the number of
foreground **runs** — maximal horizontal segments — which for the
thresholded document masks serving traffic is dominated by is often orders
of magnitude below the pixel count.

:class:`RLEImage` is the shared value both execution styles use:

* the **host** path (``rle.runs``) carries exact-length numpy buffers —
  run count is data-dependent, and numpy vectorized interval arithmetic is
  the fastest thing a per-request, content-dependent workload can run;
* the **fixed-capacity** path (``rle.kernels``) carries jnp buffers of a
  static ``capacity`` with a traced live count ``n`` and an ``overflow``
  flag, so run-domain stages are jittable / device-resident. Overflow never
  corrupts: the flag is sticky through every stage and ``lower_rle`` falls
  back to the host path when it trips.

Buffer contract (both paths): ``rows[i], starts[i], ends[i]`` describe the
half-open run ``[starts[i], ends[i])`` on row ``rows[i]``; live runs are
sorted by ``(row, start)``, runs are maximal (never empty, never adjacent
to another run of the same row), and dead slots (fixed-capacity path only)
sit at the tail with ``rows == H`` / ``starts == ends == 0``.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

_I32 = np.int32


@dataclasses.dataclass(frozen=True)
class RLEImage:
    """Run-length encoded binary image (see module docstring contract)."""

    rows: object  # (R,) i32
    starts: object  # (R,) i32
    ends: object  # (R,) i32
    n: object  # live run count: python/np int (host) or i32 scalar (traced)
    shape: tuple[int, int]  # static (H, W)
    overflow: object = False  # bool scalar; sticky across stages

    @property
    def capacity(self) -> int:
        return int(self.rows.shape[0])

    def density(self) -> float:
        """Run density: live runs per pixel (the dispatch gate's input)."""
        h, w = self.shape
        return float(self.n) / float(max(1, h * w))

    def to_host(self) -> "RLEImage":
        """Exact-length host (numpy) view of the live runs."""
        n = int(self.n)
        return RLEImage(
            rows=np.asarray(self.rows[:n], _I32),
            starts=np.asarray(self.starts[:n], _I32),
            ends=np.asarray(self.ends[:n], _I32),
            n=n,
            shape=self.shape,
            overflow=bool(self.overflow),
        )

    def decode(self) -> np.ndarray:
        return decode(self)


def _tree_flatten(im: RLEImage):
    return (im.rows, im.starts, im.ends, im.n, im.overflow), im.shape


def _tree_unflatten(shape, leaves):
    rows, starts, ends, n, overflow = leaves
    return RLEImage(rows, starts, ends, n, shape, overflow)


jax.tree_util.register_pytree_node(RLEImage, _tree_flatten, _tree_unflatten)


def check_binary(x) -> np.ndarray:
    """The RLE backend is bool-only by contract — reject loudly, exactly
    like ``check_backend`` does for backend typos."""
    x = np.asarray(x)
    if x.dtype != np.bool_:
        raise TypeError(
            f"the RLE backend encodes boolean masks; got dtype {x.dtype} "
            "(threshold first, or use the dense lowerings)"
        )
    return x


def encode(dense) -> RLEImage:
    """Dense ``(H, W)`` bool -> exact-length host :class:`RLEImage`.

    One ``diff`` over the columns (with virtual False borders) turns run
    starts into +1 and run ends into -1 edges; ``np.nonzero`` walks the
    image row-major, so the output is already ``(row, start)``-sorted.
    """
    dense = check_binary(dense)
    if dense.ndim != 2:
        raise ValueError(f"encode takes a single (H, W) mask, got {dense.shape}")
    # boolean shift-compare edges + 1-D flatnonzero: this runs per request
    # on the serving fast path, and the flat scan is ~10x faster than 2-D
    # np.nonzero (which walks a generic strided iterator)
    h, w = dense.shape
    is_start = np.empty_like(dense)
    is_start[:, 0] = dense[:, 0]
    np.greater(dense[:, 1:], dense[:, :-1], out=is_start[:, 1:])
    is_end = np.empty_like(dense)
    is_end[:, -1] = dense[:, -1]
    np.greater(dense[:, :-1], dense[:, 1:], out=is_end[:, :-1])
    rows, starts = np.divmod(np.flatnonzero(is_start), w)
    erows, ends = np.divmod(np.flatnonzero(is_end), w)
    ends += 1
    assert rows.shape == erows.shape
    return RLEImage(
        rows=rows.astype(_I32),
        starts=starts.astype(_I32),
        ends=ends.astype(_I32),
        n=int(rows.size),
        shape=(int(dense.shape[0]), int(dense.shape[1])),
    )


def run_cells(im: RLEImage) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Expand intervals into per-cell ``(repeat-index, row, col)`` arrays —
    O(foreground), the decode/transpose expansion primitive."""
    im = im.to_host()
    lens = im.ends - im.starts
    total = int(lens.sum())
    first = (np.cumsum(lens) - lens).astype(_I32)
    idx = np.repeat(np.arange(im.n, dtype=_I32), lens)
    offset = np.arange(total, dtype=_I32) - first[idx]
    return idx, im.rows[idx], im.starts[idx] + offset


def decode(im: RLEImage) -> np.ndarray:
    """:class:`RLEImage` -> dense bool ``(H, W)``.

    Scatter of the expanded foreground cells: O(foreground pixels) plus the
    output allocation, so a sparse mask decodes in time proportional to its
    content — the same scaling the run-domain operators have.
    """
    h, w = im.shape
    out = np.zeros(h * w, dtype=np.bool_)
    _, rows, cols = run_cells(im)
    out[rows.astype(np.int64) * w + cols] = True
    return out.reshape(h, w)


def default_capacity(shape: tuple[int, int], *, density: float = 0.125) -> int:
    """Fixed-capacity sizing for the jittable path: room for ``density``
    runs/pixel (8x the dispatch gate's densest plausible RLE pick, so the
    overflow fallback is the exception, not the steady state)."""
    h, w = int(shape[-2]), int(shape[-1])
    return max(256, int(h * w * density))


def estimate_run_density(img, *, row_stride: int = 8) -> float:
    """Cheap measured run-density probe: exact run count over every
    ``row_stride``-th row, divided by the sampled pixel count.

    This is the per-request measurement the serving gate dispatches on —
    O(pixels / row_stride) numpy compares, ~free next to any execution
    path, and unbiased for the row-structured masks binary traffic carries.
    """
    img = check_binary(img)
    sample = img[::row_stride] if img.ndim == 2 else img.reshape(1, -1)
    runs = int(sample[:, 0].sum()) + int(
        (sample[:, 1:] & ~sample[:, :-1]).sum()
    )
    return runs / max(1, sample.size)
