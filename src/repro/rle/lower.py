"""``lower_rle`` — the fifth lowering: MorphExpr -> run-domain execution.

Sits beside ``lower_xla`` / ``lower_kernel`` / ``to_plan`` / ``to_sharded``
(and lives here rather than in ``repro.morph`` for the same import-cycle
reason ``to_sharded`` lives in ``repro.shard``). The run domain is a
boolean lattice: only flat structural nodes — ``Var`` / ``Erode`` /
``Dilate`` (and whatever the optimizer folds them into) — have a run-domain
meaning. Arithmetic, gradients, casts and iteration are rejected up front
with :class:`RLEUnsupported` so callers can catch one typed error and fall
back to a dense lowering.

Two execution modes share the graph walk:

* ``mode="host"`` (default, and what the serving gate uses): exact-length
  numpy buffers, O(runs) per operator — per-request cost follows content.
* ``mode="jit"``: the fixed-capacity kernels under one ``jax.jit`` per
  input shape; if the capacity contract trips (sticky ``overflow`` flag)
  the request transparently re-runs on the host path, so results are
  always exact.
"""
from __future__ import annotations

import numpy as np

from repro.morph.analyze import free_vars
from repro.morph.expr import Dilate, Erode, MorphExpr, Var
from repro.rle import kernels, runs
from repro.rle.image import RLEImage, check_binary, decode, default_capacity, encode


class RLEUnsupported(TypeError):
    """Raised for MorphExpr graphs with no run-domain meaning."""


def check_supported(expr: MorphExpr) -> None:
    """Walk ``expr``; raise :class:`RLEUnsupported` at the first node that
    is not Var/Erode/Dilate (iterative duals included — an opening is just
    ``Dilate(Erode(x))`` in the IR, so flat chains pass naturally)."""
    seen: set[int] = set()

    def walk(e: MorphExpr) -> None:
        if id(e) in seen:
            return
        seen.add(id(e))
        if isinstance(e, Var):
            return
        if isinstance(e, (Erode, Dilate)):
            walk(e.child)
            return
        raise RLEUnsupported(
            f"lower_rle supports flat structural graphs (Var/Erode/Dilate); "
            f"{type(e).__name__} has no run-domain meaning — use a dense "
            "lowering (lower_xla / lower_kernel) for this expression"
        )

    walk(expr)


def supports_expr(expr: MorphExpr) -> bool:
    try:
        check_supported(expr)
    except RLEUnsupported:
        return False
    return True


def plan_rle_eligible(plan) -> bool:
    """True iff every output of a serving plan is run-domain lowerable.

    This is the *structural* half of the serving gate (the density probe is
    the per-request half): a plan qualifies when all its outputs are flat
    Var/Erode/Dilate chains over the single input ``x``.
    """
    try:
        outputs = plan.outputs
    except AttributeError:
        return False
    if not outputs:
        return False
    for _, e in outputs:
        if not supports_expr(e) or free_vars(e) - {"x"}:
            return False
    return True


def _as_outputs(outputs):
    single = isinstance(outputs, MorphExpr)
    return single, {"out": outputs} if single else dict(outputs)


def _eval_host(expr: MorphExpr, im: RLEImage, memo: dict) -> RLEImage:
    key = id(expr)
    if key in memo:
        return memo[key]
    if isinstance(expr, Var):
        out = im
    elif isinstance(expr, Erode):
        out = runs.erode(_eval_host(expr.child, im, memo), (expr.se.h, expr.se.w))
    else:
        out = runs.dilate(_eval_host(expr.child, im, memo), (expr.se.h, expr.se.w))
    memo[key] = out
    return out


def _eval_fixed(expr: MorphExpr, im: RLEImage, memo: dict) -> RLEImage:
    key = id(expr)
    if key in memo:
        return memo[key]
    if isinstance(expr, Var):
        out = im
    elif isinstance(expr, Erode):
        out = kernels.erode_fixed(_eval_fixed(expr.child, im, memo), (expr.se.h, expr.se.w))
    else:
        out = kernels.dilate_fixed(_eval_fixed(expr.child, im, memo), (expr.se.h, expr.se.w))
    memo[key] = out
    return out


def lower_rle(outputs, *, mode: str = "host", capacity: int | None = None, policy=None):
    """``expr | {name: expr}`` -> ``fn(x) -> bool array | {name: bool array}``.

    ``x`` is a bool mask, ``(H, W)`` or any ``(..., H, W)`` leading-batch
    layout (batch items run independently — run buffers are ragged across a
    batch, so there is no batched trace to share). Graphs are optimized
    first like every other lowering (erode-of-erode folding and CSE are
    profitable in the run domain too), then re-checked: optimization can
    only remove structural nodes, never introduce arithmetic.
    """
    if mode not in ("host", "jit"):
        raise ValueError(f"lower_rle mode must be 'host' or 'jit', got {mode!r}")
    single, outs = _as_outputs(outputs)
    for name, e in outs.items():
        check_supported(e)
        extra = free_vars(e) - {"x"}
        if extra:
            raise RLEUnsupported(
                f"lower_rle output {name!r} reads vars {sorted(extra)}; the "
                "run-domain path serves single-input graphs over Var('x')"
            )

    from repro.core.dispatch import DispatchPolicy
    from repro.morph.opt import optimize

    policy = policy or DispatchPolicy.calibrated()
    outs = optimize(outs, policy=policy, kinds=("major", "minor"), dtype="bool")
    for e in outs.values():
        check_supported(e)

    def run_host(x2d: np.ndarray) -> dict:
        im = encode(x2d)
        memo: dict = {}
        return {k: decode(_eval_host(e, im, memo)) for k, e in outs.items()}

    if mode == "host":
        run_one = run_host
    else:
        import jax

        @jax.jit
        def jitted(x2d):
            im = kernels.encode_fixed(
                x2d, capacity or default_capacity(x2d.shape)
            )
            memo: dict = {}
            res = {k: kernels.decode_fixed(_eval_fixed(e, im, memo)) for k, e in outs.items()}
            flag = im.overflow
            for v in memo.values():
                flag = flag | v.overflow
            return res, flag

        def run_one(x2d: np.ndarray) -> dict:
            res, overflow = jitted(x2d)
            if bool(overflow):
                # Capacity contract tripped: buffers are unspecified, the
                # exact-length host path is the documented fallback.
                return run_host(x2d)
            return {k: np.asarray(v) for k, v in res.items()}

    def fn(x):
        x = check_binary(x)
        if x.ndim < 2:
            raise ValueError(f"lower_rle needs an (..., H, W) mask, got {x.shape}")
        if x.ndim == 2:
            res = run_one(x)
        else:
            lead = x.shape[:-2]
            flat = x.reshape((-1,) + x.shape[-2:])
            per = [run_one(flat[i]) for i in range(flat.shape[0])]
            res = {
                k: np.stack([p[k] for p in per]).reshape(lead + x.shape[-2:])
                for k in outs
            }
        return res["out"] if single else res

    return fn
