"""Fixed-capacity, jittable run-domain kernels.

The host operators (``rle.runs``) carry exact-length buffers — the fastest
shape for per-request host dispatch, but untraceable: run count is data.
This module is the device-resident variant: every buffer has a static
``capacity``, the live count ``n`` is a traced scalar, and each stage is a
pure jnp function over the :class:`RLEImage` pytree, so run-domain stages
can live inside a jitted pipeline.

Capacity contract: a stage that would need more than ``capacity`` runs sets
the sticky ``overflow`` flag (ORed through every subsequent stage) and its
buffers are **unspecified** — callers must treat any overflowed result as
garbage and re-run on the host path, which is exactly what ``lower_rle``'s
fallback does. Dead slots sort to the tail (``rows == H``), so live runs
always occupy a sorted prefix.

One documented asymmetry: :func:`transpose_fixed` re-encodes through a
dense intermediate (O(pixels) elementwise work under jit) instead of the
host path's run-domain event sweep — data-dependent expansion sizes are
hostile to a fixed trace, and the jit path exists for device residency,
not for the host path's O(runs) serving speed.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.rle.image import RLEImage, default_capacity


def encode_fixed(x, capacity: int | None = None) -> RLEImage:
    """Dense ``(H, W)`` bool -> fixed-capacity :class:`RLEImage` (traced).

    Edge detection along columns exactly as the host encoder; the first
    ``capacity`` runs (row-major, so ``(row, start)``-sorted) fill the
    buffers and ``overflow`` records whether any were dropped.
    """
    x = jnp.asarray(x)
    if x.dtype != jnp.bool_:
        raise TypeError(f"encode_fixed takes a bool mask, got {x.dtype}")
    h, w = x.shape
    capacity = int(capacity or default_capacity((h, w)))
    edges = jnp.diff(x.astype(jnp.int8), axis=1, prepend=0, append=0)
    pad = h * (w + 1)
    sidx = jnp.nonzero(edges.ravel() == 1, size=capacity, fill_value=pad)[0]
    eidx = jnp.nonzero(edges.ravel() == -1, size=capacity, fill_value=pad)[0]
    n = jnp.sum(edges == 1, dtype=jnp.int32)
    live = jnp.arange(capacity, dtype=jnp.int32) < n
    return RLEImage(
        rows=jnp.where(live, sidx // (w + 1), h).astype(jnp.int32),
        starts=jnp.where(live, sidx % (w + 1), 0).astype(jnp.int32),
        ends=jnp.where(live, eidx % (w + 1), 0).astype(jnp.int32),
        n=jnp.minimum(n, capacity),
        shape=(int(h), int(w)),
        overflow=n > capacity,
    )


def decode_fixed(im: RLEImage):
    """Fixed-capacity runs -> dense ``(H, W)`` bool (traced): +/-1 coverage
    edges scattered flat, one cumsum. Dead slots index the drop slot."""
    h, w = im.shape
    live = (jnp.arange(im.capacity, dtype=jnp.int32) < im.n).astype(jnp.int32)
    base = jnp.minimum(im.rows.astype(jnp.int32) * w, h * w)
    delta = jnp.zeros(h * w + 1, jnp.int32)
    delta = delta.at[jnp.minimum(base + im.starts, h * w)].add(live)
    delta = delta.at[jnp.minimum(base + im.ends, h * w)].add(-live)
    return (jnp.cumsum(delta[:-1]) > 0).reshape(h, w)


def _compact(im: RLEImage, keep, starts, ends) -> RLEImage:
    """Rebuild with only ``keep`` slots live, stably sorted to the prefix."""
    h, _ = im.shape
    order = jnp.argsort(~keep, stable=True)
    return dataclasses.replace(
        im,
        rows=jnp.where(keep, im.rows, h).astype(jnp.int32)[order],
        starts=jnp.where(keep, starts, 0).astype(jnp.int32)[order],
        ends=jnp.where(keep, ends, 0).astype(jnp.int32)[order],
        n=jnp.sum(keep, dtype=jnp.int32),
    )


def erode_h_fixed(im: RLEImage, window: int) -> RLEImage:
    """Horizontal erosion, fixed capacity: same coordinate arithmetic as
    the host pass (virtual-True borders), with a stable compaction in place
    of the host path's boolean gather. Never overflows (runs only die)."""
    wing = (int(window) - 1) // 2
    if wing == 0:
        return im
    _, w = im.shape
    live = jnp.arange(im.capacity, dtype=jnp.int32) < im.n
    sv = jnp.where(im.starts == 0, -wing, im.starts)
    ev = jnp.where(im.ends == w, w + wing, im.ends)
    ns, ne = sv + wing, ev - wing
    return _compact(im, live & (ne > ns), ns, ne)


def dilate_h_fixed(im: RLEImage, window: int) -> RLEImage:
    """Horizontal dilation, fixed capacity: grow, clip, merge each row's
    overlapping runs (head flags -> gather each group's first start / last
    end). Never overflows (merging only shrinks the run count)."""
    wing = (int(window) - 1) // 2
    if wing == 0:
        return im
    h, w = im.shape
    cap = im.capacity
    idx = jnp.arange(cap, dtype=jnp.int32)
    live = idx < im.n
    ns = jnp.maximum(im.starts - wing, 0)
    ne = jnp.minimum(im.ends + wing, w)
    head = live & (
        (idx == 0)
        | (im.rows != jnp.roll(im.rows, 1))
        | (ns > jnp.roll(ne, 1))
    )
    hidx = jnp.nonzero(head, size=cap, fill_value=cap)[0].astype(jnp.int32)
    n_out = jnp.sum(head, dtype=jnp.int32)
    next_head = jnp.concatenate([hidx[1:], jnp.full((1,), cap, jnp.int32)])
    last = jnp.clip(jnp.minimum(next_head, im.n) - 1, 0, cap - 1)
    first = jnp.clip(hidx, 0, cap - 1)
    out_live = idx < n_out
    return dataclasses.replace(
        im,
        rows=jnp.where(out_live, im.rows[first], h).astype(jnp.int32),
        starts=jnp.where(out_live, ns[first], 0).astype(jnp.int32),
        ends=jnp.where(out_live, ne[last], 0).astype(jnp.int32),
        n=n_out,
    )


def transpose_fixed(im: RLEImage, capacity: int | None = None) -> RLEImage:
    """Column runs via a dense re-encode (module docstring); the transposed
    mask can hold more runs than the input, so this is the one stage that
    can overflow — the flag is ORed with the input's."""
    out = encode_fixed(decode_fixed(im).T, capacity or im.capacity)
    return dataclasses.replace(out, overflow=out.overflow | im.overflow)


def _separable_fixed(im: RLEImage, se, hpass) -> RLEImage:
    se_h, se_w = int(se[0]), int(se[1])
    out = hpass(im, se_w)
    if se_h > 1:
        out = transpose_fixed(hpass(transpose_fixed(out), se_h))
    return out


def erode_fixed(im: RLEImage, se) -> RLEImage:
    return _separable_fixed(im, se, erode_h_fixed)


def dilate_fixed(im: RLEImage, se) -> RLEImage:
    return _separable_fixed(im, se, dilate_h_fixed)


def opening_fixed(im: RLEImage, se) -> RLEImage:
    return dilate_fixed(erode_fixed(im, se), se)


def closing_fixed(im: RLEImage, se) -> RLEImage:
    return erode_fixed(dilate_fixed(im, se), se)


@partial(jax.jit, static_argnums=(1, 2))
def roundtrip_fixed(x, capacity: int, _marker: int = 0):
    """encode -> decode under one jit (capacity-contract smoke hook)."""
    im = encode_fixed(x, capacity)
    return decode_fixed(im), im.overflow
