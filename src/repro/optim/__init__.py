"""Optimizer substrate: AdamW, schedules, gradient clipping, compression."""
from repro.optim.adamw import AdamWState, adamw_init, adamw_update, clip_by_global_norm, global_norm
from repro.optim.compress import compressed_psum, dequantize_int8, quantize_int8
from repro.optim.schedule import warmup_cosine
