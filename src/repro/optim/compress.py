"""Int8 gradient compression for the thin cross-pod (DCN) axis.

At 512+ chips the intra-pod ICI all-reduce is cheap relative to the
inter-pod DCN hop, so we compress only the "pod"-axis reduction:
per-chunk symmetric int8 quantization, an int8 ``all_gather`` over the pod
axis (+ f32 scales), and a local dequantize-sum. For a pod axis of size P
this moves N + 4N/chunk bytes instead of ~2·4N for a ring all-reduce in
f32 — an ~8x wire-byte reduction at P=2.

Used inside ``shard_map`` (see train.loop cross-pod hook and
tests/test_compress.py); numerics: relative error bounded by ~1/254 per
chunk, which is far below gradient noise at batch 256 (EXPERIMENTS.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray, *, chunk: int = 1024):
    """Symmetric per-chunk int8 quantization. Returns (q, scales, shape)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = -flat.size % chunk
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(-1, chunk)
    scale = jnp.max(jnp.abs(chunks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(chunks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    size = 1
    for s in shape:
        size *= s
    return flat[:size].reshape(shape)


def compressed_psum(x: jnp.ndarray, axis_name: str, *, chunk: int = 1024):
    """Mean-reduce ``x`` over ``axis_name`` with int8 wire format.

    all_gather(int8) + local dequant-sum == psum, but at ~1/8 the DCN bytes.
    Must be called inside shard_map/pmap with ``axis_name`` bound.
    """
    q, scale = quantize_int8(x, chunk=chunk)
    qs = jax.lax.all_gather(q, axis_name)          # (P, nchunk, chunk) int8
    ss = jax.lax.all_gather(scale, axis_name)      # (P, nchunk, 1) f32
    total = jnp.sum(qs.astype(jnp.float32) * ss, axis=0)  # (nchunk, chunk)
    n = jax.lax.psum(1, axis_name)
    return (total.reshape(-1)[: x.size].reshape(x.shape) / n).astype(x.dtype)
