"""Learning-rate schedules (warmup + cosine decay) as pure step -> lr fns."""
from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = peak_lr * jnp.minimum(1.0, step / max(warmup_steps, 1))
    t = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)
