"""AdamW with decoupled weight decay, f32 moments over (possibly bf16) params.

No optax in this environment, so the optimizer is a small pytree transform.
Moments are stored in float32 regardless of param dtype (mixed-precision
training convention); the update is computed in f32 and cast back to the
param dtype. Under the sharding rules every moment leaf inherits its
parameter's NamedSharding, so optimizer state is fully sharded (ZeRO-style
— no replicated copies; see DESIGN.md §5 memory budget for grok-1-314b).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
        return new_p, m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
