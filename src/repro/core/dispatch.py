"""Hybrid dispatch between the linear and vHGW 1-D passes (paper §5.3).

The paper measures crossover windows w_x0 = 59 and w_y0 = 69 on Exynos 5422
and selects the linear implementation below the crossover, vHGW+SIMD above.
The two thresholds differ because the two passes touch memory differently —
the same asymmetry exists on TPU, where the lane (minor) axis pays a
lane-roll per shifted operand while the sublane axis does not.

Here the thresholds are a :class:`DispatchPolicy` value: defaults come from
the CPU calibration run (benchmarks/bench_hybrid.py writes
``calibration.json``), and an analytic TPU estimate is documented in
EXPERIMENTS.md. The policy is a static (trace-time) decision, like the
paper's branch — no runtime cost.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.linear_pass import linear_1d, linear_1d_paired, linear_1d_tree
from repro.core.types import Array, as_op, check_window
from repro.core.vhgw import vhgw_1d

Method = Literal["auto", "linear", "linear_paired", "linear_tree", "vhgw"]

_CALIBRATION_FILE = os.path.join(os.path.dirname(__file__), "calibration.json")

# calibrated() memo: {"policy": ((calib_mtime, cost_mtime), DispatchPolicy)}
_CALIBRATED_CACHE: dict = {}


def _file_mtime(path: str):
    try:
        return os.stat(path).st_mtime_ns
    except OSError:
        return None


def _cost_table_mtime():
    from repro.morph.opt.cost import COST_TABLE_FILE

    return _file_mtime(COST_TABLE_FILE)

INTERPRET_ENV = "REPRO_PALLAS_INTERPRET"


def resolve_interpret(
    interpret: bool | None, policy: "DispatchPolicy | None" = None
) -> bool:
    """Single resolver for the Pallas ``interpret`` flag.

    Precedence: explicit argument > ``DispatchPolicy.interpret`` >
    ``REPRO_PALLAS_INTERPRET`` env var > backend default (compiled Mosaic on
    TPU, interpret elsewhere). Kernel entry points (kernels/ops.py) call this
    once instead of hard-coding ``interpret=True``, so production serving on
    TPU never silently runs interpreted Pallas; tests keep pinning
    ``interpret=True`` explicitly.
    """
    if interpret is not None:
        return bool(interpret)
    if policy is not None and policy.interpret is not None:
        return policy.interpret
    env = os.environ.get(INTERPRET_ENV)
    if env is not None:
        return env.strip().lower() not in ("0", "false", "no", "off", "")
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class DispatchPolicy:
    """Crossover thresholds per axis kind.

    ``w0_minor``: threshold for passes along the minormost (lane) axis.
    ``w0_major``: threshold for passes along any other (sublane/batch) axis.
    Both mirror the paper's (w_x0, w_y0) pair.

    ``fused_2d``: whether the kernel-backed 2-D operators run as the fused
    single-``pallas_call`` megakernel (kernels/morph_fused.py — one HBM read
    + one write per operator) or as the legacy two-pass + double-transpose
    pipeline (four HBM traversals; kept for A/B and for SEs too wide for the
    fused halo). Like the method thresholds this is a trace-time decision.
    """

    w0_minor: int = 15
    w0_major: int = 31
    small_method: Method = "linear_tree"  # beyond-paper default; paper used "linear"
    # IR optimizer level applied by the lowerings (repro.morph.opt.optimize):
    # 0 = off, 1 = structural passes (CSE / folding / dead-output elim /
    # gradient canonicalization), 2 = plus cost-model-driven SE
    # decomposition. Part of the policy so serving cache keys capture it and
    # so callers opt out per call site (DispatchPolicy(opt_level=0)).
    opt_level: int = 2
    fused_2d: bool = True
    # Pallas interpret-mode override: None defers to the env var / backend
    # default (see resolve_interpret). Part of the policy so serving cache
    # keys capture it.
    interpret: bool | None = None
    # Force a specific 1-D algorithm for every pass ("auto" = threshold
    # dispatch via w0_*/small_method). Collapses the old per-call ``method=``
    # kwarg into the policy, so cache keys capture it too. The Pallas paths
    # implement only the linear/vhgw pair; a forced linear_tree/linear_paired
    # runs the kernels' linear ladder there (nearest same-family analog).
    method: Method = "auto"
    # Lane-axis strategy for the two-pass kernel pipeline: the paper's §5.2
    # transpose-kernel sandwich or an XLA transpose (§Perf A/B). Collapses
    # the old per-call ``lane_strategy=`` kwarg.
    lane_strategy: str = "transpose_kernel"  # "transpose_kernel" | "xla"
    # Crossover for passes inside the fused megakernel. Much higher than
    # w0_major: the fused linear ladder is slice-reductions over a
    # VMEM-resident strip that the compiler fuses into one loop nest, while
    # the vHGW doubling scans materialize a full strip per step — measured
    # crossover ~255 on the CPU-interpret harness (DESIGN.md §5); expected
    # to drop when recalibrated on real TPU Mosaic lowering.
    w0_fused: int = 255

    def cache_token(self) -> tuple:
        """Stable, hashable fingerprint of every dispatch-relevant field.

        The serving layer keys its executable cache on this (alongside
        bucket/dtype/op), so two policies that compile identically share an
        executable and any differing field forces a fresh compile.
        """
        return tuple(
            (f.name, getattr(self, f.name)) for f in dataclasses.fields(self)
        )

    def with_overrides(
        self,
        *,
        fused: bool | None = None,
        method: "Method | None" = None,
        lane_strategy: str | None = None,
        interpret: bool | None = None,
    ) -> "DispatchPolicy":
        """Fold the deprecated per-call kwargs into a policy value.

        The kernel entry points (``kernels/ops.py``) and ``core.morphology``
        keep their old ``fused=`` / ``method=`` / ``lane_strategy=`` /
        ``interpret=`` keywords as shims; each non-default value becomes the
        corresponding policy field so one ``DispatchPolicy`` carries every
        dispatch decision (``method="auto"`` and ``None`` mean "no change").
        """
        changes: dict = {}
        if fused is not None:
            changes["fused_2d"] = bool(fused)
        if method is not None and method != "auto":
            changes["method"] = method
        if lane_strategy is not None:
            changes["lane_strategy"] = lane_strategy
        if interpret is not None:
            changes["interpret"] = bool(interpret)
        return dataclasses.replace(self, **changes) if changes else self

    @classmethod
    def paper(cls) -> "DispatchPolicy":
        """Thresholds as published for Exynos 5422 + NEON."""
        return cls(w0_minor=59, w0_major=69, small_method="linear")

    @classmethod
    def calibrated(cls) -> "DispatchPolicy":
        """The machine-local policy, memoized on calibration-file mtimes.

        Thresholds come from the measured per-device cost table
        (``cost_table.json``, fit by ``bench_hybrid --fit-cost-table``) when
        one exists for this device — its fitted curves imply the crossovers
        — else from the scalar ``calibration.json``, else the defaults.
        This used to re-``os.path.exists`` + ``json.load`` on *every*
        ``morph_1d`` call; now a stat comparison is the steady-state cost
        and a refit (new mtime) invalidates the cache.
        """
        mt = (_file_mtime(_CALIBRATION_FILE), _cost_table_mtime())
        cached = _CALIBRATED_CACHE.get("policy")
        if cached is not None and cached[0] == mt:
            return cached[1]
        policy = cls._load_calibrated()
        _CALIBRATED_CACHE["policy"] = (mt, policy)
        return policy

    @classmethod
    def _load_calibrated(cls) -> "DispatchPolicy":
        kw: dict = {}
        if os.path.exists(_CALIBRATION_FILE):
            with open(_CALIBRATION_FILE) as f:
                d = json.load(f)
            kw = dict(
                w0_minor=int(d.get("w0_minor", cls.w0_minor)),
                w0_major=int(d.get("w0_major", cls.w0_major)),
                small_method=d.get("small_method", "linear_tree"),
                fused_2d=bool(d.get("fused_2d", True)),
                w0_fused=int(d.get("w0_fused", cls.w0_fused)),
            )
        # the measured cost table, when present for this device, supersedes
        # the scalar calibration: its curves *imply* the crossovers
        from repro.morph.opt.cost import load_measured

        measured = load_measured()
        if measured is not None:
            for field in ("w0_minor", "w0_major", "w0_fused", "small_method"):
                if field in measured.crossovers:
                    v = measured.crossovers[field]
                    kw[field] = v if field == "small_method" else int(v)
        return cls(**kw)


_METHODS = {
    "linear": linear_1d,
    "linear_paired": linear_1d_paired,
    "linear_tree": linear_1d_tree,
    "vhgw": vhgw_1d,
}


def morph_1d(
    x: Array,
    w: int,
    *,
    axis: int = -1,
    op="min",
    method: Method = "auto",
    policy: DispatchPolicy | None = None,
) -> Array:
    """1-D running min/max with hybrid method selection.

    ``method="auto"`` consults the per-device cost model
    (``repro.morph.opt.cost``): measured per-(axis kind, method, dtype)
    curves when a fitted ``cost_table.json`` matches the policy, else the
    analytic model built from the policy's own thresholds — which
    reproduces the historical ``w <= w0`` branch exactly.
    """
    op = as_op(op)
    w = check_window(w)
    if method == "auto":
        policy = policy or DispatchPolicy.calibrated()
        if policy.method != "auto":
            method = policy.method
        else:
            from repro.morph.opt.cost import cost_model_for

            kind = "minor" if (axis % x.ndim) == x.ndim - 1 else "major"
            method = cost_model_for(policy).best_method(
                kind, w, jnp.dtype(x.dtype).name, small=policy.small_method
            )
    return _METHODS[method](x, w, axis=axis, op=op)
