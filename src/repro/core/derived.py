"""Derived morphological operators (paper §2: "other morphological
operations ... can be expressed via erosion, dilation and arithmetical
operations"). Everything here composes the fast separable primitives, so
every operator inherits the hybrid vHGW/linear/tree dispatch and the
Pallas kernels underneath.

Included: geodesic dilation/erosion, morphological reconstruction
(by dilation and by erosion), h-maxima/h-minima, the open-close /
close-open smoothing filters (OCCO — the classic salt+pepper remover),
the morphological Laplacian, and granulometry (pattern spectrum) — the
standard texture descriptor built from an opening scale-sweep.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.morphology import closing, dilate, erode, opening
from repro.core.types import Array


def geodesic_dilate(marker: Array, mask: Array, se=(3, 3)) -> Array:
    """One geodesic step: dilate the marker, clamp under the mask."""
    return jnp.minimum(dilate(marker, se), mask)


def geodesic_erode(marker: Array, mask: Array, se=(3, 3)) -> Array:
    return jnp.maximum(erode(marker, se), mask)


def reconstruct_by_dilation(marker: Array, mask: Array, se=(3, 3),
                            *, max_iters: int = 256) -> Array:
    """Morphological reconstruction: iterate geodesic dilation to
    stability (lax.while_loop; converges in <= image-diameter steps)."""
    marker = jnp.minimum(marker, mask)

    def cond(state):
        prev, cur, i = state
        return jnp.logical_and(i < max_iters, jnp.any(prev != cur))

    def body(state):
        _, cur, i = state
        return cur, geodesic_dilate(cur, mask, se), i + 1

    _, out, _ = jax.lax.while_loop(
        cond, body, (marker, geodesic_dilate(marker, mask, se), jnp.int32(0))
    )
    return out


def reconstruct_by_erosion(marker: Array, mask: Array, se=(3, 3),
                           *, max_iters: int = 256) -> Array:
    marker = jnp.maximum(marker, mask)

    def cond(state):
        prev, cur, i = state
        return jnp.logical_and(i < max_iters, jnp.any(prev != cur))

    def body(state):
        _, cur, i = state
        return cur, geodesic_erode(cur, mask, se), i + 1

    _, out, _ = jax.lax.while_loop(
        cond, body, (marker, geodesic_erode(marker, mask, se), jnp.int32(0))
    )
    return out


def h_maxima(x: Array, h: int, se=(3, 3)) -> Array:
    """Suppress local maxima shallower than ``h`` (reconstruction of x-h
    under x). Integer images."""
    marker = jnp.clip(x.astype(jnp.int32) - h, 0, None).astype(x.dtype)
    return reconstruct_by_dilation(marker, x, se)


def h_minima(x: Array, h: int, se=(3, 3)) -> Array:
    info = jnp.iinfo(x.dtype) if jnp.issubdtype(x.dtype, jnp.integer) else None
    hi = info.max if info else jnp.inf
    marker = jnp.clip(x.astype(jnp.int32) + h, None, hi).astype(x.dtype)
    return reconstruct_by_erosion(marker, x, se)


def open_close(x: Array, se=(3, 3)) -> Array:
    """OC smoothing: removes bright then dark impulse noise."""
    return closing(opening(x, se), se)


def close_open(x: Array, se=(3, 3)) -> Array:
    return opening(closing(x, se), se)


def occo(x: Array, se=(3, 3)) -> Array:
    """OCCO filter: average of OC and CO — the standard self-dual-ish
    morphological smoother (integer-safe midpoint)."""
    a = open_close(x, se).astype(jnp.int32)
    b = close_open(x, se).astype(jnp.int32)
    return ((a + b) // 2).astype(x.dtype) if jnp.issubdtype(
        x.dtype, jnp.integer) else ((a + b) / 2).astype(x.dtype)


def laplacian(x: Array, se=(3, 3)) -> Array:
    """Morphological Laplacian: (dilate - x) - (x - erode)."""
    xi = x.astype(jnp.int32)
    return (dilate(x, se).astype(jnp.int32) - xi) - (xi - erode(x, se).astype(jnp.int32))


def granulometry(x: Array, sizes=(3, 5, 9, 15, 21)) -> Array:
    """Pattern spectrum: d/ds of the opening-volume curve over SE sizes.

    Returns the normalized volume removed between consecutive scales —
    the classic granulometric texture signature (runs one hybrid-dispatch
    opening per scale, so large scales use vHGW automatically).
    """
    vol0 = jnp.sum(x.astype(jnp.float32))
    vols = [vol0]
    for s in sizes:
        vols.append(jnp.sum(opening(x, (s, s)).astype(jnp.float32)))
    vols = jnp.stack(vols)
    return (vols[:-1] - vols[1:]) / jnp.maximum(vol0, 1.0)
