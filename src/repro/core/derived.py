"""Derived morphological operators (paper §2: "other morphological
operations ... can be expressed via erosion, dilation and arithmetical
operations"). Each operator is its expression graph (``repro.morph``)
lowered through the XLA pass, so everything here inherits the hybrid
vHGW/linear/tree dispatch — and the *same* graphs are what make these
operators servable (``repro.morph.to_plan`` compiles reconstruction/OCCO
chains into bounded-iteration serving plans).

Included: geodesic dilation/erosion, morphological reconstruction
(by dilation and by erosion), h-maxima/h-minima, the open-close /
close-open smoothing filters (OCCO — the classic salt+pepper remover),
the morphological Laplacian, and granulometry (pattern spectrum) — the
standard texture descriptor built from an opening scale-sweep.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.morphology import opening
from repro.core.types import Array


def _lower(outputs):
    from repro.morph.lower_xla import lower_xla

    return lower_xla(outputs)


def _exprs():
    from repro import morph as ir

    return ir


def geodesic_dilate(marker: Array, mask: Array, se=(3, 3)) -> Array:
    """One geodesic step: dilate the marker, clamp under the mask."""
    ir = _exprs()
    expr = ir.geodesic_dilate_expr(ir.Var("marker"), ir.Var("mask"), se)
    return _lower(expr)(marker=marker, mask=mask)


def geodesic_erode(marker: Array, mask: Array, se=(3, 3)) -> Array:
    ir = _exprs()
    expr = ir.geodesic_erode_expr(ir.Var("marker"), ir.Var("mask"), se)
    return _lower(expr)(marker=marker, mask=mask)


def reconstruct_by_dilation(marker: Array, mask: Array, se=(3, 3),
                            *, max_iters: int = 256) -> Array:
    """Morphological reconstruction: iterate geodesic dilation to
    stability (a bounded ``while_loop``; converges in <= image-diameter
    steps). The graph is ``reconstruct_by_dilation_expr`` — the same node
    the serving engine compiles into bounded-iteration plans."""
    ir = _exprs()
    expr = ir.reconstruct_by_dilation_expr(
        ir.Var("marker"), ir.Var("mask"), se, iters=max_iters, until_stable=True
    )
    return _lower(expr)(marker=marker, mask=mask)


def reconstruct_by_erosion(marker: Array, mask: Array, se=(3, 3),
                           *, max_iters: int = 256) -> Array:
    ir = _exprs()
    expr = ir.reconstruct_by_erosion_expr(
        ir.Var("marker"), ir.Var("mask"), se, iters=max_iters, until_stable=True
    )
    return _lower(expr)(marker=marker, mask=mask)


def h_maxima(x: Array, h: int, se=(3, 3)) -> Array:
    """Suppress local maxima shallower than ``h`` (reconstruction of x-h
    under x). Integer images."""
    marker = jnp.clip(x.astype(jnp.int32) - h, 0, None).astype(x.dtype)
    return reconstruct_by_dilation(marker, x, se)


def h_minima(x: Array, h: int, se=(3, 3)) -> Array:
    info = jnp.iinfo(x.dtype) if jnp.issubdtype(x.dtype, jnp.integer) else None
    hi = info.max if info else jnp.inf
    marker = jnp.clip(x.astype(jnp.int32) + h, None, hi).astype(x.dtype)
    return reconstruct_by_erosion(marker, x, se)


def open_close(x: Array, se=(3, 3)) -> Array:
    """OC smoothing: removes bright then dark impulse noise."""
    ir = _exprs()
    return _lower(ir.X.opening(se).closing(se))(x)


def close_open(x: Array, se=(3, 3)) -> Array:
    ir = _exprs()
    return _lower(ir.X.closing(se).opening(se))(x)


def occo(x: Array, se=(3, 3)) -> Array:
    """OCCO filter: average of OC and CO — the standard self-dual-ish
    morphological smoother (integer-safe midpoint via the IR ``Mean``)."""
    ir = _exprs()
    return _lower(ir.occo_expr(ir.X, se))(x)


def laplacian(x: Array, se=(3, 3)) -> Array:
    """Morphological Laplacian: (dilate - x) - (x - erode), each difference
    in the centralized widened dtype."""
    ir = _exprs()
    expr = (ir.X.dilate(se) - ir.X) - (ir.X - ir.X.erode(se))
    return _lower(expr)(x)


def granulometry(x: Array, sizes=(3, 5, 9, 15, 21)) -> Array:
    """Pattern spectrum: d/ds of the opening-volume curve over SE sizes.

    Returns the normalized volume removed between consecutive scales —
    the classic granulometric texture signature (runs one hybrid-dispatch
    opening per scale, so large scales use vHGW automatically).
    """
    vol0 = jnp.sum(x.astype(jnp.float32))
    vols = [vol0]
    for s in sizes:
        vols.append(jnp.sum(opening(x, (s, s)).astype(jnp.float32)))
    vols = jnp.stack(vols)
    return (vols[:-1] - vols[1:]) / jnp.maximum(vol0, 1.0)
