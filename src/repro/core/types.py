"""Shared type helpers for the morphology core.

The paper works on 8-bit unsigned images. On TPU we additionally support
int8 / bfloat16 / float32 so the same primitives can be reused on masks,
spectrograms and feature maps. Every algorithm in this package is expressed
in terms of an associative, commutative, idempotent reduction ``op`` (min or
max) together with its *neutral element*, which is what the paper's
"process edges separately" becomes in a branch-free padded formulation.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray
Op = Callable[[Array, Array], Array]


@dataclasses.dataclass(frozen=True)
class MorphOp:
    """A lattice operation (min for erosion, max for dilation)."""

    name: str
    reduce: Op

    def neutral(self, dtype) -> np.generic:
        dtype = jnp.dtype(dtype)
        if dtype == jnp.bool_:
            # Boolean lattice: erosion (min/AND) is neutral on True, dilation
            # (max/OR) on False — the binary-mask case the RLE backend serves.
            return np.bool_(self.name == "min")
        if jnp.issubdtype(dtype, jnp.floating):
            inf = np.array(np.inf, dtype=dtype)
            return inf if self.name == "min" else -inf
        info = jnp.iinfo(dtype)
        return np.array(info.max if self.name == "min" else info.min, dtype=dtype)


MIN = MorphOp("min", jnp.minimum)
MAX = MorphOp("max", jnp.maximum)


def as_op(name_or_op) -> MorphOp:
    if isinstance(name_or_op, MorphOp):
        return name_or_op
    if name_or_op in ("min", "erode", "erosion"):
        return MIN
    if name_or_op in ("max", "dilate", "dilation"):
        return MAX
    raise ValueError(f"unknown morphological op: {name_or_op!r}")


def check_window(w: int) -> int:
    """Windows are odd (anchor at center), per the paper's 2*wing+1 form."""
    w = int(w)
    if w < 1 or w % 2 == 0:
        raise ValueError(f"structuring-element extent must be odd and >= 1, got {w}")
    return w


def widen_dtype(dtype) -> jnp.dtype:
    """Dtype in which morphological differences are computed.

    Integer images widen to ``promote_types(dtype, int32)`` (an i8/u8
    difference overflows its own type); floats keep their dtype. This is the
    single source of truth for the widening rule that used to be copied in
    ``core.morphology.gradient``, ``kernels.ops.gradient2d_tpu`` and the
    serving-plan gradient step.
    """
    dtype = jnp.dtype(dtype)
    if dtype == jnp.bool_:
        # bool is not an integer subdtype, but a boolean difference is not a
        # bool either (gradient of a mask counts 0/1 edges): widen like the
        # narrow integers do.
        return jnp.dtype(jnp.int32)
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.promote_types(dtype, jnp.int32)
    return dtype


def widened_sub(a: Array, b: Array) -> Array:
    """``a - b`` computed (and returned) in ``widen_dtype`` of the inputs."""
    wide = widen_dtype(jnp.result_type(a, b))
    return a.astype(wide) - b.astype(wide)
