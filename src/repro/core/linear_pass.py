"""Linear (O(w), low-constant) 1-D running min/max — pure-JAX implementation.

This is the paper's §5.1.2 / §5.2.2 "linear implementation": a single vector
accumulator reduced against ``w`` shifted loads. With SIMD each instruction
covers 16 pixels on NEON; under XLA each ``jnp.minimum`` covers a whole
(8,128)-tiled vreg batch on TPU, so the structure carries over unchanged.

Two variants are provided:

* ``linear_1d``           — the direct w-term reduction (paper's code).
* ``linear_1d_paired``    — the paper's row-pairing trick generalized: the
  shared inner reduction over ``w - 2`` terms is computed once and reused by
  the two outputs that straddle it. In the paper this halves work across two
  adjacent *rows* for a column-window; expressed on shifted views it is a
  shared partial reduction and generalizes to any axis.
* ``linear_1d_tree``      — beyond-paper: logarithmic "ladder" reduction.
  A window-w min can be built from O(log2 w) doubling steps (min of two
  shifts of a running half-window), dropping the per-pixel cost from w to
  ~ceil(log2 w) + 1 vector ops. This is profitable on TPU where each shifted
  operand is a lane-roll with the same cost as the min itself.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import Array, as_op, check_window


def _padded(x: Array, wing_lo: int, wing_hi: int, neutral) -> Array:
    return jnp.pad(
        x, [(0, 0)] * (x.ndim - 1) + [(wing_lo, wing_hi)], constant_values=neutral
    )


def _shift_slice(xp: Array, k: int, n: int) -> Array:
    return jax.lax.slice_in_dim(xp, k, k + n, axis=-1)


def linear_1d(x: Array, w: int, *, axis: int = -1, op="min") -> Array:
    """Direct O(w) reduction: out[i] = op_{k in [-wing, wing]} x[i+k]."""
    op = as_op(op)
    w = check_window(w)
    if w == 1:
        return x
    axis = axis % x.ndim
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    wing = (w - 1) // 2
    xp = _padded(x, wing, wing, op.neutral(x.dtype))
    val = _shift_slice(xp, 0, n)
    for k in range(1, w):  # unrolled, like the paper's inner intrinsic loop
        val = op.reduce(val, _shift_slice(xp, k, n))
    return jnp.moveaxis(val, -1, axis)


def linear_1d_paired(x: Array, w: int, *, axis: int = -1, op="min") -> Array:
    """Paper's shared-core trick: core = reduction over the w-2 interior
    terms, each output = op(core, two rim terms). Written so the core is
    computed once per *pair of outputs*; under XLA CSE the core slices for
    out[i] and out[i+1] share all but one term, mirroring the paper's
    filling of two adjacent rows from one accumulator."""
    op = as_op(op)
    w = check_window(w)
    if w <= 3:
        return linear_1d(x, w, axis=axis, op=op)
    axis = axis % x.ndim
    xm = jnp.moveaxis(x, axis, -1)
    n = xm.shape[-1]
    wing = (w - 1) // 2
    xp = _padded(xm, wing, wing, op.neutral(xm.dtype))
    # core[i] = reduction over padded [i+1, i+w-2]  (w-2 interior terms)
    core = _shift_slice(xp, 1, n)
    for k in range(2, w - 1):
        core = op.reduce(core, _shift_slice(xp, k, n))
    out = op.reduce(op.reduce(core, _shift_slice(xp, 0, n)), _shift_slice(xp, w - 1, n))
    return jnp.moveaxis(out, -1, axis)


def linear_1d_tree(x: Array, w: int, *, axis: int = -1, op="min") -> Array:
    """Beyond-paper logarithmic ladder.

    Maintain ``run(L)[i] = op over x[i .. i+L-1]`` and double L each step:
    ``run(2L)[i] = op(run(L)[i], run(L)[i+L])``. A final op stitches the
    remainder: run(w)[i] = op(run(L)[i], run(L)[i + w - L]) for any
    L >= w/2. Total ops: ceil(log2 w) doublings + 1 stitch.
    """
    op = as_op(op)
    w = check_window(w)
    if w == 1:
        return x
    axis = axis % x.ndim
    xm = jnp.moveaxis(x, axis, -1)
    n = xm.shape[-1]
    wing = (w - 1) // 2
    xp = _padded(xm, wing, wing, op.neutral(xm.dtype))
    m = xp.shape[-1]

    run, length = xp, 1
    while 2 * length <= w:
        shifted = _padded(
            _shift_slice(run, length, m - length), 0, length, op.neutral(xp.dtype)
        )
        run = op.reduce(run, shifted)
        length *= 2
    if length < w:
        k = w - length
        shifted = _padded(_shift_slice(run, k, m - k), 0, k, op.neutral(xp.dtype))
        run = op.reduce(run, shifted)
    out = _shift_slice(run, 0, n)
    return jnp.moveaxis(out, -1, axis)
