"""Core contribution of the paper: fast separable morphological filtering.

Public API re-exports. See DESIGN.md for how each piece maps to the paper.
"""
from repro.core.dispatch import DispatchPolicy, morph_1d, resolve_interpret
from repro.core.linear_pass import linear_1d, linear_1d_paired, linear_1d_tree
from repro.core.masks import band_mask, dilate_mask, erode_mask, maxpool2d
from repro.core.morphology import (
    blackhat,
    closing,
    dilate,
    dilate_naive,
    erode,
    erode_naive,
    gradient,
    morph2d_naive,
    opening,
    tophat,
)
from repro.core.types import MAX, MIN, MorphOp, as_op
from repro.core.vhgw import vhgw_1d

__all__ = [
    "DispatchPolicy",
    "morph_1d",
    "resolve_interpret",
    "linear_1d",
    "linear_1d_paired",
    "linear_1d_tree",
    "band_mask",
    "dilate_mask",
    "erode_mask",
    "maxpool2d",
    "erode",
    "dilate",
    "erode_naive",
    "dilate_naive",
    "opening",
    "closing",
    "gradient",
    "tophat",
    "blackhat",
    "morph2d_naive",
    "MorphOp",
    "MIN",
    "MAX",
    "as_op",
    "vhgw_1d",
]

from repro.core.derived import (  # noqa: E402
    close_open,
    geodesic_dilate,
    geodesic_erode,
    granulometry,
    h_maxima,
    h_minima,
    laplacian,
    occo,
    open_close,
    reconstruct_by_dilation,
    reconstruct_by_erosion,
)
