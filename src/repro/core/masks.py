"""Morphology applied to attention masks and frontend pooling.

These are the honest in-framework uses of the paper's primitive (DESIGN.md
§4): sliding-window (local) attention masks are dilations of the causal
diagonal; block-sparse masks can be grown/shrunk by dilation/erosion; and
max-pooling is dilation with a flat SE followed by striding.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.dispatch import morph_1d
from repro.core.linear_pass import linear_1d_tree
from repro.core.types import Array, check_window


def band_mask(q_len: int, kv_len: int, window: int, *, causal: bool = True) -> Array:
    """Local-attention mask as dilation of the diagonal.

    The identity band (i == j + offset) dilated along the key axis by a
    1 x (2*window-1) (or causal 1 x window) SE yields exactly the sliding
    window mask used by Gemma-2 / Hymba local layers.
    """
    offset = kv_len - q_len  # query i attends keys <= i + offset
    eye = (
        jnp.arange(q_len)[:, None] + offset == jnp.arange(kv_len)[None, :]
    ).astype(jnp.int8)
    if causal:
        # dilate only backwards in keys: shift the (2w-1) dilation and crop
        w = 2 * window - 1
        dil = linear_1d_tree(eye, check_window(w), axis=-1, op="max")
        keep = jnp.arange(kv_len)[None, :] <= jnp.arange(q_len)[:, None] + offset
        return (dil > 0) & keep
    w = 2 * window - 1
    return linear_1d_tree(eye, check_window(w), axis=-1, op="max") > 0


def dilate_mask(mask: Array, radius: int, *, axis: int = -1) -> Array:
    """Grow a boolean mask by ``radius`` along ``axis`` (SpecAugment-style)."""
    if radius == 0:
        return mask
    w = 2 * radius + 1
    return morph_1d(mask.astype(jnp.int8), w, axis=axis, op="max") > 0


def erode_mask(mask: Array, radius: int, *, axis: int = -1) -> Array:
    if radius == 0:
        return mask
    w = 2 * radius + 1
    return morph_1d(mask.astype(jnp.int8), w, axis=axis, op="min") > 0


def maxpool2d(x: Array, pool: int = 2) -> Array:
    """Max-pool = dilation with a flat pool x pool SE + striding.

    Uses an even-window variant: dilate with window (2*pool-1) centered, then
    sample the window-anchor grid. For pool in {2, 3} this matches the usual
    framing of pooling as a morphological operation.
    """
    w = 2 * pool - 1
    d = morph_1d(x, w, axis=-2, op="max")
    d = morph_1d(d, w, axis=-1, op="max")
    off = pool // 2
    return d[..., off::pool, off::pool]
