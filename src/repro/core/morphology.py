"""2-D grayscale morphology with flat rectangular structuring elements.

Public API of the paper's contribution: separable erosion/dilation plus the
derived operators (opening, closing, gradient, top-hat, black-hat). Every
2-D operator factors into two 1-D hybrid passes (core/dispatch.py), exactly
the paper's §5 pipeline; a deliberately naive non-separable reference is kept
for tests and for quantifying the separability win.

Shapes: (..., H, W) — arbitrary leading batch dims. SE: (w_h, w_w), odd
extents, anchor at center. Dtypes: u8/i8/i32/bf16/f32.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.dispatch import DispatchPolicy, Method, morph_1d
from repro.core.types import MAX, MIN, Array, as_op, check_window


def _separable(
    x: Array,
    se: tuple[int, int],
    op,
    method: Method = "auto",
    policy: DispatchPolicy | None = None,
) -> Array:
    w_h, w_w = (check_window(w) for w in se)
    op = as_op(op)
    # Pass order: sublane (H) pass first, then lane (W) pass — both orders are
    # mathematically identical (min/max commute); this order keeps the larger
    # intermediate in the layout the W-pass wants.
    y = morph_1d(x, w_h, axis=-2, op=op, method=method, policy=policy)
    return morph_1d(y, w_w, axis=-1, op=op, method=method, policy=policy)


def erode(x: Array, se=(3, 3), *, method: Method = "auto", policy=None) -> Array:
    """Grayscale erosion by a flat w_h x w_w rectangle."""
    return _separable(x, se, MIN, method, policy)


def dilate(x: Array, se=(3, 3), *, method: Method = "auto", policy=None) -> Array:
    """Grayscale dilation by a flat w_h x w_w rectangle."""
    return _separable(x, se, MAX, method, policy)


def opening(x: Array, se=(3, 3), **kw) -> Array:
    return dilate(erode(x, se, **kw), se, **kw)


def closing(x: Array, se=(3, 3), **kw) -> Array:
    return erode(dilate(x, se, **kw), se, **kw)


def gradient(x: Array, se=(3, 3), **kw) -> Array:
    """Morphological gradient; computed in a widened dtype for integers."""
    d, e = dilate(x, se, **kw), erode(x, se, **kw)
    if jnp.issubdtype(x.dtype, jnp.integer):
        wide = jnp.promote_types(x.dtype, jnp.int32)
        return (d.astype(wide) - e.astype(wide)).astype(jnp.int32)
    return d - e


def tophat(x: Array, se=(3, 3), **kw) -> Array:
    o = opening(x, se, **kw)
    if jnp.issubdtype(x.dtype, jnp.integer):
        return x.astype(jnp.int32) - o.astype(jnp.int32)
    return x - o


def blackhat(x: Array, se=(3, 3), **kw) -> Array:
    c = closing(x, se, **kw)
    if jnp.issubdtype(x.dtype, jnp.integer):
        return c.astype(jnp.int32) - x.astype(jnp.int32)
    return c - x


# ---------------------------------------------------------------------------
# Naive non-separable reference (the paper's implicit baseline): a full
# w_h*w_w-term reduction per pixel. Kept un-jitted-fast on purpose: tests and
# benchmarks use it as ground truth and to measure the separability speedup.
# ---------------------------------------------------------------------------


def morph2d_naive(x: Array, se=(3, 3), *, op="min") -> Array:
    op = as_op(op)
    w_h, w_w = (check_window(w) for w in se)
    wing_h, wing_w = (w_h - 1) // 2, (w_w - 1) // 2
    neutral = op.neutral(x.dtype)
    xp = jnp.pad(
        x,
        [(0, 0)] * (x.ndim - 2) + [(wing_h, wing_h), (wing_w, wing_w)],
        constant_values=neutral,
    )
    h, w = x.shape[-2], x.shape[-1]
    out = None
    for dy in range(w_h):
        for dx in range(w_w):
            sl = xp[..., dy : dy + h, dx : dx + w]
            out = sl if out is None else op.reduce(out, sl)
    return out


def erode_naive(x: Array, se=(3, 3)) -> Array:
    return morph2d_naive(x, se, op=MIN)


def dilate_naive(x: Array, se=(3, 3)) -> Array:
    return morph2d_naive(x, se, op=MAX)
