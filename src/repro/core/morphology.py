"""2-D grayscale morphology with flat rectangular structuring elements.

Public API of the paper's contribution: separable erosion/dilation plus the
derived operators (opening, closing, gradient, top-hat, black-hat). Every
function here is a thin wrapper over the morphology expression IR
(``repro.morph``): it builds the operator's graph and lowers it through
``lower_xla`` — two 1-D hybrid passes per primitive (core/dispatch.py),
exactly the paper's §5 pipeline. The same graphs lower to the fused Pallas
kernels (``repro.morph.lower_kernel``) and compile into serving plans
(``repro.morph.to_plan``), so this module, ``kernels/ops.py`` and
``serve/morph`` are one computation with three backends.

Shapes: (..., H, W) — arbitrary leading batch dims. SE: (w_h, w_w), odd
extents, anchor at center. Dtypes: u8/i8/i32/bf16/f32.

.. deprecated:: the per-call ``method=`` kwarg
    Fold it into the policy instead: ``DispatchPolicy(method="vhgw")``.
    The kwarg keeps working as a shim (``DispatchPolicy.with_overrides``).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.dispatch import DispatchPolicy, Method
from repro.core.types import MAX, MIN, Array, as_op, check_window


def _lower(expr_builder, x: Array, method: Method, policy) -> Array:
    """Build the operator graph and run it through the XLA lowering pass."""
    from repro.morph.expr import X
    from repro.morph.lower_xla import lower_xla

    policy = (policy or DispatchPolicy.calibrated()).with_overrides(method=method)
    return lower_xla(expr_builder(X), policy=policy)(x)


def erode(x: Array, se=(3, 3), *, method: Method = "auto", policy=None) -> Array:
    """Grayscale erosion by a flat w_h x w_w rectangle."""
    return _lower(lambda e: e.erode(se), x, method, policy)


def dilate(x: Array, se=(3, 3), *, method: Method = "auto", policy=None) -> Array:
    """Grayscale dilation by a flat w_h x w_w rectangle."""
    return _lower(lambda e: e.dilate(se), x, method, policy)


def opening(x: Array, se=(3, 3), *, method: Method = "auto", policy=None) -> Array:
    return _lower(lambda e: e.opening(se), x, method, policy)


def closing(x: Array, se=(3, 3), *, method: Method = "auto", policy=None) -> Array:
    return _lower(lambda e: e.closing(se), x, method, policy)


def gradient(x: Array, se=(3, 3), *, method: Method = "auto", policy=None) -> Array:
    """Morphological gradient; integer inputs return the centralized widened
    dtype (``core.types.widen_dtype`` — promote_types(dtype, int32)), the
    same rule the kernel and serving paths share."""
    return _lower(lambda e: e.gradient(se), x, method, policy)


def tophat(x: Array, se=(3, 3), *, method: Method = "auto", policy=None) -> Array:
    return _lower(lambda e: e.tophat(se), x, method, policy)


def blackhat(x: Array, se=(3, 3), *, method: Method = "auto", policy=None) -> Array:
    return _lower(lambda e: e.blackhat(se), x, method, policy)


# ---------------------------------------------------------------------------
# Naive non-separable reference (the paper's implicit baseline): a full
# w_h*w_w-term reduction per pixel. Kept un-jitted-fast on purpose: tests and
# benchmarks use it as ground truth and to measure the separability speedup.
# Deliberately NOT expressed via the IR — it is the independent oracle.
# ---------------------------------------------------------------------------


def morph2d_naive(x: Array, se=(3, 3), *, op="min") -> Array:
    op = as_op(op)
    w_h, w_w = (check_window(w) for w in se)
    wing_h, wing_w = (w_h - 1) // 2, (w_w - 1) // 2
    neutral = op.neutral(x.dtype)
    xp = jnp.pad(
        x,
        [(0, 0)] * (x.ndim - 2) + [(wing_h, wing_h), (wing_w, wing_w)],
        constant_values=neutral,
    )
    h, w = x.shape[-2], x.shape[-1]
    out = None
    for dy in range(w_h):
        for dx in range(w_w):
            sl = xp[..., dy : dy + h, dx : dx + w]
            out = sl if out is None else op.reduce(out, sl)
    return out


def erode_naive(x: Array, se=(3, 3)) -> Array:
    return morph2d_naive(x, se, op=MIN)


def dilate_naive(x: Array, se=(3, 3)) -> Array:
    return morph2d_naive(x, se, op=MAX)
