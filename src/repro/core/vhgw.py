"""van Herk / Gil-Werman 1-D running min/max — pure-JAX implementation.

Algorithm (paper §5.1.1): split the (padded) signal into segments of length
``w``; compute a forward prefix reduction ``F`` and a backward prefix
reduction ``B`` within each segment; then every window of length ``w`` spans
at most two adjacent segments and

    out[i] = op(B[i], F[i + w - 1])          (padded coordinates)

costs O(1) reductions per output element regardless of ``w`` — three
min/max per pixel amortized, exactly the paper's accounting.

The paper streams F and B through two image-sized scratch buffers; here they
are materialized as values and XLA fuses the scans, so the "doubled image
memory" cost of the paper becomes transient. The Pallas kernel variant
(kernels/morph_vhgw.py) keeps F/B entirely in VMEM per block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.types import Array, MorphOp, as_op, check_window


def _cum(op: MorphOp, x: Array, axis: int, reverse: bool = False) -> Array:
    fn = jax.lax.cummin if op.name == "min" else jax.lax.cummax
    if x.dtype == jnp.bool_:
        # the lax cum-scans reject bool; the 0/1 embedding is order-
        # isomorphic, so scan it and come back
        return fn(
            x.astype(jnp.uint8), axis=axis % x.ndim, reverse=reverse
        ).astype(jnp.bool_)
    return fn(x, axis=axis % x.ndim, reverse=reverse)


def vhgw_1d(x: Array, w: int, *, axis: int = -1, op="min") -> Array:
    """Running min/max of odd window ``w`` along ``axis`` (same-size output).

    Edge policy: neutral-element padding (erosion pads with dtype-max,
    dilation with dtype-min) — see DESIGN.md §2 for why this replaces the
    paper's separate edge loop.
    """
    op = as_op(op)
    w = check_window(w)
    if w == 1:
        return x
    axis = axis % x.ndim
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    wing = (w - 1) // 2

    # Pad so every window is in-bounds, then to a multiple of the segment
    # length w. Output element i corresponds to padded window [i, i + w - 1].
    neutral = op.neutral(x.dtype)
    padded = n + 2 * wing
    nseg = -(-padded // w)
    extra = nseg * w - padded
    xp = jnp.pad(
        x,
        [(0, 0)] * (x.ndim - 1) + [(wing, wing + extra)],
        constant_values=neutral,
    )
    segs = xp.reshape(x.shape[:-1] + (nseg, w))
    fwd = _cum(op, segs, axis=-1).reshape(x.shape[:-1] + (nseg * w,))
    bwd = _cum(op, segs, axis=-1, reverse=True).reshape(x.shape[:-1] + (nseg * w,))

    out = op.reduce(
        jax.lax.slice_in_dim(bwd, 0, n, axis=-1),
        jax.lax.slice_in_dim(fwd, w - 1, w - 1 + n, axis=-1),
    )
    return jnp.moveaxis(out, -1, axis)
