"""Launchers: production mesh, sharding rules, multi-pod dry-run, CLIs."""
