"""Per-tensor sharding rules: param-name-suffix -> PartitionSpec tail.

Strategy (DESIGN.md §5): 2-D "FSDP + TP" sharding. Every large matrix gets
one dim on the "data" axis (ZeRO-style — params, grads and AdamW moments
all fully sharded; the per-layer all-gather happens inside the layer scan)
and one on "model" (tensor parallelism). Attention heads shard over
"model" when divisible, otherwise head_dim / sequence takes the axis (see
``kv_cache_spec``). Batch shards over ("pod","data").

Specs are written for the *last* N dims of a leaf; leading dims (layer
stack, VLM group dims) are never sharded. Non-divisible dims drop their
axis (replicate) — guarded by ``_fits``.
"""
from __future__ import annotations

import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes

# (regex on leaf path, spec tail applied to trailing dims)
# dp = data axes tuple, tp = "model"
def _rules(dp, tp):
    return [
        (r"embed/embedding$", (tp, dp)),
        (r"embed/unembed$", (dp, tp)),
        (r"pos_embed$", (None, dp)),
        # attention
        (r"attn/wq$", (dp, tp, None)),
        (r"attn/wk$", (dp, tp, None)),
        (r"attn/wv$", (dp, tp, None)),
        (r"attn/wo$", (tp, dp)),
        (r"attn/b[qkv]$", (tp, None)),
        (r"xattn/wq$", (dp, tp, None)),
        (r"xattn/wk$", (dp, tp, None)),
        (r"xattn/wv$", (dp, tp, None)),
        (r"xattn/wo$", (tp, dp)),
        (r"xattn/b[qkv]$", (tp, None)),
        # dense mlp
        (r"mlp/w_gate$", (dp, tp)),
        (r"mlp/w_up$", (dp, tp)),
        (r"mlp/w_down$", (tp, dp)),
        # moe (leading E dim unsharded -> TP-in-expert; _EP_RULES below is the
        # shard_map expert-parallel layout, §Perf iteration D)
        (r"moe/router$", (dp, None)),
        (r"moe/w_gate$", (None, dp, tp)),
        (r"moe/w_up$", (None, dp, tp)),
        (r"moe/w_down$", (None, tp, dp)),
        # rwkv
        (r"tm/w[rkvgo]$", (dp, tp)),
        (r"tm/ddlerp_a$", (dp, None)),
        (r"tm/ddlerp_b$", (None, None, dp)),
        (r"tm/w_a$", (dp, None)),
        (r"tm/w_b$", (None, dp)),
        (r"cm/cm_wk$", (dp, tp)),
        (r"cm/cm_wv$", (tp, dp)),
        (r"cm/cm_wr$", (dp, tp)),
        # mamba (hymba)
        (r"mamba/in_proj$", (dp, tp)),
        (r"mamba/out_proj$", (tp, dp)),
        (r"mamba/x_proj$", (tp, None)),
        (r"mamba/conv_w$", (None, tp)),
        (r"mamba/(dt_bias|a_log|d_skip)$", (tp,) ),
        (r"mamba/a_log$", (tp, None)),
    ]


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return "/".join(parts)


def _fits(dim: int, axes, mesh) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return dim % size == 0


_EP_RULES = [  # §Perf iteration D: expert-parallel MoE layout
    (r"moe/router$", (None, None)),
    (r"moe/w_gate$", ("model", None, "DP")),
    (r"moe/w_up$", ("model", None, "DP")),
    (r"moe/w_down$", ("model", "DP", None)),
]


def spec_for_leaf(path: str, shape: tuple, mesh, *, moe_ep: bool = False) -> P:
    dp = data_axes(mesh)
    tp = "model"
    if moe_ep:
        for pattern, tail in _EP_RULES:
            if re.search(pattern, path):
                tail = tuple(dp if a == "DP" else a for a in tail)
                n = len(tail)
                lead = (None,) * (len(shape) - n)
                spec = [a if _fits(d, a, mesh) else None
                        for d, a in zip(shape[-n:], tail)]
                return P(*(lead + tuple(spec)))
    for pattern, tail in _rules(dp, tp):
        if re.search(pattern, path):
            n = len(tail)
            if n > len(shape):
                tail = tail[-len(shape):]
                n = len(tail)
            lead = (None,) * (len(shape) - n)
            spec = []
            for dim, axes in zip(shape[-n:], tail):
                spec.append(axes if _fits(dim, axes, mesh) else None)
            return P(*(lead + tuple(spec)))
    return P()  # replicate (norms, scalars, small vectors)


def _param_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree.leaves(tree):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n * jnp.dtype(leaf.dtype).itemsize
    return total


def _strip_dp(spec: P, dp) -> P:
    """Replace data-axis entries with None (replicate over DP)."""
    dpset = set(dp if isinstance(dp, tuple) else (dp,))
    def clean(e):
        if e is None:
            return None
        es = set(e) if isinstance(e, tuple) else {e}
        return None if es <= dpset else e
    return P(*(clean(e) for e in spec))


def tree_shardings(tree, mesh, *, serve: bool = False,
                   serve_hbm_budget: float = 12e9, moe_ep: bool = False) -> Any:
    """NamedSharding pytree matching ``tree`` (arrays or ShapeDtypeStructs).

    ``serve=True`` applies the inference sharding policy (§Perf iteration
    A): FSDP's data-axis param sharding exists to fit optimizer state and
    amortize per-layer all-gathers over large train batches; at decode
    every step pays the gather for 1 token of work. If TP-sharded params
    fit the HBM budget, replicate them over the data axes instead — the
    per-step param all-gathers disappear. Models too big for that
    (grok-1-314b) keep FSDP sharding.
    """
    dp = data_axes(mesh)
    replicate_dp = False
    if serve:
        per_chip = _param_bytes(tree) / mesh.shape["model"]
        replicate_dp = per_chip <= serve_hbm_budget
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        ps = _path_str(path)
        is_ep_leaf = moe_ep and re.search(r"moe/", ps)
        spec = spec_for_leaf(ps, tuple(leaf.shape), mesh, moe_ep=moe_ep)
        if replicate_dp and not is_ep_leaf:  # EP specs are shard_map ABI
            spec = _strip_dp(spec, dp)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Activations / inputs
# ---------------------------------------------------------------------------


def batch_spec(mesh, batch_size: int) -> P:
    dp = data_axes(mesh)
    return P(dp) if _fits(batch_size, dp, mesh) else P()


def token_shardings(mesh, batch: dict) -> dict:
    out = {}
    for k, v in batch.items():
        bspec = batch_spec(mesh, v.shape[0])
        if v.ndim >= 3 and v.shape[-1] % mesh.shape["model"] == 0:
            # frame/image embeddings: shard feature dim over TP too
            spec = P(*(bspec + (None,) * (v.ndim - 2) + ("model",)))
        else:
            spec = P(*(bspec + (None,) * (v.ndim - 1)))
        out[k] = NamedSharding(mesh, spec)
    return out


def activation_spec(mesh, cfg, seq: int) -> Optional[P]:
    """Megatron-SP-style constraint for the layer-scan carry (B, S, d):
    batch over DP, sequence over TP — bounds remat-saved bytes/chip."""
    dp = data_axes(mesh)
    if seq % mesh.shape["model"] == 0 and seq > 1:
        return P(dp, "model", None)
    return P(dp, None, None)


def kv_cache_spec(mesh, cfg, batch: int, kv_len: int) -> P:
    """(L, B, T, Kv, D) cache: heads over TP when divisible, else sequence
    (decode context parallelism); batch over DP when divisible."""
    dp = data_axes(mesh)
    b_ax = dp if _fits(batch, dp, mesh) else None
    if cfg.num_kv_heads % mesh.shape["model"] == 0:
        return P(None, b_ax, None, "model", None)
    if kv_len % mesh.shape["model"] == 0:
        return P(None, b_ax, "model", None, None)
    return P(None, b_ax, None, None, None)


def cache_shardings(mesh, cfg, cache, batch: int, kv_len: int):
    """Shardings for a DecodeCache pytree (by leaf path family)."""
    kvspec = kv_cache_spec(mesh, cfg, batch, kv_len)
    dp = data_axes(mesh)
    b_ax = dp if _fits(batch, dp, mesh) else None

    def leaf_spec(path, leaf):
        name = _path_str(path)
        top = name.split("/")[0]
        nd = len(leaf.shape)
        if top in ("k", "v", "cross_k", "cross_v", "k_scale", "v_scale") and nd >= 4:
            # KV-like: trailing dims (..., B, T, Kv, D)
            lead = (None,) * (nd - 4)
            return P(*(lead + tuple(kvspec)[-4:]))
        if top in ("rwkv", "mamba") and nd >= 2:
            # states: (L, B, ...) — batch over DP
            return P(None, b_ax, *(None,) * (nd - 2))
        return P(*(None,) * nd)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    out = [NamedSharding(mesh, leaf_spec(p, l)) for p, l in flat]
    return jax.tree_util.tree_unflatten(treedef, out)
