"""Serving launcher CLI: batched generation with the decode engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --batch 4 --prompt-len 8 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ARCH_IDS, get_config
from repro.models.model import init_params
from repro.serve import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32))
    ctx = None
    if cfg.family == "encdec":
        ctx = jnp.asarray(0.01 * rng.standard_normal(
            (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        ctx = jnp.asarray(0.01 * rng.standard_normal(
            (args.batch, cfg.num_image_tokens, cfg.d_model)), jnp.float32)

    t0 = time.perf_counter()
    toks = generate(cfg, params, prompt, max_new_tokens=args.new_tokens,
                    temperature=args.temperature, context=ctx)
    toks = np.asarray(toks)
    dt = time.perf_counter() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"[serve] generated {toks.shape} in {dt:.2f}s ({tps:.1f} tok/s)")
    print(f"[serve] sample: {toks[0][:16].tolist()}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
