"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 100 --batch 8 --seq 128 [--ckpt DIR] [--resume]

On a real fleet this binary runs once per host under the TPU runtime
(jax.distributed.initialize happens automatically from env); here it runs
on the local CPU device set. ``--reduced`` selects the smoke config; the
full configs are exercised via the dry-run (--dryrun delegates).
"""
from __future__ import annotations

import argparse

import jax

from repro.data import TokenPipeline, TokenPipelineConfig
from repro.models.config import ARCH_IDS, get_config
from repro.train import Trainer, TrainLoopConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced (CPU smoke) config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()

    import numpy as np

    base = TokenPipeline(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
    ), process_index=jax.process_index(), process_count=jax.process_count())

    def with_extras(it):
        rng = np.random.default_rng(0)
        for b in it:
            if cfg.family == "encdec":
                b["encoder_frames"] = 0.01 * rng.standard_normal(
                    (args.batch, cfg.encoder_seq, cfg.d_model)).astype(np.float32)
            if cfg.family == "vlm":
                b["image_embeddings"] = 0.01 * rng.standard_normal(
                    (args.batch, cfg.num_image_tokens, cfg.d_model)).astype(np.float32)
            yield b

    loop = TrainLoopConfig(
        total_steps=args.steps, warmup_steps=max(1, args.steps // 10),
        peak_lr=args.lr, microbatches=args.microbatches,
        checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt,
    )
    trainer = Trainer(cfg, loop, with_extras(base))
    metrics = trainer.run()
    print(f"[train] done: {metrics}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
