import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", ""
) + " --xla_force_host_platform_device_count=512"
# ^ MUST run before any other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  2. constructs ShapeDtypeStruct stand-ins for params / optimizer / inputs
     (jax.eval_shape — zero allocation),
  3. jits train_step or serve_step with the launch/sharding.py rules,
  4. ``.lower().compile()`` — any sharding mismatch, OOM-at-compile or
     unsupported collective fails the cell,
  5. records memory_analysis / cost_analysis / collective-bytes parsed from
     the HLO into benchmarks/results/dryrun/<cell>.json for §Roofline.

Shape grid (per assignment):
  train_4k     seq 4096  gbatch 256   train_step
  prefill_32k  seq 32768 gbatch 32    train-style forward (prefill lowering)
  decode_32k   seq 32768 gbatch 128   serve_step (1 token, 32k cache)
  long_500k    seq 524288 gbatch 1    serve_step — ssm/hybrid archs only

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch import sharding as shd
from repro.models import transformer as tfm
from repro.models.config import ARCH_IDS, get_config
from repro.models.model import (
    init_decode_cache,
    init_params,
    loss_fn,
    serve_step,
)
from repro.optim import adamw_init
from repro.train.loop import TrainLoopConfig, make_train_step

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def cell_applicable(arch: str, shape: str) -> tuple[bool, str]:
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("skipped: long_500k needs sub-quadratic attention; "
                       f"{arch} is full-attention (DESIGN.md §Arch-applicability)")
    return True, ""


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def input_specs(arch: str, shape: str, cfg=None, *, kv_cache_dtype=None) -> dict:
    """ShapeDtypeStructs for every model input of this cell."""
    cfg = cfg or get_config(arch)
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    i32 = jnp.int32
    if info["kind"] in ("train", "prefill"):
        batch = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        dt = jnp.dtype(cfg.dtype)
        if cfg.family == "encdec":
            batch["encoder_frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.d_model), dt)
        if cfg.family == "vlm":
            batch["image_embeddings"] = jax.ShapeDtypeStruct(
                (b, cfg.num_image_tokens, cfg.d_model), dt)
        return batch
    token = jax.ShapeDtypeStruct((b, 1), i32)
    pos = jax.ShapeDtypeStruct((), i32)
    cache = jax.eval_shape(
        lambda: init_decode_cache(cfg, b, s, kv_cache_dtype=kv_cache_dtype))
    return {"token": token, "pos": pos, "cache": cache}


# ---------------------------------------------------------------------------
# Lowering per cell
# ---------------------------------------------------------------------------


def lower_any(cfg, shape: str, mesh, *, serve_shardings: bool = False,
              donate_cache: bool = False, kv_cache_dtype=None,
              moe_ep: bool = False):
    """Lower one cell for an explicit ModelConfig (roofline probes pass
    modified configs; the dry-run passes the registered full config).

    ``serve_shardings`` / ``donate_cache`` are the §Perf decode iterations
    (A: replicate TP-sharded params over DP at inference; B: donate the KV
    cache so the update is in-place) — both default OFF so the recorded
    baseline stays the paper-faithful FSDP lowering."""
    info = SHAPES[shape]
    b, s = info["batch"], info["seq"]
    tfm.set_activation_spec(
        shd.activation_spec(mesh, cfg, s if info["kind"] != "decode" else 1))

    if info["kind"] == "train":
        specs = input_specs(cfg.name, shape, cfg)
        state_struct = jax.eval_shape(
            lambda: (lambda p: {"params": p, "opt": adamw_init(p)})(
                init_params(cfg, jax.random.PRNGKey(0))
            )
        )
        state_sh = shd.tree_shardings(state_struct, mesh)
        batch_sh = shd.token_shardings(mesh, specs)
        step = make_train_step(cfg, TrainLoopConfig(total_steps=1000))
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
        with jax.set_mesh(mesh):
            return jitted.lower(state_struct, specs)

    if info["kind"] == "prefill":
        # Prefill = the forward (loss without update) at full sequence:
        # the compute/collective profile of chunked-prefill serving.
        specs = input_specs(cfg.name, shape, cfg)
        params_struct = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        params_sh = shd.tree_shardings(params_struct, mesh)
        batch_sh = shd.token_shardings(mesh, specs)

        def fwd(params, batch):
            return loss_fn(cfg, params, batch)[0]

        jitted = jax.jit(fwd, in_shardings=(params_sh, batch_sh))
        with jax.set_mesh(mesh):
            return jitted.lower(params_struct, specs)

    # decode
    specs = input_specs(cfg.name, shape, cfg, kv_cache_dtype=kv_cache_dtype)
    params_struct = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    params_sh = shd.tree_shardings(params_struct, mesh, serve=serve_shardings,
                                   moe_ep=moe_ep)
    cache_sh = shd.cache_shardings(mesh, cfg, specs["cache"], b, s)
    tok_sh = NamedSharding(mesh, P(*(shd.batch_spec(mesh, b) + (None,))))
    pos_sh = NamedSharding(mesh, P())
    jitted = jax.jit(
        partial(serve_step, cfg),
        in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,) if donate_cache else (),
    )
    with jax.set_mesh(mesh):
        return jitted.lower(
            params_struct, specs["cache"], specs["token"], specs["pos"])


def lower_cell(arch: str, shape: str, mesh):
    return lower_any(get_config(arch), shape, mesh)


# ---------------------------------------------------------------------------
# Analysis extraction
# ---------------------------------------------------------------------------

_OPERAND_BYTES = {
    "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
    "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2,
}


def _shape_bytes(shape_str: str) -> int:
    """bytes of an HLO shape string like 'bf16[256,4096,3072]{...}'."""
    m = re.match(r"(\w+)\[([\d,]*)\]", shape_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _OPERAND_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum output-shape bytes of every collective op, keyed by kind.

    Scan bodies appear once in HLO but execute L times — the caller
    rescales using the scan trip counts (see roofline.py probe logic).
    """
    out = {k: 0 for k in COLLECTIVES}
    counts = {k: 0 for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"(?:ROOT )?[%\w.-]+ = ((?:\([^)]*\))|(?:\w+\[[^\]]*\][^ ]*)) (\w[\w-]*)\(", ls)
        if not m:
            continue
        shape_part, opname = m.groups()
        kind = None
        for c in COLLECTIVES:
            if opname.startswith(c.replace("-", "_")) or opname.startswith(c):
                kind = c
                break
        if kind is None:
            continue
        if shape_part.startswith("("):
            inner = shape_part[1:-1]
            total = sum(_shape_bytes(s.strip()) for s in inner.split(",") if "[" in s)
        else:
            total = _shape_bytes(shape_part)
        out[kind] += total
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def analyze(lowered, compiled) -> dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    out = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "transcendentals": float(cost.get("transcendentals", 0.0)),
        "collectives": coll,
    }
    for attr in (
        "generated_code_size_in_bytes",
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
    ):
        out[attr] = getattr(mem, attr, None)
    return out


def run_cell(arch: str, shape: str, *, multi_pod: bool) -> dict:
    ok, why = cell_applicable(arch, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {"arch": arch, "shape": shape, "mesh": mesh_name}
    if not ok:
        cell.update(status="skipped", reason=why)
        return cell
    mesh = make_production_mesh(multi_pod=multi_pod)
    # durations use the monotonic perf counter (repro.obs.now_s convention);
    # wall-clock is reserved for checkpoint metadata
    t0 = time.perf_counter()
    try:
        lowered = lower_cell(arch, shape, mesh)
        t1 = time.perf_counter()
        compiled = lowered.compile()
        t2 = time.perf_counter()
        cell.update(
            status="ok",
            lower_s=round(t1 - t0, 1),
            compile_s=round(t2 - t1, 1),
            analysis=analyze(lowered, compiled),
        )
    except Exception as e:  # noqa: BLE001 — cell failures are data
        cell.update(status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-2000:])
    finally:
        tfm.set_activation_spec(None)
    return cell


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        r = run_cell(arch, shape, multi_pod=args.multi_pod)
        status = r["status"]
        extra = ""
        if status == "ok":
            a = r["analysis"]
            extra = (f"flops={a['flops']:.3e} bytes={a['bytes_accessed']:.3e} "
                     f"coll={a['collectives']['total_bytes']:.3e} "
                     f"compile={r['compile_s']}s")
        elif status == "error":
            extra = r["error"]
        print(f"[dryrun] {arch:>22} {shape:<12} {r['mesh']:<8} {status:<8} {extra}",
              flush=True)
        results.append(r)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {len(results)} cells, {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
