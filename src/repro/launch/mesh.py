"""Production mesh construction for the LM stack (DESIGN.md §5).

A TPU v5e pod is 16x16 = 256 chips; the multi-pod config stacks 2 pods on
a leading "pod" (DCN) axis. Defined as functions so importing this module
never touches jax device state (device count is locked at first init).

These meshes partition *parameter* axes ("data"/"model"). The morphology
workload partitions the *image plane* instead — that mesh family lives in
``repro.shard.mesh`` (``image_mesh``: 1-D row strips / 2-D row x col
grids), which superseded the generic host-mesh scaffolding here for
everything morphology-shaped (DESIGN.md §10).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def data_axes(mesh) -> tuple:
    """Mesh axes carrying the batch dimension (DP across pods + intra-pod)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def make_host_mesh():
    """Whatever is locally available — used by examples/smoke runs."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
