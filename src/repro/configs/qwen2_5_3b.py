"""qwen2.5-3b [dense] — hf:Qwen/Qwen2.5-3B (family config per Qwen2.5 report).

36L, d_model=2048, 16 heads GQA kv=2, head_dim=128, d_ff=11008 SwiGLU,
vocab 151936, QKV bias, RoPE theta 1e6.
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151_936,
    ffn_act="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    notes="GQA kv=2 with QKV bias",
))
