"""dbrx-132b [moe] — hf:databricks/dbrx-base (unverified tier).

40L, d_model=6144, 48 heads GQA kv=8, head_dim=128, d_ff=10752 per expert,
vocab 100352, fine-grained MoE 16 experts top-4.
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100_352,
    ffn_act="swiglu",
    num_experts=16,
    num_experts_per_tok=4,
    tie_embeddings=False,
    notes="16 experts top-4, fine-grained",
))
