"""hymba-1.5b [hybrid] — arXiv:2411.13676 (hf-verified).

32L, d_model=1600, 25 heads GQA kv=5, head_dim=64, d_ff=5504,
ssm_state=16: parallel attention + Mamba heads in every layer, sliding
window attention everywhere except first/middle/last (global) layers.
Sub-quadratic: runs long_500k (window cache + O(1) SSM state).
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32_001,
    ffn_act="swiglu",
    ssm_state=16,
    ssm_expand=2,
    local_window=1024,
    layer_pattern="local",
    notes="parallel attn+mamba heads; SWA except layers {0, L/2, L-1}",
))
