"""gemma-7b [dense] — arXiv:2403.08295 (hf-verified).

28L, d_model=3072, 16 heads (GQA kv=16 == MHA), head_dim=256 (note:
heads*head_dim = 4096 != d_model; o_proj maps back), d_ff=24576 GeGLU,
vocab 256000, RoPE, tied embeddings with sqrt(d) input scaling.
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    head_dim=256,
    d_ff=24576,
    vocab_size=256_000,
    ffn_act="geglu",
    rope_theta=10_000.0,
    notes="GeGLU; head_dim=256; MQA variant exists on gemma-2b only",
))
