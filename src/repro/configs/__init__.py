"""Per-architecture configs. Importing this package registers all of them."""
from repro.configs import (  # noqa: F401
    gemma_7b,
    gemma2_2b,
    qwen2_5_3b,
    qwen1_5_0_5b,
    rwkv6_7b,
    grok_1_314b,
    dbrx_132b,
    whisper_medium,
    hymba_1_5b,
    llama_3_2_vision_90b,
    morphology,
)
