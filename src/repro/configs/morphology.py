"""The paper's own workload config: 800x600 u8 grayscale images,
rectangular SE sweep — used by benchmarks and the document-cleanup example."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class MorphologyConfig:
    height: int = 600
    width: int = 800
    dtype: str = "uint8"
    window_sweep: tuple = (3, 5, 9, 15, 21, 31, 41, 51, 61, 71, 81, 101, 121)
    paper_w0_minor: int = 59   # paper's w_x0 (lane-axis pass)
    paper_w0_major: int = 69   # paper's w_y0 (sublane-axis pass)


CONFIG = MorphologyConfig()
