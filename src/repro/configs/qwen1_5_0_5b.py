"""qwen1.5-0.5b [dense] — hf:Qwen/Qwen1.5-0.5B.

24L, d_model=1024, 16 heads (kv=16, MHA), head_dim=64, d_ff=2816 SwiGLU,
vocab 151936, QKV bias.
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151_936,
    ffn_act="swiglu",
    qkv_bias=True,
    notes="QKV bias; MHA (kv==heads)",
))
