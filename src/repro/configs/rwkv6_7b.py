"""rwkv6-7b [ssm] — RWKV-6 "Finch", arXiv:2404.05892 (hf-verified).

32L, d_model=4096, attention-free (WKV recurrence with data-dependent
per-channel decay), d_ff=14336 squared-relu channel-mix, vocab 65536.
head_dim fixed at 64 -> 64 WKV heads. Sub-quadratic: runs long_500k.
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    num_layers=32,
    d_model=4096,
    num_heads=64,       # d_model / 64 WKV heads
    num_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65_536,
    rope_theta=None,
    lora_rank=32,
    tie_embeddings=False,
    notes="Finch: ddlerp token shift + data-dependent decay; O(1) decode state",
))
