"""whisper-medium [audio] — arXiv:2212.04356 (unverified tier).

Enc-dec: 24L encoder + 24L decoder, d_model=1024, 16 heads (MHA),
head_dim=64, d_ff=4096 GELU, vocab 51865, LayerNorm, absolute positions
(sinusoidal encoder / learned decoder). Conv frontend is a STUB: the
assignment provides precomputed frame embeddings via input_specs(); the
data pipeline applies the paper's dilation to SpecAugment masks.
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    num_encoder_layers=24,
    encoder_seq=1500,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    ffn_act="gelu",
    norm="layernorm",
    rope_theta=None,
    pos_embed="absolute",
    max_position=32_768,   # stretched beyond whisper's 448 for decode_32k cells
    tie_embeddings=True,
    notes="enc-dec; conv frontend stubbed as precomputed frame embeddings",
))
