"""grok-1-314b [moe] — hf:xai-org/grok-1 (unverified tier).

64L, d_model=6144, 48 heads GQA kv=8, head_dim=128, d_ff=32768,
vocab 131072, MoE 8 experts top-2.
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131_072,
    ffn_act="geglu",
    num_experts=8,
    num_experts_per_tok=2,
    tie_embeddings=False,
    notes="8 experts top-2",
))
