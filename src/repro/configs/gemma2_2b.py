"""gemma2-2b [dense] — arXiv:2408.00118 (hf-verified).

26L, d_model=2304, 8 heads GQA kv=4, head_dim=256, d_ff=9216 GeGLU,
vocab 256000. Alternating local(window 4096)/global layers, logit softcap
30, attention softcap 50. Local band masks are built with the paper's
dilation primitive (core.masks.band_mask).
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    ffn_act="geglu",
    local_window=4096,
    layer_pattern="local_global",
    logit_softcap=30.0,
    attn_softcap=50.0,
    notes="local+global alternating; softcaps per Gemma-2 report",
))
