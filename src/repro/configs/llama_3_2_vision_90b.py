"""llama-3.2-vision-90b [vlm] — hf:meta-llama/Llama-3.2-90B-Vision (unverified).

100L total, d_model=8192, 64 heads GQA kv=8, head_dim=128, d_ff=28672
SwiGLU, vocab 128256. Every 5th layer is followed by image cross-attention
(20 cross-attn layers over precomputed patch embeddings — vision tower is
a STUB per the assignment; the data pipeline runs the paper's morphology
document-cleanup on images before the stub).
"""
from repro.models.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128_256,
    ffn_act="swiglu",
    rope_theta=500_000.0,
    cross_attn_every=5,
    num_image_tokens=1024,
    tie_embeddings=False,
    notes="80 self + 20 cross-attn layers; vision tower stubbed",
))
