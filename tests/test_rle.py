"""RLE binary morphology backend (repro.rle).

The load-bearing invariants:

* encode -> decode is the identity for any boolean mask (hypothesis-
  property-tested where available, seeded rng loops regardless);
* run-domain erode/dilate/opening/closing are bit-exact against the dense
  ``lower_xla`` path across densities and SE sizes — including SE wing
  far beyond the typical run length, the regime where every run dies or
  everything merges;
* ``lower_rle`` is bit-exact with ``lower_xla`` on randomized boolean
  expression graphs (both execution modes), rejects non-flat graphs with
  the typed :class:`RLEUnsupported`, and the jit mode's capacity-overflow
  fallback still returns exact results;
* the serving gate routes a mixed sparse/dense traffic stream to RLE and
  dense respectively, with the decisions visible in ``stats()``.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.data.images import synth_sparse_masks
from repro.morph import X, lower_xla, op_expr
from repro.rle import (
    RLEImage,
    RLEUnsupported,
    decode,
    encode,
    estimate_run_density,
    lower_rle,
    plan_rle_eligible,
    supports_expr,
)
from repro.rle import kernels, runs
from repro.serve.morph import MorphService, Plan, ServiceConfig, Step

RNG = np.random.default_rng(7)

SES = [(1, 1), (3, 3), (1, 7), (9, 1), (5, 7), (31, 3)]
OPS = ("erode", "dilate", "opening", "closing")


def mask(h, w, density=0.05):
    return RNG.random((h, w)) < density


def xla_ref(op, se, m):
    return np.asarray(lower_xla(op_expr(op, se))(jnp.asarray(m)))


# ------------------------------------------------------------- representation
def test_encode_decode_roundtrip_rng():
    for _ in range(25):
        h, w = RNG.integers(1, 50, 2)
        m = mask(h, w, RNG.choice([0.0, 0.01, 0.3, 1.0]))
        np.testing.assert_array_equal(decode(encode(m)), m)


def test_encode_rejects_non_bool_and_non_2d():
    with pytest.raises(TypeError, match="boolean"):
        encode(np.zeros((4, 4), np.uint8))
    with pytest.raises(ValueError, match="single"):
        encode(np.zeros((2, 4, 4), np.bool_))


def test_runs_are_sorted_and_maximal():
    m = mask(40, 60, 0.2)
    im = encode(m)
    assert im.n == im.rows.size
    order = np.lexsort((im.starts, im.rows))
    np.testing.assert_array_equal(order, np.arange(im.n))
    assert (im.ends > im.starts).all()
    # maximality: consecutive runs of one row never touch
    same = im.rows[1:] == im.rows[:-1]
    assert (im.starts[1:][same] > im.ends[:-1][same]).all()


def test_transpose_is_dense_transpose():
    for _ in range(10):
        h, w = RNG.integers(1, 40, 2)
        m = mask(h, w, 0.2)
        np.testing.assert_array_equal(decode(runs.transpose(encode(m))), m.T)


def test_estimate_run_density_exact_on_stride_1():
    m = synth_sparse_masks(1, 64, 256, run_density=0.01, seed=3)[0]
    exact = encode(m).n / m.size
    assert estimate_run_density(m, row_stride=1) == pytest.approx(exact)


# ------------------------------------------------------- dense-vs-RLE exactness
@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("se", SES)
def test_run_ops_match_dense(op, se):
    for density in (0.0, 0.005, 0.05, 0.4):
        m = mask(37, 53, density)
        got = decode(getattr(runs, op)(encode(m), se))
        np.testing.assert_array_equal(got, xla_ref(op, se, m))


def test_se_wing_exceeds_run_length():
    # mean run ~3 px against a 31-wide SE: every erosion survivor comes from
    # the virtual border rule, every dilation merges long chains
    m = synth_sparse_masks(1, 48, 200, run_density=0.02, mean_run=3, seed=5)[0]
    for op in OPS:
        got = decode(getattr(runs, op)(encode(m), (3, 31)))
        np.testing.assert_array_equal(got, xla_ref(op, (3, 31), m))


# ------------------------------------------------------------------ lower_rle
@pytest.mark.parametrize("mode", ["host", "jit"])
def test_lower_rle_matches_lower_xla_random_graphs(mode):
    for seed in range(8):
        rng = np.random.default_rng(seed)
        e = X
        for _ in range(rng.integers(1, 4)):
            op = OPS[rng.integers(len(OPS))]
            se = (1 + 2 * int(rng.integers(0, 4)), 1 + 2 * int(rng.integers(0, 4)))
            e = getattr(e, op)(se)
        m = rng.random((rng.integers(1, 64), rng.integers(1, 64))) < 0.05
        got = lower_rle(e, mode=mode)(m)
        assert got.dtype == np.bool_
        np.testing.assert_array_equal(got, np.asarray(lower_xla(e)(jnp.asarray(m))))


def test_lower_rle_batched_and_named_outputs():
    m = synth_sparse_masks(3, 40, 56, run_density=0.01, seed=2)
    outs = {"open": X.opening((3, 3)), "grown": X.dilate((5, 5))}
    got = lower_rle(outs)(m)
    want = lower_xla(outs)(jnp.asarray(m))
    for k in outs:
        assert got[k].shape == m.shape
        np.testing.assert_array_equal(got[k], np.asarray(want[k]))


def test_lower_rle_rejects_non_flat_graphs_typed():
    for e in (X.gradient((3, 3)), X.tophat((3, 3)),
              X.erode((3, 3)).astype("uint8")):
        assert not supports_expr(e)
        with pytest.raises(RLEUnsupported):
            lower_rle(e)
    # RLEUnsupported is a TypeError: one except clause covers dtype + graph
    assert issubclass(RLEUnsupported, TypeError)


def test_lower_rle_rejects_non_bool_input():
    with pytest.raises(TypeError, match="boolean"):
        lower_rle(X.erode((3, 3)))(np.zeros((8, 8), np.uint8))


def test_plan_eligibility():
    assert plan_rle_eligible(Plan("m", (Step("opening", (3, 3)),)))
    assert not plan_rle_eligible(Plan("g", (Step("gradient", (3, 3)),)))


# ------------------------------------------------------------- fixed capacity
def test_fixed_kernels_roundtrip_and_ops():
    m = mask(32, 48, 0.1)
    dec, overflow = kernels.roundtrip_fixed(jnp.asarray(m), 512)
    assert not bool(overflow)
    np.testing.assert_array_equal(np.asarray(dec), m)
    for op, se in (("erode", (3, 5)), ("dilate", (5, 3)),
                   ("opening", (3, 3)), ("closing", (3, 3))):
        out = getattr(kernels, f"{op}_fixed")(kernels.encode_fixed(m, 512), se)
        assert not bool(out.overflow)
        np.testing.assert_array_equal(
            np.asarray(kernels.decode_fixed(out)), xla_ref(op, se, m)
        )


def test_capacity_overflow_flag_is_sticky():
    m = mask(32, 32, 0.5)  # far more runs than capacity below
    im = kernels.encode_fixed(m, 8)
    assert bool(im.overflow)
    out = kernels.opening_fixed(im, (3, 3))
    assert bool(out.overflow)  # survives every stage


def test_jit_mode_overflow_falls_back_to_host_exactly():
    m = mask(64, 64, 0.5)
    e = X.opening((3, 3))
    got = lower_rle(e, mode="jit", capacity=16)(m)
    np.testing.assert_array_equal(got, np.asarray(lower_xla(e)(jnp.asarray(m))))


# ----------------------------------------------------------------- serving gate
def _svc_cfg(**kw):
    return ServiceConfig(window_ms=0.5, adaptive_window=False, **kw)


def test_service_density_gate_splits_mixed_traffic():
    plan = Plan("mask_open", (Step("opening", (3, 3)),))
    sparse = synth_sparse_masks(3, 128, 128, run_density=0.005, seed=0)
    dense = RNG.random((3, 128, 128)) < 0.5
    with MorphService(_svc_cfg()) as svc:
        got_s = svc.run_batch(list(sparse), plan)
        got_d = svc.run_batch(list(dense), plan)
        st = svc.stats()
    assert st["repr"]["rle"] == 3 and st["rle_requests"] == 3
    assert st["repr"]["dense"] == 3
    assert 0.0 < st["repr"]["density_p50"] < 0.05
    assert st["requests"] == 6
    want_s = np.asarray(lower_xla(X.opening((3, 3)))(jnp.asarray(sparse)))
    want_d = np.asarray(lower_xla(X.opening((3, 3)))(jnp.asarray(dense)))
    for i in range(3):
        np.testing.assert_array_equal(got_s[i], want_s[i])
        np.testing.assert_array_equal(got_d[i], want_d[i])


def test_service_rle_gate_off_serves_dense_only():
    plan = Plan("mask_open", (Step("opening", (3, 3)),))
    sparse = synth_sparse_masks(2, 64, 64, run_density=0.005, seed=1)
    with MorphService(_svc_cfg(rle_gate=False)) as svc:
        outs = svc.run_batch(list(sparse), plan)
        st = svc.stats()
    assert st["rle_requests"] == 0 and st["repr"]["rle"] == 0
    want = np.asarray(lower_xla(X.opening((3, 3)))(jnp.asarray(sparse)))
    for i in range(2):
        np.testing.assert_array_equal(outs[i], want[i])


def test_service_ineligible_plan_stays_dense():
    plan = Plan("edges", (Step("gradient", (3, 3)),))
    m = synth_sparse_masks(1, 64, 64, run_density=0.005, seed=2)[0]
    with MorphService(_svc_cfg()) as svc:
        out = svc.run_plan(m, plan)
        st = svc.stats()
    assert st["rle_requests"] == 0
    want = np.asarray(lower_xla(X.gradient((3, 3)))(jnp.asarray(m)))
    np.testing.assert_array_equal(out, want)


# --------------------------------------------------------------- data generator
def test_synth_sparse_masks_density_knob():
    for target in (0.002, 0.01, 0.05):
        m = synth_sparse_masks(1, 256, 512, run_density=target, seed=9)[0]
        got = encode(m).n / m.size
        # overlap merging pulls realized density below the knob, never above
        assert got <= target * 1.01
        assert got >= target * 0.5


# ------------------------------------------------------ hypothesis properties
try:
    from hypothesis import given, settings, strategies as st_

    _HAVE_HYPOTHESIS = True
except ImportError:  # minimal envs lack it; the rng loops above still run
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        seed=st_.integers(0, 2**31),
        h=st_.integers(1, 48),
        w=st_.integers(1, 48),
        density=st_.floats(0.0, 1.0),
    )
    def test_property_encode_decode_roundtrip(seed, h, w, density):
        m = np.random.default_rng(seed).random((h, w)) < density
        im = encode(m)
        np.testing.assert_array_equal(decode(im), m)
        assert im.density() == im.n / (h * w)

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st_.integers(0, 2**31),
        op=st_.sampled_from(OPS),
        se=st_.sampled_from(SES),
        density=st_.sampled_from([0.0, 0.01, 0.2, 0.9]),
    )
    def test_property_run_ops_match_dense(seed, op, se, density):
        m = np.random.default_rng(seed).random((30, 44)) < density
        got = decode(getattr(runs, op)(encode(m), se))
        np.testing.assert_array_equal(got, xla_ref(op, se, m))
