"""Correctness tests for the §Perf hillclimb features: banded local
attention, int8 KV cache, serve-mode shardings."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as shd
from repro.models import attention as A
from repro.models import transformer as tfm
from repro.models.config import get_config
from repro.models.model import (
    forward_train,
    init_decode_cache,
    init_params,
    serve_step,
)

pytestmark = pytest.mark.slow  # heavyweight: deselected from tier-1 (see pytest.ini)


def test_banded_equals_masked_full_attention():
    cfg = dataclasses.replace(get_config("gemma2-2b").reduced(), local_window=8)
    p = A.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(32)[None], (2, 32))
    full = A.self_attention(
        cfg, p, x, mask=A.causal_mask(32, 32, window=8), positions=pos)
    banded = A.local_attention_banded(cfg, p, x, positions=pos, window=8)
    np.testing.assert_allclose(np.asarray(banded), np.asarray(full),
                               rtol=2e-5, atol=2e-5)


def test_banded_fallback_when_not_divisible():
    cfg = dataclasses.replace(get_config("gemma2-2b").reduced(), local_window=8)
    p = A.attn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model)) * 0.3
    pos = jnp.broadcast_to(jnp.arange(12)[None], (2, 12))
    out = A.local_attention_banded(cfg, p, x, positions=pos, window=8)
    want = A.self_attention(
        cfg, p, x, mask=A.causal_mask(12, 12, window=8), positions=pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_banded_model_forward_matches_baseline():
    cfg = get_config("gemma2-2b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    base, _ = forward_train(cfg, params, batch)
    tfm.set_banded_local(True)
    try:
        opt, _ = forward_train(cfg, params, batch)
    finally:
        tfm.set_banded_local(False)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(opt, np.float32),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "gemma2-2b", "dbrx-132b"])
def test_int8_kv_decode_matches_bf16(arch):
    cfg = get_config(arch).reduced()
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 6), dtype=np.int32))
    c_ref = init_decode_cache(cfg, 2, 8)
    c_q = init_decode_cache(cfg, 2, 8, kv_cache_dtype="int8")
    assert c_q.k.dtype == jnp.int8
    for t in range(6):
        lr, c_ref = serve_step(cfg, p, c_ref, toks[:, t:t+1], jnp.int32(t))
        lq, c_q = serve_step(cfg, p, c_q, toks[:, t:t+1], jnp.int32(t))
    lr = np.asarray(lr, np.float32)
    lq = np.asarray(lq, np.float32)
    rel = np.abs(lr - lq).max() / (np.abs(lr).max() + 1e-9)
    assert rel < 0.05
    assert (lr.argmax(-1) == lq.argmax(-1)).mean() > 0.9


def test_serve_shardings_strip_dp():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    spec = P(None, ("data",), "model")
    assert shd._strip_dp(spec, ("data",)) == P(None, None, "model")
    # mixed tuple axis partially outside dp is preserved
    assert shd._strip_dp(P(("data", "model")), ("data",)) == P(("data", "model"))


def test_serve_shardings_budget_gate():
    """Small model replicates over DP at serve; huge model keeps FSDP."""
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    small = {"layers": {"mlp": {"w_gate": jax.ShapeDtypeStruct((8, 8), jnp.bfloat16)}}}
    sh = shd.tree_shardings(small, mesh, serve=True)
    assert sh["layers"]["mlp"]["w_gate"].spec == P(None, "model")  # DP stripped, TP kept
    sh_train = shd.tree_shardings(small, mesh, serve=False)
    assert sh_train["layers"]["mlp"]["w_gate"].spec == P("data", "model")
    huge = {"layers": {"mlp": {"w_gate": jax.ShapeDtypeStruct(
        (1 << 20, 1 << 14), jnp.bfloat16)}}}  # 32 GB > budget
    sh2 = shd.tree_shardings(huge, mesh, serve=True)
    assert sh2["layers"]["mlp"]["w_gate"].spec == P("data", "model")  # FSDP kept


def test_remat_policy_value_neutral():
    """§Perf iteration E: 'dots' remat must not change loss or grads."""
    import jax

    cfg = get_config("qwen1.5-0.5b").reduced()
    from repro.models.model import loss_fn

    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 16), jnp.int32),
             "labels": jnp.ones((2, 16), jnp.int32)}
    l1, _ = loss_fn(cfg, params, batch)
    g1 = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    tfm.set_remat_policy("dots")
    try:
        l2, _ = loss_fn(cfg, params, batch)
        g2 = jax.grad(lambda p: loss_fn(cfg, p, batch)[0])(params)
    finally:
        tfm.set_remat_policy("full")
    assert abs(float(l1) - float(l2)) < 1e-6
    diffs = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
             for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2))]
    assert max(diffs) < 1e-6
