"""Pallas kernels vs pure-jnp oracles: shape x dtype x window sweeps.

All kernels run in interpret mode (CPU container; TPU is the lowering
target). Results must be bit-exact for integer dtypes and exactly equal
for floats (min/max are exact ops).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    dilate2d_tpu,
    erode2d_tpu,
    gradient_1d_tpu,
    morph_1d_tpu,
    morph_linear_sublane,
    morph_vhgw_sublane,
    transpose_tiled,
)
from repro.kernels.ref import gradient_1d_ref, morph_1d_ref, transpose_ref
from repro.core import dilate_naive, erode_naive

RNG = np.random.default_rng(7)


def rand(shape, dtype):
    if np.issubdtype(dtype, np.floating):
        return jnp.asarray(RNG.standard_normal(shape).astype(dtype))
    info = np.iinfo(dtype)
    return jnp.asarray(RNG.integers(info.min, info.max, shape, dtype=dtype))


# ------------------------------------------------------------------ transpose
@pytest.mark.parametrize("shape", [(8, 8), (16, 16), (128, 128), (130, 257), (600, 800)])
@pytest.mark.parametrize("dtype", [np.uint8, np.uint16, np.float32])
def test_transpose_kernel(shape, dtype):
    x = rand(shape, dtype)
    got = np.asarray(transpose_tiled(x))
    np.testing.assert_array_equal(got, np.asarray(transpose_ref(x)))


@pytest.mark.parametrize("tile", [8, 16, 128])
def test_transpose_paper_tiles(tile):
    """The paper's 8x8.16 and 16x16.8 cases, plus the TPU-native 128 tile."""
    dtype = {8: np.uint16, 16: np.uint8, 128: np.float32}[tile]
    x = rand((tile, tile), dtype)
    got = np.asarray(transpose_tiled(x, tile=tile))
    np.testing.assert_array_equal(got, np.asarray(x).T)


def test_transpose_involution():
    x = rand((100, 259), np.uint8)
    np.testing.assert_array_equal(
        np.asarray(transpose_tiled(transpose_tiled(x))), np.asarray(x)
    )


# ------------------------------------------------------------- morph kernels
@pytest.mark.parametrize("kernel", [morph_linear_sublane, morph_vhgw_sublane])
@pytest.mark.parametrize("w", [3, 9, 31, 61])
@pytest.mark.parametrize("op", ["min", "max"])
def test_morph_kernels_vs_oracle(kernel, w, op):
    x = rand((137, 201), np.uint8)
    got = np.asarray(kernel(x, w=w, op=op))
    np.testing.assert_array_equal(got, np.asarray(morph_1d_ref(x, w, axis=0, op=op)))


@pytest.mark.parametrize("dtype", [np.uint8, np.int8, np.int32, np.float32])
def test_morph_kernels_dtypes(dtype):
    x = rand((64, 128), dtype)
    for kernel in (morph_linear_sublane, morph_vhgw_sublane):
        got = np.asarray(kernel(x, w=5, op="min"))
        np.testing.assert_array_equal(got, np.asarray(morph_1d_ref(x, 5, axis=0, op="min")))


@pytest.mark.parametrize("h,wd", [(37, 53), (600, 800), (128, 130)])
def test_morph_kernel_shapes(h, wd):
    x = rand((h, wd), np.uint8)
    for w in (3, 15):
        for axis in (0, 1):
            got = np.asarray(morph_1d_tpu(x, w, axis=axis, op="max"))
            np.testing.assert_array_equal(
                got, np.asarray(morph_1d_ref(x, w, axis=axis, op="max"))
            )


def test_lane_axis_strategies_agree():
    x = rand((96, 160), np.uint8)
    a = np.asarray(morph_1d_tpu(x, 7, axis=1, op="min", lane_strategy="transpose_kernel"))
    b = np.asarray(morph_1d_tpu(x, 7, axis=1, op="min", lane_strategy="xla"))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("se", [(3, 3), (5, 9), (31, 7)])
def test_2d_kernels_vs_naive(se):
    x = rand((97, 141), np.uint8)
    np.testing.assert_array_equal(
        np.asarray(erode2d_tpu(x, se)), np.asarray(erode_naive(x, se))
    )
    np.testing.assert_array_equal(
        np.asarray(dilate2d_tpu(x, se)), np.asarray(dilate_naive(x, se))
    )


def test_fused_gradient_kernel():
    x = rand((80, 144), np.uint8)
    for w in (3, 9, 21):
        got = np.asarray(gradient_1d_tpu(x, w, axis=0))
        np.testing.assert_array_equal(got, np.asarray(gradient_1d_ref(x, w, axis=0)))


def test_fused_gradient_float():
    x = rand((64, 128), np.float32)
    got = np.asarray(gradient_1d_tpu(x, 5, axis=0))
    np.testing.assert_allclose(got, np.asarray(gradient_1d_ref(x, 5, axis=0)), rtol=1e-6)
