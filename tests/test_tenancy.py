"""Tenant-aware overload control suite (ISSUE 9).

Drives the three new admission/ordering mechanisms plus the two satellite
fixes that ride with them:

* per-tenant quotas reject with the typed ``QuotaExceeded`` (an
  ``Overloaded`` that names the tenant) and release on completion;
* start-time fair queuing never starves a positive-weight tenant —
  asserted deterministically and as a hypothesis property with the
  analytic SFQ gap bound;
* the brownout ladder degrades in steps (widen window -> shed low
  priority typed -> shed all) with hysteresis, driven by queue depth and
  the dispatch-latency EWMA, and surfaces its level in ``stats()``;
* retry backoff is capped at the group's remaining deadline slack, so a
  retried request fails fast with ``DeadlineExceeded`` instead of
  sleeping past its deadline and dispatching anyway;
* RLE-routed requests honor admission control, quotas, and per-request
  deadlines (the ``("rle", plan, dtype)`` regression).
"""
import math
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.data.images import synth_sparse_masks
from repro.serve.morph import (
    BrownoutController,
    BrownoutPolicy,
    BrownoutShed,
    DeadlineExceeded,
    FairScheduler,
    FaultPlan,
    InjectedFault,
    MicroBatcher,
    MorphService,
    Overloaded,
    PRIORITY_HIGH,
    PRIORITY_LOW,
    PRIORITY_NORMAL,
    Plan,
    QuotaExceeded,
    RetryPolicy,
    ServiceConfig,
    Step,
    TenantQuota,
)
from repro.serve.morph.tenancy import effective_weight

RNG = np.random.default_rng(23)


def rand(h=40, w=50, dtype=np.uint8):
    return RNG.integers(0, 255, (h, w), dtype=dtype)


def cfg(**kw):
    kw.setdefault("buckets", ((64, 64),))
    kw.setdefault("window_ms", 1.0)
    kw.setdefault("retry", RetryPolicy(max_retries=0, backoff_ms=0.5))
    return ServiceConfig(**kw)


class Req:
    """Raw batcher-level request double (same shape test_resilience uses,
    plus the tenancy fields)."""

    def __init__(self, key="k", deadline=None, tenant=None,
                 priority=PRIORITY_NORMAL):
        self.key = key
        self.future = Future()
        self.deadline = deadline
        self.tenant = tenant
        self.priority = priority


# ------------------------------------------------------------------- quotas
def test_tenant_quota_validates():
    with pytest.raises(ValueError):
        TenantQuota(max_outstanding=0)
    with pytest.raises(ValueError):
        TenantQuota(weight=0.0)
    with pytest.raises(ValueError):
        TenantQuota(weight=-1.0)


def test_quota_exceeded_is_typed_and_tenant_scoped():
    """A tenant at its max_outstanding sheds alone — typed, non-retryable,
    naming the tenant — while other tenants keep flowing through the same
    queue; completed requests return the slots."""
    c = cfg(window_ms=150.0, max_batch=8,
            tenants={"free": TenantQuota(max_outstanding=2)})
    img = rand()
    with MorphService(c) as svc:
        held = [svc.submit(img, tenant="free") for _ in range(2)]
        with pytest.raises(QuotaExceeded) as ei:
            svc.submit(img, tenant="free")
        assert ei.value.tenant == "free"
        assert isinstance(ei.value, Overloaded)
        assert not ei.value.retryable
        # the shared queue is nowhere near full: other tenants unaffected
        gold = svc.submit(img, tenant="gold")
        anon = svc.submit(img)
        st = svc.stats()["resilience"]
        assert st["rejected_quota"] == 1
        assert st["tenants"]["free"]["rejected_quota"] == 1
        assert st["tenants"]["free"]["outstanding"] == 2
        for f in (*held, gold, anon):
            assert f.result(timeout=60) is not None
        # completion released the quota: the tenant is admitted again
        assert svc.submit(img, tenant="free").result(timeout=60) is not None


def test_unknown_tenant_gets_default_quota():
    with MorphService(cfg(tenants={"vip": TenantQuota(weight=8.0)})) as svc:
        out = svc.run(rand(), "erode", (3, 3), tenant="stranger")
        assert out is not None


# ------------------------------------------------- weighted-fair scheduling
def _simulate(tenants, priorities, rounds):
    """All tenants permanently backlogged, one single-member group each;
    dispatch the scheduler's top pick each round. Returns the dispatch
    sequence of tenant names."""
    fs = FairScheduler(tenants)
    names = list(tenants)
    seq = []
    for _ in range(rounds):
        items = [
            (0.0, t, [(t, priorities[t])]) for t in names
        ]
        winner = fs.order(items)[0]
        fs.account([(winner, priorities[winner])])
        seq.append(winner)
    return seq


def test_fair_ordering_tracks_weights():
    tenants = {"a": TenantQuota(weight=3.0), "b": TenantQuota(weight=1.0)}
    seq = _simulate(tenants, {"a": PRIORITY_NORMAL, "b": PRIORITY_NORMAL}, 200)
    na, nb = seq.count("a"), seq.count("b")
    assert nb > 0  # never starved
    assert 2.0 <= na / nb <= 4.0  # ~3:1 share


def test_priority_folds_into_share_not_strict_tiers():
    """High priority gets a larger share (the boost), but low priority is
    still dispatched — priority must not become a starvation tier."""
    tenants = {"hi": TenantQuota(), "lo": TenantQuota()}
    seq = _simulate(tenants, {"hi": PRIORITY_HIGH, "lo": PRIORITY_LOW}, 200)
    nh, nl = seq.count("hi"), seq.count("lo")
    assert nl > 0
    assert nh > nl  # boost = 4x weight for HIGH vs LOW


def _gap_bound(weights, t):
    """SFQ liveness bound: between two dispatches of backlogged tenant t,
    every other tenant u fits at most ceil(w_u/w_t) + 1 dispatches."""
    return 1 + sum(
        math.ceil(w / weights[t]) + 1 for u, w in weights.items() if u != t
    )


def test_no_starvation_deterministic():
    tenants = {
        "whale": TenantQuota(weight=10.0),
        "mid": TenantQuota(weight=2.0),
        "min": TenantQuota(weight=0.25),
    }
    prios = {t: PRIORITY_NORMAL for t in tenants}
    seq = _simulate(tenants, prios, 400)
    weights = {
        t: effective_weight(q, PRIORITY_NORMAL) for t, q in tenants.items()
    }
    for t in tenants:
        bound = _gap_bound(weights, t)
        last = -1
        for i, name in enumerate(seq):
            if name != t:
                continue
            assert i - last <= bound, (t, i - last, bound)
            last = i
        assert last >= 0, f"{t} never dispatched"


def test_idle_tenant_reenters_at_floor_not_with_credit():
    """A tenant that sat idle while others were served cannot burst ahead:
    its tag re-enters at the floor, not at its stale virtual time."""
    fs = FairScheduler({"busy": TenantQuota(), "idle": TenantQuota()})
    for _ in range(50):
        fs.account([("busy", PRIORITY_NORMAL)])
    step = 1.0 / effective_weight(TenantQuota(), PRIORITY_NORMAL)
    assert fs.tag("idle") == pytest.approx(fs.tag("busy") - step)


def test_no_starvation_property():
    """Hypothesis: for arbitrary positive weights and priority classes,
    every backlogged tenant is dispatched within the analytic gap bound."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    weights_st = st.lists(
        st.floats(0.1, 16.0, allow_nan=False), min_size=2, max_size=5
    )
    prios_st = st.lists(st.integers(0, 2), min_size=5, max_size=5)

    @settings(max_examples=40, deadline=None)
    @given(ws=weights_st, ps=prios_st)
    def prop(ws, ps):
        tenants = {
            f"t{i}": TenantQuota(weight=w) for i, w in enumerate(ws)
        }
        prios = {f"t{i}": ps[i] for i in range(len(ws))}
        eff = {
            t: effective_weight(q, prios[t]) for t, q in tenants.items()
        }
        bounds = {t: _gap_bound(eff, t) for t in tenants}
        # enough rounds that even the lightest tenant must appear
        rounds = max(bounds.values()) + 10
        seq = _simulate(tenants, prios, rounds)
        for t in tenants:
            bound = bounds[t]
            last = -1
            for i, name in enumerate(seq):
                if name == t:
                    assert i - last <= bound
                    last = i
            assert last >= 0
            assert len(seq) - last <= bound  # still live at the end

    prop()


def test_batcher_fair_order_under_flood():
    """End-to-end through MicroBatcher: a flooding tenant cannot starve a
    light one — the light tenant's requests complete interleaved, not
    parked behind the whole flood."""
    order = []

    def execute(key, reqs):
        for r in reqs:
            order.append(r.tenant)
            r.future.set_result(True)

    # max_batch > group size: groups pend until the window expires, so the
    # whole flood is due at once and dispatch order is the scheduler's
    b = MicroBatcher(execute, max_batch=4, window_s=0.05,
                     tenants={"whale": TenantQuota(weight=1.0),
                              "shrimp": TenantQuota(weight=1.0)},
                     retry=RetryPolicy(max_retries=0))
    try:
        reqs = []
        # distinct keys -> one group per request, all due at once
        for i in range(20):
            reqs.append(Req(key=f"w{i}", tenant="whale"))
        for i in range(4):
            reqs.append(Req(key=f"s{i}", tenant="shrimp"))
        for r in reqs:
            b.submit(r)
        for r in reqs:
            assert r.future.result(timeout=30)
    finally:
        b.close()
    # equal weights: shrimp's 4 must all land within the first ~half of the
    # dispatch order, not after the 20-deep whale flood
    last_shrimp = max(i for i, t in enumerate(order) if t == "shrimp")
    assert last_shrimp < 16, order


# ----------------------------------------------------------- brownout ladder
def test_brownout_policy_validates():
    with pytest.raises(ValueError):
        BrownoutPolicy(enter_widen=0.8, enter_shed=0.5)
    with pytest.raises(ValueError):
        BrownoutPolicy(hysteresis=-0.1)


def test_brownout_ladder_levels_and_hysteresis():
    p = BrownoutPolicy(enter_widen=0.5, enter_shed=0.75, enter_global=0.95,
                       hysteresis=0.10)
    c = BrownoutController(p, max_queue=100)
    assert c.update(49) == 0 and c.window_multiplier() == 1.0
    assert c.update(50) == 1 and c.window_multiplier() == p.window_widen
    assert not c.sheds(PRIORITY_LOW)
    assert c.update(75) == 2
    assert c.sheds(PRIORITY_LOW) and not c.sheds(PRIORITY_NORMAL)
    assert c.update(95) == 3
    assert c.sheds(PRIORITY_HIGH)  # level 3 sheds everything
    # hysteresis: level 3 holds until below enter_global - hysteresis
    assert c.update(86) == 3
    assert c.update(84) == 2
    # and level 1 holds at 41 (exit 0.40) but releases at 39
    assert c.update(41) == 1
    assert c.update(39) == 0
    assert c.transitions >= 5


def test_brownout_latency_ewma_escalates_one_level():
    p = BrownoutPolicy(latency_ms=10.0, latency_alpha=1.0)
    c = BrownoutController(p, max_queue=100)
    assert c.update(10) == 0
    c.observe_latency(50.0)
    assert c.update(10) == 1  # queue says 0, latency says worse
    assert c.snapshot()["latency_ewma_ms"] == 50.0
    c.observe_latency(1.0)
    assert c.update(10) == 0


def test_brownout_sheds_low_priority_typed():
    """With the worker pinned, queue depth climbs into level 2: low
    priority sheds with BrownoutShed while normal priority is admitted
    until the global bound, and stats() reports the active level."""
    import threading

    release = threading.Event()

    def execute(key, reqs):
        release.wait(30)
        for r in reqs:
            r.future.set_result(True)

    b = MicroBatcher(
        execute, max_batch=1, window_s=0.0, max_queue=10,
        brownout=BrownoutPolicy(enter_widen=0.15, enter_shed=0.3,
                                enter_global=0.9, hysteresis=0.05),
        retry=RetryPolicy(max_retries=0),
    )
    try:
        reqs = [Req(key=f"k{i}") for i in range(4)]
        for r in reqs:
            b.submit(r)  # outstanding climbs to 4 (>= 0.3 * 10)
        with pytest.raises(BrownoutShed) as ei:
            b.submit(Req(key="low", priority=PRIORITY_LOW))
        assert ei.value.level >= 2
        assert ei.value.priority == PRIORITY_LOW
        assert isinstance(ei.value, Overloaded)
        ok = Req(key="norm", priority=PRIORITY_NORMAL)
        b.submit(ok)  # normal class still admitted at level 2
        counters = b.counters()
        assert counters["shed_brownout"] == 1
        assert counters["brownout"]["level"] >= 2
        release.set()
        for r in reqs:
            assert r.future.result(timeout=30)
        assert ok.future.result(timeout=30)
    finally:
        release.set()
        b.close()


def test_brownout_service_integration_levels_in_stats():
    c = cfg(max_queue=10, window_ms=200.0, max_batch=1,
            brownout=BrownoutPolicy(enter_widen=0.15, enter_shed=0.3,
                                    enter_global=0.9, hysteresis=0.05),
            faults=FaultPlan(latency_ms=40.0))
    img = rand()
    with MorphService(c) as svc:
        accepted = [svc.submit(img) for _ in range(4)]
        with pytest.raises(BrownoutShed):
            svc.submit(img, priority=PRIORITY_LOW)
        st = svc.stats()["resilience"]
        assert st["brownout"]["level"] >= 2
        assert st["shed_brownout"] == 1
        for f in accepted:
            assert f.result(timeout=60) is not None


def test_default_brownout_cannot_preempt_max_queue_cliff():
    """The default ladder thresholds must leave single-tenant behavior
    untouched: everything rejected under default config is plain
    Overloaded at the max_queue cliff, not a BrownoutShed."""
    p = BrownoutPolicy()  # defaults: enter_global=0.95
    c = BrownoutController(p, max_queue=4)
    # no integer outstanding below max_queue=4 reaches frac 0.95
    for n in range(4):
        c.update(n)
        assert not c.sheds(PRIORITY_NORMAL)


# ------------------------------------- satellite: backoff capped by deadline
def test_retry_backoff_capped_at_deadline_slack():
    """A retried group whose backoff would sleep past the deadline fails
    fast with DeadlineExceeded instead — and well before the configured
    backoff elapses."""
    calls = []

    def execute(key, reqs):
        calls.append(time.monotonic())
        raise InjectedFault("flaky")

    b = MicroBatcher(
        execute, max_batch=4, window_s=0.0,
        retry=RetryPolicy(max_retries=3, backoff_ms=1000.0,
                          backoff_cap_ms=1000.0, bisect=False),
    )
    try:
        t0 = time.monotonic()
        r = Req(deadline=t0 + 0.08)
        b.submit(r)
        with pytest.raises(DeadlineExceeded):
            r.future.result(timeout=30)
        elapsed = time.monotonic() - t0
        # uncapped: first backoff alone is 1s; capped: ~80ms of slack
        assert elapsed < 0.8, elapsed
        assert len(calls) == 1  # never re-dispatched past the deadline
        assert b.counters()["deadline_expired"] == 1
    finally:
        b.close()


def test_retry_redrops_expired_members_before_sleeping():
    """Mixed group: the member with slack survives the retry, the expired
    member fails typed — the retry never rides an already-dead request."""
    attempts = []

    def execute(key, reqs):
        attempts.append([r.name for r in reqs])
        if len(attempts) == 1:
            raise InjectedFault("first dispatch dies")
        for r in reqs:
            r.future.set_result(True)

    b = MicroBatcher(
        execute, max_batch=4, window_s=0.0,
        retry=RetryPolicy(max_retries=2, backoff_ms=60.0,
                          backoff_cap_ms=60.0, bisect=False),
    )
    try:
        now = time.monotonic()
        short = Req(deadline=now + 0.03)
        long_ = Req(deadline=now + 30.0)
        short.name, long_.name = "short", "long"
        short.key = long_.key = "same-group"
        b.submit(short)
        b.submit(long_)
        assert long_.future.result(timeout=30)
        with pytest.raises(DeadlineExceeded):
            short.future.result(timeout=30)
        # the retry dispatched only the live member
        assert attempts[-1] == ["long"]
    finally:
        b.close()


# ------------------------------------ satellite: RLE route admission (S1)
RLE_PLAN = Plan("mask_open_t", (Step("opening", (3, 3)),))


def sparse_mask(seed=0):
    return synth_sparse_masks(1, 128, 128, run_density=0.005, seed=seed)[0]


def test_rle_route_honors_max_queue():
    """RLE-routed requests bypass bucketing, not admission: past max_queue
    they shed typed — and the rejected request never reaches the density
    probe (no repr decision is recorded for it)."""
    c = cfg(max_queue=1, window_ms=300.0)
    with MorphService(c) as svc:
        first = svc.submit_plan(sparse_mask(0), RLE_PLAN)
        with pytest.raises(Overloaded):
            svc.submit_plan(sparse_mask(1), RLE_PLAN)
        st = svc.stats()
        # admission rejected BEFORE the probe: one decision recorded, not two
        assert st["repr"]["rle"] + st["repr"]["dense"] == 1
        assert st["resilience"]["rejected_overloaded"] == 1
        assert first.result(timeout=60) is not None


def test_rle_route_honors_tenant_quota():
    c = cfg(window_ms=300.0,
            tenants={"free": TenantQuota(max_outstanding=1)})
    with MorphService(c) as svc:
        first = svc.submit_plan(sparse_mask(0), RLE_PLAN, tenant="free")
        with pytest.raises(QuotaExceeded):
            svc.submit_plan(sparse_mask(1), RLE_PLAN, tenant="free")
        assert first.result(timeout=60) is not None
    assert isinstance(first.result(), np.ndarray)


def test_rle_route_honors_mid_group_deadline():
    """Serial RLE execution: a group member whose deadline lapses while an
    earlier member runs fails typed instead of executing anyway."""
    c = cfg(window_ms=40.0, faults=FaultPlan(latency_ms=120.0))
    with MorphService(c) as svc:
        r1 = svc.submit_plan(sparse_mask(0), RLE_PLAN)
        r2 = svc.submit_plan(sparse_mask(1), RLE_PLAN, deadline_ms=60.0)
        assert r1.result(timeout=60) is not None
        with pytest.raises(DeadlineExceeded):
            r2.result(timeout=60)
        assert svc.stats()["resilience"]["deadline_expired"] >= 1


def test_rle_route_respects_fair_order_fields():
    """tenant/priority ride the RLE group key path end to end (smoke: the
    per-tenant dispatch counters tick for RLE-routed work)."""
    c = cfg(window_ms=1.0)
    with MorphService(c) as svc:
        out = svc.run_plan(sparse_mask(0), RLE_PLAN, tenant="gold",
                           priority=PRIORITY_HIGH)
        assert out is not None
        st = svc.stats()
        assert st["rle_requests"] == 1
        assert st["resilience"]["tenants"]["gold"]["dispatched"] == 1
