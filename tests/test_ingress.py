"""Ingress suite (ISSUE 10): wire protocol, worker hosts, frontier routing.

Three layers, pinned from the outside in:

* **proto** — framing round-trips; frozen message schemas (a key-set change
  is a protocol change and must show up here); tensor dtypes incl. bool;
  the typed error family round-trips losslessly (hypothesis over every
  wire error); version skew — unknown fields are ignored, an unknown
  version byte is answered with a typed ``ProtocolError`` on a surviving
  connection, never a drop;
* **worker** — a live ``WorkerHost`` serves bit-exact results; typed
  rejections (``UnknownPlan``, ``DeadlineExceeded``, ``QuotaExceeded``
  with its ``.tenant``) reconstruct client-side; drain-then-reject
  ``close()`` resolves every outstanding future exactly once with a result
  or ``ServiceClosed`` — never ``ConnectionLost``;
* **frontier** — crc32 affinity lands every (plan, bucket, dtype) group on
  its hash-owner worker; a killed worker's in-flight requests reroute with
  zero lost futures; a *gracefully* closing worker's traffic moves without
  callers ever seeing its ``ServiceClosed``; fleet ``stats()`` merges
  worker registries and ``export_trace()`` stitches a schema-valid
  multi-process timeline with zero open spans.

Everything runs on in-process ``WorkerHost``s over loopback sockets (real
frames, real reader threads) so the suite is tier-1; the one true
multi-*process* test (``spawn_worker`` fleet) is marked ``slow`` and runs
in the ingress CI job.
"""
import socket
import threading
import time
import zlib

import numpy as np
import pytest

from repro.serve.ingress import proto
from repro.serve.ingress.client import Connection, IngressClient
from repro.serve.ingress.frontier import Frontier
from repro.serve.ingress.stats import merge_process_traces, shift_events
from repro.serve.ingress.worker import WorkerHost, config_from_json, spawn_worker
from repro.serve.morph import (
    DeadlineExceeded,
    FailoverPolicy,
    FaultPlan,
    MorphService,
    QuotaExceeded,
    ServeError,
    ServiceClosed,
    ServiceConfig,
    TenantQuota,
    UnknownPlan,
    get_plan,
    single_op_plan,
)

RNG = np.random.default_rng(23)


def rand(h=40, w=50, dtype=np.uint8):
    return RNG.integers(0, 255, (h, w), dtype=dtype)


def svc_cfg(**kw):
    kw.setdefault("buckets", ((64, 64),))
    kw.setdefault("window_ms", 1.0)
    return ServiceConfig(**kw)


ERODE3 = single_op_plan("erode", (3, 3))
DILATE3 = single_op_plan("dilate", (3, 3))


def owner(plan, n, bucket=(64, 64), dtype=np.uint8):
    """The crc32 hash-owner index for a group, mirroring the frontier."""
    name = plan if isinstance(plan, str) else plan.name
    token = f"{name}|{bucket}|{np.dtype(dtype).str}".encode()
    return zlib.crc32(token) % n


def poll_until(pred, timeout=20.0, interval=0.01):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return pred()


# =========================================================== proto: framing
def test_frame_round_trip_header_and_payload():
    import io

    header = {"type": "submit", "id": 7, "nested": {"a": [1, 2]}}
    payload = bytes(range(256)) * 3
    buf = proto.encode_frame(header, payload)
    rfile = io.BytesIO(buf + proto.encode_frame({"type": "x"}))
    h1, p1 = proto.read_frame(rfile)
    assert h1 == header and p1 == payload
    h2, p2 = proto.read_frame(rfile)
    assert h2 == {"type": "x"} and p2 == b""
    assert proto.read_frame(rfile) is None  # clean EOF at a boundary


def test_frame_eof_mid_frame_is_connection_lost():
    import io

    buf = proto.encode_frame({"type": "submit", "id": 1}, b"abc")
    with pytest.raises(proto.ConnectionLost):
        proto.read_frame(io.BytesIO(buf[:3]))  # inside the prefix
    with pytest.raises(proto.ConnectionLost):
        proto.read_frame(io.BytesIO(buf[:-1]))  # inside the body


def test_frame_bad_magic_and_bad_lengths_are_protocol_errors():
    import io

    with pytest.raises(proto.ProtocolError):
        proto.read_frame(io.BytesIO(b"NOPE" + b"\x00" * 9))
    bad = proto._FRAME.pack(proto.MAGIC, proto.PROTOCOL_VERSION,
                            proto.MAX_HEADER + 1, 0)
    with pytest.raises(proto.ProtocolError):
        proto.read_frame(io.BytesIO(bad))


def test_unknown_version_rejected_after_frame_is_consumed():
    """The skew rule: the unparseable frame is consumed in full, the error
    is typed, and the *next* frame on the stream still reads — a v2 peer
    cannot wedge a v1 reader."""
    import io

    hdr = b'{"type": "submit"}'
    v2 = proto._FRAME.pack(proto.MAGIC, 2, len(hdr), 0) + hdr
    stream = io.BytesIO(v2 + proto.encode_frame({"type": "health", "id": 9}))
    with pytest.raises(proto.ProtocolError, match="version 2"):
        proto.read_frame(stream)
    h, _ = proto.read_frame(stream)
    assert h == {"type": "health", "id": 9}


def test_unknown_header_fields_are_ignored():
    """Additive evolution: decoders read with .get, so headers from a
    newer peer with extra fields parse into the same results."""
    meta, payload = proto.encode_tensor(rand())
    meta["compression"] = "zstd-someday"  # future field
    np.testing.assert_array_equal(proto.decode_tensor(meta, payload),
                                  proto.decode_tensor(dict(meta), payload))
    d = proto.encode_error(DeadlineExceeded("late"))
    d["severity"] = "page"  # future field
    assert isinstance(proto.decode_error(d), DeadlineExceeded)


# ==================================================== proto: frozen schemas
def test_frozen_message_schemas():
    """Key sets are the wire contract; a change here is a protocol rev."""
    h, _ = proto.submit_message(7, {"name": "document_cleanup"},
                                np.zeros((4, 4), np.uint8))
    assert set(h) == {"type", "id", "plan", "tensor", "deadline_ms", "tag",
                      "tenant", "priority", "trace"}
    assert set(h["tensor"]) == {"dtype", "shape"}

    h, _ = proto.result_message(7, {"out": np.zeros((2, 2), np.uint8)})
    assert set(h) == {"type", "id", "result"}
    assert set(h["result"]) == {"kind", "outputs"}
    assert set(h["result"]["outputs"][0]) == {"dtype", "shape", "name"}

    h, _ = proto.error_message(7, QuotaExceeded("over", tenant="free"))
    assert set(h) == {"type", "id", "error"}
    assert set(h["error"]) == {"name", "message", "retryable", "context",
                               "extra"}
    # context-free errors omit "extra" entirely (absent, not empty)
    h, _ = proto.error_message(None, proto.ProtocolError("bad"))
    assert set(h["error"]) == {"name", "message", "retryable", "context"}


def test_plan_wire_round_trip():
    spec = proto.plan_to_wire(ERODE3)
    rebuilt = proto.plan_from_wire(spec)
    assert rebuilt == ERODE3  # frozen dataclass equality: steps and all
    assert proto.plan_from_wire({"name": "document_cleanup"}) == \
        "document_cleanup"  # bare names resolve on the worker
    assert proto.plan_to_wire("document_cleanup") == {
        "name": "document_cleanup"
    }
    with pytest.raises(proto.ProtocolError):
        proto.plan_from_wire({})


# ===================================================== proto: tensor dtypes
@pytest.mark.parametrize("dtype", [
    np.bool_, np.uint8, np.uint16, np.int32, np.int64, np.float32,
    np.float64,
])
def test_tensor_round_trip_dtypes(dtype):
    if dtype is np.bool_:
        arr = RNG.integers(0, 2, (13, 17)).astype(np.bool_)
    elif np.issubdtype(dtype, np.floating):
        arr = RNG.random((13, 17)).astype(dtype)
    else:
        arr = RNG.integers(0, 100, (13, 17)).astype(dtype)
    meta, payload = proto.encode_tensor(arr)
    out = proto.decode_tensor(meta, payload)
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(out, arr)


def test_result_round_trip_dict_and_bare_array():
    d = {"edges": rand(8, 9), "mask": rand(8, 9).astype(np.bool_)}
    meta, payload = proto.encode_result(d)
    out = proto.decode_result(meta, payload)
    assert set(out) == set(d)
    for k in d:
        np.testing.assert_array_equal(out[k], d[k])
        assert out[k].dtype == d[k].dtype
    arr = rand(5, 6)
    meta, payload = proto.encode_result(arr)
    out = proto.decode_result(meta, payload)
    assert isinstance(out, np.ndarray)  # bare in, bare out
    np.testing.assert_array_equal(out, arr)


def test_tensor_short_payload_is_protocol_error():
    meta, payload = proto.encode_tensor(rand())
    with pytest.raises(proto.ProtocolError):
        proto.decode_tensor(meta, payload[:-1])


# ================================================= proto: typed error family
def _build_error(name, message, ctx, extra):
    cls = proto.WIRE_ERRORS[name]
    kw = dict(ctx)
    if name == "QuotaExceeded":
        kw["tenant"] = extra
    elif name == "BrownoutShed":
        kw.update(level=3, priority=0)
    elif name == "PoisonedRequest":
        kw["tag"] = extra
    return cls(message, **kw)


def _assert_error_round_trips(exc):
    import json

    wire = json.loads(json.dumps(proto.encode_error(exc),
                                 default=proto._json_default))
    got = proto.decode_error(wire)
    assert type(got) is type(exc)
    assert str(got) == str(exc)  # incl. the composed [ctx] suffix
    assert got.retryable == exc.retryable
    for f in proto._CONTEXT_FIELDS + proto._EXTRA_FIELDS:
        assert getattr(got, f, None) == getattr(exc, f, None), f


@pytest.mark.parametrize("name", sorted(proto.WIRE_ERRORS))
def test_error_round_trip_every_wire_type(name):
    """Deterministic sweep: every wire error, with and without context,
    reconstructs losslessly through real JSON."""
    _assert_error_round_trips(_build_error(name, "plain message", {}, "t1"))
    _assert_error_round_trips(_build_error(
        name, "with context",
        {"plan": "document_cleanup", "bucket": (64, 64), "dtype": "|u1",
         "batch": 3, "shard": 2},
        "gold",
    ))


def test_error_round_trip_all_wire_types_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    names = sorted(proto.WIRE_ERRORS)
    ctx = st.fixed_dictionaries({}, optional={
        "plan": st.sampled_from(["document_cleanup", "erode3x3"]),
        "bucket": st.tuples(st.integers(1, 4096), st.integers(1, 4096)),
        "dtype": st.sampled_from(["|u1", "|b1", "<f4"]),
        "batch": st.integers(1, 64),
        "shard": st.integers(0, 7),
    })

    @settings(deadline=None, max_examples=120)
    @given(name=st.sampled_from(names), message=st.text(max_size=60),
           context=ctx, extra=st.text(min_size=1, max_size=12))
    def check(name, message, context, extra):
        _assert_error_round_trips(_build_error(name, message, context, extra))

    check()


def test_unknown_error_name_degrades_to_serveerror():
    got = proto.decode_error({
        "name": "FutureFancyError", "message": "from a newer server",
        "retryable": True, "context": {"plan": "p"},
    })
    assert type(got) is ServeError
    assert got.retryable is True  # the newer peer's verdict, as data
    assert got.plan == "p"
    # and a non-ServeError on the wire names its class in the message
    d = proto.encode_error(ValueError("boom"))
    assert d["name"] == "ServeError" and "ValueError" in d["message"]


# ======================================================== worker: round trip
def test_worker_serves_bit_exact_results():
    imgs = [rand(40 + i, 50) for i in range(6)]
    with MorphService(svc_cfg()) as direct:
        refs = [direct.run_plan(im, "document_cleanup") for im in imgs]
    with WorkerHost(config=svc_cfg(), worker_id=0) as host:
        with IngressClient(host.address) as client:
            outs = [client.run_plan(im, "document_cleanup") for im in imgs]
            stats = client.stats()
            health = client.health()
    for got, ref in zip(outs, refs):
        assert set(got) == set(ref)
        for k in ref:
            np.testing.assert_array_equal(got[k], np.asarray(ref[k]))
            assert got[k].dtype == np.asarray(ref[k]).dtype
    assert stats["requests"] >= len(imgs)
    assert health["worker"] == 0 and health["closing"] is False
    assert host.requests == len(imgs)


def test_worker_reconstructs_typed_errors():
    cfg = svc_cfg(tenants={"free": TenantQuota(max_outstanding=1)},
                  faults=FaultPlan(latency_ms=80.0), window_ms=20.0)
    with WorkerHost(config=cfg) as host:
        with Connection(host.address) as conn:
            with pytest.raises(UnknownPlan):
                conn.submit_plan(rand(), "no_such_plan").result(30)
            with pytest.raises(DeadlineExceeded):
                conn.submit_plan(rand(), ERODE3, deadline_ms=0).result(30)
            # fill the free tenant's single slot (held by the 80 ms fault),
            # then overflow it — same connection, so ordering is the wire's
            first = conn.submit_plan(rand(), ERODE3, tenant="free")
            with pytest.raises(QuotaExceeded) as ei:
                conn.submit_plan(rand(), ERODE3, tenant="free").result(30)
            assert ei.value.tenant == "free"
            assert isinstance(first.result(60), np.ndarray)


def test_worker_answers_unknown_message_and_version_typed():
    """Skew over a real socket: garbage message types and future version
    bytes get typed replies and the connection keeps serving."""
    with WorkerHost(config=svc_cfg()) as host:
        s = socket.create_connection(host.address)
        rfile = s.makefile("rb")
        try:
            hdr = b'{"type": "submit", "id": 3}'
            s.sendall(proto._FRAME.pack(proto.MAGIC, 2, len(hdr), 0) + hdr)
            s.sendall(proto.encode_frame({"type": "frobnicate", "id": 4}))
            s.sendall(proto.encode_frame({"type": "health", "id": 5}))
            h1, _ = proto.read_frame(rfile)
            assert h1["type"] == "error" and h1["id"] is None
            exc = proto.decode_error(h1["error"])
            assert isinstance(exc, proto.ProtocolError)
            assert "version 2" in str(exc)
            h2, _ = proto.read_frame(rfile)
            assert h2["type"] == "error" and h2["id"] == 4
            assert isinstance(proto.decode_error(h2["error"]),
                              proto.ProtocolError)
            h3, _ = proto.read_frame(rfile)
            assert h3["type"] == "health_result" and h3["id"] == 5
        finally:
            s.close()


def test_worker_ignores_unknown_submit_fields():
    with WorkerHost(config=svc_cfg()) as host:
        with Connection(host.address) as conn:
            img = rand()
            header, payload = proto.submit_message(
                None, proto.plan_to_wire(ERODE3), img
            )
            header["routing_hints"] = {"zone": "us-east1-b"}  # future field
            rid, fut = conn._register()
            header["id"] = rid
            conn._send(rid, header, payload)
            assert isinstance(fut.result(30), np.ndarray)


# =============================================== worker: drain-then-reject
def test_close_resolves_every_future_exactly_once():
    """The ISSUE 10 shutdown satellite: close() mid-request drains accepted
    work to results and answers late work with typed ServiceClosed; no
    future resolves twice, none hangs, and none sees ConnectionLost."""
    cfg = svc_cfg(faults=FaultPlan(latency_ms=120.0), window_ms=1.0)
    resolved = []
    rlock = threading.Lock()

    def track(fut):
        with rlock:
            resolved.append(fut)

    with WorkerHost(config=cfg) as host:
        conn = Connection(host.address)
        early = [conn.submit_plan(rand(40 + i, 50), ERODE3)
                 for i in range(6)]
        # "accepted" means read off the socket and admitted, not merely in
        # the TCP buffer — wait for that before closing, so the early/late
        # split below is deterministic
        assert poll_until(lambda: host.requests == len(early), timeout=10)
        closer = threading.Thread(target=host.close)
        closer.start()
        # once the closing flag is up, every further submit must be
        # rejected typed — never raced into the batcher, never dropped
        assert poll_until(lambda: host._closing, timeout=10)
        late = [conn.submit_plan(rand(), ERODE3) for _ in range(6)]
        for f in early + late:
            f.add_done_callback(track)
        closer.join(timeout=60)
        assert not closer.is_alive()
        assert host.wait_closed(10)

    results, closed_errs = 0, 0
    for f in early + late:
        assert f.done()
        exc = f.exception(timeout=0)
        if exc is None:
            assert isinstance(f.result(), np.ndarray)
            results += 1
        else:
            assert isinstance(exc, ServiceClosed), exc
            assert not isinstance(exc, proto.ConnectionLost)
            closed_errs += 1
    assert results >= len(early)  # accepted work drained to real results
    assert closed_errs == len(late)  # post-flag work rejected typed
    assert len(resolved) == len(early) + len(late)  # exactly once each
    conn.close()


def test_shutdown_rpc_drains_remotely():
    with WorkerHost(config=svc_cfg()) as host:
        with IngressClient(host.address) as client:
            assert isinstance(client.run(rand(), "erode", (3, 3)),
                              np.ndarray)
            client.shutdown_server()
        assert host.wait_closed(30)
    # post-close dials are refused at the socket — the listener is gone
    with pytest.raises(OSError):
        socket.create_connection(host.address, timeout=2.0)


# ========================================================= frontier: routing
def two_hosts(cfgs=None):
    cfgs = cfgs or [svc_cfg(shard=i) for i in range(2)]
    return [WorkerHost(config=c, worker_id=i) for i, c in enumerate(cfgs)]


def test_frontier_affinity_and_bit_exact():
    """Every (plan, bucket, dtype) group lands on its crc32 owner — the
    cross-process extension of the shard router's affinity — and results
    are bit-exact vs a direct MorphService."""
    hosts = two_hosts()
    imgs = [rand(40 + i, 50) for i in range(4)]
    with MorphService(svc_cfg()) as direct:
        refs = {
            "erode": [np.asarray(direct.run_plan(im, ERODE3)) for im in imgs],
            "dilate": [np.asarray(direct.run_plan(im, DILATE3)) for im in imgs],
        }
    try:
        with Frontier([h.address for h in hosts],
                      buckets=((64, 64),)) as front:
            for plan, key in ((ERODE3, "erode"), (DILATE3, "dilate")):
                for im, ref in zip(imgs, refs[key]):
                    np.testing.assert_array_equal(
                        np.asarray(front.run_plan(im, plan)), ref
                    )
            stats = front.stats()
        # affinity: each plan's traffic went only to its hash owner
        expected = [0, 0]
        for plan in (ERODE3, DILATE3):
            expected[owner(plan, 2)] += len(imgs)
        assert [h.requests for h in hosts] == expected
        assert stats["workers"] == 2 and stats["healthy_workers"] == 2
        assert stats["requests"] == 2 * len(imgs)
    finally:
        for h in hosts:
            h.close()


def test_frontier_worker_kill_reroutes_zero_lost():
    """Chaos: SIGKILL-equivalent on the owner worker mid-flight. Every
    future resolves with the bit-exact result via the survivor; the dead
    worker reads open in fleet health; merged stats still compute."""
    victim = owner(ERODE3, 2)
    cfgs = [svc_cfg(shard=i) for i in range(2)]
    cfgs[victim] = svc_cfg(shard=victim, faults=FaultPlan(latency_ms=150.0))
    hosts = two_hosts(cfgs)
    imgs = [rand(40 + i, 50) for i in range(8)]
    with MorphService(svc_cfg()) as direct:
        refs = [np.asarray(direct.run_plan(im, ERODE3)) for im in imgs]
    try:
        with Frontier([h.address for h in hosts],
                      buckets=((64, 64),),
                      failover=FailoverPolicy(probe_interval_s=600.0)) as front:
            futs = [front.submit_plan(im, ERODE3) for im in imgs]
            hosts[victim].kill()  # no drain, no typed goodbye
            results = [f.result(timeout=120) for f in futs]
            for got, ref in zip(results, refs):
                np.testing.assert_array_equal(np.asarray(got), ref)
            # late traffic routes straight to the survivor
            late = np.asarray(front.run_plan(imgs[0], ERODE3))
            np.testing.assert_array_equal(late, refs[0])
            stats = front.stats()
        assert stats["health"][victim]["state"] == "open"
        assert stats["healthy_workers"] == 1
        assert stats["per_worker"][victim] is None  # dead, not required
        assert stats["per_worker"][1 - victim] is not None
        assert stats["requests"] == len(imgs) + 1
        assert hosts[1 - victim].requests >= len(imgs)
    finally:
        for h in hosts:
            h.kill() if not h._closed.is_set() else None


def test_frontier_graceful_worker_close_moves_traffic():
    """A worker announcing its drain (typed ServiceClosed) is a routing
    event, not a caller-visible failure: the frontier marks it dead and
    moves the group to the survivor — every caller gets a result."""
    victim = owner(ERODE3, 2)
    hosts = two_hosts()
    imgs = [rand(40 + i, 50) for i in range(6)]
    with MorphService(svc_cfg()) as direct:
        refs = [np.asarray(direct.run_plan(im, ERODE3)) for im in imgs]
    try:
        with Frontier([h.address for h in hosts],
                      buckets=((64, 64),),
                      failover=FailoverPolicy(probe_interval_s=600.0)) as front:
            np.testing.assert_array_equal(
                np.asarray(front.run_plan(imgs[0], ERODE3)), refs[0]
            )
            hosts[victim].close()  # graceful: drain-then-reject
            for im, ref in zip(imgs, refs):
                np.testing.assert_array_equal(
                    np.asarray(front.run_plan(im, ERODE3)), ref
                )
            assert front.stats()["health"][victim]["state"] == "open"
    finally:
        for h in hosts:
            h.close()


# =================================================== frontier: stats/traces
def test_frontier_merges_stats_and_cross_process_trace():
    from repro.obs import ObsConfig, validate_chrome_trace

    cfgs = [svc_cfg(shard=i, obs=ObsConfig()) for i in range(2)]
    hosts = two_hosts(cfgs)
    try:
        with Frontier([h.address for h in hosts], buckets=((64, 64),),
                      obs=ObsConfig()) as front:
            for i in range(4):
                front.run_plan(rand(40 + i, 50), ERODE3)
                front.run_plan(rand(40 + i, 50), DILATE3)
            stats = front.stats()
            doc = front.export_trace()
            open_spans = front.open_spans()
        assert stats["requests"] == 8
        assert stats["batches"] >= 1  # merged from worker registries
        assert stats["p99_ms"] > 0.0
        assert set(stats["cache"]) >= {"size", "hits", "misses"}
        assert "tenants" in stats["resilience"]
        assert validate_chrome_trace(doc) == []
        pids = {e.get("pid") for e in doc["traceEvents"]}
        assert "frontier" in pids and len(pids) >= 3  # both worker lanes
        # frontier-minted IDs must appear on worker-side spans: the trace
        # crosses the process boundary, not just the function boundary
        by_trace = {}
        for ev in doc["traceEvents"]:
            t = (ev.get("args") or {}).get("trace_id")
            if t is not None:
                by_trace.setdefault(t, set()).add(ev.get("pid"))
        assert any(len(p) >= 2 for p in by_trace.values()), by_trace
        assert open_spans == 0
    finally:
        for h in hosts:
            h.close()


def test_trace_shift_clamps_and_skips_metadata():
    evs = [{"ph": "M", "ts": 0, "pid": "0", "name": "process_name"},
           {"ph": "X", "ts": 5.0, "dur": 1.0, "pid": "0", "name": "s"}]
    out = shift_events(evs, offset_s=1.0)
    assert out[0]["ts"] == 0  # metadata untouched
    assert out[1]["ts"] == 0.0  # clamped, not negative
    doc = merge_process_traces(
        [{"ph": "X", "ts": 9.0, "dur": 1.0, "pid": "f", "name": "hop"}],
        [({"traceEvents": evs}, 0.0), (None, None)],
    )
    assert [e["ts"] for e in doc["traceEvents"]] == [0, 5.0, 9.0]  # sorted


def test_frontier_serve_composes_recursively():
    """client -> WorkerHost(Frontier) -> workers: one protocol end to end."""
    hosts = two_hosts()
    img = rand()
    with MorphService(svc_cfg()) as direct:
        ref = np.asarray(direct.run_plan(img, ERODE3))
    try:
        with Frontier([h.address for h in hosts],
                      buckets=((64, 64),)) as front:
            edge = front.serve()
            try:
                with IngressClient(edge.address) as client:
                    np.testing.assert_array_equal(
                        np.asarray(client.run_plan(img, ERODE3)), ref
                    )
                    stats = client.stats()
                assert stats["workers"] == 2  # fleet stats over the wire
            finally:
                edge.close()
    finally:
        for h in hosts:
            h.close()


# ===================================================== subprocess fleet (CI)
@pytest.mark.slow
def test_subprocess_fleet_round_trip_and_kill():
    """The real thing: two worker *processes*, spawned and handshaken,
    serving bit-exact results; killing one reroutes with zero lost
    futures. Slow (two interpreter boots + compiles); the ingress CI job
    runs it."""
    wcfg = {"buckets": [[64, 64]], "window_ms": 1.0, "interpret": True}
    procs, addrs = [], []
    try:
        for i in range(2):
            proc, addr = spawn_worker(dict(wcfg), worker_id=i)
            procs.append(proc)
            addrs.append(addr)
        imgs = [rand(40 + i, 50) for i in range(6)]
        with MorphService(svc_cfg(interpret=True)) as direct:
            refs = [np.asarray(direct.run_plan(im, ERODE3)) for im in imgs]
        with Frontier(addrs,
                      buckets=((64, 64),),
                      failover=FailoverPolicy(probe_interval_s=600.0)) as front:
            for im, ref in zip(imgs, refs):
                np.testing.assert_array_equal(
                    np.asarray(front.run_plan(im, ERODE3)), ref
                )
            victim = owner(ERODE3, 2)
            futs = [front.submit_plan(im, ERODE3) for im in imgs]
            procs[victim].kill()
            for f, ref in zip(futs, refs):
                np.testing.assert_array_equal(
                    np.asarray(f.result(timeout=120)), ref
                )
            assert front.stats()["healthy_workers"] >= 1
    finally:
        for p in procs:
            p.kill()
            p.wait(timeout=30)


def test_config_from_json_maps_and_ignores_unknowns():
    cfg = config_from_json({
        "buckets": [[64, 64], [128, 128]], "max_batch": 4,
        "window_ms": 2.5, "tenants": {"gold": {"max_outstanding": 8,
                                               "weight": 4.0}},
        "brownout": False, "interpret": True,
        "a_future_knob": {"x": 1},  # ignored, like unknown wire fields
    })
    assert cfg.buckets == ((64, 64), (128, 128))
    assert cfg.max_batch == 4 and cfg.window_ms == 2.5
    assert cfg.tenants["gold"].max_outstanding == 8
    assert cfg.brownout is None and cfg.interpret is True
