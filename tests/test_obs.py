"""Observability suite (ISSUE 7): metrics registry, tracing, profiling.

Three layers of guarantees:

* **Metrics** — counters/gauges/histograms merge by type with explicit
  semantics (sum / mode / bucket-add); histogram quantiles track
  ``np.percentile`` to within a bucket width; the stats surfaces keep a
  frozen key schema across ``MorphService`` and ``ShardedMorphService``
  (dashboards parse these dicts — key drift is an API break).
* **Tracing** — span handles close exactly once (double-end raises), the
  export is schema-valid Chrome trace-event JSON, and a chaos replay of the
  ISSUE 6 fault scenarios (failing shard + poison request) produces a trace
  containing the full resilience vocabulary — queue, dispatch, executor,
  retry, bisect, hop, failover — with zero spans left open.
* **Gating** — ``obs=None`` (the default) constructs no observability
  runtime at all: the off path is structurally the pre-obs service.

Runs on logical shards (one CPU device repeated), so the suite is tier-1.
"""
import threading

import jax
import numpy as np
import pytest

from repro.obs import (
    DEFAULT_LATENCY_BUCKETS_MS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    ObsConfig,
    Tracer,
    cache_stats,
    chrome_trace,
    hit_rate,
    merge_snapshots,
    new_trace_id,
    quantile_from_snapshot,
    validate_chrome_trace,
)
from repro.serve.morph import (
    FaultPlan,
    MorphService,
    PoisonedRequest,
    RetryPolicy,
    ServeError,
    ServiceConfig,
    single_op_plan,
)
from repro.shard import ShardedMorphService

RNG = np.random.default_rng(23)


def rand(h=40, w=50):
    return RNG.integers(0, 255, (h, w), dtype=np.uint8)


def cfg(**kw):
    kw.setdefault("buckets", ((64, 64),))
    kw.setdefault("window_ms", 1.0)
    return ServiceConfig(**kw)


# ------------------------------------------------------------------ metrics
def test_counter_gauge_histogram_basics():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.snapshot() == {"type": "counter", "value": 5}
    g = Gauge(mode="max")
    g.set(3.5)
    assert g.snapshot()["value"] == 3.5
    with pytest.raises(ValueError):
        Gauge(mode="average")
    h = Histogram((1.0, 10.0, 100.0))
    h.observe_many([0.5, 5.0, 50.0, 500.0])
    s = h.snapshot()
    assert s["counts"] == [1, 1, 1, 1]
    assert s["count"] == 4 and s["min"] == 0.5 and s["max"] == 500.0
    with pytest.raises(ValueError):
        Histogram(())
    with pytest.raises(ValueError):
        Histogram((5.0, 5.0))


def test_registry_names_are_typed():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    with pytest.raises(TypeError):
        reg.gauge("a")
    snap = reg.snapshot()
    assert snap == {"a": {"type": "counter", "value": 0}}


def test_merge_by_type():
    def make(vals, mode="sum"):
        reg = MetricsRegistry()
        reg.counter("n").inc(vals[0])
        reg.gauge("g", mode=mode).set(vals[1])
        reg.histogram("h", (10.0, 20.0)).observe(vals[2])
        return reg.snapshot()

    merged = merge_snapshots([make((1, 5.0, 3.0)), make((2, 7.0, 15.0))])
    assert merged["n"]["value"] == 3
    assert merged["g"]["value"] == 12.0  # sum mode
    assert merged["h"]["counts"] == [1, 1, 0]
    assert merged["h"]["count"] == 2
    assert merged["h"]["min"] == 3.0 and merged["h"]["max"] == 15.0
    # max-mode gauges take the worst shard
    m2 = merge_snapshots([make((0, 5.0, 1.0), "max"), make((0, 2.0, 1.0), "max")])
    assert m2["g"]["value"] == 5.0
    # a metric missing from some shards merges over those that have it
    partial = merge_snapshots([make((1, 1.0, 1.0)), {}])
    assert partial["n"]["value"] == 1


def test_merge_conflicts_raise():
    a = MetricsRegistry()
    a.counter("m")
    b = MetricsRegistry()
    b.gauge("m")
    with pytest.raises(ValueError, match="conflicting"):
        merge_snapshots([a.snapshot(), b.snapshot()])
    c = MetricsRegistry()
    c.histogram("h", (1.0, 2.0))
    d = MetricsRegistry()
    d.histogram("h", (1.0, 3.0))
    with pytest.raises(ValueError, match="bounds"):
        merge_snapshots([c.snapshot(), d.snapshot()])
    e = MetricsRegistry()
    e.gauge("g", mode="sum")
    f = MetricsRegistry()
    f.gauge("g", mode="max")
    with pytest.raises(ValueError, match="modes"):
        merge_snapshots([e.snapshot(), f.snapshot()])


def test_histogram_quantiles_track_percentile():
    rng = np.random.default_rng(3)
    samples = rng.lognormal(mean=1.0, sigma=1.0, size=4000)  # ms-ish spread
    h = Histogram(DEFAULT_LATENCY_BUCKETS_MS)
    h.observe_many(samples)
    snap = h.snapshot()
    for q in (0.5, 0.9, 0.99):
        est = quantile_from_snapshot(snap, q)
        exact = float(np.percentile(samples, q * 100))
        # within one bucket width of the exact answer
        hi = next(
            (b for b in DEFAULT_LATENCY_BUCKETS_MS if b >= exact),
            snap["max"],
        )
        lo = max(
            (b for b in DEFAULT_LATENCY_BUCKETS_MS if b < exact),
            default=snap["min"],
        )
        assert lo - 1e-9 <= est <= hi + 1e-9, (q, est, exact)
    # tails clamp to observed data
    assert quantile_from_snapshot(snap, 0.0) >= snap["min"]
    assert quantile_from_snapshot(snap, 1.0) <= snap["max"]
    assert quantile_from_snapshot(Histogram((1.0,)).snapshot(), 0.5) == 0.0


def test_shared_cache_arithmetic():
    assert hit_rate(0, 0) == 0.0
    assert hit_rate(3, 1) == 0.75
    s = cache_stats(2, 3, 1, 0)
    assert s == {"size": 2, "hits": 3, "misses": 1, "evictions": 0,
                 "hit_rate": 0.75}


# ------------------------------------------------------------------ tracing
def test_span_ends_exactly_once():
    t = Tracer()
    s = t.begin("work", trace=7, plan="erode")
    t.end(s, ok=True)
    with pytest.raises(RuntimeError, match="already ended"):
        t.end(s)
    assert t.open_count() == 0
    snap = t.snapshot()
    assert snap["spans_begun"] == snap["spans_ended"] == 1
    done = t.finished()[0]
    assert done.trace == 7 and done.attrs["ok"] is True


def test_ring_buffer_bounds_memory():
    t = Tracer(ring=4)
    for i in range(10):
        with t.span("s", trace=i):
            pass
    assert len(t.finished()) == 4
    assert t.dropped == 6
    assert [s.trace for s in t.finished()] == [6, 7, 8, 9]


def test_trace_ids_unique_across_threads():
    ids = []
    lock = threading.Lock()

    def mint():
        got = [new_trace_id() for _ in range(200)]
        with lock:
            ids.extend(got)

    threads = [threading.Thread(target=mint) for _ in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert len(ids) == len(set(ids))


def test_chrome_export_is_schema_valid():
    t = Tracer(pid="3", name="shard-3")
    with t.span("dispatch", trace=1, plan="erode", bucket=(64, 64)):
        pass
    t.instant("failover", trace=1, shard=2)
    doc = chrome_trace([t, None])  # None tracers are skipped
    assert validate_chrome_trace(doc) == []
    names = [e["name"] for e in doc["traceEvents"]]
    assert "process_name" in names and "dispatch" in names
    x = next(e for e in doc["traceEvents"] if e["name"] == "dispatch")
    assert x["ph"] == "X" and x["dur"] >= 0 and x["pid"] == "3"
    assert x["args"]["trace_id"] == 1 and x["args"]["bucket"] == [64, 64]
    inst = next(e for e in doc["traceEvents"] if e["name"] == "failover")
    assert inst["ph"] == "i" and inst["s"] == "t"


def test_validator_rejects_malformed_documents():
    assert validate_chrome_trace([]) != []
    assert validate_chrome_trace({"traceEvents": [{}]}) != []
    bad = {"traceEvents": [
        {"name": "x", "ph": "X", "pid": "0", "tid": 1, "ts": 1.0},  # no dur
        {"name": "y", "ph": "Q", "pid": "0", "tid": 1, "ts": 1.0},  # bad ph
        {"name": "z", "ph": "i", "pid": "0", "tid": 1, "ts": -5},   # bad ts
    ]}
    errs = validate_chrome_trace(bad)
    assert len(errs) == 3


# ------------------------------------------------------------- stats schema
SERVICE_STATS_KEYS = {
    "requests", "batches", "tiled_requests", "rle_requests", "repr",
    "bounded_iter", "img_per_s", "p50_ms", "p99_ms", "mean_batch",
    "occupancy", "cache", "backend", "interpret", "window_ms",
    "effective_window_ms", "adaptive_window", "resilience", "obs",
}
ROUTER_STATS_KEYS = {
    "shards", "healthy_shards", "slow_shards", "health", "requests",
    "batches", "tiled_requests", "rle_requests", "repr", "img_per_s",
    "p50_ms", "p99_ms", "cache", "bounded_iter", "resilience",
    "effective_window_ms", "backend", "interpret", "obs", "per_shard",
}
REPR_KEYS = {"dense", "rle", "density_p50"}
CACHE_KEYS = {"size", "hits", "misses", "evictions", "hit_rate"}
BOUNDED_KEYS = {"executions", "iters_used", "iters_budget", "saved_frac"}
BATCHER_COUNTERS = {
    "rejected_overloaded", "rejected_quota", "shed_brownout",
    "deadline_expired", "retries", "bisections", "request_failures",
}


def test_service_stats_schema_frozen():
    with MorphService(cfg()) as svc:
        svc.run(rand(), "erode", (3, 3))
        st = svc.stats()
    assert set(st) == SERVICE_STATS_KEYS
    assert set(st["cache"]) == CACHE_KEYS
    assert set(st["bounded_iter"]) == BOUNDED_KEYS
    assert set(st["repr"]) == REPR_KEYS
    assert set(st["resilience"]) == BATCHER_COUNTERS | {
        "max_queue", "faults", "brownout", "tenants",
    }
    assert st["requests"] == 1
    assert st["obs"] is None  # off by default
    assert st["p50_ms"] > 0.0


def test_router_stats_schema_frozen_and_consistent():
    devices = [jax.devices()[0]] * 3
    with ShardedMorphService(cfg(), devices=devices) as svc:
        for _ in range(6):
            svc.run(rand(), "erode", (3, 3))
        st = svc.stats()
    assert set(st) == ROUTER_STATS_KEYS
    assert set(st["cache"]) == CACHE_KEYS
    assert set(st["bounded_iter"]) == BOUNDED_KEYS
    assert set(st["repr"]) == REPR_KEYS
    assert set(st["resilience"]) == BATCHER_COUNTERS | {
        "reroutes", "rewarms", "failovers", "hedges", "hedge_wins",
        "hedge_delay_ms", "brownout_level", "tenants",
    }
    assert set(st["per_shard"][0]) == SERVICE_STATS_KEYS
    # the by-type merge must agree with summing the per-shard views
    assert st["requests"] == sum(p["requests"] for p in st["per_shard"]) == 6
    assert st["cache"]["misses"] == sum(
        p["cache"]["misses"] for p in st["per_shard"]
    )
    assert st["cache"]["hit_rate"] == pytest.approx(
        hit_rate(st["cache"]["hits"], st["cache"]["misses"])
    )
    # merged latency histogram yields a real cross-shard quantile
    assert st["p99_ms"] >= st["p50_ms"] > 0.0


def test_metrics_snapshot_merges_by_registry():
    devices = [jax.devices()[0]] * 2
    with ShardedMorphService(cfg(), devices=devices) as svc:
        svc.run(rand(), "erode", (3, 3))
        merged = svc.metrics_snapshot()
    assert merged["requests"]["value"] == 1
    assert merged["latency_ms"]["type"] == "histogram"
    assert merged["latency_ms"]["count"] == 1
    assert merged["window.effective_ms"]["mode"] == "max"


# ------------------------------------------------------------------- gating
def test_obs_off_is_structurally_absent():
    with MorphService(cfg()) as svc:
        svc.run(rand(), "erode", (3, 3))
        assert svc._obs is None
        assert svc._batcher._obs is None
        assert svc.export_trace() is None
        assert svc.executor_profile() == {}
    devices = [jax.devices()[0]] * 2
    with ShardedMorphService(cfg(), devices=devices) as svc:
        assert svc._obs is None
        assert svc.export_trace() is None


def test_obs_config_enabled_flag():
    assert ObsConfig().enabled
    assert not ObsConfig(trace=False, profile_executors=False).enabled
    assert ObsConfig(trace=False, profile_executors=False,
                     jax_profiler=True).enabled


# -------------------------------------------------------- enabled pipeline
def test_single_service_trace_and_profile():
    with MorphService(cfg(obs=ObsConfig())) as svc:
        for _ in range(4):
            svc.run(rand(), "erode", (3, 3))
        svc.flush(10)
        st = svc.stats()
        prof = svc.executor_profile()
        doc = svc.export_trace()
        assert svc._obs.tracer.open_count() == 0
    assert validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"queue", "dispatch", "executor"} <= names
    # every request minted a distinct trace id, carried by its queue span
    qids = [
        e["args"]["trace_id"] for e in doc["traceEvents"]
        if e["name"] == "queue"
    ]
    assert len(qids) == 4 and len(set(qids)) == 4
    # compile-vs-run split: one cold first call, three warm runs
    assert len(prof) == 1
    row = next(iter(prof.values()))
    assert row["first_call_ms"] is not None
    assert row["calls"] == 3
    assert row["first_call_ms"] > row["run_ms_mean"]
    assert st["obs"]["trace"]["open"] == 0
    assert st["obs"]["profiled_keys"] == 1


def test_submit_rejection_leaves_no_open_spans():
    """Admission rejects before the queue span (or the RLE density probe)
    exists, so shed requests cost nothing in the tracer — but they stay
    observable through the admission counters, and nothing leaks."""
    c = cfg(obs=ObsConfig(), max_queue=1, window_ms=50.0)
    with MorphService(c) as svc:
        futs = []
        rejected = 0
        for _ in range(8):
            try:
                futs.append(svc.submit(rand(), "erode", (3, 3)))
            except ServeError:
                rejected += 1
        for f in futs:
            f.result()
        svc.flush(10)
        assert rejected > 0
        assert svc._obs.tracer.open_count() == 0
        errs = [
            e for e in svc.export_trace()["traceEvents"]
            if e["name"] == "queue" and e["args"].get("error")
        ]
        assert errs == []  # never opened, nothing to error-close
        assert svc.stats()["resilience"]["rejected_overloaded"] == rejected


# ----------------------------------------------------- chaos trace replay
def test_chaos_trace_is_complete():
    """Replay the ISSUE 6 chaos scenario with tracing on: the primary shard
    fails every dispatch (InjectedFault -> retry -> breaker -> failover) and
    one request is poisoned (bisect isolates it on the survivor). The
    exported trace must be schema-valid, contain the whole resilience span
    vocabulary, and close every span exactly once."""
    n = 4
    plan = single_op_plan("erode", (3, 3))
    import zlib

    primary = zlib.crc32(
        f"{plan.name}|{(64, 64)}|{np.dtype(np.uint8).str}".encode()
    ) % n
    c = cfg(
        window_ms=30.0,  # coalesce the whole cohort into one group
        max_batch=8,
        retry=RetryPolicy(max_retries=1, backoff_ms=0.5, backoff_cap_ms=2.0),
        faults=FaultPlan(
            fail_shard=primary, fail_after=0, fail_for=None,
            poison_tags=frozenset({"bad"}),
        ),
        obs=ObsConfig(),
    )
    devices = [jax.devices()[0]] * n
    imgs = [rand(60, 60) for _ in range(8)]
    with ShardedMorphService(c, devices=devices) as svc:
        futs = [
            svc.submit_plan(img, plan, tag="bad" if i == 3 else None)
            for i, img in enumerate(imgs)
        ]
        outcomes = []
        for f in futs:
            try:
                f.result(timeout=120)
                outcomes.append("ok")
            except PoisonedRequest:
                outcomes.append("poison")
            except ServeError as e:  # pragma: no cover - diagnostic
                outcomes.append(type(e).__name__)
        svc.flush(30)
        doc = svc.export_trace()
        stats = svc.stats()
        # exactly-once accounting: nothing left open on any tracer
        assert svc._obs.tracer.open_count() == 0
        for s in svc.shards:
            assert s._obs.tracer.open_count() == 0
    assert outcomes.count("ok") == 7
    assert outcomes[3] == "poison"
    assert validate_chrome_trace(doc) == []
    names = {e["name"] for e in doc["traceEvents"]}
    assert {"queue", "dispatch", "executor", "retry", "bisect", "hop",
            "failover"} <= names, names
    # the failing primary tripped its breaker and traffic moved
    assert stats["resilience"]["failovers"] >= 1
    assert stats["resilience"]["retries"] >= 1
    assert stats["resilience"]["bisections"] >= 1
    # one trace id per request, threaded through router hops unchanged:
    # every queue span's id also appears on at least one hop span
    hops = {
        e["args"]["trace_id"] for e in doc["traceEvents"]
        if e["name"] == "hop"
    }
    queued = {
        e["args"]["trace_id"] for e in doc["traceEvents"]
        if e["name"] == "queue"
    }
    assert queued <= hops
    assert len(queued) == 8
    # spans begun == spans ended on every lane (the balance the open_count
    # checks above prove, restated from the exported snapshots)
    trace_stats = stats["obs"]["trace"]
    assert trace_stats["spans_begun"] == trace_stats["spans_ended"]
