"""Expert-parallel MoE (§Perf iteration D): shard_map path vs GSPMD path."""
import pytest

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ffn, get_config
from repro.models.model import init_decode_cache, init_params, serve_step

pytestmark = pytest.mark.slow  # heavyweight: deselected from tier-1 (see pytest.ini)


def test_ep_decode_matches_baseline():
    cfg = get_config("dbrx-132b").reduced()  # 4 experts, top-2
    p = init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (4, 4), dtype=np.int32))
    c1 = init_decode_cache(cfg, 4, 8)
    base = []
    for t in range(4):
        lg, c1 = serve_step(cfg, p, c1, toks[:, t:t+1], jnp.int32(t))
        base.append(np.asarray(lg, np.float32))
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    ffn.set_moe_ep(mesh)
    try:
        assert ffn.ep_enabled(cfg)
        c2 = init_decode_cache(cfg, 4, 8)
        with jax.set_mesh(mesh):
            for t, want in enumerate(base):
                lg, c2 = serve_step(cfg, p, c2, toks[:, t:t+1], jnp.int32(t))
                got = np.asarray(lg, np.float32)
                # capacity policy differs (per-row vs global): small tolerance
                rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
                assert rel < 5e-2, rel
    finally:
        ffn.set_moe_ep(None)


def test_ep_disabled_when_not_divisible():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    ffn.set_moe_ep(FakeMesh())
    try:
        # grok: 8 experts don't divide the 16-way model axis
        assert not ffn.ep_enabled(get_config("grok-1-314b"))
        # dbrx: 16 experts do
        assert ffn.ep_enabled(get_config("dbrx-132b"))
    finally:
        ffn.set_moe_ep(None)
    # with no mesh installed, EP is always off
    assert not ffn.ep_enabled(get_config("dbrx-132b"))


def test_ep_sharding_rules():
    from jax.sharding import PartitionSpec as P

    from repro.launch import sharding as shd

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    m = FakeMesh()
    # EP layout: experts over model, ff over data
    assert shd.spec_for_leaf(
        "layers/moe/w_gate", (40, 16, 6144, 10752), m, moe_ep=True
    ) == P(None, "model", None, ("data",))
    assert shd.spec_for_leaf(
        "layers/moe/w_down", (40, 16, 10752, 6144), m, moe_ep=True
    ) == P(None, "model", ("data",), None)
    assert shd.spec_for_leaf(
        "layers/moe/router", (40, 6144, 16), m, moe_ep=True
    ) == P(None, None, None)
    # grok's 8 experts don't divide the 16-way model axis -> E replicated
    assert shd.spec_for_leaf(
        "layers/moe/w_gate", (64, 8, 6144, 32768), m, moe_ep=True
    ) == P(None, None, None, ("data",))
