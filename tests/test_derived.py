"""Derived morphological operators: lattice invariants + known behaviors."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.derived import (
    close_open,
    geodesic_dilate,
    granulometry,
    h_maxima,
    laplacian,
    occo,
    open_close,
    reconstruct_by_dilation,
    reconstruct_by_erosion,
)

RNG = np.random.default_rng(5)


def img(shape=(32, 40)):
    return jnp.asarray(RNG.integers(0, 256, shape, dtype=np.uint8))


def test_geodesic_dilate_bounded_by_mask():
    mask = img()
    marker = jnp.minimum(mask, 100)
    g = geodesic_dilate(marker, mask)
    assert bool(jnp.all(g <= mask))
    assert bool(jnp.all(g >= marker))


def test_reconstruction_idempotent_and_bounded():
    mask = img()
    marker = jnp.clip(mask.astype(jnp.int32) - 40, 0, None).astype(jnp.uint8)
    r = reconstruct_by_dilation(marker, mask)
    assert bool(jnp.all(r <= mask))
    # reconstruction is idempotent: reconstructing from the result is a fixpoint
    r2 = reconstruct_by_dilation(r, mask)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(r2))


def test_reconstruction_recovers_connected_peak():
    # one bright plateau on dark bg: marker touching it recovers it fully
    x = np.zeros((16, 16), np.uint8)
    x[4:8, 4:8] = 200
    marker = np.zeros_like(x)
    marker[5, 5] = 200
    r = np.asarray(reconstruct_by_dilation(jnp.asarray(marker), jnp.asarray(x)))
    np.testing.assert_array_equal(r, x)


def test_h_maxima_flattens_shallow_peaks():
    x = np.full((16, 16), 50, np.uint8)
    x[3, 3] = 60   # shallow peak (depth 10)
    x[10, 10] = 120  # tall peak (depth 70)
    out = np.asarray(h_maxima(jnp.asarray(x), 20))
    assert out[3, 3] == 50          # suppressed
    assert out[10, 10] >= 100       # survives (reduced by h)


def test_reconstruct_by_erosion_dual():
    mask = img()
    marker = jnp.clip(mask.astype(jnp.int32) + 40, None, 255).astype(jnp.uint8)
    r = reconstruct_by_erosion(marker, mask)
    assert bool(jnp.all(r >= mask))


def test_smoothers_remove_salt_and_pepper():
    x = np.full((40, 40), 128, np.uint8)
    pts = RNG.integers(2, 38, (30, 2))
    x[pts[:15, 0], pts[:15, 1]] = 255  # salt
    x[pts[15:, 0], pts[15:, 1]] = 0    # pepper
    for f in (open_close, close_open, occo):
        out = np.asarray(f(jnp.asarray(x)))
        assert out.min() > 0 and out.max() < 255, f.__name__


def test_laplacian_zero_on_flat():
    x = jnp.full((16, 16), 77, jnp.uint8)
    np.testing.assert_array_equal(np.asarray(laplacian(x)), 0)


def test_granulometry_sums_and_orders():
    # objects of size ~6 should put mass at the scale that removes them
    x = np.zeros((64, 64), np.uint8)
    x[10:16, 10:16] = 200  # 6x6 object: survives (5,5) opening, dies at (9,9)
    ps = np.asarray(granulometry(jnp.asarray(x), sizes=(3, 5, 9, 15)))
    assert ps.shape == (4,)
    assert ps[2] == ps.max()  # mass concentrated at the 9-scale bin
    assert np.all(ps >= -1e-6)  # openings are decreasing => nonneg spectrum
