"""Per-architecture smoke tests: reduced config, one forward + train step +
decode step on CPU, asserting shapes and no NaNs (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCH_IDS, get_config
from repro.models.model import (
    forward_train,
    init_decode_cache,
    init_params,
    loss_fn,
    prefill_cross_kv,
    serve_step,
)
from repro.optim import adamw_init, adamw_update

pytestmark = pytest.mark.slow  # heavyweight: deselected from tier-1 (see pytest.ini)

B, S = 2, 16


def make_batch(cfg):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S), dtype=np.int32)),
    }
    if cfg.family == "encdec":
        batch["encoder_frames"] = jnp.asarray(
            0.01 * rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.family == "vlm":
        batch["image_embeddings"] = jnp.asarray(
            0.01 * rng.standard_normal((B, cfg.num_image_tokens, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nans(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits, aux = forward_train(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_descends_and_finite(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = make_batch(cfg)

    @jax.jit
    def step(params, opt):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        params, opt = adamw_update(grads, opt, params, lr=1e-3, weight_decay=0.0)
        return params, opt, loss

    losses = []
    for _ in range(4):
        params, opt, loss = step(params, opt)
        losses.append(float(loss))
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]  # same batch: must descend


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step(arch):
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    cache = init_decode_cache(cfg, B, 8)
    if cfg.family == "encdec":
        ctx = jnp.zeros((B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        cache = prefill_cross_kv(cfg, params, cache, ctx)
    if cfg.family == "vlm":
        ctx = jnp.zeros((B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
        cache = prefill_cross_kv(cfg, params, cache, ctx)
    tok = jnp.zeros((B, 1), jnp.int32)
    for pos in range(3):
        logits, cache = serve_step(cfg, params, cache, tok, jnp.int32(pos))
        assert logits.shape == (B, 1, cfg.vocab_size)
        assert not np.isnan(np.asarray(logits, np.float32)).any()
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "rwkv6-7b", "hymba-1.5b"])
def test_decode_matches_teacher_forcing(arch):
    """serve_step chained over a prompt must agree with full-seq forward."""
    cfg = get_config(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 6), dtype=np.int32))
    full_logits, _ = forward_train(cfg, params, {"tokens": toks, "labels": toks})
    cache = init_decode_cache(cfg, B, 8)
    outs = []
    for t in range(6):
        lg, cache = serve_step(cfg, params, cache, toks[:, t : t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_param_counts_match_spec():
    """Full-config param counts are in the right ballpark of the model names."""
    expected = {
        "gemma-7b": (7e9, 0.4),        # (target, rel tolerance)
        "gemma2-2b": (2.6e9, 0.4),
        "qwen2.5-3b": (3e9, 0.45),
        "qwen1.5-0.5b": (0.5e9, 0.4),
        "rwkv6-7b": (7e9, 0.4),
        "grok-1-314b": (314e9, 0.25),
        "dbrx-132b": (132e9, 0.25),
        "whisper-medium": (0.76e9, 0.5),
        "hymba-1.5b": (1.5e9, 0.45),
        "llama-3.2-vision-90b": (90e9, 0.25),
    }
    for arch, (target, tol) in expected.items():
        n = get_config(arch).param_count()
        assert abs(n - target) / target < tol, f"{arch}: {n:.3e} vs {target:.3e}"
