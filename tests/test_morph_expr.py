"""Morphology expression IR: structural analyses, lowering bit-exactness
across backends, expr-derived serving plans, and bounded-iteration serving.

The load-bearing invariants:

* ``to_plan(expr).halo()`` equals the legacy hand-computed rule (wings
  summed per sequential pass, opening/closing twice, gradient once) for
  every plan op and for randomly composed chains;
* IR-lowered operators are bit-exact against the independent naive oracle
  and across the jnp / kernel backends;
* the three gradient paths (core, kernel, serving plan) agree on the
  widened output dtype for every supported input dtype;
* tiled execution through an expr-built plan is bit-exact at tile seams;
* an iterative operator (reconstruction by dilation, bounded iterations)
  round-trips through ``MorphService``.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (
    DispatchPolicy,
    closing,
    dilate_naive,
    erode_naive,
    gradient,
    opening,
    reconstruct_by_dilation,
)
from repro.core.types import widen_dtype, widened_sub
from repro.kernels import gradient2d_tpu
from repro.morph import (
    BoundedIter,
    Cast,
    Var,
    X,
    evaluate,
    free_vars,
    halo,
    is_gradient,
    lower_kernel,
    lower_xla,
    masking_requirements,
    node_count,
    occo_expr,
    op_expr,
    reconstruct_by_dilation_expr,
    to_plan,
)
from repro.morph.expr import StructuringElement
from repro.serve.morph import (
    MorphService,
    ServiceConfig,
    Plan,
    Step,
    build_executor,
    check_backend,
    run_tiled,
    single_op_plan,
)
from repro.serve.morph.plans import _OPS

RNG = np.random.default_rng(11)


def rand(shape, dtype=np.uint8):
    if dtype == np.bool_:
        return RNG.random(shape) < 0.3
    if np.issubdtype(dtype, np.floating):
        return RNG.standard_normal(shape).astype(dtype)
    info = np.iinfo(dtype)
    return RNG.integers(info.min, info.max, shape, dtype=dtype)


def legacy_step_halo(steps):
    """The old hand-maintained rule from plans.py: wings summed over
    sequential passes, opening/closing counted twice, gradient once."""
    gh = gw = 0
    for op, (h, w) in steps:
        mult = 2 if op in ("opening", "closing") else 1
        gh += mult * (h - 1) // 2
        gw += mult * (w - 1) // 2
    return gh, gw


# ------------------------------------------------------------------ structure
def test_structuring_element_coercion_and_validation():
    assert StructuringElement.of((3, 5)).pair == (3, 5)
    assert StructuringElement.of(7).pair == (7, 7)
    assert StructuringElement.of((9, 3)).wings == (4, 1)
    with pytest.raises(ValueError):
        StructuringElement.of((2, 3))


def test_exprs_are_hashable_and_structurally_equal():
    a = X.opening((3, 3)).gradient((5, 5))
    b = X.opening((3, 3)).gradient((5, 5))
    assert a == b and hash(a) == hash(b)
    assert a != X.opening((3, 3)).gradient((5, 7))


def test_gradient_pattern_recognized():
    assert is_gradient(X.gradient((3, 3)))
    assert is_gradient(X.closing((5, 5)).gradient((3, 3)))
    assert not is_gradient(X.dilate((3, 3)) - X.erode((5, 5)))  # SE mismatch
    assert not is_gradient(X.tophat((3, 3)))


def test_free_vars_and_node_count():
    rec = reconstruct_by_dilation_expr(Var("marker"), Var("mask"), (3, 3), iters=8)
    assert free_vars(rec) == {"marker", "mask"}  # loop var is bound
    assert free_vars(X.gradient((3, 3))) == {"x"}
    # gradient shares its child: Var + Dilate + Erode + Sub = 4 distinct nodes
    assert node_count(X.gradient((3, 3))) == 4


def test_masking_requirements_cover_both_neutrals():
    reqs = masking_requirements(X.gradient((3, 3)))
    assert ("min", (3, 3)) in reqs and ("max", (3, 3)) in reqs


# ---------------------------------------------------------- expr-derived halo
@pytest.mark.parametrize("op", _OPS)
@pytest.mark.parametrize("se", [(3, 3), (9, 5), (1, 7), (31, 3)])
def test_single_op_halo_matches_legacy_rule(op, se):
    assert to_plan(op_expr(op, se)).halo() == legacy_step_halo([(op, se)])
    assert single_op_plan(op, se).halo() == legacy_step_halo([(op, se)])


def test_random_chain_halo_matches_legacy_rule():
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = rng.integers(1, 5)
        steps = [
            (
                _OPS[rng.integers(len(_OPS))],
                (1 + 2 * int(rng.integers(0, 8)), 1 + 2 * int(rng.integers(0, 8))),
            )
            for _ in range(n)
        ]
        cur = X
        for op, se in steps:
            cur = op_expr(op, se, cur)
        assert halo(cur) == legacy_step_halo(steps), steps
        plan = Plan("chain", tuple(Step(op, se) for op, se in steps))
        assert plan.halo() == legacy_step_halo(steps), steps


def test_bounded_iter_halo_scales_with_iterations():
    body_se = (3, 3)
    rec = reconstruct_by_dilation_expr(
        X.erode((5, 5)), X, body_se, iters=10, until_stable=False
    )
    # init = Min(erode(5,5) -> (2,2), x -> 0) = (2,2); 10 body dilations
    assert halo(rec) == (2 + 10 * 1, 2 + 10 * 1)
    stable = reconstruct_by_dilation_expr(
        X.erode((5, 5)), X, body_se, iters=10, until_stable=True
    )
    # the until-stable form seeds the loop with one extra body application
    assert halo(stable) == (2 + 11 * 1, 2 + 11 * 1)


# ----------------------------------------------------- lowering bit-exactness
def naive_ref(op, x, se):
    xj = jnp.asarray(x)
    if op == "erode":
        return erode_naive(xj, se)
    if op == "dilate":
        return dilate_naive(xj, se)
    if op == "opening":
        return dilate_naive(erode_naive(xj, se), se)
    if op == "closing":
        return erode_naive(dilate_naive(xj, se), se)
    if op == "gradient":
        return widened_sub(dilate_naive(xj, se), erode_naive(xj, se))
    if op == "tophat":
        return widened_sub(xj, dilate_naive(erode_naive(xj, se), se))
    if op == "blackhat":
        return widened_sub(erode_naive(dilate_naive(xj, se), se), xj)
    raise ValueError(op)


ALL_OPS = _OPS + ("tophat", "blackhat")


@pytest.mark.parametrize("op", ALL_OPS)
def test_lower_xla_matches_naive_oracle(op):
    x = rand((37, 53))
    got = np.asarray(lower_xla(op_expr(op, (5, 7)))(jnp.asarray(x)))
    want = np.asarray(naive_ref(op, x, (5, 7)))
    assert got.dtype == want.dtype
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("op", ALL_OPS)
def test_lower_kernel_matches_lower_xla(op):
    x = jnp.asarray(rand((40, 66)))
    expr = op_expr(op, (3, 5))
    a = np.asarray(lower_xla(expr)(x))
    b = np.asarray(lower_kernel(expr, interpret=True)(x))
    assert a.dtype == b.dtype
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------- boolean lattice
@pytest.mark.parametrize("op", ("erode", "dilate", "opening", "closing"))
@pytest.mark.parametrize("se", [(3, 3), (1, 7), (9, 5)])
def test_bool_agrees_with_u8_255_semantics(op, se):
    """bool is in the cross-backend dtype matrix: a boolean mask must behave
    exactly like its uint8 0/255 embedding under every lattice op, on both
    lowering backends, and keep its dtype."""
    m = rand((29, 37), np.bool_)
    expr = op_expr(op, se)
    u8 = np.asarray(lower_xla(expr)(jnp.asarray(m.astype(np.uint8) * 255)))
    for lower in (
        lambda e: lower_xla(e),
        lambda e: lower_kernel(e, interpret=True),
    ):
        got = np.asarray(lower(expr)(jnp.asarray(m)))
        assert got.dtype == np.bool_
        np.testing.assert_array_equal(got.astype(np.uint8) * 255, u8)


def test_bool_neutral_padding_and_widening():
    """Erosion pads True, dilation pads False (the boolean neutrals), and a
    boolean difference widens like the narrow integers do."""
    from repro.core.types import MAX, MIN

    assert MIN.neutral(np.bool_) == np.True_
    assert MAX.neutral(np.bool_) == np.False_
    assert widen_dtype(np.bool_) == np.int32
    # all-True survives any erosion only because the border is erosion-neutral
    ones = jnp.ones((8, 8), jnp.bool_)
    assert bool(np.asarray(lower_xla(X.erode((5, 5)))(ones)).all())
    # all-False survives any dilation only because the border is dilation-neutral
    zeros = jnp.zeros((8, 8), jnp.bool_)
    assert not bool(np.asarray(lower_xla(X.dilate((5, 5)))(zeros)).any())


def test_lowering_composed_chain_across_backends():
    x = jnp.asarray(rand((33, 49)))
    expr = X.opening((3, 3)).closing((5, 5)).gradient((3, 3))
    a = np.asarray(lower_xla(expr)(x))
    b = np.asarray(lower_kernel(expr, interpret=True)(x))
    np.testing.assert_array_equal(a, b)
    # and the chain equals composing the public core ops
    want = np.asarray(gradient(closing(opening(x, (3, 3)), (5, 5)), (3, 3)))
    np.testing.assert_array_equal(a, want)


def test_shared_subgraph_evaluated_once():
    calls = []

    def prim(op, v, se):
        calls.append(op.name)
        return v

    evaluate(X.gradient((3, 3)), {"x": jnp.zeros((8, 8))}, prim=prim)
    assert sorted(calls) == ["max", "min"]  # shared child walked once


def test_occo_expr_matches_derived():
    from repro.core import occo

    x = jnp.asarray(rand((30, 30)))
    got = np.asarray(lower_xla(occo_expr(X, (3, 3)))(x))
    np.testing.assert_array_equal(got, np.asarray(occo(x, (3, 3))))


# ------------------------------------------------- cross-path gradient dtypes
@pytest.mark.parametrize("dtype", [np.uint8, np.int8, np.int32, np.float32, np.bool_])
def test_gradient_dtype_agrees_across_all_paths(dtype):
    x = rand((24, 40), dtype)
    want = widen_dtype(dtype)
    core_out = gradient(jnp.asarray(x), (3, 3))
    kernel_fused = gradient2d_tpu(jnp.asarray(x), (3, 3), interpret=True)
    kernel_two_pass = gradient2d_tpu(
        jnp.asarray(x), (3, 3), fused=False, interpret=True
    )
    assert core_out.dtype == want
    assert kernel_fused.dtype == want
    assert kernel_two_pass.dtype == want
    ex = build_executor(single_op_plan("gradient", (3, 3)))
    plan_out = ex(jnp.asarray(x)[None], jnp.asarray([[0, 24, 0, 40]], jnp.int32))
    assert plan_out["out"].dtype == want
    np.testing.assert_array_equal(np.asarray(core_out), np.asarray(kernel_fused))
    np.testing.assert_array_equal(np.asarray(core_out), np.asarray(plan_out["out"][0]))


# --------------------------------------------------------- expr-built serving
def test_to_plan_rejects_foreign_inputs():
    with pytest.raises(ValueError, match="Var"):
        to_plan(Var("marker").dilate((3, 3)))


def test_to_plan_equals_step_plan_executables():
    """An expr-built plan and the legacy Step plan of the same chain produce
    identical outputs (and identical halos)."""
    img = rand((45, 58))
    steps_plan = Plan(
        "oc_edges",
        (Step("opening", (3, 3)), Step("gradient", (3, 3), save_as="edges")),
    )
    expr_plan = to_plan(
        {"edges": X.opening((3, 3)).gradient((3, 3))}, name="oc_edges_expr"
    )
    assert steps_plan.halo() == expr_plan.halo()
    rect = jnp.asarray([[0, 45, 0, 58]], jnp.int32)
    xb = jnp.asarray(img[None])
    a = build_executor(steps_plan)(xb, rect)
    b = build_executor(expr_plan)(xb, rect)
    np.testing.assert_array_equal(np.asarray(a["edges"]), np.asarray(b["edges"]))


def test_expr_plan_tiled_bit_exact_at_seams():
    """Tiled execution through an expr-built plan stitches bit-exactly —
    the halo driving tiling comes from graph traversal."""
    img = rand((75, 90))
    expr = X.closing((5, 5)).gradient((3, 3))
    plan = to_plan(expr, name="close_edges")
    ex = build_executor(plan)
    outs = run_tiled(
        img, plan, lambda t, r: ex(jnp.asarray(t), jnp.asarray(r)),
        tile_interior=(16, 16), launch_batch=4,
    )
    want = np.asarray(lower_xla(expr)(jnp.asarray(img)))
    np.testing.assert_array_equal(outs["out"], want)


def test_expr_plan_through_service_bucketed():
    img = rand((40, 52))
    expr = X.opening((3, 3)).closing((5, 5))
    with MorphService(ServiceConfig(buckets=((64, 128),), window_ms=1.0)) as svc:
        got = svc.run_expr(img, expr, name="smooth")
    want = np.asarray(closing(opening(jnp.asarray(img), (3, 3)), (5, 5)))
    np.testing.assert_array_equal(got, want)


def test_reconstruction_round_trips_through_service():
    """Opening-by-reconstruction (erode marker, geodesically re-dilate under
    the image) as a bounded-iteration plan == core.derived's while-loop
    reconstruction, served through buckets with masking."""
    img = rand((40, 48))
    iters = 64  # >= image diameter / wing, so bounded == converged
    expr = reconstruct_by_dilation_expr(
        X.erode((7, 7)), X, (3, 3), iters=iters, until_stable=False
    )
    with MorphService(ServiceConfig(buckets=((64, 128),), window_ms=1.0)) as svc:
        got = svc.run_expr(img, expr, name="open_by_reconstruction")
    xj = jnp.asarray(img)
    want = np.asarray(
        reconstruct_by_dilation(
            jnp.asarray(np.asarray(erode_naive(xj, (7, 7)))), xj, (3, 3)
        )
    )
    np.testing.assert_array_equal(got, want)
    assert got.dtype == img.dtype


def test_bounded_iter_until_stable_matches_fori_when_converged():
    x = jnp.asarray(rand((24, 24)))
    marker = Var("m")
    stable = reconstruct_by_dilation_expr(marker, Var("x"), iters=64, until_stable=True)
    fixed = reconstruct_by_dilation_expr(marker, Var("x"), iters=64, until_stable=False)
    m = jnp.minimum(x, 90)
    a = np.asarray(lower_xla(stable)(m=m, x=x))
    b = np.asarray(lower_xla(fixed)(m=m, x=x))
    np.testing.assert_array_equal(a, b)


def test_cast_clip_nodes():
    x = jnp.asarray(rand((16, 16)))
    expr = Cast(X.gradient((3, 3)).clip(0, 255), "uint8")
    out = lower_xla(expr)(x)
    assert out.dtype == jnp.uint8


# ----------------------------------------------------------- backend validity
def test_backend_typo_fails_loudly():
    with pytest.raises(ValueError, match="backend"):
        build_executor(single_op_plan("erode", (3, 3)), backend="kernl")
    with pytest.raises(ValueError, match="backend"):
        MorphService(ServiceConfig(backend="jnpp"))
    assert check_backend("jnp") == "jnp"
    assert check_backend("kernel") == "kernel"


def test_policy_collapses_legacy_kwargs():
    p = DispatchPolicy()
    q = p.with_overrides(fused=False, method="vhgw", lane_strategy="xla", interpret=True)
    assert (q.fused_2d, q.method, q.lane_strategy, q.interpret) == (
        False, "vhgw", "xla", True,
    )
    assert p.with_overrides() is p
    assert q.cache_token() != p.cache_token()
