"""IR optimizer: rewrite-pass semantics, cost model, calibration memoization,
and the adaptive batching window.

Load-bearing contracts (DESIGN.md §9):

* **equivalence** — ``optimize()`` output is bit-identical to the raw graph
  through ``lower_xla``, ``lower_kernel`` and served (masked + cropped)
  plans, for random expression chains;
* **halo monotonicity** — the optimized graph's per-axis halo never exceeds
  the raw graph's;
* the analytic cost model reproduces the historical scalar-threshold
  dispatch exactly, and never decomposes (so behavior only changes once a
  measured table is fit);
* refcount guards: folding/fusing never un-shares a subgraph another
  output still reads;
* ``DispatchPolicy.calibrated()`` is memoized on file mtime;
* the adaptive window shrinks under light load and grows under pressure.
"""
import dataclasses
import json
import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DispatchPolicy
from repro.core import dispatch as dispatch_mod
from repro.morph import (
    Dilate,
    Erode,
    Gradient,
    Sub,
    X,
    halo,
    lower_kernel,
    lower_xla,
    masking_requirements,
    optimize,
    prim_count,
    to_plan,
)
from repro.morph.opt import CostModel, cost, cost_model_for
from repro.morph.opt.cost import feature, fit_affine
from repro.serve.morph import MorphService, ServiceConfig, build_executor
from repro.serve.morph.batcher import MicroBatcher
from repro.serve.morph.buckets import valid_rect

RNG = np.random.default_rng(7)

RAW = dataclasses.replace(DispatchPolicy.calibrated(), opt_level=0)
OPT = DispatchPolicy.calibrated()


def rand(shape, dtype=np.uint8):
    return RNG.integers(0, 256, shape, dtype=dtype)


def random_chain(rng, depth=None):
    """A random single-input expression chain (the property-test subject)."""
    ops = ("erode", "dilate", "opening", "closing", "gradient", "tophat")
    ses = ((3, 3), (5, 3), (3, 7), (5, 5), (1, 5))
    e = X
    for _ in range(depth if depth is not None else rng.integers(1, 4)):
        op = ops[rng.integers(0, len(ops))]
        e = getattr(e, op)(ses[rng.integers(0, len(ses))])
    return e


# ------------------------------------------------------------- rewrite passes
def test_fold_merges_same_op_chains():
    folded = optimize(X.erode((3, 3)).erode((5, 3)).erode((3, 5)))
    assert isinstance(folded, Erode)
    assert folded.se.pair == (9, 9)  # wings add: 1+2+1 and 1+1+2
    d = optimize(X.dilate((3, 3)).dilate((3, 3)))
    assert isinstance(d, Dilate) and d.se.pair == (5, 5)
    # mixed ops never fold
    assert prim_count(optimize(X.opening((3, 3)))) == 2


def test_fold_respects_shared_consumers():
    inner = X.erode((3, 3))
    outs = {"small": inner, "big": inner.erode((5, 5))}
    opt = optimize(outs)
    # folding "big" into one 7x7 erode would recompute what "small" needs;
    # the refcount guard must keep the shared 3x3 pass shared
    assert isinstance(opt["big"], Erode) and opt["big"].se.pair == (5, 5)
    assert opt["big"].child is opt["small"]


def test_cse_shares_structural_duplicates():
    se = (5, 5)
    outs = {"open": X.opening(se), "tophat": X.tophat(se), "grad": X.gradient(se)}
    assert prim_count(outs) == 6  # raw: each output rebuilt its own chain
    opt = optimize(outs)
    assert prim_count(opt) == 3  # one erode, opening's dilate, gradient's
    assert opt["tophat"].b is opt["open"]  # tophat reuses the opening


def test_gradient_canonicalizes_when_unshared():
    g = optimize(X.gradient((3, 3)))
    assert isinstance(g, Gradient) and g.se.pair == (3, 3)
    # ... but not when a branch feeds another output (fusing would un-share)
    outs = optimize({"g": X.gradient((3, 3)), "d": X.dilate((3, 3))})
    assert isinstance(outs["g"], Sub)
    assert outs["g"].a is outs["d"]


def test_dead_output_elimination():
    outs = {"a": X.erode((3, 3)), "b": X.opening((3, 3))}
    kept = optimize(outs, keep=["b"])
    assert list(kept) == ["b"]
    with pytest.raises(KeyError):
        optimize(outs, keep=["nope"])
    with pytest.raises(ValueError):
        optimize(X.erode((3, 3)), keep=["out"])
    plan = to_plan(outs, keep=["a"])
    assert plan.output_names() == ("a",)
    assert plan.halo() == (1, 1)  # the opening's 2-wing halo died with "b"


def test_opt_level_zero_is_identity():
    e = X.erode((3, 3)).erode((3, 3))
    assert optimize(e, level=0) is e


def test_halo_never_grows():
    rng = np.random.default_rng(3)
    for _ in range(60):
        e = random_chain(rng)
        raw_halo = halo(e)
        opt_halo = halo(optimize(e))
        assert opt_halo[0] <= raw_halo[0] and opt_halo[1] <= raw_halo[1]
        # the current passes are halo-exact (fold/decompose preserve wings)
        assert opt_halo == raw_halo


def test_gradient_node_analyses():
    g = Gradient(X, (5, 3))
    assert halo(g) == (2, 1)
    reqs = masking_requirements(g)
    assert ("max", (5, 3)) in reqs and ("min", (5, 3)) in reqs


# ------------------------------------------------- equivalence (bit-exactness)
def test_optimized_lowerings_bit_exact_random_chains():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rand((32, 40)))
    for _ in range(10):
        e = random_chain(rng)
        raw = np.asarray(lower_xla(e, policy=RAW)(x))
        opt = np.asarray(lower_xla(e, policy=OPT)(x))
        assert np.array_equal(raw, opt)


def test_optimized_kernel_lowering_bit_exact():
    x = jnp.asarray(rand((24, 40)))
    for e in (
        X.gradient((3, 3)),
        X.erode((3, 3)).erode((3, 3)),
        {"open": X.opening((3, 3)), "tophat": X.tophat((3, 3))},
    ):
        raw = lower_kernel(e, policy=RAW, interpret=True)(x)
        opt = lower_kernel(e, policy=OPT, interpret=True)(x)
        if isinstance(raw, dict):
            for k in raw:
                assert np.array_equal(np.asarray(raw[k]), np.asarray(opt[k]))
        else:
            assert np.array_equal(np.asarray(raw), np.asarray(opt))


def test_optimized_served_plan_bit_exact_with_masking():
    """Bucket-padded + per-node masked execution of an optimized plan (incl.
    the expanded Gradient node) matches the raw graph after cropping."""
    img = rand((30, 40))
    batch = np.zeros((1, 64, 64), dtype=img.dtype)
    batch[0, :30, :40] = img
    rects = np.asarray([valid_rect(30, 40)], dtype=np.int32)
    # distinct SEs keep the gradient's erosion unshared, so it canonicalizes
    outs = {"grad": X.gradient((3, 3)), "feat": X.tophat((5, 5))}
    raw_plan = to_plan(outs, "raw", policy=RAW)
    opt_plan = to_plan(outs, "opt", policy=OPT)
    assert any(isinstance(e, Gradient) for _, e in opt_plan.outputs)
    a = build_executor(raw_plan, policy=RAW)(jnp.asarray(batch), jnp.asarray(rects))
    b = build_executor(opt_plan, policy=OPT)(jnp.asarray(batch), jnp.asarray(rects))
    for k in outs:
        assert np.array_equal(
            np.asarray(a[k])[0, :30, :40], np.asarray(b[k])[0, :30, :40]
        )


def test_decomposition_schedule_is_bit_exact():
    """A synthetic measured model with a convex vHGW curve (the regime where
    iterated small passes beat one large one) decomposes a large SE; the
    iterated chain must be bit-identical and halo-preserving."""
    entries = {
        ("major", "linear_tree", "uint8"): (1.0, 10.0),
        ("major", "vhgw", "uint8"): (1.0, 0.5),
        ("minor", "linear_tree", "uint8"): (1.0, 10.0),
        ("minor", "vhgw", "uint8"): (1.0, 0.5),
    }
    model = CostModel(entries=entries, crossovers={}, source="measured")
    e = X.erode((9, 9))
    opt = optimize(e, level=2, cost_model=model)
    assert opt != e  # it actually decomposed
    assert halo(opt) == halo(e) == (4, 4)
    assert prim_count(opt) > 1
    x = jnp.asarray(rand((32, 32)))
    assert np.array_equal(
        np.asarray(lower_xla(e, policy=RAW)(x)),
        np.asarray(lower_xla(opt, policy=RAW)(x)),
    )


# ------------------------------------------------------------------ cost model
def test_analytic_model_reproduces_thresholds():
    pol = DispatchPolicy(w0_minor=7, w0_major=11, w0_fused=5)
    m = CostModel.analytic(pol)
    assert m.best_method("major", 11, small="linear_tree") == "linear_tree"
    assert m.best_method("major", 13, small="linear_tree") == "vhgw"
    assert m.best_method("minor", 7, small="linear_tree") == "linear_tree"
    assert m.best_method("minor", 9, small="linear_tree") == "vhgw"
    assert m.best_method("fused", 5, small="linear") == "linear"
    assert m.best_method("fused", 7, small="linear") == "vhgw"
    assert m.crossover("major", small="linear_tree") == 13
    # zero per-pass overhead: k small passes never beat one large pass
    assert m.decompose((31, 31)) is None
    assert m.fused_wins((9, 9))


def test_fit_affine_recovers_coefficients():
    c0, c1 = fit_affine([(w, 3.0 + 0.5 * w) for w in (3, 5, 9, 15)])
    assert abs(c0 - 3.0) < 1e-9 and abs(c1 - 0.5) < 1e-9
    c0, c1 = fit_affine([(1.0, 4.0), (1.0, 6.0)])  # degenerate: constant
    assert c0 == 5.0 and c1 == 0.0
    assert feature("linear", 9) == 9.0
    assert feature("linear_tree", 9) == 4.0  # ceil(log2 9)
    assert feature("vhgw", 9) == 81.0  # quadratic: captures measured bend
    assert feature("vhgw", 1) == 0.0


def test_decompose_schedule_wings_sum():
    entries = {
        ("major", "linear_tree", "uint8"): (1.0, 10.0),
        ("major", "vhgw", "uint8"): (1.0, 0.5),
        ("fused", "linear", "uint8"): (1.0, 5.0),
        ("fused", "vhgw", "uint8"): (1.0, 0.5),
    }
    m = CostModel(entries=entries, crossovers={}, source="measured")
    sched = m.decompose((17, 9), kinds=("major", "fused"))
    assert sched is not None
    wings_h = sum((h - 1) // 2 for h, _ in sched)
    wings_w = sum((w - 1) // 2 for _, w in sched)
    assert (wings_h, wings_w) == (8, 4)


def test_fused_wins_uses_op2d_fits():
    m = CostModel(
        entries={},
        crossovers={},
        source="measured",
        op2d={
            ("fused", "uint8"): (10.0, 1.0),
            ("two_pass", "uint8"): (1.0, 0.1),
        },
    )
    assert not m.fused_wins((3, 3))  # two-pass measured cheaper everywhere


def test_cost_table_roundtrip_and_policy_matching(tmp_path, monkeypatch):
    path = str(tmp_path / "cost_table.json")
    monkeypatch.setattr(cost, "COST_TABLE_FILE", path)
    entries = {
        ("major", "linear_tree", "uint8"): (1.0, 0.25),
        ("major", "vhgw", "uint8"): (4.0, 0.0),
    }
    crossovers = {"w0_major": 21, "w0_minor": 15, "w0_fused": 255,
                  "small_method": "linear_tree"}
    cost.save_measured(entries, crossovers, path=path)
    m = cost.load_measured(path=path)
    assert m is not None and m.source == "measured"
    assert m.entries[("major", "linear_tree", "uint8")] == (1.0, 0.25)
    matching = DispatchPolicy(w0_major=21, w0_minor=15, w0_fused=255)
    assert m.matches(matching)
    hand_tuned = DispatchPolicy(w0_fused=5)
    assert not m.matches(hand_tuned)
    # a hand-tuned policy falls back to its own analytic model
    assert cost_model_for(hand_tuned).source == "analytic"
    # a second device's fit must not clobber the first
    cost.save_measured(entries, crossovers, path=path, device="other-dev")
    with open(path) as f:
        table = json.load(f)
    assert len(table["devices"]) == 2


# --------------------------------------------------- calibration memoization
def test_calibrated_policy_memoized_on_mtime(tmp_path, monkeypatch):
    calib = tmp_path / "calibration.json"
    calib.write_text(json.dumps({"w0_major": 41, "w0_minor": 21}))
    monkeypatch.setattr(dispatch_mod, "_CALIBRATION_FILE", str(calib))
    monkeypatch.setattr(cost, "COST_TABLE_FILE", str(tmp_path / "absent.json"))
    dispatch_mod._CALIBRATED_CACHE.clear()
    p1 = DispatchPolicy.calibrated()
    assert (p1.w0_major, p1.w0_minor) == (41, 21)
    assert DispatchPolicy.calibrated() is p1  # memo hit: same object
    # rewrite with a strictly newer mtime -> cache invalidates
    calib.write_text(json.dumps({"w0_major": 43, "w0_minor": 21}))
    st = os.stat(calib)
    os.utime(calib, ns=(st.st_atime_ns, st.st_mtime_ns + 1_000_000))
    p2 = DispatchPolicy.calibrated()
    assert p2.w0_major == 43
    dispatch_mod._CALIBRATED_CACHE.clear()


def test_calibrated_adopts_cost_table_crossovers(tmp_path, monkeypatch):
    calib = tmp_path / "calibration.json"
    calib.write_text(json.dumps({"w0_major": 41, "w0_minor": 21}))
    table = tmp_path / "cost_table.json"
    monkeypatch.setattr(dispatch_mod, "_CALIBRATION_FILE", str(calib))
    monkeypatch.setattr(cost, "COST_TABLE_FILE", str(table))
    cost.save_measured(
        {("major", "vhgw", "uint8"): (1.0, 0.0)},
        {"w0_major": 99, "w0_minor": 33, "w0_fused": 111,
         "small_method": "linear_tree"},
        path=str(table),
    )
    dispatch_mod._CALIBRATED_CACHE.clear()
    p = DispatchPolicy.calibrated()
    # the measured table supersedes the scalar file
    assert (p.w0_major, p.w0_minor, p.w0_fused) == (99, 33, 111)
    # and the measured model applies to the calibrated policy
    assert cost_model_for(p).source == "measured"
    dispatch_mod._CALIBRATED_CACHE.clear()


# ------------------------------------------------------------ adaptive window
def test_adaptive_window_shrinks_and_grows():
    b = MicroBatcher(lambda key, reqs: None, max_batch=16, window_s=0.02,
                     adaptive=True)
    try:
        assert b.window_s == b.max_window_s == 0.02
        b._adapt(1)  # light load: singleton deadline expiry
        assert b.window_s < 0.02
        for _ in range(20):
            b._adapt(1)
        assert b.window_s == b.min_window_s  # drained: converges to min
        # zero is not absorbing: at a zero-width window every group is size
        # 1, so queued backlog (not group size) must reopen the window
        b._adapt(1, backlog=True)
        assert b.window_s > b.min_window_s
        for _ in range(20):
            b._adapt(1)
        b._adapt(16)  # full batch: pressure
        assert b.window_s > b.min_window_s
        for _ in range(20):
            b._adapt(16)
        assert b.window_s == b.max_window_s
        mid = b.window_s
        b._adapt(4)  # between the water marks: hold
        assert b.window_s == mid
    finally:
        b.close()


def test_adaptive_window_static_when_disabled():
    b = MicroBatcher(lambda key, reqs: None, max_batch=16, window_s=0.02)
    try:
        b._adapt(1)
        assert b.window_s == 0.02
    finally:
        b.close()


def test_service_exposes_effective_window():
    cfg = ServiceConfig(buckets=((64, 128),), max_batch=8, window_ms=50.0,
                        adaptive_window=True)
    with MorphService(cfg) as svc:
        for _ in range(4):  # sequential singletons: light load
            svc.run(rand((16, 24)), op="erode", se=(3, 3))
        svc.flush(timeout=5.0)
        deadline = time.monotonic() + 5.0
        while (svc.stats()["effective_window_ms"] >= 50.0
               and time.monotonic() < deadline):
            time.sleep(0.01)
        stats = svc.stats()
    assert stats["window_ms"] == 50.0
    assert stats["adaptive_window"] is True
    assert stats["effective_window_ms"] < 50.0  # shrank under light load


# ----------------------------------------------------- hypothesis properties
try:
    from hypothesis import given, settings, strategies as st

    _HAVE_HYPOTHESIS = True
except ImportError:  # minimal envs lack it; the rng loops above still run
    _HAVE_HYPOTHESIS = False


if _HAVE_HYPOTHESIS:
    _ops = st.sampled_from(
        ["erode", "dilate", "opening", "closing", "gradient", "tophat"])
    _ses = st.sampled_from([(3, 3), (5, 3), (3, 7), (1, 5)])
    _chains = st.lists(st.tuples(_ops, _ses), min_size=1, max_size=4)

    def _build(chain):
        e = X
        for op, se in chain:
            e = getattr(e, op)(se)
        return e

    @settings(max_examples=25, deadline=None)
    @given(chain=_chains, seed=st.integers(0, 2**31))
    def test_property_optimize_bit_exact_xla(chain, seed):
        e = _build(chain)
        x = jnp.asarray(
            np.random.default_rng(seed).integers(0, 256, (20, 28), np.uint8))
        raw = np.asarray(lower_xla(e, policy=RAW)(x))
        opt = np.asarray(lower_xla(e, policy=OPT)(x))
        assert np.array_equal(raw, opt)

    @settings(max_examples=8, deadline=None)
    @given(chain=_chains, seed=st.integers(0, 2**31))
    def test_property_optimize_bit_exact_kernel(chain, seed):
        e = _build(chain)
        x = jnp.asarray(
            np.random.default_rng(seed).integers(0, 256, (16, 24), np.uint8))
        raw = np.asarray(lower_kernel(e, policy=RAW, interpret=True)(x))
        opt = np.asarray(lower_kernel(e, policy=OPT, interpret=True)(x))
        assert np.array_equal(raw, opt)

    @settings(max_examples=50, deadline=None)
    @given(chain=_chains)
    def test_property_halo_monotone(chain):
        e = _build(chain)
        rh, oh = halo(e), halo(optimize(e))
        assert oh[0] <= rh[0] and oh[1] <= rh[1]
