"""Chaos suite for the fault-tolerant serving tier (ISSUE 6).

Every guarantee the resilience layer makes is driven here with the
deterministic fault-injection harness (``FaultPlan``/``FaultInjector`` —
dispatch-ordinal counting, no randomness, no wall-clock triggers):

* admission control sheds load with typed ``Overloaded`` instead of
  growing the queue without bound;
* deadlines fail expired requests with ``DeadlineExceeded`` instead of
  occupying the executor;
* a failed group retries, then bisects, so one poison request fails alone
  while every batch-mate completes;
* executor/compile failures carry (plan, bucket, dtype, batch) context;
* ``close()`` is idempotent and post-close ``submit()`` raises
  ``ServiceClosed``; concurrent submit/flush/close races resolve every
  future exactly once;
* the sharded router trips a per-shard circuit breaker, deterministically
  reroutes the broken shard's groups to survivors (with cache rewarm),
  readmits a recovered shard through a half-open probe, and surfaces all
  of it in ``stats()`` — with zero hung futures throughout.

Shard chaos runs on logical shards (the same CPU device repeated), so the
whole suite is tier-1; the CI chaos job re-runs it on 8 forced host
devices for real device separation.
"""
import threading
import time
import zlib
from concurrent.futures import Future

import jax
import numpy as np
import pytest

from repro.core import erode
from repro.serve.morph import (
    DeadlineExceeded,
    ExecutorError,
    FailoverPolicy,
    FaultInjector,
    FaultPlan,
    HedgePolicy,
    InjectedFault,
    MicroBatcher,
    MorphService,
    Overloaded,
    PoisonedRequest,
    RetryPolicy,
    ServeError,
    ServiceClosed,
    ServiceConfig,
    ShardUnavailable,
    UnknownPlan,
    get_plan,
    single_op_plan,
)
from repro.shard import ShardedMorphService

RNG = np.random.default_rng(11)


def rand(h=40, w=50, dtype=np.uint8):
    return RNG.integers(0, 255, (h, w), dtype=dtype)


def fast_retry(max_retries=1):
    return RetryPolicy(max_retries=max_retries, backoff_ms=0.5, backoff_cap_ms=2.0)


def cfg(**kw):
    kw.setdefault("buckets", ((64, 64),))
    kw.setdefault("window_ms", 1.0)
    kw.setdefault("retry", fast_retry())
    return ServiceConfig(**kw)


def poll_until(pred, timeout=20.0, interval=0.02):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return pred()


# ------------------------------------------------------------- typed errors
def test_serve_error_carries_context():
    e = ExecutorError("boom", plan="cleanup", bucket=(64, 64), dtype="uint8",
                      batch=8, shard=3)
    s = str(e)
    for frag in ("cleanup", "(64, 64)", "uint8", "batch=8", "shard=3"):
        assert frag in s
    assert e.retryable
    assert not Overloaded("x").retryable
    assert not DeadlineExceeded("x").retryable
    assert not PoisonedRequest("x", tag="t").retryable


def test_unknown_plan_is_typed_and_keyerror():
    with pytest.raises(UnknownPlan):
        get_plan("no_such_plan")
    with pytest.raises(KeyError):  # pre-resilience contract preserved
        get_plan("no_such_plan")
    with pytest.raises(ServeError, match="no_such_plan"):
        get_plan("no_such_plan")


def test_empty_bucket_ladder_rejected_at_construction():
    with pytest.raises(ServeError, match="bucket"):
        MorphService(ServiceConfig(buckets=()))


# --------------------------------------------------------- admission control
def test_overloaded_sheds_excess_load():
    """With the worker pinned by injected latency, submits past max_queue
    raise Overloaded; every accepted request still completes."""
    c = cfg(max_queue=4, window_ms=200.0, max_batch=1,
            faults=FaultPlan(latency_ms=30.0))
    img = rand()
    with MorphService(c) as svc:
        accepted, rejected = [], 0
        for _ in range(16):
            try:
                accepted.append(svc.submit(img, "erode", (3, 3)))
            except Overloaded as e:
                assert not e.retryable
                rejected += 1
        assert rejected > 0
        for f in accepted:
            assert f.result(timeout=60) is not None
        stats = svc.stats()
    assert stats["resilience"]["rejected_overloaded"] == rejected
    assert stats["resilience"]["max_queue"] == 4
    assert all(f.done() for f in accepted)


def test_unbounded_queue_opt_out():
    with MorphService(cfg(max_queue=None)) as svc:
        futs = [svc.submit(rand(), "erode", (3, 3)) for _ in range(64)]
        for f in futs:
            f.result(timeout=60)
        assert svc.stats()["resilience"]["rejected_overloaded"] == 0


# ------------------------------------------------------------------ deadlines
def test_deadline_already_expired_rejected_at_submit():
    with MorphService(cfg()) as svc:
        with pytest.raises(DeadlineExceeded, match="erode"):
            svc.submit(rand(), "erode", (3, 3), deadline_ms=0)


def test_deadline_expires_in_queue():
    """A request stuck behind a slow dispatch fails typed when its deadline
    passes, instead of hanging or occupying the executor."""
    c = cfg(window_ms=0.0, max_batch=1, faults=FaultPlan(latency_ms=120.0),
            retry=None)
    with MorphService(c) as svc:
        blocker = svc.submit(rand(), "erode", (3, 3))  # pins the worker
        time.sleep(0.02)
        doomed = svc.submit(rand(), "dilate", (3, 3), deadline_ms=5.0)
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=60)
        blocker.result(timeout=60)
        stats = svc.stats()
    assert stats["resilience"]["deadline_expired"] >= 1


def test_default_deadline_from_config():
    c = cfg(window_ms=0.0, max_batch=1, default_deadline_ms=5.0,
            faults=FaultPlan(latency_ms=120.0), retry=None)
    with MorphService(c) as svc:
        blocker = svc.submit(rand(), "erode", (3, 3), deadline_ms=10_000.0)
        time.sleep(0.02)
        doomed = svc.submit(rand(), "dilate", (3, 3))  # inherits 5 ms
        with pytest.raises(DeadlineExceeded):
            doomed.result(timeout=60)
        blocker.result(timeout=60)


def test_urgent_deadline_pulls_group_dispatch_forward():
    """A tight deadline overrides the batching window: the group dispatches
    at the deadline, not window_ms later."""
    with MorphService(cfg(window_ms=5000.0, adaptive_window=False)) as svc:
        t0 = time.monotonic()
        out = svc.run(rand(), "erode", (3, 3), deadline_ms=50.0)
        assert out is not None  # completed, not expired
        assert time.monotonic() - t0 < 4.0  # nowhere near the 5 s window


# --------------------------------------------------- retry + batch isolation
def test_retry_recovers_transient_fault():
    img = rand()
    c = cfg(max_batch=1, faults=FaultPlan(fail_after=0, fail_for=1),
            retry=fast_retry(max_retries=2))
    with MorphService(c) as svc:
        got = svc.run(img, "erode", (3, 3))
        stats = svc.stats()
    np.testing.assert_array_equal(got, np.asarray(erode(img, (3, 3))))
    assert stats["resilience"]["retries"] >= 1
    assert stats["resilience"]["request_failures"] == 0
    assert stats["resilience"]["faults"]["injected_faults"] == 1


def test_retries_exhausted_gives_typed_error():
    c = cfg(max_batch=1, faults=FaultPlan(fail_after=0, fail_for=None),
            retry=fast_retry(max_retries=1))
    with MorphService(c) as svc:
        with pytest.raises(InjectedFault):
            svc.run(rand(), "erode", (3, 3))
        stats = svc.stats()
    assert stats["resilience"]["request_failures"] == 1


def test_bisection_isolates_poison_request():
    """One poisoned request in a batch of 8: the seven batch-mates complete
    bit-exact, the poison fails alone with PoisonedRequest."""
    imgs = [rand(40 + i, 50) for i in range(8)]
    c = cfg(max_batch=8, window_ms=500.0, adaptive_window=False,
            faults=FaultPlan(poison_tags=frozenset({"bad"})),
            retry=fast_retry(max_retries=0))
    with MorphService(c) as svc:
        futs = [
            svc.submit(im, "erode", (3, 3), tag="bad" if i == 3 else None)
            for i, im in enumerate(imgs)
        ]
        results = []
        for i, f in enumerate(futs):
            if i == 3:
                with pytest.raises(PoisonedRequest) as ei:
                    f.result(timeout=60)
                assert ei.value.tag == "bad"
                results.append(None)
            else:
                results.append(f.result(timeout=60))
        stats = svc.stats()
    for i, (im, got) in enumerate(zip(imgs, results)):
        if i == 3:
            continue
        np.testing.assert_array_equal(got, np.asarray(erode(im, (3, 3))))
    assert stats["resilience"]["bisections"] >= 1
    assert stats["resilience"]["request_failures"] == 1
    assert all(f.done() for f in futs)  # zero hung futures


def test_injected_faults_are_deterministic():
    """Same FaultPlan + same traffic -> identical injector trace."""
    def run_once():
        c = cfg(max_batch=1, faults=FaultPlan(fail_after=1, fail_for=2),
                retry=fast_retry(max_retries=3))
        with MorphService(c) as svc:
            svc.run(rand(32, 32), "erode", (3, 3))
            svc.run(rand(32, 32), "erode", (3, 3))
            return svc.stats()["resilience"]["faults"]
    a, b = run_once(), run_once()
    assert a == b
    assert a["injected_faults"] == 2


def test_zero_overhead_when_faults_off():
    with MorphService(cfg()) as svc:
        assert svc._injector is None  # the off path is one None check
        assert svc.stats()["resilience"]["faults"] is None


# ------------------------------------------------------- typed executor errors
def test_executor_error_carries_group_context():
    """A real compile failure (Mosaic lowering on CPU) surfaces as
    ExecutorError with (plan, bucket, dtype, batch) instead of a bare XLA
    traceback."""
    if jax.default_backend() == "tpu":
        pytest.skip("kernel backend compiles fine on TPU")
    c = cfg(backend="kernel", interpret=False, max_batch=1,
            retry=fast_retry(max_retries=0))
    with MorphService(c) as svc:
        with pytest.raises(ExecutorError) as ei:
            svc.run(rand(), "erode", (3, 3))
    e = ei.value
    assert e.plan == "erode"
    assert e.bucket == (64, 64)
    assert e.dtype == "uint8"
    assert e.batch == 1
    assert e.__cause__ is not None  # original traceback chained


# ------------------------------------------------------------ close semantics
def test_close_is_idempotent_and_submit_after_close_raises():
    svc = MorphService(cfg())
    f = svc.submit(rand(), "erode", (3, 3))
    svc.close()
    f.result(timeout=60)  # close drains in-flight work
    svc.close()  # double close: no-op, no error
    with pytest.raises(ServiceClosed):
        svc.submit(rand(), "erode", (3, 3))
    with pytest.raises(RuntimeError):  # pre-resilience contract preserved
        svc.submit(rand(), "erode", (3, 3))
    assert svc.flush(timeout=1.0)  # drained service: flush trivially true


def test_submit_during_drain_never_hangs():
    """Submissions racing close() either complete or raise ServiceClosed —
    no future is ever left pending."""
    svc = MorphService(cfg(window_ms=5.0))
    futs, closed_rejections = [], 0
    stop = threading.Event()

    def submitter():
        nonlocal closed_rejections
        while not stop.is_set():
            try:
                futs.append(svc.submit(rand(16, 16), "erode", (3, 3)))
            except Overloaded:
                time.sleep(0.005)  # backpressure: shed and retry
            except ServiceClosed:
                closed_rejections += 1
                return

    threads = [threading.Thread(target=submitter) for _ in range(4)]
    for t in threads:
        t.start()
    time.sleep(0.1)
    svc.close()
    stop.set()
    for t in threads:
        t.join(timeout=30)
    for f in futs:
        f.result(timeout=60)  # accepted => served, even mid-drain
    assert all(f.done() for f in futs)


# -------------------------------------------------- batcher race stress test
def test_batcher_concurrent_submit_flush_close_stress():
    """Threaded barrier stress on MicroBatcher: every accepted request's
    future resolves exactly once across concurrent submit + flush + close."""
    resolved = []

    class Req:
        def __init__(self, i):
            self.key = f"k{i % 3}"
            self.future = Future()
            self.i = i

    def execute(key, reqs):
        for r in reqs:
            r.future.set_result(r.i)  # double-resolve would raise here
            resolved.append(r.i)

    b = MicroBatcher(execute, max_batch=8, window_s=0.002,
                     max_queue=None, retry=RetryPolicy(max_retries=0))
    n_threads, per_thread = 8, 50
    barrier = threading.Barrier(n_threads)
    accepted: list = []
    lock = threading.Lock()
    closed_at: list = []

    def worker(t):
        barrier.wait()
        for i in range(per_thread):
            req = Req(t * per_thread + i)
            try:
                b.submit(req)
            except ServiceClosed:
                closed_at.append(req.i)
                return
            with lock:
                accepted.append(req)
            if i % 10 == 0:
                b.flush(timeout=5)

    threads = [threading.Thread(target=worker, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    time.sleep(0.05)
    b.close()  # races the submitters
    for t in threads:
        t.join(timeout=30)
    b.close()  # idempotent under stress too
    for req in accepted:
        assert req.future.result(timeout=10) == req.i
    # exactly once: every accepted id resolved, none twice
    assert sorted(resolved) == sorted(r.i for r in accepted)
    assert len(set(resolved)) == len(resolved)


# ----------------------------------------------------------- sharded failover
N_LOGICAL = 4


def logical_devices(n=N_LOGICAL):
    """n logical shards on whatever devices exist (repeats the first device
    when the host has fewer — routing/failover logic is device-agnostic)."""
    devs = jax.devices()
    return [devs[i % len(devs)] for i in range(n)]


def primary_index(plan_name, bucket, dtype_str, n):
    token = f"{plan_name}|{bucket}|{dtype_str}".encode()
    return zlib.crc32(token) % n


ERODE5 = single_op_plan("erode", (5, 5))
E5_PRIMARY = primary_index("erode", (64, 64), np.dtype(np.uint8).str, N_LOGICAL)


def test_shard_failover_reroutes_and_completes_all():
    """Kill one shard mid-traffic: every in-flight and subsequent request
    completes (rerouted to survivors), stats() reports the shard unhealthy,
    and no future hangs."""
    c = cfg(window_ms=2.0,
            retry=fast_retry(max_retries=0),
            failover=FailoverPolicy(failure_threshold=1, probe_interval_s=600.0),
            faults=FaultPlan(fail_shard=E5_PRIMARY, fail_after=0, fail_for=None))
    imgs = [rand(40 + i, 50) for i in range(12)]
    with ShardedMorphService(c, devices=logical_devices()) as svc:
        futs = [svc.submit_plan(im, ERODE5) for im in imgs]
        results = [f.result(timeout=120) for f in futs]
        # subsequent traffic routes straight to the survivor
        late_img = rand()
        late = svc.run_plan(late_img, ERODE5)
        stats = svc.stats()
    for im, got in zip(imgs, results):
        np.testing.assert_array_equal(got, np.asarray(erode(im, (5, 5))))
    np.testing.assert_array_equal(late, np.asarray(erode(late_img, (5, 5))))
    assert all(f.done() for f in futs)
    assert stats["healthy_shards"] == N_LOGICAL - 1
    assert stats["health"][E5_PRIMARY]["state"] == "open"
    assert stats["health"][E5_PRIMARY]["trips"] == 1
    assert stats["resilience"]["reroutes"] >= len(imgs)
    assert stats["resilience"]["failovers"] == 1


def test_shard_failover_rewarms_survivor_cache():
    c = cfg(window_ms=2.0,
            retry=fast_retry(max_retries=0),
            failover=FailoverPolicy(failure_threshold=1, probe_interval_s=600.0),
            faults=FaultPlan(fail_shard=E5_PRIMARY, fail_after=0, fail_for=None))
    with ShardedMorphService(c, devices=logical_devices()) as svc:
        svc.run_plan(rand(), ERODE5)  # trips the breaker, reroutes, rewarm fires
        assert poll_until(
            lambda: svc.stats()["resilience"]["rewarms"] >= 1, timeout=30
        ), svc.stats()["resilience"]
        stats = svc.stats()
        # the deterministic survivor holds a compiled executable for the group
        n = len(svc.shards)
        survivors = [i for i in range(n) if i != E5_PRIMARY]
        token = svc._token(ERODE5, (64, 64), np.dtype(np.uint8).str)
        target = survivors[zlib.crc32(token) % len(survivors)]
        assert svc.shards[target].cache.snapshot()["size"] >= 1
    assert stats["resilience"]["rewarms"] >= 1


def test_shard_recovery_via_half_open_probe():
    """A shard that fails for a finite window is readmitted by a half-open
    probe after probe_interval_s; stats() reports the recovery."""
    c = cfg(window_ms=1.0,
            retry=fast_retry(max_retries=0),
            failover=FailoverPolicy(failure_threshold=1, probe_interval_s=0.15),
            faults=FaultPlan(fail_shard=E5_PRIMARY, fail_after=0, fail_for=2))
    img = rand()
    ref = np.asarray(erode(img, (5, 5)))
    with ShardedMorphService(c, devices=logical_devices()) as svc:
        # trip: dispatch 0 fails, reroutes, breaker opens
        np.testing.assert_array_equal(svc.run_plan(img, ERODE5), ref)
        assert svc.stats()["healthy_shards"] == N_LOGICAL - 1

        def recovered():
            np.testing.assert_array_equal(svc.run_plan(img, ERODE5), ref)
            s = svc.stats()
            return s["healthy_shards"] == N_LOGICAL
        # probes burn through the remaining injected failure, then readmit
        assert poll_until(recovered, timeout=60, interval=0.05)
        stats = svc.stats()
    h = stats["health"][E5_PRIMARY]
    assert h["state"] == "closed"
    assert h["probes"] >= 1
    assert h["recoveries"] == 1


def test_all_shards_down_is_typed_not_hung():
    c = cfg(window_ms=1.0,
            retry=fast_retry(max_retries=0),
            failover=FailoverPolicy(failure_threshold=1, probe_interval_s=600.0),
            faults=FaultPlan(fail_after=0, fail_for=None))  # every shard fails
    with ShardedMorphService(c, devices=logical_devices(2)) as svc:
        f = svc.submit_plan(rand(), ERODE5)
        with pytest.raises((InjectedFault, ShardUnavailable)):
            f.result(timeout=60)
        assert f.done()
        # subsequent submits reject typed too (both breakers open)
        f2 = svc.submit_plan(rand(), ERODE5)
        with pytest.raises((InjectedFault, ShardUnavailable)):
            f2.result(timeout=60)


def test_router_request_level_errors_do_not_trip_breaker():
    """Poison and deadline failures indict the request, not the shard: the
    breaker stays closed and traffic keeps flowing."""
    c = cfg(window_ms=2.0,
            retry=fast_retry(max_retries=0),
            failover=FailoverPolicy(failure_threshold=1, probe_interval_s=600.0),
            faults=FaultPlan(poison_tags=frozenset({"bad"})))
    img = rand()
    with ShardedMorphService(c, devices=logical_devices()) as svc:
        with pytest.raises(PoisonedRequest):
            svc.run_plan(img, ERODE5, tag="bad")
        with pytest.raises(DeadlineExceeded):
            svc.run_plan(img, ERODE5, deadline_ms=0.0001)
        got = svc.run_plan(img, ERODE5)  # service still healthy
        stats = svc.stats()
    np.testing.assert_array_equal(got, np.asarray(erode(img, (5, 5))))
    assert stats["healthy_shards"] == N_LOGICAL
    assert stats["resilience"]["failovers"] == 0


def test_router_stats_surface_health_block():
    with ShardedMorphService(cfg(), devices=logical_devices(2)) as svc:
        svc.run_plan(rand(), ERODE5)
        stats = svc.stats()
    assert stats["shards"] == 2
    assert stats["healthy_shards"] == 2
    assert len(stats["health"]) == 2
    for h in stats["health"]:
        assert h["state"] == "closed"
        assert set(h) == {"state", "consecutive_failures", "trips", "probes",
                          "recoveries", "slow", "slow_marks",
                          "slow_recoveries", "latency_ewma_ms"}
    for k in ("reroutes", "rewarms", "failovers", "retries", "bisections",
              "rejected_overloaded", "rejected_quota", "shed_brownout",
              "deadline_expired", "request_failures", "hedges", "hedge_wins"):
        assert k in stats["resilience"]


def test_router_close_idempotent_and_submit_after_close():
    svc = ShardedMorphService(cfg(), devices=logical_devices(2))
    svc.run_plan(rand(), ERODE5)
    svc.close()
    svc.close()
    f = svc.submit_plan(rand(), ERODE5)
    with pytest.raises(ServiceClosed):
        f.result(timeout=60)


# ------------------------------------------------- gray failures (ISSUE 9)
def peer_plan():
    """A plan whose group routes to a different primary shard than ERODE5,
    so a second shard accumulates latency samples (peer-relative slow
    scoring needs at least two reporting shards)."""
    for op in ("dilate", "opening", "closing"):
        idx = primary_index(op, (64, 64), np.dtype(np.uint8).str, N_LOGICAL)
        if idx != E5_PRIMARY:
            return single_op_plan(op, (5, 5))
    raise AssertionError("no plan maps off the erode primary")  # pragma: no cover


def test_gray_latency_clauses_are_deterministic():
    """latency_after/latency_every count by dispatch ordinal — the same
    plan replays the exact same gray schedule, run after run."""
    inj = FaultInjector(FaultPlan(latency_ms=1.0, latency_after=2,
                                  latency_every=3))
    assert [inj._latency_due(n) for n in range(8)] == [
        False, False, True, False, False, True, False, False]
    # persistent clause: every dispatch from latency_after onward pays
    inj2 = FaultInjector(FaultPlan(latency_ms=1.0, latency_after=3))
    assert [inj2._latency_due(n) for n in range(6)] == [
        False, False, False, True, True, True]
    # the schedule is a pure function of the ordinal: a replay matches
    replay = FaultInjector(FaultPlan(latency_ms=1.0, latency_after=2,
                                     latency_every=3))
    assert [replay._latency_due(n) for n in range(8)] == [
        inj._latency_due(n) for n in range(8)]


def test_slow_shard_marked_and_drained_without_breaker():
    """A persistently slow (but correct) shard is marked "slow" from its
    peer-relative latency EWMA and drained of traffic — breaker closed the
    whole time, never "open", zero trips."""
    c = cfg(window_ms=1.0, retry=fast_retry(max_retries=0),
            failover=FailoverPolicy(slow_min_count=4, slow_min_ms=5.0,
                                    slow_probe_interval_s=600.0),
            faults=FaultPlan(latency_ms=80.0, latency_shard=E5_PRIMARY))
    img = rand()
    ref = np.asarray(erode(img, (5, 5)))
    with ShardedMorphService(c, devices=logical_devices()) as svc:
        # peer baseline on a healthy shard: enough traffic that the peer's
        # own first-request compile spike decays out of its EWMA (the
        # median must reflect steady state, not the cold start)
        for _ in range(12):
            svc.run_plan(img, peer_plan())
        for _ in range(5):  # slow primary feeds its own EWMA
            np.testing.assert_array_equal(svc.run_plan(img, ERODE5), ref)
        assert poll_until(
            lambda: svc.stats()["health"][E5_PRIMARY]["state"] == "slow",
            timeout=30,
        ), svc.stats()["health"][E5_PRIMARY]
        before = svc.stats()["resilience"]["reroutes"]
        svc.run_plan(img, ERODE5)  # first drained request warms the survivor
        t0 = time.monotonic()
        for _ in range(5):
            np.testing.assert_array_equal(svc.run_plan(img, ERODE5), ref)
        drained_s = time.monotonic() - t0
        stats = svc.stats()
    h = stats["health"][E5_PRIMARY]
    assert h["slow"] and h["slow_marks"] >= 1
    assert h["state"] == "slow"  # degraded, not dead
    assert h["trips"] == 0
    assert stats["slow_shards"] == 1
    assert stats["resilience"]["failovers"] == 0
    assert stats["resilience"]["reroutes"] > before
    # drained traffic never pays the 80 ms gray tax
    assert drained_s < 5 * 0.080, drained_s


def test_slow_state_recovers_on_ewma_decay():
    """Slow is reversible: when the EWMA falls back toward the peer median
    the shard is unmarked (hysteresis via slow_exit_factor) and the
    recovery is counted — all without the breaker ever moving."""
    c = cfg(failover=FailoverPolicy(slow_min_count=2, slow_min_ms=1.0))
    with ShardedMorphService(c, devices=logical_devices(2)) as svc:
        other = 1 - E5_PRIMARY % 2
        for _ in range(4):
            svc._observe_latency(E5_PRIMARY % 2, 100.0)
            svc._observe_latency(other, 2.0)
        assert svc.stats()["health"][E5_PRIMARY % 2]["state"] == "slow"
        for _ in range(40):  # decay back to the peer's neighborhood
            svc._observe_latency(E5_PRIMARY % 2, 2.0)
        h = svc.stats()["health"][E5_PRIMARY % 2]
    assert not h["slow"]
    assert h["state"] == "closed"
    assert h["slow_recoveries"] == 1
    assert h["trips"] == 0


def test_hedged_requests_exactly_once_and_single_count():
    """Chaos: every request races a hedge against a gray-slow primary.
    Every future resolves exactly once with the bit-exact result, and the
    router's request count ticks once per caller request even though two
    shards did the work (extends the barrier-race guarantees to hedging)."""
    c = cfg(window_ms=1.0, retry=fast_retry(max_retries=0),
            failover=FailoverPolicy(slow_detection=False),
            hedge=HedgePolicy(enabled=True, min_delay_ms=10.0,
                              max_delay_ms=40.0),
            faults=FaultPlan(latency_ms=120.0, latency_shard=E5_PRIMARY))
    imgs = [rand(40 + i, 50) for i in range(16)]
    refs = [np.asarray(erode(im, (5, 5))) for im in imgs]
    with ShardedMorphService(c, devices=logical_devices()) as svc:
        futs = [svc.submit_plan(im, ERODE5) for im in imgs]
        results = [f.result(timeout=120) for f in futs]
        stats = svc.stats()
    for got, ref in zip(results, refs):
        np.testing.assert_array_equal(got, ref)
    assert all(f.done() for f in futs)
    res = stats["resilience"]
    assert res["hedges"] >= 1
    assert res["hedge_wins"] <= res["hedges"]
    # exactly one count per caller request, however many shards raced on it
    assert stats["requests"] == len(imgs)
    # shard-side counters still see the duplicated work
    assert sum(p["requests"] for p in stats["per_shard"]) >= len(imgs)
    # hedging is a latency tool, not a health verdict: nothing tripped
    assert all(h["trips"] == 0 for h in stats["health"])


def test_hedge_disabled_keeps_request_counts_equal():
    """Without hedging the router-own count and the per-shard sum agree —
    the single-count bookkeeping is invisible when nothing races."""
    with ShardedMorphService(cfg(), devices=logical_devices(2)) as svc:
        for _ in range(6):
            svc.run_plan(rand(), ERODE5)
        stats = svc.stats()
    assert stats["requests"] == 6
    assert sum(p["requests"] for p in stats["per_shard"]) == 6
    assert stats["resilience"]["hedges"] == 0


def test_hedge_delay_excludes_gray_target_latency():
    """Regression for the PR 9 survivor-bias debt: the hedge trigger delay
    is the p99 of the *peers* of the shard being hedged, not of a merged
    histogram that shard itself inflates. Before the fix, a gray shard's
    own slow completions dragged the merged p99 up to its latency, so the
    hedge meant to rescue its requests armed too late to ever fire."""
    c = cfg(window_ms=1.0, retry=fast_retry(max_retries=0),
            failover=FailoverPolicy(slow_detection=False),
            hedge=HedgePolicy(enabled=True, min_delay_ms=5.0,
                              max_delay_ms=1000.0, refresh_s=600.0),
            faults=FaultPlan(latency_ms=80.0, latency_shard=E5_PRIMARY))
    peer = next(i for i in range(N_LOGICAL) if i != E5_PRIMARY)
    with ShardedMorphService(c, devices=logical_devices()) as svc:
        # deterministic histograms: the primary is gray at ~100 ms, every
        # peer serves at ~3 ms
        for i, shard in enumerate(svc.shards):
            h = shard.metrics.histogram("latency_ms")
            for _ in range(50):
                h.observe(100.0 if i == E5_PRIMARY else 3.0)
        # the old, biased number: a merge that includes the gray shard
        # (here: excluding a healthy peer instead) reads the gray tax
        biased_ms = svc._hedge_delay_s(exclude=peer) * 1e3
        # the fixed number: hedging OFF the gray primary reads peers only
        delay_ms = svc._hedge_delay_s(exclude=E5_PRIMARY) * 1e3
        assert biased_ms >= 60.0, biased_ms
        assert delay_ms <= 20.0, (delay_ms, biased_ms)
        # live path: the trigger (cached above for refresh_s) fires well
        # inside the primary's 80 ms gray tax, so its requests hedge out
        imgs = [rand(40 + i, 50) for i in range(6)]
        refs = [np.asarray(erode(im, (5, 5))) for im in imgs]
        futs = [svc.submit_plan(im, ERODE5) for im in imgs]
        results = [f.result(timeout=120) for f in futs]
        stats = svc.stats()
    for got, ref in zip(results, refs):
        np.testing.assert_array_equal(got, ref)
    res = stats["resilience"]
    assert res["hedges"] >= 1  # the gray shard no longer suppresses them
    assert res["hedge_delay_ms"] <= 20.0
    assert stats["requests"] == len(imgs)
