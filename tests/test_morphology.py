"""Core morphology: every algorithm vs the naive oracle, all dtypes/axes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    dilate,
    dilate_naive,
    erode,
    erode_naive,
    linear_1d,
    linear_1d_paired,
    linear_1d_tree,
    morph_1d,
    vhgw_1d,
)
from repro.core.types import as_op

RNG = np.random.default_rng(42)


def ref_1d(x: np.ndarray, w: int, axis: int, op: str) -> np.ndarray:
    o = as_op(op)
    wing = (w - 1) // 2
    pads = [(0, 0)] * x.ndim
    pads[axis] = (wing, wing)
    xp = np.pad(x, pads, constant_values=np.asarray(o.neutral(x.dtype)))
    out = None
    red = np.minimum if o.name == "min" else np.maximum
    for k in range(w):
        sl = [slice(None)] * x.ndim
        sl[axis] = slice(k, k + x.shape[axis])
        v = xp[tuple(sl)]
        out = v if out is None else red(out, v)
    return out


METHODS = {
    "vhgw": vhgw_1d,
    "linear": linear_1d,
    "linear_paired": linear_1d_paired,
    "linear_tree": linear_1d_tree,
}


@pytest.mark.parametrize("method", list(METHODS))
@pytest.mark.parametrize("w", [1, 3, 5, 9, 31, 63])
@pytest.mark.parametrize("axis", [-1, -2])
@pytest.mark.parametrize("op", ["min", "max"])
def test_1d_matches_oracle(method, w, axis, op):
    x = RNG.integers(0, 256, (3, 41, 57), dtype=np.uint8)
    got = np.asarray(METHODS[method](jnp.asarray(x), w, axis=axis, op=op))
    np.testing.assert_array_equal(got, ref_1d(x, w, axis % 3, op))


@pytest.mark.parametrize("dtype", [np.uint8, np.int8, np.int32, np.float32])
def test_dtype_sweep(dtype):
    if np.issubdtype(dtype, np.floating):
        x = RNG.standard_normal((17, 33)).astype(dtype)
    else:
        info = np.iinfo(dtype)
        x = RNG.integers(info.min, info.max, (17, 33), dtype=dtype)
    for w in (3, 9):
        for axis in (-1, -2):
            got = np.asarray(vhgw_1d(jnp.asarray(x), w, axis=axis, op="min"))
            np.testing.assert_array_equal(got, ref_1d(x, w, axis % 2, "min"))


def test_bfloat16():
    x = jnp.asarray(RNG.standard_normal((16, 32)), jnp.bfloat16)
    a = np.asarray(vhgw_1d(x, 5, op="max").astype(jnp.float32))
    b = np.asarray(linear_1d(x, 5, op="max").astype(jnp.float32))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("se", [(3, 3), (1, 9), (9, 1), (5, 7), (31, 3)])
def test_2d_separable_equals_naive(se):
    x = jnp.asarray(RNG.integers(0, 256, (2, 43, 61), dtype=np.uint8))
    np.testing.assert_array_equal(np.asarray(erode(x, se)), np.asarray(erode_naive(x, se)))
    np.testing.assert_array_equal(np.asarray(dilate(x, se)), np.asarray(dilate_naive(x, se)))


def test_hybrid_dispatch_matches_each_method():
    x = jnp.asarray(RNG.integers(0, 256, (64, 80), dtype=np.uint8))
    for w in (3, 15, 33, 65, 91):
        want = ref_1d(np.asarray(x), w, 0, "min")
        got = np.asarray(morph_1d(x, w, axis=0, op="min", method="auto"))
        np.testing.assert_array_equal(got, want)


def test_even_window_rejected():
    x = jnp.zeros((8, 8), jnp.uint8)
    with pytest.raises(ValueError):
        vhgw_1d(x, 4)
    with pytest.raises(ValueError):
        erode(x, (2, 3))
