"""Distribution-layer unit tests: sharding rules, mesh factories, masks.

These run on the single local device (specs are validated structurally;
the 512-device compile proof lives in launch/dryrun.py per the assignment
— smoke tests must NOT set xla_force_host_platform_device_count)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import band_mask
from repro.launch import sharding as shd
from repro.launch.mesh import data_axes
from repro.models import get_config
from repro.models.attention import causal_mask
from repro.models.model import init_decode_cache, init_params
from repro.optim import adamw_init

pytestmark = pytest.mark.slow  # heavyweight: deselected from tier-1 (see pytest.ini)


class FakeMesh:
    """Structural stand-in: sharding rules only need .shape and .axis_names."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def spec(path, shape, mesh=MESH):
    return shd.spec_for_leaf(path, shape, mesh)


def test_attention_param_specs():
    assert spec("layers/attn/wq", (28, 3072, 16, 256)) == P(None, ("data",), "model", None)
    assert spec("layers/attn/wo", (28, 4096, 3072)) == P(None, "model", ("data",))
    # kv heads not divisible by model axis -> replicated head dim
    assert spec("layers/attn/wk", (36, 2048, 2, 128)) == P(None, ("data",), None, None)


def test_mlp_and_moe_specs():
    assert spec("layers/mlp/w_gate", (28, 3072, 24576)) == P(None, ("data",), "model")
    assert spec("layers/moe/w_down", (64, 8, 32768, 6144)) == P(None, None, "model", ("data",))
    assert spec("layers/moe/router", (64, 6144, 8)) == P(None, ("data",), None)


def test_embed_specs_divisibility_guard():
    assert spec("embed/embedding", (256000, 3072)) == P("model", ("data",))
    # whisper vocab 51865 is not divisible by 16 -> vocab dim replicated
    assert spec("embed/embedding", (51865, 1024)) == P(None, ("data",))


def test_norms_replicated():
    assert spec("layers/ln_attn/scale", (28, 3072)) == P()


def test_multipod_batch_axes():
    assert data_axes(MESH_MP) == ("pod", "data")
    assert shd.batch_spec(MESH_MP, 256) == P(("pod", "data"))
    assert shd.batch_spec(MESH_MP, 1) == P()  # batch 1 cannot shard


def test_full_param_tree_shardings_cover_all_leaves():
    cfg = get_config("gemma-7b")
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    struct = jax.eval_shape(
        lambda: (lambda p: {"params": p, "opt": adamw_init(p)})(
            init_params(cfg, jax.random.PRNGKey(0))))
    sh = shd.tree_shardings(struct, mesh)
    n = len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
    assert n == len(jax.tree.leaves(struct))


def test_kv_cache_specs():
    cfg = get_config("gemma-7b")  # kv=16: head sharding
    assert shd.kv_cache_spec(MESH, cfg, 128, 32768) == P(None, ("data",), None, "model", None)
    cfg2 = get_config("qwen2.5-3b")  # kv=2: sequence sharding
    assert shd.kv_cache_spec(MESH, cfg2, 128, 32768) == P(None, ("data",), "model", None, None)
    # batch=1 long-context: batch replicated, seq sharded
    cfg3 = get_config("hymba-1.5b")  # kv=5
    assert shd.kv_cache_spec(MESH, cfg3, 1, 524288) == P(None, None, "model", None, None)


def test_cache_shardings_tree():
    cfg = get_config("hymba-1.5b")
    cache = jax.eval_shape(lambda: init_decode_cache(cfg, 128, 1024))
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    sh = shd.cache_shardings(mesh, cfg, cache, 128, 1024)
    assert len(jax.tree.leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))) == len(
        jax.tree.leaves(cache))


def test_band_mask_equals_causal_window_mask():
    """The dilation-built local mask == the attention module's band mask."""
    s, w = 32, 5
    a = np.asarray(band_mask(s, s, w))
    b = np.asarray(causal_mask(s, s, window=w))[0, 0, 0]
    np.testing.assert_array_equal(a, b)


def test_activation_spec():
    cfg = get_config("gemma-7b")
    assert shd.activation_spec(MESH, cfg, 4096) == P(("data",), "model", None)
    assert shd.activation_spec(MESH, cfg, 1) == P(("data",), None, None)


def test_dryrun_cell_applicability():
    from repro.launch.dryrun import SHAPES, cell_applicable

    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    ok, _ = cell_applicable("rwkv6-7b", "long_500k")
    assert ok
    ok, why = cell_applicable("gemma-7b", "long_500k")
    assert not ok and "sub-quadratic" in why
    # 40 cells total: 32 runnable + 8 documented skips
    runnable = sum(
        cell_applicable(a, s)[0]
        for a in __import__("repro.models.config", fromlist=["ARCH_IDS"]).ARCH_IDS
        for s in SHAPES
    )
    assert runnable == 32
