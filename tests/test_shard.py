"""Sharded morphology: mesh lowering, halo exchange, router.

Runs at any device count: shard counts are filtered to what is available,
so the tier-1 single-device run exercises the degenerate n=1 path and the
CI multi-device job (XLA_FLAGS=--xla_force_host_platform_device_count=8)
exercises real collectives. Every case asserts **bit-exactness** against
``lower_xla`` — the sharded path is the same computation, partitioned.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.morph import (
    Var,
    X,
    lower_xla,
    occo_expr,
    reconstruct_by_dilation_expr,
    to_plan,
)
from repro.morph.opt.cost import CostModel
from repro.core.dispatch import DispatchPolicy
from repro.serve.morph import MorphService, ServiceConfig
from repro.shard import (
    ShardedMorphService,
    available_shards,
    exchange_halo,
    image_mesh,
    mesh_axis_sizes,
    to_sharded,
)

N_DEV = available_shards()
SHARD_COUNTS = [n for n in (1, 2, 4, 8) if n <= N_DEV]
MULTI = [n for n in SHARD_COUNTS if n > 1]

rng = np.random.default_rng(42)


def u8(h, w):
    return rng.integers(0, 256, (h, w), dtype=np.uint8)


def sharded(expr, shards, **kw):
    return jax.jit(to_sharded(expr, image_mesh(shards), **kw))


def assert_bitexact(expr, img, shards, **kw):
    ref = np.asarray(lower_xla(expr)(img))
    got = np.asarray(sharded(expr, shards, **kw)(img))
    assert got.dtype == ref.dtype
    np.testing.assert_array_equal(got, ref)


# ---------------------------------------------------------------- mesh layer
def test_image_mesh_shapes():
    assert mesh_axis_sizes(image_mesh(1)) == (1, 1)
    assert mesh_axis_sizes(image_mesh((1, 1))) == (1, 1)
    if N_DEV >= 2:
        assert mesh_axis_sizes(image_mesh(2)) == (2, 1)
        assert mesh_axis_sizes(image_mesh((1, 2))) == (1, 2)


def test_image_mesh_rejects_oversubscription():
    with pytest.raises(ValueError, match="devices"):
        image_mesh(N_DEV + 1)
    with pytest.raises(ValueError):
        image_mesh(0)


def test_mesh_axis_sizes_rejects_foreign_axes():
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="image meshes"):
        mesh_axis_sizes(mesh)


# ------------------------------------------------------------- halo exchange
@pytest.mark.parametrize("n", MULTI or [1])
@pytest.mark.parametrize("wing", [1, 3, 5])
def test_exchange_halo_contents(n, wing):
    """The extended slab holds exactly the neighbor rows (neutral beyond)."""
    if n == 1:
        pytest.skip("needs >= 2 devices")
    mesh = image_mesh(n)
    rows = 6  # wing=5 < 6: single hop; separate case covers multi-hop
    x = rng.integers(0, 256, (rows * n, 8), dtype=np.uint8)
    neutral = np.uint8(255)

    def local(v):
        return exchange_halo(
            v, wing, axis=-2, axis_name="rows", size=n, neutral=neutral
        )

    ext = shard_map(
        local, mesh=mesh, in_specs=P("rows", None),
        out_specs=P("rows", None), check_rep=False,
    )(x)
    ext = np.asarray(ext).reshape(n, rows + 2 * wing, 8)
    padded = np.full((wing + rows * n + wing, 8), neutral, dtype=np.uint8)
    padded[wing:-wing] = x
    for i in range(n):
        np.testing.assert_array_equal(
            ext[i], padded[i * rows : i * rows + rows + 2 * wing]
        )


@pytest.mark.parametrize("n", MULTI or [1])
def test_exchange_halo_multi_hop(n):
    """wing > slab rows: the halo spans several neighbors exactly."""
    if n == 1:
        pytest.skip("needs >= 2 devices")
    mesh = image_mesh(n)
    rows, wing = 3, 7  # 3 hops
    x = rng.integers(0, 256, (rows * n, 4), dtype=np.uint8)
    neutral = np.uint8(0)

    def local(v):
        return exchange_halo(
            v, wing, axis=-2, axis_name="rows", size=n, neutral=neutral
        )

    ext = np.asarray(
        shard_map(local, mesh=mesh, in_specs=P("rows", None),
                  out_specs=P("rows", None), check_rep=False)(x)
    ).reshape(n, rows + 2 * wing, 4)
    padded = np.full((wing + rows * n + wing, 4), neutral, dtype=np.uint8)
    padded[wing:-wing] = x
    for i in range(n):
        np.testing.assert_array_equal(
            ext[i], padded[i * rows : i * rows + rows + 2 * wing]
        )


def test_exchange_halo_noop_cases():
    x = jnp.asarray(u8(8, 8))
    assert exchange_halo(x, 0, axis=-2, axis_name="rows", size=4,
                         neutral=0) is x
    assert exchange_halo(x, 3, axis=-2, axis_name="rows", size=1,
                         neutral=0) is x


# ------------------------------------------------------- sharded bit-exactness
OPS = [
    ("erode", X.erode((5, 5))),
    ("gradient", X.gradient((3, 7))),
    ("open_close", X.opening((3, 3)).closing((5, 5))),
    ("occo", occo_expr(X, (3, 3))),
]


@pytest.mark.parametrize("shards", SHARD_COUNTS)
@pytest.mark.parametrize("name,expr", OPS, ids=[n for n, _ in OPS])
def test_sharded_bitexact_non_divisible(shards, name, expr):
    # 61 rows: indivisible by 2/4/8; 37 cols: indivisible by anything even
    assert_bitexact(expr, u8(61, 37), shards)


@pytest.mark.parametrize("shards", SHARD_COUNTS)
def test_sharded_wing_larger_than_interior(shards):
    # 8 shards x 33 rows -> slab of 5; wing 15 needs 3 exchange hops
    assert_bitexact(X.erode((31, 3)), u8(33, 24), shards)


@pytest.mark.parametrize("shards", MULTI)
def test_reshard_strategy_bitexact(shards):
    assert_bitexact(X.dilate((9, 5)), u8(50, 40), shards, strategy="reshard")


@pytest.mark.parametrize("strategy", ["exchange", "reshard", "auto"])
def test_strategies_agree(strategy):
    if strategy == "reshard" and not MULTI:
        pytest.skip("reshard needs a multi-device rows mesh")
    shards = MULTI[-1] if MULTI else 1
    assert_bitexact(X.opening((7, 7)), u8(96, 64), shards, strategy=strategy)


def test_bad_strategy_rejected():
    with pytest.raises(ValueError, match="strategy"):
        to_sharded(X.erode((3, 3)), image_mesh(1), strategy="telepathy")
    with pytest.raises(ValueError, match="reshard"):
        to_sharded(X.erode((3, 3)), image_mesh(1), strategy="reshard")


def test_sharded_2d_mesh():
    if N_DEV < 4:
        pytest.skip("2-D mesh needs >= 4 devices")
    mesh = image_mesh((2, 2))
    img = u8(45, 51)
    for _, expr in OPS:
        ref = np.asarray(lower_xla(expr)(img))
        got = np.asarray(jax.jit(to_sharded(expr, mesh))(img))
        np.testing.assert_array_equal(got, ref)


def test_sharded_batch_dims():
    shards = SHARD_COUNTS[-1]
    imgs = rng.integers(0, 256, (3, 41, 29), dtype=np.uint8)
    expr = X.opening((5, 5))
    np.testing.assert_array_equal(
        np.asarray(sharded(expr, shards)(imgs)),
        np.asarray(lower_xla(expr)(imgs)),
    )


def test_sharded_multi_output_shared_graph():
    shards = SHARD_COUNTS[-1]
    er = X.erode((5, 5))
    outs = {"open": er.dilate((5, 5)), "grad": X.gradient((3, 3))}
    img = u8(47, 33)
    ref = lower_xla(outs)(img)
    got = jax.jit(to_sharded(outs, image_mesh(shards)))(img)
    assert set(got) == {"open", "grad"}
    for k in got:
        np.testing.assert_array_equal(np.asarray(got[k]), np.asarray(ref[k]))


@pytest.mark.parametrize("until_stable", [True, False])
def test_sharded_reconstruction(until_stable):
    shards = SHARD_COUNTS[-1]
    expr = reconstruct_by_dilation_expr(
        X.erode((7, 7)), Var("x"), iters=24, until_stable=until_stable
    )
    img = u8(40, 36)
    np.testing.assert_array_equal(
        np.asarray(sharded(expr, shards)(img)),
        np.asarray(lower_xla(expr)(img)),
    )


def test_sharded_float_and_int_dtypes():
    shards = SHARD_COUNTS[-1]
    expr = X.gradient((5, 3))
    for arr in (
        rng.standard_normal((30, 22)).astype(np.float32),
        rng.integers(-100, 100, (30, 22), dtype=np.int8),
    ):
        np.testing.assert_array_equal(
            np.asarray(sharded(expr, shards)(arr)),
            np.asarray(lower_xla(expr)(arr)),
        )


def test_sharded_input_validation():
    fn = to_sharded(X.erode((3, 3)), image_mesh(1))
    with pytest.raises(ValueError, match="at least one input"):
        fn()
    with pytest.raises(ValueError, match="\\(\\.\\.\\., H, W\\)"):
        fn(np.zeros((8,), np.uint8))


# ------------------------------------------------------ property tests (fast)
try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dev dep
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    @settings(max_examples=8, deadline=None)
    @given(
        h=st.integers(9, 70),
        w=st.integers(9, 70),
        se_h=st.sampled_from([1, 3, 7, 17]),
        se_w=st.sampled_from([1, 3, 5]),
        shards=st.sampled_from(SHARD_COUNTS),
        op=st.sampled_from(["erode", "dilate", "gradient"]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_property_sharded_equals_xla(h, w, se_h, se_w, shards, op, seed):
        from repro.morph.plan_compile import op_expr

        img = np.random.default_rng(seed).integers(
            0, 256, (h, w), dtype=np.uint8
        )
        expr = op_expr(op, (se_h, se_w))
        ref = np.asarray(lower_xla(expr)(img))
        got = np.asarray(to_sharded(expr, image_mesh(shards))(img))
        np.testing.assert_array_equal(got, ref)


# ----------------------------------------------------- collective cost model
def test_exchange_wins_analytic_fallback():
    model = CostModel.analytic(DispatchPolicy())
    assert model.collective_cost("ppermute", 1000) is None
    # no measured curves -> byte heuristic: exchange until wing > interior
    assert model.exchange_wins(4, 64, 128)
    assert not model.exchange_wins(65, 64, 128)
    with pytest.raises(ValueError, match="collective method"):
        model.collective_cost("gossip", 10)


def test_sparse_measured_table_keeps_scalar_dispatch():
    """A table holding only collective curves (bench_shard --fit-collective
    on a device never fit by bench_hybrid) must not corrupt 1-D dispatch:
    with no measured 1-D entries, best_method degrades to the recorded
    crossovers — the scalar branch — not an inf-vs-inf coin flip."""
    pol = DispatchPolicy(w0_major=31, w0_minor=15)
    model = dataclasses.replace(
        CostModel.analytic(pol),
        entries={("collective", "ppermute", "uint8"): (100.0, 0.01)},
        source="measured",
    )
    assert model.best_method("major", 31) == "linear_tree"
    assert model.best_method("major", 33) == "vhgw"
    assert model.best_method("minor", 17) == "vhgw"


def test_exchange_wins_measured_curves():
    entries = dict(CostModel.analytic(DispatchPolicy()).entries)
    # ppermute cheap per element but fixed launch cost; all_to_all dearer
    entries[("collective", "ppermute", "uint8")] = (50.0, 0.001)
    entries[("collective", "all_to_all", "uint8")] = (80.0, 0.01)
    model = dataclasses.replace(
        CostModel.analytic(DispatchPolicy()), entries=entries, source="measured"
    )
    assert model.exchange_wins(2, 256, 1024)  # small halo: ppermute
    # huge halo traffic vs tiny reshard: all_to_all wins despite intercept
    assert not model.exchange_wins(500, 4, 1024)


# ------------------------------------------------------------------- router
def test_router_results_match_direct():
    imgs = [u8(30, 40), u8(50, 20), u8(33, 33)]
    expr = X.opening((3, 3))
    refs = [np.asarray(lower_xla(expr)(im)) for im in imgs]
    cfg = ServiceConfig(buckets=((64, 64),), window_ms=1.0)
    with ShardedMorphService(cfg) as svc:
        outs = [np.asarray(svc.run_expr(im, expr)) for im in imgs]
    for got, ref in zip(outs, refs):
        np.testing.assert_array_equal(got, ref)


def test_router_uses_all_devices_and_merges_stats():
    cfg = ServiceConfig(buckets=((32, 32), (64, 64), (128, 128)),
                        window_ms=1.0)
    with ShardedMorphService(cfg) as svc:
        assert len(svc.shards) == N_DEV
        reqs = [(u8(b - 2, b - 2), ("erode", (3, 3))) for b in (32, 64, 128)
                for _ in range(4)]
        futs = [svc.submit(im, op, se) for im, (op, se) in reqs]
        for f in futs:
            f.result()
        stats = svc.stats()
    assert stats["shards"] == N_DEV
    assert stats["requests"] == len(reqs)
    assert len(stats["per_shard"]) == N_DEV
    assert stats["cache"]["misses"] == sum(
        p["cache"]["misses"] for p in stats["per_shard"]
    )
    # distinct buckets hash to distinct shards when devices allow
    if N_DEV >= 2:
        active = sum(p["requests"] > 0 for p in stats["per_shard"])
        assert active >= 2


def test_router_bucket_affinity_is_stable():
    cfg = ServiceConfig(buckets=((64, 64),))
    with ShardedMorphService(cfg) as svc:
        plan = to_plan(X.erode((3, 3)), name="affinity")
        img = u8(10, 10)
        targets = {id(svc._route(plan, img)) for _ in range(16)}
        assert len(targets) == 1  # same (plan, bucket, dtype) -> same shard


def test_router_rejects_mesh_and_devices():
    with pytest.raises(ValueError, match="not both"):
        ShardedMorphService(mesh=image_mesh(1), devices=jax.devices())


def test_router_from_mesh():
    img = u8(16, 16)
    with ShardedMorphService(mesh=image_mesh(1)) as svc:
        assert len(svc.shards) == 1
        np.testing.assert_array_equal(
            np.asarray(svc.run(img, "dilate", (3, 3))),
            np.asarray(lower_xla(X.dilate((3, 3)))(img)),
        )


# --------------------------------------------- convergence-aware BoundedIter
def test_router_reports_bounded_iter_stats():
    marker = X.erode((9, 9))
    expr = reconstruct_by_dilation_expr(
        marker, Var("x"), iters=64, until_stable=False
    )
    img = u8(48, 48)
    ref = np.asarray(lower_xla(expr)(img))
    with ShardedMorphService(ServiceConfig(buckets=((64, 64),))) as svc:
        got = np.asarray(svc.run_expr(img, expr))
        stats = svc.stats()["bounded_iter"]
    np.testing.assert_array_equal(got, ref)
    assert stats["executions"] >= 1
    assert stats["iters_budget"] >= 64
    # a 48x48 image converges before the 64-iteration budget (the geodesic
    # wavefront crosses ~1 px/iter), so the predicated scan must save work
    assert 0 < stats["iters_used"] < stats["iters_budget"]
    assert stats["saved_frac"] > 0.1
