"""End-to-end system tests: trainer (+fault tolerance), serving engine,
checkpoint manager, data pipelines, optimizer substrate."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import (
    ImagePipelineConfig,
    TokenPipeline,
    TokenPipelineConfig,
    cleanup_batch,
    patch_embed_stub,
    spec_augment,
    synth_documents,
    synth_frames,
)
from repro.models import get_config
from repro.models.model import init_params
from repro.optim import (
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    dequantize_int8,
    global_norm,
    quantize_int8,
    warmup_cosine,
)
from repro.serve import generate
from repro.train import Trainer, TrainLoopConfig

pytestmark = pytest.mark.slow  # heavyweight: deselected from tier-1 (see pytest.ini)

CFG = get_config("qwen1.5-0.5b").reduced()


def _pipeline(batch=4, seq=16):
    return TokenPipeline(
        TokenPipelineConfig(vocab_size=CFG.vocab_size, seq_len=seq, global_batch=batch)
    )


def test_trainer_loss_decreases():
    t = Trainer(CFG, TrainLoopConfig(total_steps=20, warmup_steps=2, peak_lr=1e-3,
                                     checkpoint_every=100, log_every=100), _pipeline())
    m = t.run()
    assert np.isfinite(m["loss"])
    assert m["loss"] < 6.3  # below ~uniform init loss ln(512)=6.24 + slack


def test_trainer_checkpoint_resume(tmp_path):
    d = str(tmp_path / "ckpt")
    loop = TrainLoopConfig(total_steps=6, warmup_steps=1, checkpoint_every=3,
                           checkpoint_dir=d, log_every=100)
    t = Trainer(CFG, loop, _pipeline())
    t.run()
    t2 = Trainer(CFG, TrainLoopConfig(total_steps=8, warmup_steps=1,
                                      checkpoint_every=3, checkpoint_dir=d,
                                      log_every=100), _pipeline())
    assert t2.start_step == 6
    t2.run()


def test_trainer_microbatching_equivalence():
    """grad accumulation over 2 microbatches ~= full batch step."""
    l1 = TrainLoopConfig(total_steps=3, warmup_steps=1, microbatches=1, log_every=100)
    l2 = TrainLoopConfig(total_steps=3, warmup_steps=1, microbatches=2, log_every=100)
    m1 = Trainer(CFG, l1, _pipeline(batch=4), seed=0).run()
    m2 = Trainer(CFG, l2, _pipeline(batch=4), seed=0).run()
    assert abs(m1["loss"] - m2["loss"]) < 0.2


def test_emergency_checkpoint(tmp_path):
    d = str(tmp_path / "ckpt")

    class Poison:
        def __init__(self, it, fail_at):
            self.it, self.n, self.fail_at = iter(it), 0, fail_at

        def __iter__(self):
            return self

        def __next__(self):
            self.n += 1
            if self.n > self.fail_at:
                raise RuntimeError("injected data failure")
            return next(self.it)

    loop = TrainLoopConfig(total_steps=50, warmup_steps=1, checkpoint_every=1000,
                           checkpoint_dir=d, log_every=1000)
    t = Trainer(CFG, loop, Poison(_pipeline(), 4))
    with pytest.raises(RuntimeError):
        t.run()
    mgr = CheckpointManager(d)
    assert mgr.latest_step() == 4  # emergency save captured progress


def test_checkpoint_manager_atomicity(tmp_path):
    d = str(tmp_path / "c")
    mgr = CheckpointManager(d, keep=2)
    state = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    for s in (1, 2, 3):
        mgr.save(s, state, blocking=True)
    assert mgr.all_steps() == [2, 3]  # GC keeps newest 2
    # incomplete checkpoint (no manifest) is invisible
    os.makedirs(os.path.join(d, "step_00000009"), exist_ok=True)
    assert mgr.latest_step() == 3
    restored = mgr.restore(3, state)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.arange(5))


def test_elastic_reshard_restore(tmp_path):
    """Restore with explicit shardings (elastic resume onto current devices)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    d = str(tmp_path / "c")
    mgr = CheckpointManager(d)
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    mgr.save(1, state, blocking=True)
    mesh = jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    sh = {"w": NamedSharding(mesh, P("data", None))}
    restored = mgr.restore(1, state, shardings=sh)
    assert restored["w"].sharding == sh["w"]


def test_straggler_watchdog_flags_slow_steps():
    t = Trainer(CFG, TrainLoopConfig(total_steps=1, log_every=100), _pipeline())
    for i in range(10):
        t._watchdog(i, 0.1)
    t._watchdog(10, 1.0)  # 10x median
    assert 10 in t.straggler_flags


def test_generation_deterministic_greedy():
    params = init_params(CFG, jax.random.PRNGKey(0))
    prompt = jnp.ones((2, 4), jnp.int32)
    a = np.asarray(generate(CFG, params, prompt, max_new_tokens=6))
    b = np.asarray(generate(CFG, params, prompt, max_new_tokens=6))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 6)


def test_token_pipeline_host_sharding():
    c = TokenPipelineConfig(vocab_size=100, seq_len=8, global_batch=8)
    p0 = TokenPipeline(c, process_index=0, process_count=2)
    p1 = TokenPipeline(c, process_index=1, process_count=2)
    b0, b1 = next(iter(p0)), next(iter(p1))
    assert b0["tokens"].shape == (4, 8)
    assert not np.array_equal(b0["tokens"], b1["tokens"])  # different slices


def test_image_pipeline_morphology_cleans_noise():
    cfg = ImagePipelineConfig(height=96, width=128, noise_frac=0.05)
    imgs = synth_documents(cfg, 2)
    clean, edges = cleanup_batch(imgs)
    # opening removes salt: isolated extreme-bright pixels mostly vanish
    salt_before = int((np.asarray(imgs) == 255).sum())
    salt_after = int((np.asarray(clean) == 255).sum())
    assert salt_after < max(1, salt_before // 5)
    emb = patch_embed_stub(jnp.asarray(imgs), 32, n_tokens=16)
    assert emb.shape == (2, 16, 32)


def test_audio_pipeline_dilated_masks():
    fr = jnp.asarray(synth_frames(2, 128, 32))
    out = spec_augment(fr, time_width=8, freq_width=4)
    frac = float(jnp.mean(out == 0))
    assert 0.0 < frac < 0.9


def test_adamw_moves_params_toward_lower_loss():
    params = {"w": jnp.array([1.0, -2.0, 3.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt = adamw_update(g, opt, params, lr=0.1, weight_decay=0.0)
    assert float(loss(params)) < 0.2


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-4)


def test_schedule_shape():
    lrs = [float(warmup_cosine(jnp.int32(s), peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0 and lrs[1] == pytest.approx(1.0)
    assert all(a >= b - 1e-6 for a, b in zip(lrs[1:], lrs[2:]))  # decays


def test_int8_compression_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((1000,)) * 5, jnp.float32)
    q, s = quantize_int8(x, chunk=128)
    back = dequantize_int8(q, s, x.shape)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 1.0 / 100


def test_compressed_psum_matches_mean():
    """shard_map over a 1-device 'pod' axis still exercises the collective."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.optim import compressed_psum

    mesh = jax.make_mesh((1,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
    x = jnp.asarray(np.random.default_rng(1).standard_normal((64,)), jnp.float32)
    # check_vma=False: the all_gather+local-sum result is replicated in
    # value but the static replication checker cannot prove it.
    f = shard_map(lambda v: compressed_psum(v, "pod"), mesh=mesh,
                  in_specs=P(), out_specs=P(), check_rep=False)
    out = f(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=2e-2, atol=2e-2)
