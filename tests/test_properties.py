"""Hypothesis property tests: the lattice-algebra invariants of morphology.

These are the system's mathematical invariants (the paper relies on all of
them implicitly): duality, monotonicity, extensivity/anti-extensivity,
idempotence of opening/closing, separability commutation, and
method-equivalence (vHGW == linear == tree for arbitrary inputs/windows).
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # minimal envs lack it; skip, don't break collection
from hypothesis import given, settings, strategies as st

from repro.core import (
    closing,
    dilate,
    erode,
    gradient,
    linear_1d,
    linear_1d_tree,
    opening,
    vhgw_1d,
)

shapes = st.tuples(st.integers(4, 24), st.integers(4, 24))
windows = st.integers(0, 6).map(lambda k: 2 * k + 1)  # odd 1..13


def arr(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, 256, shape, dtype=np.uint8))


@settings(max_examples=30, deadline=None)
@given(shape=shapes, w=windows, seed=st.integers(0, 2**31))
def test_method_equivalence(shape, w, seed):
    x = arr(shape, seed)
    a = np.asarray(vhgw_1d(x, w, axis=-1, op="min"))
    b = np.asarray(linear_1d(x, w, axis=-1, op="min"))
    c = np.asarray(linear_1d_tree(x, w, axis=-1, op="min"))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, c)


@settings(max_examples=25, deadline=None)
@given(shape=shapes, w=windows, seed=st.integers(0, 2**31))
def test_duality(shape, w, seed):
    """erode(x) == 255 - dilate(255 - x) for u8 (min-max duality)."""
    x = arr(shape, seed)
    e = np.asarray(erode(x, (w, w)))
    d = np.asarray(dilate(255 - x, (w, w)))
    np.testing.assert_array_equal(e, 255 - d)


@settings(max_examples=25, deadline=None)
@given(shape=shapes, w=windows.filter(lambda w: w > 1), seed=st.integers(0, 2**31))
def test_extensivity(shape, w, seed):
    """erode <= x <= dilate; opening <= x <= closing (flat SE w/ anchor)."""
    x = arr(shape, seed)
    assert bool(jnp.all(erode(x, (w, w)) <= x))
    assert bool(jnp.all(dilate(x, (w, w)) >= x))
    assert bool(jnp.all(opening(x, (w, w)) <= x))
    assert bool(jnp.all(closing(x, (w, w)) >= x))


@settings(max_examples=20, deadline=None)
@given(shape=shapes, w=windows, seed=st.integers(0, 2**31))
def test_idempotence(shape, w, seed):
    """opening(opening(x)) == opening(x); same for closing."""
    x = arr(shape, seed)
    o = opening(x, (w, w))
    np.testing.assert_array_equal(np.asarray(opening(o, (w, w))), np.asarray(o))
    c = closing(x, (w, w))
    np.testing.assert_array_equal(np.asarray(closing(c, (w, w))), np.asarray(c))


@settings(max_examples=20, deadline=None)
@given(shape=shapes, seed=st.integers(0, 2**31),
       w1=windows, w2=windows)
def test_separability_commutes(shape, seed, w1, w2):
    """H-pass then W-pass == W-pass then H-pass."""
    x = arr(shape, seed)
    a = vhgw_1d(vhgw_1d(x, w1, axis=-2, op="min"), w2, axis=-1, op="min")
    b = vhgw_1d(vhgw_1d(x, w2, axis=-1, op="min"), w1, axis=-2, op="min")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(max_examples=20, deadline=None)
@given(shape=shapes, w=windows, seed=st.integers(0, 2**31))
def test_monotonicity(shape, w, seed):
    """x <= y pointwise => erode(x) <= erode(y)."""
    x = arr(shape, seed)
    y = jnp.minimum(255, x.astype(jnp.int32) + 10).astype(jnp.uint8)
    assert bool(jnp.all(erode(x, (w, w)) <= erode(y, (w, w))))


@settings(max_examples=20, deadline=None)
@given(shape=shapes, w=windows, seed=st.integers(0, 2**31))
def test_gradient_nonnegative(shape, w, seed):
    x = arr(shape, seed)
    assert bool(jnp.all(gradient(x, (w, w)) >= 0))


@settings(max_examples=20, deadline=None)
@given(shape=shapes, w=windows, seed=st.integers(0, 2**31))
def test_constant_image_fixed_point(shape, w, seed):
    c = int(np.random.default_rng(seed).integers(0, 256))
    x = jnp.full(shape, c, jnp.uint8)
    np.testing.assert_array_equal(np.asarray(erode(x, (w, w))), np.asarray(x))
    np.testing.assert_array_equal(np.asarray(dilate(x, (w, w))), np.asarray(x))
