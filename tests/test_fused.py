"""Fused 2-D megakernel vs oracles, two-pass A/B, and launch-count checks.

The acceptance contract for the fused path (kernels/morph_fused.py):

* bit-exact against the naive non-separable ``morph2d_naive`` oracle and
  against the legacy two-pass + double-transpose pipeline, across dtypes,
  asymmetric SEs, non-tile-aligned shapes, and batched inputs;
* the default ``erode2d_tpu``/``dilate2d_tpu`` path issues exactly ONE
  ``pallas_call`` (verified by walking the jaxpr), versus four for the
  two-pass path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DispatchPolicy, morph2d_naive
from repro.kernels import (
    dilate2d_tpu,
    erode2d_tpu,
    gradient2d_fused,
    gradient2d_tpu,
    morph2d_fused,
)
from repro.kernels.ref import gradient2d_ref, morph2d_ref

RNG = np.random.default_rng(11)


def rand(shape, dtype):
    if np.issubdtype(dtype, np.floating):
        return jnp.asarray(RNG.standard_normal(shape).astype(dtype))
    info = np.iinfo(dtype)
    return jnp.asarray(RNG.integers(info.min, info.max, shape, dtype=dtype))


# ------------------------------------------------------------- jaxpr walking
def _iter_jaxprs(jaxpr):
    yield jaxpr
    for eqn in jaxpr.eqns:
        for val in eqn.params.values():
            vals = val if isinstance(val, (list, tuple)) else [val]
            for v in vals:
                if isinstance(v, jax.core.ClosedJaxpr):
                    yield from _iter_jaxprs(v.jaxpr)
                elif isinstance(v, jax.core.Jaxpr):
                    yield from _iter_jaxprs(v)


def count_pallas_calls(fn, *args) -> int:
    closed = jax.make_jaxpr(fn)(*args)
    return sum(
        eqn.primitive.name == "pallas_call"
        for j in _iter_jaxprs(closed.jaxpr)
        for eqn in j.eqns
    )


# ----------------------------------------------------------- oracle equality
@pytest.mark.parametrize("dtype", [np.uint8, np.int32, np.float32])
@pytest.mark.parametrize("se", [(3, 3), (3, 31), (31, 3), (63, 63)])
def test_fused_vs_naive(dtype, se):
    x = rand((97, 141), dtype)
    for op in ("min", "max"):
        got = np.asarray(morph2d_fused(x, se, op=op))
        np.testing.assert_array_equal(got, np.asarray(morph2d_ref(x, se, op=op)))


@pytest.mark.parametrize("shape", [(257, 191), (128, 128), (37, 260)])
def test_fused_nonaligned_shapes(shape):
    x = rand(shape, np.uint8)
    for se in ((3, 3), (5, 9)):
        np.testing.assert_array_equal(
            np.asarray(morph2d_fused(x, se, op="min")),
            np.asarray(morph2d_naive(x, se, op="min")),
        )


@pytest.mark.parametrize("se", [(3, 3), (3, 31), (31, 3), (9, 9)])
def test_fused_vs_two_pass(se):
    x = rand((130, 150), np.uint8)
    np.testing.assert_array_equal(
        np.asarray(erode2d_tpu(x, se, fused=True)),
        np.asarray(erode2d_tpu(x, se, fused=False)),
    )
    np.testing.assert_array_equal(
        np.asarray(dilate2d_tpu(x, se, fused=True)),
        np.asarray(dilate2d_tpu(x, se, fused=False)),
    )


def test_fused_batched():
    xb = rand((5, 64, 200), np.uint8)
    got = np.asarray(morph2d_fused(xb, (5, 7), op="min"))
    np.testing.assert_array_equal(got, np.asarray(morph2d_naive(xb, (5, 7), op="min")))
    # batch grid == per-image results
    for i in range(xb.shape[0]):
        np.testing.assert_array_equal(
            got[i], np.asarray(morph2d_fused(xb[i], (5, 7), op="min"))
        )


def test_fused_method_override():
    x = rand((90, 110), np.uint8)
    a = np.asarray(morph2d_fused(x, (15, 15), op="max", method="linear"))
    b = np.asarray(morph2d_fused(x, (15, 15), op="max", method="vhgw"))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a, np.asarray(morph2d_naive(x, (15, 15), op="max")))


def test_wide_se_still_fused():
    # wing_w in (128, 512]: auto block sizing widens the strip to cover it.
    x = rand((24, 300), np.uint8)
    assert count_pallas_calls(lambda a: erode2d_tpu(a, (3, 259)), x) == 1
    np.testing.assert_array_equal(
        np.asarray(erode2d_tpu(x, (3, 259))),
        np.asarray(morph2d_naive(x, (3, 259), op="min")),
    )


def test_giant_se_falls_back_to_two_pass():
    # wing_w > 512 exceeds the fused policy range; dispatch falls back cleanly.
    x = rand((16, 1100), np.uint8)
    got = np.asarray(erode2d_tpu(x, (3, 1031)))
    np.testing.assert_array_equal(got, np.asarray(morph2d_naive(x, (3, 1031), op="min")))


def test_batched_two_pass_fallback():
    # (B, H, W) must also work on the legacy path (vmap-of-kernels).
    xb = rand((3, 40, 70), np.uint8)
    got = np.asarray(erode2d_tpu(xb, (3, 5), fused=False))
    np.testing.assert_array_equal(got, np.asarray(morph2d_naive(xb, (3, 5), op="min")))


# ------------------------------------------------------------ fused gradient
@pytest.mark.parametrize("dtype", [np.uint8, np.int32, np.float32])
def test_gradient2d_fused_vs_ref(dtype):
    x = rand((80, 144), dtype)
    for se in ((3, 3), (3, 15), (15, 3)):
        got = np.asarray(gradient2d_fused(x, se))
        want = np.asarray(gradient2d_ref(x, se))
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("dtype", [np.uint8, np.int8])
@pytest.mark.parametrize("se", [(3, 3), (3, 9), (9, 3), (9, 9)])
def test_gradient_integer_widening_consistent_across_paths(dtype, se):
    """Fused and two-pass gradient2d_tpu must agree on integer widening
    (u8/i8 -> i32) for SEs on BOTH sides of the w0_fused crossover — a
    w0_fused=5 policy puts 3-wide passes on the linear side and 9-wide
    passes on the vHGW side without needing giant SEs."""
    x = rand((40, 60), dtype)
    policy = DispatchPolicy(w0_fused=5)
    fused = np.asarray(gradient2d_tpu(x, se, fused=True, policy=policy))
    two_pass = np.asarray(gradient2d_tpu(x, se, fused=False, policy=policy))
    assert fused.dtype == np.int32
    assert two_pass.dtype == np.int32
    np.testing.assert_array_equal(fused, two_pass)
    # floats keep their dtype on both paths
    xf = rand((40, 60), np.float32)
    assert gradient2d_tpu(xf, se, fused=True, policy=policy).dtype == np.float32
    assert gradient2d_tpu(xf, se, fused=False, policy=policy).dtype == np.float32


def test_gradient2d_tpu_paths_agree():
    x = rand((3, 70, 90), np.uint8)
    two_pass = jnp.stack([gradient2d_tpu(x[i], (5, 5), fused=False) for i in range(3)])
    np.testing.assert_array_equal(
        np.asarray(gradient2d_tpu(x, (5, 5), fused=True)), np.asarray(two_pass)
    )


# -------------------------------------------------------- launch-count tests
def test_default_erode_is_one_pallas_call():
    x = rand((64, 128), np.uint8)
    assert count_pallas_calls(lambda a: erode2d_tpu(a, (5, 9)), x) == 1
    assert count_pallas_calls(lambda a: dilate2d_tpu(a, (5, 9)), x) == 1


def test_batched_erode_is_one_pallas_call():
    xb = rand((4, 64, 128), np.uint8)
    assert count_pallas_calls(lambda a: erode2d_tpu(a, (3, 3)), xb) == 1


def test_gradient_is_one_pallas_call():
    x = rand((64, 128), np.uint8)
    assert count_pallas_calls(lambda a: gradient2d_tpu(a, (3, 3)), x) == 1


def test_two_pass_is_four_pallas_calls():
    x = rand((64, 128), np.uint8)
    n = count_pallas_calls(lambda a: erode2d_tpu(a, (5, 9), fused=False), x)
    assert n == 4  # H pass + (transpose, W pass, transpose)


def test_policy_knob_disables_fusion():
    x = rand((64, 128), np.uint8)
    policy = DispatchPolicy(fused_2d=False)
    n = count_pallas_calls(lambda a: erode2d_tpu(a, (5, 9), policy=policy), x)
    assert n == 4
    np.testing.assert_array_equal(
        np.asarray(erode2d_tpu(x, (5, 9), policy=policy)),
        np.asarray(erode2d_tpu(x, (5, 9))),
    )
